"""Trainer: convergence, microbatch equivalence, exact resume, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.loader import ShardedBatcher
from repro.training.grad_compression import (
    apply_error_feedback, compress, decompress, init_error_state,
)
from repro.training.optimizer import adamw
from repro.training.trainer import Trainer, TrainerConfig


def make_problem(seed=0, n=512):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    w_true = rng.normal(size=(8, 1)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(n, 1)).astype(np.float32)
    params = {"w": jnp.zeros((8, 1), jnp.float32)}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    return params, loss_fn, {"x": x, "y": y}


def test_trainer_converges(tmp_path):
    params, loss_fn, data = make_problem()
    t = Trainer(loss_fn, adamw(lr=5e-2), params,
                TrainerConfig(n_steps=60, log_every=1000))
    batches = ShardedBatcher(data, global_batch=64, seed=0)
    losses = t.fit(batches, log=lambda *_: None)
    assert losses[-1] < losses[0] * 0.2


def test_microbatch_equivalence():
    params, loss_fn, data = make_problem()
    batch = {k: jnp.asarray(v[:64]) for k, v in data.items()}
    outs = []
    for n_mb in (1, 4):
        t = Trainer(loss_fn, adamw(lr=1e-2), params,
                    TrainerConfig(n_steps=1, microbatches=n_mb))
        t.train_one(batch)
        outs.append(np.asarray(t.params["w"], np.float64))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-6)


def test_exact_resume(tmp_path):
    """Crash after step 6 + resume == uninterrupted run (bit-exact params)."""
    params, loss_fn, data = make_problem()
    cfg = TrainerConfig(n_steps=12, ckpt_dir=str(tmp_path), ckpt_every=6,
                        ckpt_async=False, log_every=1000)

    # uninterrupted reference
    t_ref = Trainer(loss_fn, adamw(lr=1e-2), params, cfg)
    b_ref = ShardedBatcher(data, global_batch=64, seed=0)
    t_ref.fit(b_ref, log=lambda *_: None)

    # crashy run: stops after 6 steps (checkpoint fires at 6)
    t1 = Trainer(loss_fn, adamw(lr=1e-2), params,
                 TrainerConfig(n_steps=6, ckpt_dir=str(tmp_path) + "/b",
                               ckpt_every=6, ckpt_async=False, log_every=1000))
    b1 = ShardedBatcher(data, global_batch=64, seed=0)
    t1.fit(b1, log=lambda *_: None)
    t1.maybe_checkpoint(data_state=b1.state(), force=True)

    # resume into a fresh trainer (fresh process semantics)
    t2 = Trainer(loss_fn, adamw(lr=1e-2), params,
                 TrainerConfig(n_steps=12, ckpt_dir=str(tmp_path) + "/b",
                               ckpt_every=100, ckpt_async=False, log_every=1000))
    assert t2.resume()
    assert t2.step == 6
    b2 = ShardedBatcher(data, global_batch=64, seed=0)
    b2.restore(b1.state())
    t2.fit(b2, log=lambda *_: None)

    np.testing.assert_array_equal(
        np.asarray(t_ref.params["w"]), np.asarray(t2.params["w"])
    )


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    q, s = compress(g)
    assert q.dtype == jnp.int8
    err = float(jnp.max(jnp.abs(decompress(q, s) - g)))
    assert err <= float(s) * 0.51 + 1e-9  # half-ulp of the int8 grid
    # error feedback keeps the accumulated bias bounded
    grads = {"w": g}
    e = init_error_state(grads)
    total_true = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for _ in range(50):
        deq, e = apply_error_feedback(grads, e)
        total_true = total_true + g
        total_sent = total_sent + deq["w"]
    drift = float(jnp.max(jnp.abs(total_true - total_sent)))
    assert drift <= float(s) + 1e-6  # bounded by one quantization step


def test_trainer_with_compression_converges():
    params, loss_fn, data = make_problem()
    t = Trainer(loss_fn, adamw(lr=5e-2), params,
                TrainerConfig(n_steps=60, grad_compression=True, log_every=1000))
    batches = ShardedBatcher(data, global_batch=64, seed=0)
    losses = t.fit(batches, log=lambda *_: None)
    assert losses[-1] < losses[0] * 0.25


def test_straggler_watchdog_records():
    params, loss_fn, data = make_problem()
    t = Trainer(loss_fn, adamw(lr=1e-2), params, TrainerConfig(n_steps=10))
    batch = {k: jnp.asarray(v[:64]) for k, v in data.items()}
    for _ in range(8):
        t.train_one(batch)
    t.step_times[-1] = 0.0  # fake fast history
    import time

    orig = time.time
    seq = iter([0.0, 100.0])  # one pathologically slow step
    time.time = lambda: next(seq, orig())
    try:
        t.train_one(batch)
    finally:
        time.time = orig
    assert len(t.straggler_events) >= 1
