"""Scenario registry + end-to-end pipeline (DESIGN.md §12).

Covers the launch surface contracts:

  * registry resolution (smoke shrink, dotted overrides, seed precedence,
    loud failure on typos / unknown names);
  * one tiny ``cold_start_amazon`` run through the production stack
    (RQ-VAE -> ConstraintRegistry -> DecodePolicy -> ServingEngine) with the
    Table 3 gates;
  * bit-reproducibility: two runs of the same config produce identical
    beams, scores, and result dicts (the one-seed discipline);
  * legacy-vs-new agreement: the old raw-TransitionMatrix direct eval and
    the scenario's stacked-slot engine path retrieve the same alive beams
    and metrics;
  * resume: a pre-populated context skips completed stages;
  * the trie-aware auxiliary signal (stats vs brute force, loss identities).
"""
import dataclasses
import inspect

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.constraints import ConstraintRegistry
from repro.constraints.refresh import TrieSource
from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.models import transformer
from repro.scenarios import (
    ScenarioRegistry,
    ScenarioSpec,
    apply_overrides,
    config_to_dict,
    get_default_registry,
    gr_model_config,
    parse_override,
    trie_signal,
)
from repro.scenarios.stages import EvalStage
from repro.serving.generative_retrieval import GenerativeRetriever

# Tiny but complete: 7 cold items, beam 16 >= n_cold so STATIC serving must
# surface every cold SID (hit@M = 1.0 deterministically).
TINY_OVERRIDES = {
    "data.n_items": 240,
    "data.n_users": 1_000,
    "data.n_clusters": 32,
    "data.feat_dim": 32,
    "data.cold_frac": 0.03,
    "tokenizer.train_steps": 40,
    "tokenizer.latent_dim": 16,
    "train.steps": 40,
    "train.batch": 32,
    "train.n_layers": 2,
    "train.d_model": 64,
    "train.n_heads": 2,
    "train.d_ff": 128,
    "serve.beam": 16,
    "serve.batch_size": 8,
    "eval.max_eval": 24,
}


def _resolve_tiny():
    return get_default_registry().resolve(
        "cold_start_amazon", overrides=TINY_OVERRIDES, seed=0)


@pytest.fixture(scope="module")
def cold_ctx():
    """One tiny cold-start run; its artifact context is reused below."""
    run = _resolve_tiny()
    ctx = run.run()
    return run, ctx


# ---------------------------------------------------------------------------
# registry + config resolution
# ---------------------------------------------------------------------------
def test_registry_builtin_names():
    reg = get_default_registry()
    assert set(reg.names) >= {"cold_start_amazon", "multi_constraint",
                              "refresh_churn", "spmd_smoke"}
    assert set(reg.describe()) == set(reg.names)


def test_registry_unknown_name_lists_known():
    with pytest.raises(KeyError, match="cold_start_amazon"):
        get_default_registry().get("no_such_scenario")


def test_registry_rejects_name_mismatch_and_dupes():
    reg = ScenarioRegistry()
    spec = get_default_registry().get("multi_constraint")
    with pytest.raises(ValueError, match="!= config name"):
        reg.register(dataclasses.replace(spec, name="other_name"))
    reg.register(spec)
    with pytest.raises(ValueError, match="already registered"):
        reg.register(spec)


def test_resolve_precedence_smoke_then_overrides_then_seed():
    reg = get_default_registry()
    base = reg.get("cold_start_amazon").config
    smoked = reg.resolve("cold_start_amazon", smoke=True).config
    assert smoked.data.n_items < base.data.n_items
    # an explicit --set beats the smoke preset; --seed beats both
    run = reg.resolve("cold_start_amazon", smoke=True,
                      overrides={"data.n_items": 7_777}, seed=42)
    assert run.config.data.n_items == 7_777
    assert run.config.seed == 42
    assert run.config.train.steps == smoked.train.steps  # smoke kept


def test_apply_overrides_unknown_path_fails_loudly():
    cfg = get_default_registry().get("cold_start_amazon").config
    with pytest.raises(KeyError, match="cold_frac"):
        apply_overrides(cfg, {"data.cold_fraq": 0.05})  # typo
    with pytest.raises(KeyError, match="leaf"):
        apply_overrides(cfg, {"data.n_items.x": 1})


def test_parse_override_casts_and_config_to_dict():
    assert parse_override("train.steps=40") == ("train.steps", 40)
    assert parse_override("data.cold_frac=0.05") == ("data.cold_frac", 0.05)
    assert parse_override("serve.fused=true") == ("serve.fused", True)
    assert parse_override("serve.engine=spmd") == ("serve.engine", "spmd")
    with pytest.raises(ValueError):
        parse_override("no-equals-sign")
    d = config_to_dict(get_default_registry().get("multi_constraint").config)
    assert d["serve"]["beam"] == 8 and isinstance(d["index"]["slots"], list)


# ---------------------------------------------------------------------------
# end-to-end cold start through the production stack
# ---------------------------------------------------------------------------
def test_cold_start_result_and_gates(cold_ctx):
    _, ctx = cold_ctx
    res = ctx["result"]
    for key in ("recall@1_static", "recall@1_unconstrained",
                "recall@1_constrained_random", "hit@M_static",
                "hit@M_unconstrained", "cold_frac", "n_cold", "n_test",
                "gates"):
        assert key in res, key
    # beam >= n_cold: STATIC must place every cold SID in some alive beam
    assert res["n_cold"] <= res["beam_size"]
    assert res["hit@M_static"] == 1.0
    assert res["hit@M_static"] > res["hit@M_unconstrained"]
    assert res["gates"]["static_beats_unconstrained"]
    assert res["gates"]["zero_unexpected_recompiles"]
    assert res["gates"]["passed"]


def test_cold_start_routed_through_production_stack(cold_ctx):
    _, ctx = cold_ctx
    assert isinstance(ctx["registry"], ConstraintRegistry)
    assert ctx["store"] is ctx["registry"].current()[0]
    assert ctx["slots"] == {"servable": 0, "cold_only": 1}
    meta = ctx["result"]["serve_meta"]
    assert meta["engine"] == "batch"
    assert meta["eval_slot"] == "cold_only"
    assert meta["store_version"] == ctx["registry"].version
    assert meta["unexpected_recompiles"] == 0
    # the bespoke dense-mask eval is gone: the shim module holds no masking
    import repro.pipelines as pipelines
    src = inspect.getsource(pipelines)
    assert "NEG_INF" not in src and "TransitionMatrix" not in src


def test_seed_bit_reproducibility(cold_ctx):
    _, ctx1 = cold_ctx
    ctx2 = _resolve_tiny().run()
    for arm in ("static", "unconstrained"):
        b1, s1 = ctx1["serve_results"][arm]
        b2, s2 = ctx2["serve_results"][arm]
        assert np.array_equal(b1, b2), f"{arm} beams differ across runs"
        assert np.array_equal(s1, s2), f"{arm} scores differ across runs"
    assert np.array_equal(ctx1["sids"], ctx2["sids"])
    assert ctx1["result"] == ctx2["result"]


def test_legacy_raw_tm_eval_agrees_with_scenario_path(cold_ctx):
    """Old-vs-new regression: the pre-refactor eval built a raw
    TransitionMatrix over the cold SIDs and called the retriever directly;
    the scenario serves through the stacked registry slot behind an engine.
    Same alive beams, same metrics."""
    run, ctx = cold_ctx
    cfg, data, sids = run.config, ctx["data"], ctx["sids"]
    L, V = ctx["sid_length"], ctx["vocab"]
    test = data.test_seqs[: cfg.eval.max_eval]
    hist = sids[test[:, :-1]].reshape(test.shape[0], -1).astype(np.int32)
    targets = ctx["eval_targets"]

    tm = TransitionMatrix.from_sids(sids[data.cold_items], V, dense_d=2)
    legacy = GenerativeRetriever(ctx["params"], ctx["model_cfg"], tm,
                                 sid_length=L, sid_vocab=V,
                                 beam_size=cfg.serve.beam)
    lb, ls = legacy.retrieve(hist)
    nb, ns = ctx["serve_results"]["static"]

    hit_l, r1_l = EvalStage._hits(lb, ls, targets)
    hit_n, r1_n = EvalStage._hits(nb, ns, targets)
    assert (hit_l, r1_l) == (hit_n, r1_n)
    # per-request alive beam sets are identical (order-free: dead lanes may
    # hold different garbage, tie order at the beam edge may differ)
    for i in range(hist.shape[0]):
        legacy_alive = {tuple(map(int, lb[i, m]))
                        for m in range(lb.shape[1]) if ls[i, m] > NEG_INF / 2}
        new_alive = {tuple(map(int, nb[i, m]))
                     for m in range(nb.shape[1]) if ns[i, m] > NEG_INF / 2}
        assert legacy_alive == new_alive, f"request {i}"


def test_resume_skips_completed_stages(cold_ctx):
    run, ctx = cold_ctx
    # full context: every stage resumes, nothing recomputes
    lines = []
    out = run.run(log=lines.append, ctx=dict(ctx))
    assert out["result"] == ctx["result"]
    assert sum("resumed from context" in ln for ln in lines) == 6
    # partial context: only serve + eval re-run (e.g. re-serve after a swap)
    partial = {k: v for k, v in ctx.items()
               if k not in ("serve_results", "serve_meta", "result",
                            "eval_targets")}
    lines = []
    out = run.run(log=lines.append, ctx=partial)
    ran = [ln for ln in lines if "running stage" in ln]
    assert [ln.rsplit(" ", 1)[-1] for ln in ran] == ["serve", "eval"]
    assert out["result"]["hit@M_static"] == ctx["result"]["hit@M_static"]


def test_run_cold_start_experiment_wrapper_keeps_legacy_surface():
    from repro.pipelines import run_cold_start_experiment
    res = run_cold_start_experiment(
        cold_frac=0.02, seed=0, n_items=200, train_steps=0, beam_size=16,
        smoke=True)
    for key in ("cold_frac", "n_cold", "n_test", "recall@1_unconstrained",
                "recall@1_constrained_random", "recall@1_static"):
        assert key in res, key
    assert res["n_cold"] == 4
    assert res["hit@M_static"] == 1.0  # beam 16 covers all 4 cold SIDs
    assert res["gates"]["passed"]


def test_multi_constraint_tiny_full_compliance():
    run = get_default_registry().resolve(
        "multi_constraint", smoke=True,
        overrides={"data.n_items": 300, "serve.n_requests": 8})
    res = run.run()["result"]
    assert res["alive_beams"] > 0
    assert res["compliance"] == 1.0
    assert res["gates"]["full_compliance"]
    assert res["gates"]["zero_unexpected_recompiles"]
    assert res["gates"]["passed"]


def test_custom_spec_registration_runs():
    reg = ScenarioRegistry()
    base = get_default_registry().get("multi_constraint")
    cfg = dataclasses.replace(base.config, name="my_tenant")
    reg.register(ScenarioSpec(name="my_tenant", description="custom",
                              config=cfg,
                              smoke_overrides=dict(base.smoke_overrides)))
    cfg2 = reg.resolve("my_tenant", smoke=True).config
    assert cfg2.data.n_items == 800  # smoke shrink applied


# ---------------------------------------------------------------------------
# trie-aware auxiliary signal
# ---------------------------------------------------------------------------
def _brute_admissible(sids, V):
    rows = [tuple(map(int, r)) for r in sids]
    N, L = sids.shape
    sizes = np.zeros((N, L), np.int32)
    masks = np.zeros((N, L, V), bool)
    for i, r in enumerate(rows):
        for lvl in range(L):
            nxt = {rr[lvl] for rr in rows if rr[:lvl] == r[:lvl]}
            sizes[i, lvl] = len(nxt)
            for t in nxt:
                masks[i, lvl, t] = True
    return sizes, masks


def test_admissible_stats_match_brute_force():
    rng = np.random.default_rng(3)
    sids = rng.integers(0, 6, (40, 3))  # small vocab -> many shared prefixes
    sizes, masks = trie_signal.admissible_stats(sids, 6)
    ref_sizes, ref_masks = _brute_admissible(sids, 6)
    np.testing.assert_array_equal(sizes, ref_sizes)
    np.testing.assert_array_equal(masks, ref_masks)
    assert (masks.sum(axis=2) == sizes).all()


def test_item_admissible_aligns_with_catalog_order():
    rng = np.random.default_rng(4)
    sids = np.unique(rng.integers(0, 16, (60, 4)), axis=0)
    rng.shuffle(sids)  # catalog order != slab (sorted) order
    source = TrieSource.from_sids(sids, 16, dense_d=2)
    sizes, masks = trie_signal.item_admissible(sids, source)
    ref_sizes, ref_masks = _brute_admissible(sids, 16)
    np.testing.assert_array_equal(sizes, ref_sizes)
    np.testing.assert_array_equal(masks, ref_masks)


def test_map_items_to_slab_rejects_missing_items():
    sids = np.array([[0, 1], [2, 3], [4, 5]])
    source = TrieSource.from_sids(sids, 8, dense_d=1)
    with pytest.raises(ValueError, match="not present"):
        trie_signal.map_items_to_slab(np.array([[0, 1], [7, 7]]),
                                      np.asarray(source.sids))


def test_lm_loss_trie_aware_identities():
    cfg = gr_model_config(32, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    params = transformer.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 32, (2, 8)).astype(np.int32))
    full = jnp.ones((2, 8, 32), bool)
    base = transformer.lm_loss(params, tokens, cfg)
    # all-admissible mask: the auxiliary term vanishes exactly
    same = transformer.lm_loss_trie_aware(params, tokens, cfg, full, 0.5)
    assert np.allclose(float(base), float(same), atol=1e-6)
    # restrictive mask (keep each label admissible): aux >= 0, grads flow
    adm = np.zeros((2, 8, 32), bool)
    labels = np.roll(np.asarray(tokens), -1, axis=1)
    adm[np.arange(2)[:, None], np.arange(8)[None, :], labels] = True
    adm[:, :, 0] = True
    tight = transformer.lm_loss_trie_aware(
        params, tokens, cfg, jnp.asarray(adm), 0.5)
    assert np.isfinite(float(tight)) and float(tight) >= float(base) - 1e-6
    g = jax.grad(lambda p: transformer.lm_loss_trie_aware(
        p, tokens, cfg, jnp.asarray(adm), 0.5))(params)
    norms = [float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(n) for n in norms) and sum(norms) > 0.0
