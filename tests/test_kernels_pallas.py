"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF, candidate_width
from repro.kernels import ops, ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.vntk import (
    vntk_fused_logsoftmax_pallas,
    vntk_pallas,
    vntk_stacked_topk_pallas,
    vntk_topk_pallas,
)
from conftest import make_sids


def _random_csr(rng, n_states, vocab, bmax_true):
    """Random CSR with rows of 0..bmax_true children, unique sorted tokens."""
    counts = rng.integers(0, bmax_true + 1, n_states)
    counts[0] = 0  # sink
    rowptr = np.zeros(n_states + 1, np.int64)
    rowptr[1:] = np.cumsum(counts)
    E = int(rowptr[-1])
    cols = np.empty(E, np.int64)
    vals = np.empty(E, np.int64)
    for s in range(n_states):
        lo, hi = rowptr[s], rowptr[s + 1]
        c = np.sort(rng.choice(vocab, size=hi - lo, replace=False))
        cols[lo:hi] = c
        vals[lo:hi] = rng.integers(1, n_states, size=hi - lo)
    pad = 256
    edges = np.zeros((E + pad, 2), np.int32)
    edges[:E, 0] = cols
    edges[:E, 1] = vals
    return rowptr.astype(np.int32), edges


@pytest.mark.parametrize("vocab", [128, 256, 2048])
@pytest.mark.parametrize("nb", [1, 7, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vntk_kernel_sweep(rng, vocab, nb, dtype):
    n_states = 64
    bmax = 24
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    lp = jnp.asarray(rng.normal(size=(nb, vocab)), dtype=dtype)
    got_lp, got_nx = vntk_pallas(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        interpret=True,
    )
    want_lp, want_nx = ref.vntk_ref(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab
    )
    np.testing.assert_allclose(
        np.asarray(got_lp, np.float32), np.asarray(want_lp, np.float32), rtol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


@pytest.mark.parametrize("bmax", [1, 8, 33, 128])
def test_vntk_kernel_branch_factor_sweep(rng, bmax):
    vocab, n_states, nb = 512, 40, 8
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
    got_lp, got_nx = vntk_pallas(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        interpret=True,
    )
    want_lp, want_nx = ref.vntk_ref(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab
    )
    np.testing.assert_allclose(np.asarray(got_lp), np.asarray(want_lp), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


def test_vntk_kernel_on_real_trie(rng):
    """End-to-end: kernel output == XLA path on a built TransitionMatrix."""
    vocab, length = 64, 5
    sids = make_sids(rng, 800, vocab, length, clustered=True)
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=2)
    nb = 12
    step = 2  # first sparse step
    # nodes for step 2: l1_states of valid 2-prefixes
    pref = sids[rng.integers(0, sids.shape[0], nb)]
    nodes = jnp.asarray(
        np.asarray(tm.l1_states)[pref[:, 0], pref[:, 1]].astype(np.int32)
    )
    lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
    bmax = tm.bmax_for_step(step)
    got_lp, got_nx = vntk_pallas(
        lp, nodes, tm.row_pointers, tm.edges, bmax, vocab, interpret=True
    )
    want_lp, want_nx = ref.vntk_ref(
        lp, nodes, tm.row_pointers, tm.edges, bmax, vocab
    )
    np.testing.assert_allclose(np.asarray(got_lp), np.asarray(want_lp), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


@pytest.mark.parametrize("vocab", [128, 1024])
def test_fused_logsoftmax_kernel(rng, vocab):
    n_states, nb, bmax = 32, 8, 16
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    logits = jnp.asarray((rng.normal(size=(nb, vocab)) * 4).astype(np.float32))
    got_lp, got_nx = vntk_fused_logsoftmax_pallas(
        logits, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        interpret=True,
    )
    want_lp, want_nx = ref.vntk_fused_logsoftmax_ref(
        logits, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab
    )
    np.testing.assert_allclose(
        np.asarray(got_lp), np.asarray(want_lp), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


# ---------------------------------------------------------------------------
# candidate-compressed kernels (DESIGN.md §8)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("vocab", [128, 512])
@pytest.mark.parametrize("nb", [1, 7, 16])  # 7: prime => beam-pad path
@pytest.mark.parametrize("bmax", [1, 8, 33])  # spans bmax < M and > M
def test_vntk_topk_kernel_matches_dense_rank(rng, vocab, nb, bmax):
    """Kernel candidates == dense-rank top-C of the kernel-free dense row,
    tokens and tie order included (the §8 bit-exactness contract)."""
    n_states = 40
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    lp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32)), -1)
    width = candidate_width(10, vocab)
    got = vntk_topk_pallas(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        width, interpret=True,
    )
    want = ref.vntk_topk_ref(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        width,
    )
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    # oracle sanity vs the dense scatter path: identical rank + tie order
    dense_lp, dense_nx = ref.vntk_ref(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab
    )
    dvals, didx = jax.lax.top_k(dense_lp, width)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(dvals))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(didx))
    np.testing.assert_array_equal(
        np.asarray(want[2]),
        np.asarray(dense_nx)[np.arange(nb)[:, None], np.asarray(didx)],
    )


def test_vntk_topk_kernel_fused(rng):
    vocab, n_states, nb, bmax = 256, 32, 9, 12
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    logits = jnp.asarray((rng.normal(size=(nb, vocab)) * 4).astype(np.float32))
    width = candidate_width(6, vocab)
    got = vntk_topk_pallas(
        logits, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        width, fused_logsoftmax=True, interpret=True,
    )
    want = ref.vntk_topk_ref(
        logits, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        width, fused_logsoftmax=True,
    )
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


@pytest.mark.parametrize("nb", [6, 11])
def test_vntk_stacked_topk_kernel(rng, nb):
    vocab, n_states, bmax, K = 200, 24, 9, 3
    rowptrs, edgelists = [], []
    for _ in range(K):
        rp, ed = _random_csr(rng, n_states, vocab, bmax)
        rowptrs.append(rp)
        edgelists.append(ed)
    E = max(e.shape[0] for e in edgelists)
    edges = np.zeros((K, E, 2), np.int32)
    for k, e in enumerate(edgelists):
        edges[k, : e.shape[0]] = e
    rowptr = np.stack(rowptrs)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    cids = jnp.asarray(rng.integers(0, K, nb).astype(np.int32))
    lp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32)), -1)
    width = candidate_width(8, vocab)
    got = vntk_stacked_topk_pallas(
        lp, nodes, cids, jnp.asarray(rowptr), jnp.asarray(edges), bmax,
        vocab, width, interpret=True,
    )
    want = ref.vntk_stacked_topk_ref(
        lp, nodes, cids, jnp.asarray(rowptr), jnp.asarray(edges), bmax,
        vocab, width,
    )
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_vntk_topk_ops_dispatch(rng):
    vocab, n_states, nb, bmax = 256, 32, 8, 12
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    lp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32)), -1)
    width = candidate_width(6, vocab)
    a = ops.vntk_topk(lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges),
                      bmax, vocab, width, impl="xla")
    b = ops.vntk_topk(lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges),
                      bmax, vocab, width, impl="pallas")
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))


def test_beam_tile_padding_prime_rows(rng):
    """Regression for the tile-degradation fix: a prime row count (13) used
    to fall back to beam_tile=1; it now pads to a tile multiple and slices.
    The grid must shrink accordingly and results stay exact."""
    vocab, n_states, nb, bmax = 128, 24, 13, 8
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
    got_lp, got_nx = vntk_pallas(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab,
        interpret=True,
    )
    want_lp, want_nx = ref.vntk_ref(
        lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges), bmax, vocab
    )
    assert got_lp.shape == (nb, vocab)  # padding sliced away
    np.testing.assert_allclose(np.asarray(got_lp), np.asarray(want_lp),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


@pytest.mark.parametrize("B,K,D", [(8, 1, 32), (16, 4, 128), (5, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(rng, B, K, D, dtype, mode):
    R = 200
    table = jnp.asarray(rng.normal(size=(R + 1, D)), dtype=dtype)
    table = table.at[R].set(0.0)  # sentinel pad row
    idx = jnp.asarray(rng.integers(0, R + 1, size=(B, K)).astype(np.int32))
    got = embedding_bag_pallas(table, idx, mode=mode, interpret=True)
    want = ref.embedding_bag_ref(table, idx, mode=mode)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


def test_ops_dispatch_agrees(rng):
    """ops.vntk xla vs pallas paths agree (jit boundary included)."""
    vocab, n_states, nb, bmax = 256, 32, 8, 12
    rowptr, edges = _random_csr(rng, n_states, vocab, bmax)
    nodes = jnp.asarray(rng.integers(0, n_states, nb).astype(np.int32))
    lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
    a_lp, a_nx = ops.vntk(lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges),
                          bmax, vocab, impl="xla")
    b_lp, b_nx = ops.vntk(lp, nodes, jnp.asarray(rowptr), jnp.asarray(edges),
                          bmax, vocab, impl="pallas")
    np.testing.assert_allclose(np.asarray(a_lp), np.asarray(b_lp), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a_nx), np.asarray(b_nx))
