"""RQ-VAE tokenizer + Amazon cold-start dataset coverage.

Pins the tokenizer/data contracts the scenario pipeline builds on:
straight-through training actually reduces reconstruction error, the TIGER
dedup token makes Semantic IDs unique (collision bound), and the cold/warm
split leaks nothing — no cold item (or its SID) reaches a training
sequence, and the ``age_days`` mapping lets ``freshness_window`` carve out
exactly the cold set.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import RQVAEConfig
from repro.constraints import ItemCatalog, freshness_window
from repro.data.amazon import make_cold_start_dataset
from repro.data.synthetic import make_item_corpus
from repro.models import rqvae
from repro.scenarios import train_rqvae


@pytest.fixture(scope="module")
def tiny_corpus():
    rng = np.random.default_rng(0)
    feats, cid = make_item_corpus(rng, 300, 16, 24)
    return feats, cid


# ---------------------------------------------------------------------------
# RQ-VAE: straight-through round-trip + dedup token
# ---------------------------------------------------------------------------
def test_straight_through_roundtrip_improves_with_training(tiny_corpus):
    feats, _ = tiny_corpus
    cfg = RQVAEConfig(feat_dim=feats.shape[1], latent_dim=8, n_levels=3,
                      codebook_size=32)
    init = rqvae.init_params(cfg, jax.random.key(1))
    trained = train_rqvae(feats, cfg, steps=120, seed=1, batch=128)

    def recon_err(params):
        sids = rqvae.encode_to_sids(params, jnp.asarray(feats), cfg)
        recon = rqvae.decode_from_sids(params, sids, cfg)
        return float(jnp.mean((recon - feats) ** 2))

    # encode -> decode round-trip through the codebooks, not the ST path
    assert recon_err(trained) < recon_err(init)
    # and the training loss itself is finite + lower
    l0 = float(rqvae.rqvae_loss(init, jnp.asarray(feats), cfg))
    l1 = float(rqvae.rqvae_loss(trained, jnp.asarray(feats), cfg))
    assert np.isfinite(l1) and l1 < l0


def test_assign_dedup_tokens_ranks_within_collision_groups():
    levels = np.array([[1, 2], [0, 5], [1, 2], [1, 2], [0, 5], [3, 3]])
    out = rqvae.assign_dedup_tokens(levels, codebook_size=16)
    assert out.shape == (6, 3)
    np.testing.assert_array_equal(out[:, :2], levels)
    by_group = {}
    for row in out:
        by_group.setdefault(tuple(row[:2]), []).append(int(row[2]))
    # each collision group gets dedup tokens 0..k-1 (order-free)
    for toks in by_group.values():
        assert sorted(toks) == list(range(len(toks)))
    assert np.unique(out, axis=0).shape[0] == 6


def test_sid_uniqueness_and_collision_bound(tiny_corpus):
    feats, _ = tiny_corpus
    cfg = RQVAEConfig(feat_dim=feats.shape[1], latent_dim=8, n_levels=3,
                      codebook_size=64)
    params = train_rqvae(feats, cfg, steps=80, seed=2, batch=128)
    levels = np.asarray(rqvae.encode_to_sids(params, jnp.asarray(feats), cfg))
    # collision bound: the dedup token only disambiguates groups smaller
    # than the codebook — pin that the trained quantizer stays well under
    _, counts = np.unique(levels, axis=0, return_counts=True)
    assert counts.max() < cfg.codebook_size
    sids = rqvae.assign_dedup_tokens(levels, cfg.codebook_size)
    assert sids.shape == (feats.shape[0], cfg.n_levels + 1)
    assert np.unique(sids, axis=0).shape[0] == feats.shape[0]
    # the quantizer must actually discriminate (not one giant group)
    assert np.unique(levels, axis=0).shape[0] > 1


# ---------------------------------------------------------------------------
# cold/warm split protocol
# ---------------------------------------------------------------------------
def test_cold_warm_split_disjoint_and_no_sid_leak():
    data = make_cold_start_dataset(seed=0, n_items=400, n_users=1_500,
                                   cold_frac=0.02)
    n_cold = data.cold_items.shape[0]
    assert n_cold == max(1, int(400 * 0.02))
    cold_mask = np.zeros(data.n_items, bool)
    cold_mask[data.cold_items] = True
    # no cold item anywhere in a training sequence
    assert not cold_mask[data.train_seqs].any()
    # every test target is cold
    assert cold_mask[data.test_seqs[:, -1]].all()
    assert data.test_seqs.shape[0] > 0

    # SID-level leak check: with unique per-item SIDs, no training sequence
    # can contain a cold SID prefix — the warm and cold SID sets are disjoint
    rng = np.random.default_rng(1)
    levels = rng.integers(0, 8, (data.n_items, 3))  # heavy collisions
    sids = rqvae.assign_dedup_tokens(levels, 256)
    warm_set = {tuple(map(int, s)) for s in sids[~cold_mask]}
    cold_set = {tuple(map(int, s)) for s in sids[cold_mask]}
    assert not (warm_set & cold_set)
    train_sids = {tuple(map(int, s))
                  for s in sids[data.train_seqs.ravel()]}
    assert not (train_sids & cold_set)


def test_dataset_determinism_across_seeds():
    a = make_cold_start_dataset(seed=7, n_items=200, n_users=600)
    b = make_cold_start_dataset(seed=7, n_items=200, n_users=600)
    for field in ("item_feats", "item_age", "item_cluster", "cold_items",
                  "train_seqs", "test_seqs"):
        np.testing.assert_array_equal(getattr(a, field), getattr(b, field))
    c = make_cold_start_dataset(seed=8, n_items=200, n_users=600)
    assert not np.array_equal(a.item_age, c.item_age)


def test_age_days_cold_only_predicate_is_exact():
    data = make_cold_start_dataset(seed=3, n_items=250, n_users=600,
                                   cold_frac=0.04)
    n_cold = data.cold_items.shape[0]
    age = data.age_days
    # newest (cold) band maps to [0, n_cold)
    assert age.min() == 0.0 and age.max() == data.n_items - 1
    catalog = ItemCatalog(
        sids=np.zeros((data.n_items, 4), np.int64),
        age_days=age,
        category=data.item_cluster.astype(np.int64),
    )
    mask = freshness_window(n_cold - 0.5)(catalog)
    cold_mask = np.zeros(data.n_items, bool)
    cold_mask[data.cold_items] = True
    np.testing.assert_array_equal(mask, cold_mask)
