"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + finiteness (no NaNs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_bundle, smoke_config, supports_shape
from repro.models import gnn, recsys, transformer


LM_ARCHS = [a for a in ARCHS if get_bundle(a).family in ("lm", "gr")]
RECSYS_ARCHS = [a for a in ARCHS if get_bundle(a).family == "recsys"]


def test_registry_complete():
    assert len(ASSIGNED) == 10
    assert "static-gr" in ARCHS  # the paper's own


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_loss(arch):
    cfg = smoke_config(arch)
    key = jax.random.key(0)
    params = transformer.init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    x, _, aux = transformer.forward(params, tokens, cfg)
    assert x.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(x, np.float32)))
    loss = transformer.lm_loss(params, tokens, cfg)
    assert np.isfinite(float(loss))
    # one SGD step moves the loss
    g = jax.grad(lambda p: transformer.lm_loss(p, tokens, cfg))(params)
    flat = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(a, np.float32))) for a in flat)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode_consistency(arch):
    """decode_step after prefill must reproduce teacher-forced logits."""
    cfg = smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.key(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    logits_pre, cache = transformer.prefill(
        params, tokens[:, :S], cfg, max_len=S + 4
    )
    assert logits_pre.shape == (B, 1, cfg.vocab_size)
    # full forward logits at position S-1 == prefill's last logits
    x, _, _ = transformer.forward(params, tokens[:, :S], cfg)
    w = params["emb"].T if cfg.tie_embeddings else params["unemb"]
    ref = (x[:, -1:, :] @ w).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(ref), rtol=2e-2, atol=2e-2
    )
    # one decode step == full forward on S+1 tokens, last position
    logits_dec, cache2 = transformer.decode_step(
        params, cache, tokens[:, S:S + 1], cfg
    )
    x2, _, _ = transformer.forward(params, tokens[:, :S + 1], cfg)
    ref2 = (x2[:, -1:, :] @ w).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(ref2), rtol=5e-2, atol=5e-2
    )
    assert int(cache2.pos) == S + 1


def test_sliding_window_ring_cache():
    """Mixtral smoke: decode far past the window; ring stays window-sized."""
    cfg = smoke_config("mixtral-8x7b")
    assert cfg.sliding_window == 8
    params = transformer.init_params(cfg, jax.random.key(0))
    B, S = 1, 24  # 3x window
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    _, cache = transformer.prefill(params, tokens[:, :16], cfg, max_len=S)
    assert cache.k.shape[2] == cfg.sliding_window  # ring-sized
    for t in range(16, S):
        logits, cache = transformer.decode_step(params, cache, tokens[:, t:t+1], cfg)
    # ring decode must equal full-context forward (window masks the rest)
    x, _, _ = transformer.forward(params, tokens, cfg)
    w = params["unemb"]
    ref = (x[:, -1:, :] @ w).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref), rtol=5e-2, atol=5e-2
    )


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    cfg = smoke_config(arch)
    params = recsys.init_params(cfg, jax.random.key(0))
    B = 8
    rng = np.random.default_rng(0)
    sparse = np.stack(
        [rng.integers(0, v, size=(B, cfg.multi_hot)) for v in cfg.vocab_sizes],
        axis=1,
    ).astype(np.int32)
    batch = {
        "sparse": jnp.asarray(sparse),
        "dense": jnp.asarray(rng.normal(size=(B, max(cfg.n_dense, 1))).astype(np.float32)),
        "hist": jnp.asarray(rng.integers(0, 40, size=(B, cfg.hist_len)).astype(np.int32)),
        "target": jnp.asarray(rng.integers(0, 40, size=(B,)).astype(np.int32)),
        "label": jnp.asarray(rng.integers(0, 2, size=(B,)).astype(np.float32)),
    }
    scores = recsys.forward(params, batch, cfg)
    assert scores.shape == (B,)
    assert np.all(np.isfinite(np.asarray(scores)))
    loss = recsys.recsys_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: recsys.recsys_loss(p, batch, cfg))(params)
    assert all(np.all(np.isfinite(np.asarray(a, np.float32)))
               for a in jax.tree.leaves(g))


def test_mind_retrieval_scores_shape():
    cfg = smoke_config("mind")
    params = recsys.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    hist = jnp.asarray(rng.integers(0, 40, size=(4, cfg.hist_len)).astype(np.int32))
    cand = jnp.asarray(rng.integers(0, 40, size=(100,)).astype(np.int32))
    s = recsys.mind_retrieval_scores(params, hist, cand, cfg)
    assert s.shape == (4, 100)
    assert np.all(np.isfinite(np.asarray(s)))


def test_gnn_smoke_full_and_batched():
    cfg = smoke_config("meshgraphnet")
    params = gnn.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    N, E = 20, 50
    batch = {
        "node_feats": jnp.asarray(rng.normal(size=(N, cfg.node_feat_dim)).astype(np.float32)),
        "edge_feats": jnp.asarray(rng.normal(size=(E, cfg.edge_feat_dim)).astype(np.float32)),
        "senders": jnp.asarray(rng.integers(0, N, E).astype(np.int32)),
        "receivers": jnp.asarray(rng.integers(0, N, E).astype(np.int32)),
        "targets": jnp.asarray(rng.normal(size=(N, cfg.out_dim)).astype(np.float32)),
    }
    out = gnn.forward(params, batch["node_feats"], batch["edge_feats"],
                      batch["senders"], batch["receivers"], cfg)
    assert out.shape == (N, cfg.out_dim)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    loss = gnn.gnn_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    # batched small graphs (molecule shape)
    Bg = 3
    bbatch = {
        k: jnp.stack([v] * Bg) for k, v in batch.items()
    }
    loss_b = gnn.gnn_loss(params, bbatch, cfg)
    assert np.isfinite(float(loss_b))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_shape_applicability_rules(arch):
    b = get_bundle(arch)
    for shape in b.shapes:
        ok, why = supports_shape(arch, shape.name)
        if b.family == "lm" and shape.name == "long_500k":
            cfg = b.config
            if cfg.sliding_window is None and cfg.attention != "mla":
                assert not ok
        else:
            assert ok


def test_param_counts_match_scale():
    """Sanity: declared param counts are in the advertised ballpark."""
    assert 11e9 < get_bundle("stablelm-12b").config.param_count() < 13.5e9
    assert 100e9 < get_bundle("qwen1.5-110b").config.param_count() < 120e9
    assert 6e9 < get_bundle("codeqwen1.5-7b").config.param_count() < 8.5e9
    assert 12e9 < get_bundle("mixtral-8x7b").config.param_count() < 50e9
    ds = get_bundle("deepseek-v2-lite-16b").config
    assert 12e9 < ds.param_count() < 20e9
    assert ds.active_param_count() < 4e9  # ~2.4B active
    assert 2e9 < get_bundle("static-gr").config.param_count() < 4e9
