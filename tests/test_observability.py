"""Telemetry subsystem (DESIGN.md §9): metrics math, exposition formats,
engine instrumentation, and the off-hot-path guarantee.

The load-bearing claims:
  1. Registry primitives are correct (histogram bucket math + quantile
     interpolation, labeled counters/gauges, Prometheus text exposition,
     JSONL snapshots, the scrape endpoint).
  2. ``StepTimer`` separates warmup compilation from steady-state trials
     and flags retracing; the recompile monitor turns "hot swaps never
     recompile" into a counter that must read 0.
  3. Engines record per-request latency without changing results:
     retrieval through a fully-instrumented ``ServingEngine`` is
     bit-identical to calling the retriever directly (metrics cannot touch
     the jitted computation).
"""
import json
import logging
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TransitionMatrix
from repro.constraints import (
    AsyncRefresher,
    CatalogDelta,
    ConstraintRegistry,
    ItemCatalog,
    category_allowlist,
    freshness_window,
)
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.observability import (
    MetricsRegistry,
    RecompileDetector,
    StepTimer,
    compile_events,
    record_policy,
    start_http_server,
)
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids

L = 4


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------
def test_counter_labels_and_validation():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc(lane="0")
    c.inc(2, lane="1")
    c.inc(lane="1")
    assert c.value(lane="0") == 1 and c.value(lane="1") == 3
    assert c.total() == 4
    assert c.value(lane="missing") == 0
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create returns the same object; kind mismatch is an error
    assert reg.counter("reqs_total") is c
    with pytest.raises(TypeError):
        reg.gauge("reqs_total")
    with pytest.raises(ValueError):
        reg.counter("bad name!")


def test_gauge_set_add():
    g = MetricsRegistry().gauge("depth")
    g.set(5, lane="a")
    g.add(-2, lane="a")
    assert g.value(lane="a") == 3


def test_histogram_bucket_math_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    h.observe(100.0)  # lands in the +Inf overflow bucket
    assert h.count() == 5
    assert h.sum() == pytest.approx(106.05)
    # cumulative counts per bucket edge: 1, 3, 4, 5
    # p50 -> rank 2.5 inside (0.1, 1.0]: linear interpolation within bucket
    q50 = h.quantile(0.5)
    assert 0.1 < q50 <= 1.0
    # p100 falls in the overflow bucket -> clamped to the top finite edge
    assert h.quantile(1.0) == pytest.approx(10.0)
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 1.0))


def test_histogram_quantile_interpolates_within_bucket():
    h = MetricsRegistry().histogram("x", buckets=(0.0, 10.0))
    for _ in range(100):
        h.observe(5.0)
    # all mass in (0, 10]: median interpolates to mid-bucket, not an edge
    assert 4.0 < h.quantile(0.5) < 6.0


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "total requests")
    c.inc(3, lane="a\\b\n\"q\"")
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    text = reg.render_prometheus()
    assert "# HELP req_total total requests" in text
    assert "# TYPE req_total counter" in text
    assert "# TYPE lat_seconds histogram" in text
    assert "# TYPE depth gauge" in text
    # label escaping: backslash, newline, quote
    assert 'lane="a\\\\b\\n\\"q\\""' in text
    # cumulative buckets and the +Inf edge equal to _count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text
    assert "depth 7" in text


def test_snapshot_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c_total").inc(2, k="v")
    reg.histogram("h_seconds").observe(0.25)
    p = tmp_path / "snap.jsonl"
    reg.write_snapshot(p)
    reg.write_snapshot(p)
    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert len(lines) == 2
    snap = lines[-1]
    assert snap["counters"]["c_total"] == {'{k="v"}': 2}
    (hrec,) = snap["histograms"]["h_seconds"].values()
    assert hrec["count"] == 1 and hrec["sum"] == pytest.approx(0.25)
    assert "p99" in hrec


def test_http_metrics_endpoint():
    reg = MetricsRegistry()
    reg.counter("up_total").inc()
    server, port = start_http_server(reg, port=0)
    try:
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10
        ).read().decode()
        assert "up_total 1" in body
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# timing + recompile detection
# ---------------------------------------------------------------------------
def test_step_timer_splits_warmup_and_steady_compiles():
    reg = MetricsRegistry()
    f = jax.jit(lambda x: x * 2.0 + 1.0)
    x = np.arange(7, dtype=np.float32)  # fresh shape: first call compiles
    stats = StepTimer("t", reg, warmup=2, trials=5).measure(f, x)
    assert stats.trials == 5
    assert stats.warmup_compiles >= 1  # warmup absorbed the compile
    assert stats.steady_compiles == 0  # trials measured a stable executable
    assert 0 < stats.median < 1.0
    assert stats.p99 >= stats.p50
    assert reg.histogram("step_wall_seconds").count(step="t") == 5
    assert reg.counter("step_compiles_total").value(
        step="t", phase="warmup") >= 1
    s = stats.summary()
    assert s["steady_compiles"] == 0 and s["name"] == "t"


def test_recompile_detector_fires_only_on_compiles():
    f = jax.jit(lambda x: x + 1.0)
    x = np.ones(11, np.float32)
    f(x)  # compile outside the armed window
    det = RecompileDetector()
    f(x)
    assert det.count == 0
    f(np.ones(13, np.float32))  # new shape: retrace
    assert det.count >= 1
    det.reset()
    assert det.count == 0
    assert compile_events() >= 1


# ---------------------------------------------------------------------------
# policy plan + record_policy
# ---------------------------------------------------------------------------
def test_policy_plan_info_and_gauges(rng):
    sids = make_sids(rng, 300, 32, L)
    policy = DecodePolicy.static(TransitionMatrix.from_sids(sids, 32,
                                                            dense_d=2))
    info = policy.plan_info(beams=8)
    assert [r["level"] for r in info] == list(range(L))
    assert all(r["backend"] for r in info)
    for r in info:
        assert r["topk"] == policy.supports_topk_at(r["level"])
        if r["topk"]:
            assert r["candidate_width"] >= 1
    reg = MetricsRegistry()
    record_policy(reg, policy, beams=8)
    g = reg.gauge("decode_level_backend_info")
    assert g.value(level="0", backend=info[0]["backend"]) == 1
    last = info[L - 1]
    assert reg.gauge("decode_level_candidate_width").value(
        level=str(L - 1)) == last["candidate_width"]
    assert reg.gauge("decode_level_topk").value(
        level=str(L - 1)) == int(last["topk"])


# ---------------------------------------------------------------------------
# engine instrumentation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    return params, cfg


def _catalog(rng, cfg, n):
    sids = np.unique(make_sids(rng, n, cfg.vocab_size, L, clustered=True),
                     axis=0)
    m = sids.shape[0]
    return ItemCatalog(sids=sids, age_days=rng.uniform(0, 60, m),
                       category=rng.integers(0, 4, m))


def _build_engine(params, cfg, rng, *, headroom=0.5, n_items=250,
                  batch_size=4):
    cat = _catalog(rng, cfg, n_items)
    reg = ConstraintRegistry(cfg.vocab_size, headroom=headroom)
    reg.register("fresh", freshness_window(45))
    reg.register("cats", category_allowlist(0, 1, 2))
    store = reg.build(cat)
    retr = GenerativeRetriever(params, cfg, store, sid_length=L,
                               sid_vocab=cfg.vocab_size, beam_size=4)
    eng = ServingEngine(params, cfg, batch_size=batch_size, max_len=24,
                        retriever=retr, registry=reg)
    return eng, reg, cat


def test_engine_records_request_latency_metrics(small_lm, rng):
    params, cfg = small_lm
    eng, reg, _ = _build_engine(params, cfg, rng)
    q = RequestQueue()
    rids = [q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                     constraint_id=i % 2) for i in range(6)]
    results = eng.serve(q)
    assert set(results) == set(rids)
    m = eng.metrics
    # every result carries its own measured latency split
    for r in results.values():
        assert r["latency_s"] >= r["queue_s"] >= 0.0
    # per-lane request counters add up; latency histograms saw every request
    c = m.counter("serving_requests_total")
    assert c.total() == 6
    assert c.value(lane="0") == 3 and c.value(lane="1") == 3
    h = m.histogram("serving_request_latency_seconds")
    assert h.count(lane="0") + h.count(lane="1") == 6
    assert m.histogram("serving_request_queue_seconds").count(lane="0") > 0
    assert m.counter("serving_batches_total").total() >= 2  # 6 reqs, batch 4
    assert m.counter("serving_decode_steps_total").total() > 0
    # occupancy of the LAST batch: 2 of 4 slots
    assert m.gauge("serving_batch_occupancy").value() == pytest.approx(0.5)
    # queue drained: every lane gauge reads 0
    assert m.gauge("serving_queue_depth").value(lane="0") == 0
    # the plan gauges were published at construction
    assert m.gauge("decode_level_topk").value(level="0") in (0, 1)
    # Prometheus rendering of live engine metrics does not blow up
    assert "serving_request_latency_seconds_bucket" in m.render_prometheus()


def test_engine_results_bit_identical_with_metrics_on(small_lm, rng):
    """Telemetry must not touch device work: engine == direct retriever."""
    params, cfg = small_lm
    eng, reg, _ = _build_engine(params, cfg, rng)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)) for _ in range(4)]
    q = RequestQueue()
    rids = [q.submit(p, n_tokens=L, constraint_id=i % 2)
            for i, p in enumerate(prompts)]
    results = eng.serve(q)
    # direct path: same retriever, same store, no engine/metrics around it
    store, _ = reg.current()
    direct = GenerativeRetriever(params, cfg, store, sid_length=L,
                                 sid_vocab=cfg.vocab_size, beam_size=4)
    hist = np.zeros((4, 12), np.int32)
    for i, p in enumerate(prompts):
        hist[i, :8] = p
    cids = np.asarray([i % 2 for i in range(4)], np.int32)
    beams, scores = direct.retrieve(hist, constraint_ids=cids)
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(results[rid]["sids"], beams[i])
        np.testing.assert_array_equal(results[rid]["scores"], scores[i])


def test_recompile_monitor_silent_across_hot_swaps(small_lm, rng):
    params, cfg = small_lm
    eng, reg, cat = _build_engine(params, cfg, rng, n_items=300)
    q = RequestQueue()
    for i in range(4):
        q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                 constraint_id=i % 2)
    eng.serve(q)  # first batch: compiles are EXPECTED here
    for _ in range(2):  # two hot swaps, served with metrics enabled
        n = cat.sids.shape[0]
        rm = cat.sids[rng.choice(n, 10, replace=False)]
        add = _catalog(rng, cfg, 25)
        reg.swap_delta(CatalogDelta(
            added=add, removed_sids=rm))
        for i in range(4):
            q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                     constraint_id=i % 2)
        eng.serve(q)
    m = eng.metrics
    assert eng.cold_swaps == 0
    # 2 churn swaps + the first batch's initial store install (None -> v1)
    assert m.counter("serving_hot_swaps_total").total() == 3
    # the monitored invariant: zero compiles outside expected windows
    assert m.counter("serving_recompiles_total").value(expected="false") == 0


def test_recompile_monitor_counts_cold_swap_as_expected(small_lm, rng):
    params, cfg = small_lm
    eng, reg, _ = _build_engine(params, cfg, rng, headroom=0.0, n_items=60,
                                batch_size=2)
    q = RequestQueue()
    for i in range(2):
        q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                 constraint_id=i % 2)
    eng.serve(q)
    big = _catalog(rng, cfg, 1200)  # outgrows the zero-headroom envelope
    reg.swap(big)
    for i in range(2):
        q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                 constraint_id=i % 2)
    eng.serve(q)
    m = eng.metrics
    # the cold swap recompiled, but inside an expected window
    assert eng.cold_swaps == 1
    assert m.counter("serving_cold_swaps_total").total() == 1
    assert m.counter("serving_recompiles_total").value(expected="false") == 0
    assert m.counter("serving_recompiles_total").value(expected="true") >= 1


def test_registry_publishes_headroom_and_utilization(small_lm, rng):
    params, cfg = small_lm
    eng, reg, _ = _build_engine(params, cfg, rng)
    m = reg.metrics
    assert 0 < m.gauge("constraint_envelope_states_used_frac").value() <= 1
    assert 0 < m.gauge("constraint_envelope_edges_used_frac").value() <= 1
    assert m.gauge("constraint_store_bytes").value() > 0
    assert m.gauge("constraint_slot_sids").value(slot="fresh") > 0
    util = m.gauge("constraint_slot_utilization_frac").value(slot="fresh")
    # the paper's actual<=u_max holds at production scale; toy tries carry
    # edge-slab padding that can nudge the ratio past 1, so just sanity-bound
    assert 0 < util < 4.0
    assert m.counter("constraint_swaps_total").value(
        kind="build", cold="true") == 1
    assert m.histogram("constraint_refresh_seconds").count(kind="build") == 1


def test_async_refresher_failure_logs_and_counts(rng, caplog):
    sids = np.unique(make_sids(rng, 100, 16, L), axis=0)
    n = sids.shape[0]
    cat = ItemCatalog(sids=sids, age_days=rng.uniform(0, 60, n),
                      category=rng.integers(0, 4, n))
    reg = ConstraintRegistry(16, headroom=0.5)
    reg.register("all", lambda c: np.ones(c.sids.shape[0], bool))
    reg.build(cat)
    bad = CatalogDelta(removed_sids=sids[:, :2])  # wrong SID width
    # arm caplog BEFORE submitting: the worker thread logs the failure
    # before it resolves the future
    with caplog.at_level(logging.ERROR, "repro.constraints.refresh"):
        with AsyncRefresher(reg) as ref:
            fut = ref.apply_delta_async(bad)
            with pytest.raises(ValueError):
                fut.result(timeout=60)
            assert ref.drain(timeout=60)
    assert ref.failed == 1 and ref.applied == 0
    assert isinstance(ref.last_error, ValueError)
    assert ref.metrics.counter("refresh_ops_total").value(
        kind="delta", outcome="failed") == 1
    assert any("refresh delta failed" in r.getMessage()
               for r in caplog.records)
