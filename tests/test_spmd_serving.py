"""SPMD constrained serving (DESIGN.md §6).

Load-bearing properties: (1) SPMD decoding over a mesh — replicated or
CSR-row-sharded constraints — is bit-identical to single-device decoding;
(2) a registry hot-swap under the mesh compiles NOTHING new; (3) the
continuous-batching engine drains mixed-constraint queues with per-request
compliance at any occupancy.

Runs on however many devices exist (a 1-device mesh still exercises
shard_map, the psum combine, and the padding rules); CI additionally runs
this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.constraints import (
    ConstraintRegistry,
    ConstraintStore,
    ItemCatalog,
    freshness_window,
)
from repro.core import NEG_INF, TransitionMatrix
from repro.core.vntk import vntk_xla
from repro.decoding import DecodePolicy
from repro.distributed.constraint_sharding import (
    pad_rows,
    policy_pspecs,
    spmd_beam_search,
    vntk_row_sharded,
)
from repro.distributed.sharding import dp_size
from repro.launch.mesh import make_subset_mesh
from repro.models import transformer
from repro.serving.engine import RequestQueue
from repro.serving.generative_retrieval import GenerativeRetriever
from repro.serving.spmd_engine import SpmdRetriever, SpmdServingEngine
from conftest import make_sids

V, L = 16, 4


def data_mesh():
    """All visible devices on the data axis (model kept at 1)."""
    return make_subset_mesh(len(jax.devices()), 1)


def model_mesh():
    """A mesh with a non-trivial model axis when devices allow."""
    n = len(jax.devices())
    model = 2 if n % 2 == 0 and n >= 2 else 1
    return make_subset_mesh(n // model, model)


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    return params, cfg


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    sids = np.unique(make_sids(rng, 150, V, L, clustered=True), axis=0)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=2)
    table = jnp.asarray(rng.normal(size=(L, V, V)).astype(np.float32))
    return sids, tm, table


def table_logits_fn(table):
    def fn(carry, last, step):
        return table[step][last], carry
    return fn


# ---------------------------------------------------------------------------
# spmd_beam_search: bit-identity over the mesh
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows", ["replicated", "model"])
def test_spmd_beam_search_bit_identical(corpus, rows):
    from repro.core import beam_search

    _, tm, table = corpus
    mesh = model_mesh()
    B = 2 * dp_size(mesh)
    policy = DecodePolicy.static(tm)

    @jax.jit
    def single(pol):  # compiled-vs-compiled: both sides XLA-optimized
        state, _ = beam_search(table_logits_fn(table), None, B, 5, L, pol)
        return state.tokens, state.scores

    want_t, want_s = single(policy)
    tokens, scores = spmd_beam_search(
        mesh, table_logits_fn(table), B, 5, L, policy, rows=rows
    )
    # deterministic table logits -> full float bit-identity, scores included
    np.testing.assert_array_equal(np.asarray(tokens), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(want_s))


def test_spmd_beam_search_stacked_constraint_ids(corpus, rng):
    from repro.core import beam_search

    sids, tm, table = corpus
    mats = [tm, TransitionMatrix.from_sids(
        make_sids(rng, 60, V, L, clustered=True), V, dense_d=2)]
    store = ConstraintStore.from_matrices(mats, headroom=0.25)
    mesh = data_mesh()
    B = 2 * dp_size(mesh)
    cids = np.arange(B, dtype=np.int32) % 2
    policy = DecodePolicy.stacked(store)

    @jax.jit
    def single(pol, ids):
        state, _ = beam_search(
            table_logits_fn(table), None, B, 4, L, pol, constraint_ids=ids
        )
        return state.tokens, state.scores

    want_t, want_s = single(policy, jnp.asarray(cids))
    tokens, scores = spmd_beam_search(
        mesh, table_logits_fn(table), B, 4, L, policy, constraint_ids=cids
    )
    np.testing.assert_array_equal(np.asarray(tokens), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(want_s))


def test_spmd_beam_search_rejects_ragged_batch(corpus):
    _, tm, table = corpus
    mesh = data_mesh()
    n = dp_size(mesh)
    if n == 1:
        pytest.skip("every batch divides a 1-way mesh")
    with pytest.raises(ValueError, match="pad with inactive rows"):
        spmd_beam_search(mesh, table_logits_fn(table), n + 1, 4, L,
                         DecodePolicy.static(tm))


# ---------------------------------------------------------------------------
# row-sharded CSR: one-hop gather == replicated VNTK, and padding is inert
# ---------------------------------------------------------------------------
def test_vntk_row_sharded_matches_replicated(corpus, rng):
    from repro.distributed.sharding import shard_map_compat

    _, tm, _ = corpus
    mesh = model_mesh()
    ms = mesh.shape["model"]
    tm_pad = pad_rows(tm, ms)
    assert tm_pad.edges.shape[0] % ms == 0
    step = 2
    bmax = max(tm.bmax_for_step(step), 1)
    nodes = jnp.asarray(
        rng.integers(0, tm.n_states, size=(12,)), jnp.int32)
    lp = jnp.asarray(rng.normal(size=(12, V)).astype(np.float32))
    want_lp, want_nx = vntk_xla(lp, nodes, tm, bmax)

    f = jax.jit(shard_map_compat(
        lambda lp, nodes, rp, edges: vntk_row_sharded(
            lp, nodes, rp, edges, bmax, V, "model"),
        mesh=mesh,
        in_specs=(P(), P(), P(), P("model", None)),
        out_specs=(P(), P()),
    ))
    got_lp, got_nx = f(lp, nodes, tm_pad.row_pointers, tm_pad.edges)
    np.testing.assert_array_equal(np.asarray(got_lp), np.asarray(want_lp))
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


def test_pad_rows_roundtrip_and_determinism(corpus):
    _, tm, _ = corpus
    p3 = pad_rows(tm, 3)
    assert p3.edges.shape[0] % 3 == 0
    assert p3.n_edges == tm.n_edges  # static metadata untouched
    np.testing.assert_array_equal(
        np.asarray(p3.edges[: tm.edges.shape[0]]), np.asarray(tm.edges))
    assert not np.asarray(p3.edges[tm.edges.shape[0]:]).any()
    # idempotent at the same shard count => hot-swap shapes are deterministic
    assert pad_rows(p3, 3).edges.shape == p3.edges.shape
    assert pad_rows(tm, 1) is tm


def test_policy_pspecs_structure(corpus):
    _, tm, _ = corpus
    mesh = model_mesh()
    policy = DecodePolicy.static(tm)
    specs = policy_pspecs(policy, mesh)
    assert jax.tree_util.tree_structure(specs) == \
        jax.tree_util.tree_structure(policy)
    assert all(s == P() for s in jax.tree_util.tree_leaves(specs))
    sharded = policy_pspecs(policy, mesh, rows="model")
    edge_specs = {b.tm.edges for b in sharded.backends}
    assert P("model", None) in edge_specs
    with pytest.raises(ValueError, match="rows"):
        policy_pspecs(policy, mesh, rows="banana")


def test_row_sharded_rejects_pallas_and_fused(corpus):
    _, tm, _ = corpus
    mesh = model_mesh()
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    tm_v = TransitionMatrix.from_sids(
        make_sids(np.random.default_rng(0), 40, cfg.vocab_size, L),
        cfg.vocab_size)
    for bad in (DecodePolicy.static(tm_v, fused=True),
                DecodePolicy.static(tm_v, impl="pallas")):
        with pytest.raises(ValueError, match="rows='model'"):
            SpmdRetriever(params, cfg, bad, L, cfg.vocab_size, beam_size=4,
                          mesh=mesh, rows="model")


# ---------------------------------------------------------------------------
# SpmdRetriever: end-to-end identity, padding, and hot-swap under the mesh
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows", ["replicated", "model"])
def test_spmd_retriever_matches_single_device(small_lm, rng, rows):
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 4
    sids = make_sids(rng, 80, Vm, Lm, clustered=True)
    tm = TransitionMatrix.from_sids(sids, Vm)
    mesh = model_mesh() if rows == "model" else data_mesh()
    # B deliberately NOT a multiple of the dp ways: exercises padding
    B = dp_size(mesh) + 1
    hist = rng.integers(0, Vm, (B, 8)).astype(np.int32)
    want_t, want_s = GenerativeRetriever(
        params, cfg, tm, sid_length=Lm, sid_vocab=Vm, beam_size=4
    ).retrieve(hist)
    got_t, got_s = SpmdRetriever(
        params, cfg, tm, sid_length=Lm, sid_vocab=Vm, beam_size=4,
        mesh=mesh, rows=rows,
    ).retrieve(hist)
    assert got_t.shape == (B, 4, Lm)
    np.testing.assert_array_equal(got_t, want_t)
    np.testing.assert_allclose(got_s, want_s, atol=1e-5)


def test_spmd_retriever_active_mask(small_lm, rng):
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 3
    tm = TransitionMatrix.from_sids(make_sids(rng, 50, Vm, Lm), Vm)
    mesh = data_mesh()
    B = 2 * dp_size(mesh)
    hist = rng.integers(0, Vm, (B, 8)).astype(np.int32)
    active = np.ones(B, bool)
    active[0] = False
    retr = SpmdRetriever(params, cfg, tm, sid_length=Lm, sid_vocab=Vm,
                         beam_size=4, mesh=mesh)
    _, scores = retr.retrieve(hist, active_mask=active)
    assert (scores[0] <= NEG_INF).all()  # free slot: parked, unmistakable
    assert (scores[1:, 0] > NEG_INF / 2).all()


def test_spmd_hot_swap_zero_recompile_under_mesh(small_lm, rng):
    """Acceptance: retriever.set_constraints under the mesh reuses the
    mesh-compiled executable — zero backend compiles across the swap."""
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 4
    cat = ItemCatalog(
        sids=make_sids(rng, 200, Vm, Lm, clustered=True),
        age_days=rng.uniform(0, 60, size=200),
        category=rng.integers(0, 4, size=200),
    )
    reg = ConstraintRegistry(Vm, headroom=0.5)
    reg.register("fresh_20", freshness_window(20))
    reg.register("fresh_45", freshness_window(45))
    store = reg.build(cat)
    mesh = data_mesh()
    retr = SpmdRetriever(params, cfg, store, sid_length=Lm, sid_vocab=Vm,
                         beam_size=4, mesh=mesh)
    eng = SpmdServingEngine(retr, registry=reg, slots=4, prompt_width=8)

    q = RequestQueue()
    for i in range(5):
        q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=i % 2)
    r1 = eng.serve(q)
    assert all(r["store_version"] == 1 for r in r1.values())

    cat2 = ItemCatalog(
        sids=make_sids(rng, 220, Vm, Lm, clustered=True),
        age_days=rng.uniform(0, 60, size=220),
        category=rng.integers(0, 4, size=220),
    )
    assert reg.swap(cat2) == 2
    compiles = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: compiles.append(name)
        if "backend_compile" in name else None
    )
    for i in range(3):
        q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=i % 2)
    r2 = eng.serve(q)
    assert len(compiles) == 0, f"mesh hot-swap recompiled: {compiles}"
    assert all(r["store_version"] == 2 for r in r2.values())


def test_spmd_metadata_changing_swap_rebuilds(small_lm, rng):
    """A swap OUTSIDE the registry envelope (different static metadata)
    rebuilds the mesh step instead of dying on a spec/treedef mismatch —
    matching the single-device retriever's retrace-on-metadata-change."""
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 3
    tm1 = TransitionMatrix.from_sids(make_sids(rng, 40, Vm, Lm), Vm)
    tm2 = TransitionMatrix.from_sids(make_sids(rng, 90, Vm, Lm), Vm)
    assert tm1.n_states != tm2.n_states  # genuinely different envelope
    retr = SpmdRetriever(params, cfg, tm1, sid_length=Lm, sid_vocab=Vm,
                         beam_size=4, mesh=data_mesh(), rows="model")
    hist = rng.integers(0, Vm, (dp_size(data_mesh()), 8)).astype(np.int32)
    retr.retrieve(hist)
    retr.set_constraints(tm2)
    _, scores = retr.retrieve(hist)
    assert (scores[:, 0] > NEG_INF / 2).all()


def test_spmd_engine_mixed_queue_compliance(small_lm, rng):
    """Continuous batching drains a mixed-constraint queue larger than the
    slot count, each row 100% compliant with ITS OWN constraint set."""
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 4
    cat = ItemCatalog(
        sids=make_sids(rng, 250, Vm, Lm, clustered=True),
        age_days=rng.uniform(0, 60, size=250),
        category=rng.integers(0, 4, size=250),
    )
    reg = ConstraintRegistry(Vm, headroom=0.4)
    preds = {
        reg.register("fresh_25", freshness_window(25)): freshness_window(25),
        reg.register("fresh_50", freshness_window(50)): freshness_window(50),
    }
    store = reg.build(cat)
    mesh = data_mesh()
    retr = SpmdRetriever(params, cfg, store, sid_length=Lm, sid_vocab=Vm,
                         beam_size=4, mesh=mesh)
    eng = SpmdServingEngine(retr, registry=reg, slots=4, prompt_width=8)
    q = RequestQueue()
    rids = [q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm,
                     constraint_id=i % 2) for i in range(9)]
    results = eng.serve(q)
    assert set(results) == set(rids) and len(q) == 0
    for r in results.values():
        valid = {tuple(x)
                 for x in cat.sids[preds[r["constraint_id"]](cat)]}
        for m, sid in enumerate(r["sids"]):
            if r["scores"][m] > NEG_INF / 2:
                assert tuple(sid) in valid, (r["constraint_id"], sid)
    # an out-of-range constraint id is rejected per-request (never clamped
    # to the wrong tenant), and the rest of the batch still serves
    bad = q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=77)
    ok = q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=1)
    res = eng.serve(q)
    assert "constraint_id 77" in res[bad]["error"] and "sids" not in res[bad]
    assert res[ok]["scores"][0] > NEG_INF / 2 and len(q) == 0


def test_spmd_retriever_rejects_cpu_trie(small_lm, rng):
    params, cfg = small_lm
    sids = make_sids(rng, 30, cfg.vocab_size, 3)
    with pytest.raises(TypeError, match="io_callback"):
        SpmdRetriever(params, cfg,
                      DecodePolicy.cpu_trie(sids, cfg.vocab_size),
                      3, cfg.vocab_size, mesh=data_mesh())
