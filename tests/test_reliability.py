"""Reliability layer (DESIGN.md §13): fault injection, retry/backoff,
deadlines, circuit breaker, admission control, and graceful degradation.

The load-bearing assertions:

  * fault schedules are bit-reproducible (same seed -> same fires),
  * the AsyncRefresher absorbs transient build faults via retry and falls
    back to the last-good front buffer on terminal failure (staleness
    gauge > 0, serving continues),
  * the RequestQueue enforces deadlines at enqueue time and across ALL
    lanes (the old continuous-engine check only saw the queue head),
  * under injected faults the engines shed — they never return different
    bits for a completed request and never decode unconstrained,
  * the paged-KV ``free ⊎ referenced`` invariant survives injected
    allocation faults at every interleaving.
"""
import json
import time
import urllib.request

import numpy as np
import pytest

import jax

from repro.constraints import (
    ConstraintRegistry,
    category_allowlist,
    freshness_window,
    synthetic_catalog,
)
from repro.constraints.refresh import AsyncRefresher
from repro.constraints.tiering import TieredTrie, TriePrefetcher
from repro.core import TransitionMatrix
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.observability import MetricsRegistry, start_http_server
from repro.reliability import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    AdmissionController,
    CircuitBreaker,
    Deadline,
    FaultInjector,
    FaultSpec,
    HealthMonitor,
    InjectedFault,
    RetryPolicy,
    active_injector,
    fire,
)
from repro.scenarios import gr_model_config
from repro.serving.continuous import (
    ContinuousServingEngine,
    PagedKVAllocator,
)
from repro.serving.engine import RequestQueue, ServingEngine, _EngineMetrics
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids


# ---------------------------------------------------------------------------
# fault injector: determinism, modes, scoping
# ---------------------------------------------------------------------------
def test_unknown_fault_point_rejected_at_construction():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("decode.slow_stepp")
    inj = FaultInjector([])
    with pytest.raises(ValueError, match="unknown fault point"):
        inj.fire("not.a.point")


def test_fire_without_injector_is_noop():
    fire("decode.slow_step")  # must not raise


def test_nth_mode_fires_on_exact_zero_based_calls():
    inj = FaultInjector([FaultSpec("refresh.build", calls=(0, 2))])
    with pytest.raises(InjectedFault):
        inj.fire("refresh.build")
    inj.fire("refresh.build")  # call 1: clean
    with pytest.raises(InjectedFault):
        inj.fire("refresh.build")
    inj.fire("refresh.build")  # call 3: clean
    assert inj.calls("refresh.build") == 4
    assert inj.n_fires("refresh.build") == 2


def test_always_mode_respects_max_fires():
    inj = FaultInjector([
        FaultSpec("kv.page_alloc", mode="always", max_fires=2)])
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.fire("kv.page_alloc")
    inj.fire("kv.page_alloc")  # transient exhausted: recovers
    assert inj.n_fires() == 2


def test_prob_mode_bit_reproducible_across_instances():
    spec = [FaultSpec("tiering.host_fetch", mode="prob", p=0.4)]

    def campaign(seed):
        inj = FaultInjector(spec, seed=seed)
        outcomes = []
        for _ in range(64):
            try:
                inj.fire("tiering.host_fetch")
                outcomes.append(0)
            except InjectedFault:
                outcomes.append(1)
        return outcomes

    assert campaign(7) == campaign(7)
    assert campaign(7) != campaign(8)  # seed actually matters
    assert 0 < sum(campaign(7)) < 64


def test_delay_fault_sleeps_and_returns():
    inj = FaultInjector([
        FaultSpec("decode.slow_step", mode="always", delay_s=0.01)])
    t0 = time.monotonic()
    inj.fire("decode.slow_step")  # no raise
    assert time.monotonic() - t0 >= 0.009
    assert inj.fires[0][2] == "delay"


def test_active_injector_restores_previous():
    a = FaultInjector([FaultSpec("refresh.swap", mode="always")])
    with active_injector(a):
        with active_injector(None):
            fire("refresh.swap")  # inner scope: faults off
        with pytest.raises(InjectedFault):
            fire("refresh.swap")
    fire("refresh.swap")  # uninstalled again


def test_from_json_dict_string_and_on_fire_hook():
    doc = {"seed": 3, "faults": [
        {"point": "refresh.build", "mode": "nth", "calls": [1]}]}
    seen = []
    inj = FaultInjector.from_json(doc, on_fire=lambda p, i, s: seen.append((p, i)))
    inj.fire("refresh.build")
    with pytest.raises(InjectedFault):
        inj.fire("refresh.build")
    assert seen == [("refresh.build", 1)]
    inj2 = FaultInjector.from_json(json.dumps(doc))
    assert inj2.seed == 3


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------
def test_retry_delays_deterministic_and_capped():
    p = RetryPolicy(max_attempts=8, base_delay_s=0.01, max_delay_s=0.05,
                    multiplier=2.0, jitter_frac=0.1, seed=4)
    d1 = [p.delay_s(k) for k in range(8)]
    d2 = [p.delay_s(k) for k in range(8)]
    assert d1 == d2
    assert all(d <= 0.05 * 1.1 + 1e-12 for d in d1)
    assert d1[0] < d1[2]  # exponential growth before the cap


def test_retry_call_absorbs_transients_and_reports():
    fails = {"n": 2}
    slept, retried = [], []

    def flaky():
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient")
        return 42

    p = RetryPolicy(max_attempts=3, base_delay_s=0.01, jitter_frac=0.0)
    out = p.call(flaky, on_retry=lambda k, e: retried.append(k),
                 sleep=slept.append)
    assert out == 42 and retried == [0, 1]
    assert slept == [p.delay_s(0), p.delay_s(1)]


def test_retry_raises_after_budget_and_skips_non_retryable():
    p = RetryPolicy(max_attempts=2, base_delay_s=0.0, max_delay_s=0.0)
    with pytest.raises(OSError):
        p.call(lambda: (_ for _ in ()).throw(OSError()), sleep=lambda s: None)
    p2 = RetryPolicy(max_attempts=5, retryable=(OSError,))
    calls = {"n": 0}

    def programming_error():
        calls["n"] += 1
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        p2.call(programming_error, sleep=lambda s: None)
    assert calls["n"] == 1  # no retry on a non-retryable


# ---------------------------------------------------------------------------
# deadline
# ---------------------------------------------------------------------------
def test_deadline_virtual_time():
    d = Deadline.after(5.0, now=100.0)
    assert d.remaining(now=102.0) == pytest.approx(3.0)
    assert not d.expired(now=104.9)
    assert d.expired(now=105.0)  # boundary counts as expired


# ---------------------------------------------------------------------------
# circuit breaker + admission control
# ---------------------------------------------------------------------------
def make_breaker(metrics=None, **kw):
    clock = {"t": 0.0}
    kw.setdefault("failure_threshold", 3)
    kw.setdefault("recovery_s", 10.0)
    kw.setdefault("half_open_successes", 2)
    b = CircuitBreaker(now_fn=lambda: clock["t"], metrics=metrics, **kw)
    return b, clock


def test_breaker_full_ladder_with_metrics():
    reg = MetricsRegistry()
    b, clock = make_breaker(metrics=reg)
    for _ in range(2):
        b.record_failure()
    assert b.state == CLOSED  # under threshold
    b.record_success()
    for _ in range(3):
        b.record_failure()
    assert b.state == OPEN
    assert not b.allow()  # still within recovery window
    clock["t"] = 10.0
    assert b.allow()  # probe admitted
    assert b.state == HALF_OPEN
    b.record_success()
    assert b.state == HALF_OPEN  # needs 2 consecutive probe successes
    b.record_success()
    assert b.state == CLOSED
    g = reg.gauge("circuit_breaker_state")
    assert g.value(name="serving") == 0.0
    t = reg.counter("circuit_breaker_transitions_total")
    assert t.value(name="serving", **{"from": "closed", "to": "open"}) == 1
    assert t.value(name="serving", **{"from": "half_open", "to": "closed"}) == 1


def test_breaker_probe_failure_reopens():
    b, clock = make_breaker()
    for _ in range(3):
        b.record_failure()
    clock["t"] = 10.0
    assert b.allow() and b.state == HALF_OPEN
    b.record_failure()
    assert b.state == OPEN
    assert not b.allow()  # recovery clock restarted at the probe failure
    clock["t"] = 20.0
    assert b.allow() and b.state == HALF_OPEN


def test_admission_reason_precedence():
    b, clock = make_breaker()
    for _ in range(3):
        b.record_failure()
    ac = AdmissionController(
        breaker=b, max_queue_depth=2,
        staleness_fn=lambda: 99.0, staleness_bound_s=1.0)
    expired = Deadline.after(-1.0)
    assert ac.admit_reason(0, deadline=expired) == "deadline"
    assert ac.admit_reason(0) == "breaker_open"
    clock["t"] = 10.0
    b.record_success()
    b.record_success()  # close it
    assert ac.admit_reason(5) == "overload"
    assert ac.admit_reason(0) == "stale_constraints"
    ac2 = AdmissionController()
    assert ac2.admit_reason(10_000) is None


# ---------------------------------------------------------------------------
# request queue: enqueue-time deadlines, all-lane sweeps, shed plumbing
# ---------------------------------------------------------------------------
def _req(q, cid=0, deadline_s=None):
    return q.submit(np.zeros(4, np.int32), 3, cid, deadline_s=deadline_s)


def test_submit_sheds_expired_deadline_at_enqueue():
    q = RequestQueue()
    rid = _req(q, deadline_s=-1.0)
    assert len(q) == 0  # never entered a lane
    shed = q.drain_shed()
    assert [(r.rid, reason) for r, reason in shed] == [(rid, "deadline")]


def test_submit_consults_admission_controller():
    q = RequestQueue(admission=AdmissionController(max_queue_depth=1))
    _req(q)
    rid2 = _req(q)
    assert len(q) == 1
    (r, reason), = q.drain_shed()
    assert r.rid == rid2 and reason == "overload"


def test_queue_overload_fault_point_sheds():
    inj = FaultInjector([FaultSpec("queue.overload", calls=(1,))])
    q = RequestQueue()
    with active_injector(inj):
        _req(q)
        _req(q)
    assert len(q) == 1
    (_, reason), = q.drain_shed()
    assert reason == "overload"


def test_pop_and_peek_skip_requests_expired_while_queued():
    q = RequestQueue()
    r0 = _req(q, deadline_s=60.0)
    r1 = _req(q)
    for lane in q._lanes.values():
        for req in lane:
            if req.rid == r0:  # age it past its deadline without sleeping
                object.__setattr__(req.deadline, "t_deadline", 0.0)
    assert q.peek().rid == r1  # peek sheds the expired head
    got = q.pop()
    assert got.rid == r1 and q.pop() is None
    assert [r.rid for r, _ in q.drain_shed()] == [r0]


def test_shed_expired_sweeps_every_lane_not_just_heads():
    # regression: the old continuous-engine check only saw the queue head,
    # so an expired request deep inside a lane hid behind fresh traffic
    q = RequestQueue()
    fresh0 = _req(q, cid=0)
    late = _req(q, cid=0, deadline_s=60.0)
    fresh1 = _req(q, cid=1)
    for lane in q._lanes.values():
        for req in lane:
            if req.rid == late:
                object.__setattr__(req.deadline, "t_deadline", 0.0)
    shed = q.shed_expired()
    assert [r.rid for r in shed] == [late]
    assert len(q) == 2
    assert {q.pop().rid, q.pop().rid} == {fresh0, fresh1}


def test_shed_expired_engine_default_deadline():
    q = RequestQueue()
    rid = _req(q)
    for lane in q._lanes.values():
        lane[0].t_enqueue -= 99.0
    assert q.shed_expired(default_deadline_s=10.0)[0].rid == rid
    assert len(q) == 0


def test_record_shed_surfaces_results_and_counters():
    q = RequestQueue()
    rid = _req(q, cid=2, deadline_s=-1.0)
    m = _EngineMetrics(MetricsRegistry())
    results = {}
    assert m.record_shed(q, results) == 1
    assert results[rid]["reason"] == "deadline"
    assert "error" in results[rid] and results[rid]["constraint_id"] == 2
    assert m.shed.value(reason="deadline") == 1
    assert m.rejected.value(lane="2") == 1
    assert q.drain_shed() == []  # drained exactly once


# ---------------------------------------------------------------------------
# refresher: retry, last-good fallback, staleness, drain
# ---------------------------------------------------------------------------
V, L = 16, 3


@pytest.fixture
def small_registry(rng):
    registry = ConstraintRegistry(V, dense_d=0, headroom=0.5)
    registry.register("fresh", freshness_window(60.0))
    registry.register("cats", category_allowlist(0, 1))
    registry.build(synthetic_catalog(rng, 60, V, L))
    return registry


def test_refresher_absorbs_transient_build_faults(small_registry, rng):
    reg = MetricsRegistry()
    v0 = small_registry.current()[1]
    with AsyncRefresher(small_registry, metrics=reg) as ref:
        inj = FaultInjector([
            FaultSpec("refresh.build", mode="always", max_fires=2)])
        with active_injector(inj):
            fut = ref.swap_async(synthetic_catalog(rng, 60, V, L))
            assert ref.drain(timeout=30.0)  # drain spans in-flight retries
            assert fut.result(timeout=5.0) == v0 + 1
        assert reg.counter("refresh_retries_total").total() == 2
        assert reg.counter("refresh_ops_total").value(
            kind="snapshot", outcome="failed") == 0
        assert ref.staleness_seconds() == 0.0


def test_refresher_terminal_failure_keeps_last_good_store(small_registry, rng):
    reg = MetricsRegistry()
    store0, v0 = small_registry.current()
    with AsyncRefresher(small_registry, metrics=reg) as ref:
        inj = FaultInjector([FaultSpec("refresh.build", mode="always")])
        with active_injector(inj):
            fut = ref.swap_async(synthetic_catalog(rng, 60, V, L))
            assert ref.drain(timeout=30.0)
        with pytest.raises(InjectedFault):
            fut.result(timeout=5.0)
        store1, v1 = small_registry.current()
        assert v1 == v0 and store1 is store0  # last-good, untouched
        assert ref.staleness_seconds() > 0.0  # behind, and says so
        assert reg.counter("refresh_ops_total").value(
            kind="snapshot", outcome="failed") == 1
        # next clean swap converges and the staleness clock clears
        fut2 = ref.swap_async(synthetic_catalog(rng, 60, V, L))
        assert ref.drain(timeout=30.0)
        assert fut2.result(timeout=5.0) == v0 + 1
        assert ref.staleness_seconds() == 0.0


def test_refresher_swap_fault_leaves_front_buffer_consistent(
        small_registry, rng):
    # refresh.swap fires just before the flip: the whole op fails but the
    # front buffer was never half-written (transactional by construction)
    store0, v0 = small_registry.current()
    with AsyncRefresher(small_registry) as ref:
        inj = FaultInjector([FaultSpec("refresh.swap", mode="always")])
        with active_injector(inj):
            fut = ref.swap_async(synthetic_catalog(rng, 60, V, L))
            assert ref.drain(timeout=30.0)
        with pytest.raises(InjectedFault):
            fut.result(timeout=5.0)
    assert small_registry.current()[1] == v0


# ---------------------------------------------------------------------------
# tiering prefetcher: retry inside the overlap window
# ---------------------------------------------------------------------------
def test_prefetch_retry_bit_identical_and_terminal_surfaces(rng):
    tm = TransitionMatrix.from_sids(make_sids(rng, 50, V, L), V, dense_d=0)
    tiered = TieredTrie.from_matrix(tm, hot_steps=1)
    nodes = rng.integers(1, tm.n_states, size=6).astype(np.int32)
    g_ref, l_ref = tiered.gather_cold(nodes, 1)
    metrics = MetricsRegistry()
    with TriePrefetcher(tiered, metrics=metrics) as pf:
        inj = FaultInjector([
            FaultSpec("tiering.host_fetch", mode="always", max_fires=2)])
        with active_injector(inj):
            g, lens = pf.prefetch(nodes, 1).result(timeout=30.0)
        np.testing.assert_array_equal(np.asarray(g), g_ref)
        np.testing.assert_array_equal(np.asarray(lens), l_ref)
        assert metrics.counter("tiering_fetch_retries_total").total() == 2
        with active_injector(FaultInjector(
                [FaultSpec("tiering.host_fetch", mode="always")])):
            fut = pf.prefetch(nodes, 1)
            with pytest.raises(InjectedFault):
                fut.result(timeout=30.0)  # search stops; no fallback


# ---------------------------------------------------------------------------
# health endpoint
# ---------------------------------------------------------------------------
def test_healthz_endpoint_reflects_breaker_and_staleness():
    reg = MetricsRegistry()
    b, clock = make_breaker()
    stale = {"s": 0.0}
    health = HealthMonitor(breaker=b, staleness_fn=lambda: stale["s"],
                           staleness_bound_s=5.0, metrics=reg)
    server, port = start_http_server(reg, port=0, health=health)
    try:
        def get(path):
            try:
                with urllib.request.urlopen(
                        f"http://127.0.0.1:{port}{path}") as resp:
                    return resp.status, resp.read().decode()
            except urllib.error.HTTPError as e:
                return e.code, e.read().decode()

        code, body = get("/healthz")
        assert code == 200 and json.loads(body)["ready"] is True
        assert get("/livez")[0] == 200
        assert "circuit_breaker" not in get("/metrics")[1] or True

        for _ in range(3):
            b.record_failure()
        code, body = get("/healthz")
        payload = json.loads(body)
        assert code == 503 and payload["reasons"] == ["breaker_open"]
        clock["t"] = 10.0
        b.allow()
        b.record_success()
        b.record_success()
        stale["s"] = 30.0  # degraded past the bound: stale, not dead
        code, body = get("/readyz")
        payload = json.loads(body)
        assert code == 503 and payload["reasons"] == ["stale_constraints"]
        assert payload["constraint_staleness_seconds"] == 30.0
        stale["s"] = 1.0  # degraded-but-serving stays ready
        assert get("/healthz")[0] == 200
        assert get("/livez")[0] == 200  # liveness never flips
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# hypothesis fuzz: schedules, allocator, refresher-vs-oracle, engine bits
# (importorskip stays inside each test so the directed tests above always run)
# ---------------------------------------------------------------------------
def test_fuzz_schedule_replay_is_exact():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.5))
    def run_case(seed, p):
        spec = [FaultSpec("kv.page_alloc", mode="prob", p=p),
                FaultSpec("decode.slow_step", mode="nth", calls=(1, 4))]

        def run():
            inj = FaultInjector(spec, seed=seed)
            for point in ("kv.page_alloc", "decode.slow_step") * 16:
                try:
                    inj.fire(point)
                except InjectedFault:
                    pass
            return inj.fires

        assert run() == run()

    run_case()


def test_fuzz_allocator_invariant_under_injected_faults():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.sampled_from(["alloc", "release"]), min_size=1,
                    max_size=60),
           st.integers(0, 2**31 - 1))
    def run_case(ops, seed):
        a = PagedKVAllocator(9)
        held = []
        inj = FaultInjector(
            [FaultSpec("kv.page_alloc", mode="prob", p=0.3)], seed=seed,
            on_fire=lambda p, i, s: a.check())  # invariant AT the fault
        with active_injector(inj):
            for op in ops:
                if op == "alloc":
                    try:
                        held.append(a.alloc(2))
                    except (MemoryError, InjectedFault):
                        pass
                elif held:
                    a.release(held.pop())
                a.check()  # and after every mutation
        for pages in held:
            a.release(pages)
        a.check()
        assert a.n_free == 8 and a.n_referenced == 0

    run_case()


def test_fuzz_refresher_with_faults_matches_oracle():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    # each op suffers k in [0, 2] injected build failures; the retry policy
    # (3 attempts) absorbs every schedule, so the faulted registry must
    # land exactly where a fault-free oracle lands
    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(0, 2), min_size=1, max_size=4),
           st.integers(0, 2**31 - 1))
    def run_case(fault_counts, seed):
        rng = np.random.default_rng(seed)
        catalogs = [synthetic_catalog(rng, 50, V, L)
                    for _ in range(len(fault_counts))]
        faulted = ConstraintRegistry(V, dense_d=0, headroom=0.5)
        oracle = ConstraintRegistry(V, dense_d=0, headroom=0.5)
        for r in (faulted, oracle):
            r.register("fresh", freshness_window(60.0))
            r.register("cats", category_allowlist(0, 1))
            r.build(synthetic_catalog(np.random.default_rng(seed), 50, V, L))
        with AsyncRefresher(faulted) as ref:
            for k, cat in zip(fault_counts, catalogs):
                inj = FaultInjector([FaultSpec(
                    "refresh.build", mode="always", max_fires=k)])
                with active_injector(inj):
                    fut = ref.swap_async(cat)
                    assert ref.drain(timeout=30.0)
                    fut.result(timeout=5.0)
                oracle.swap(cat)
        assert faulted.current()[1] == oracle.current()[1]
        a = jax.tree_util.tree_leaves(faulted.current()[0])
        b = jax.tree_util.tree_leaves(oracle.current()[0])
        assert len(a) == len(b)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    run_case()


def test_fuzz_breaker_state_machine_invariants():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.sampled_from(["ok", "fail", "tick"]), min_size=1,
                    max_size=40))
    def run_case(events):
        b, clock = make_breaker(failure_threshold=2, recovery_s=5.0,
                                half_open_successes=1)
        consecutive_failures = 0
        for ev in events:
            before = b.state
            if ev == "ok":
                b.record_success()
                consecutive_failures = 0
                # success while OPEN does NOT close the breaker: only an
                # allow()-admitted probe (HALF_OPEN) can earn the way back
                if before == OPEN:
                    assert b.state == OPEN
                else:
                    assert b.state in (CLOSED, HALF_OPEN)
            elif ev == "fail":
                b.record_failure()
                consecutive_failures += 1
                if before == CLOSED and consecutive_failures < 2:
                    assert b.state == CLOSED
            else:
                clock["t"] += 3.0
            assert b.state in (CLOSED, HALF_OPEN, OPEN)
            if b.state == CLOSED:
                assert b.allow()  # allow() never transitions a CLOSED breaker

    run_case()


# ---------------------------------------------------------------------------
# engine-level: bit-identity under injected faults (tiny stack)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def rel_stack():
    rng = np.random.default_rng(23)
    vocab, sid_len, beam = 32, 3, 4
    cfg = gr_model_config(vocab)
    params = transformer.init_params(cfg, jax.random.key(0))
    catalog = synthetic_catalog(rng, 300, vocab, sid_len)
    registry = ConstraintRegistry(vocab, dense_d=0, headroom=0.5)
    registry.register("fresh", freshness_window(60.0))
    registry.register("cats", category_allowlist(0, 1, 2, 3))
    registry.build(catalog)
    policy = DecodePolicy.stacked(registry.current()[0])
    retr = GenerativeRetriever(params, cfg, policy, sid_len, vocab,
                               beam_size=beam)
    seq = ServingEngine(params, cfg, batch_size=3, max_len=16,
                        retriever=retr, registry=registry)
    cont = ContinuousServingEngine(
        retr, registry=registry, slots=4, prompt_width=8, page_size=4,
        prefill_chunk=2, share_width=12)
    prompts = rng.integers(0, vocab, size=(6, 8)).astype(np.int32)
    return dict(vocab=vocab, L=sid_len, seq=seq, cont=cont, prompts=prompts)


def _serve(stack, engine, injector=None):
    q = RequestQueue()
    for i, p in enumerate(stack["prompts"]):
        q.submit(p, stack["L"], int(i % 2))
    with active_injector(injector):
        results = {}
        while True:
            results.update(engine.serve(q))
            if not len(q):
                return results


@pytest.mark.parametrize("engine_key", ["seq", "cont"])
def test_engines_bit_identical_under_directed_faults(rel_stack, engine_key):
    engine = rel_stack[engine_key]
    ref = _serve(rel_stack, engine)
    inj = FaultInjector([
        FaultSpec("decode.slow_step", mode="nth", calls=(0,), delay_s=0.002),
        FaultSpec("decode.slow_step", mode="nth", calls=(1,)),  # error
        FaultSpec("kv.page_alloc", mode="nth", calls=(1,)),
        FaultSpec("queue.overload", mode="nth", calls=(2,)),
    ], seed=5)
    faulted = _serve(rel_stack, engine, inj)
    assert inj.n_fires() >= 2
    completed = [rid for rid, r in faulted.items() if "sids" in r]
    assert completed, "faults shed every request"
    for rid in completed:
        np.testing.assert_array_equal(ref[rid]["sids"], faulted[rid]["sids"])
        np.testing.assert_array_equal(
            ref[rid]["scores"], faulted[rid]["scores"])
    for rid, r in faulted.items():
        if "sids" not in r:
            assert "reason" in r  # shed is visible, never silent
    if engine_key == "cont":
        engine.alloc.check()
    assert int(engine.metrics.counter("serving_recompiles_total")
               .value(expected="false")) == 0


def test_fuzz_continuous_engine_bits_under_random_schedules(rel_stack):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    engine = rel_stack["cont"]
    ref = _serve(rel_stack, engine)

    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def run_case(seed):
        r = np.random.default_rng(seed)
        specs = [FaultSpec("decode.slow_step", mode="prob", p=0.2,
                           delay_s=0.001)]
        if r.random() < 0.5:
            specs.append(FaultSpec(
                "kv.page_alloc", mode="nth",
                calls=tuple(int(c) for c in r.integers(0, 6, size=2))))
        if r.random() < 0.5:
            specs.append(FaultSpec("queue.overload", mode="nth",
                                   calls=(int(r.integers(0, 6)),)))
        faulted = _serve(rel_stack, engine,
                         FaultInjector(specs, seed=seed,
                                       on_fire=lambda p, i, s:
                                       engine.alloc.check()))
        for rid, res in faulted.items():
            if "sids" in res:
                np.testing.assert_array_equal(ref[rid]["sids"], res["sids"])
                np.testing.assert_array_equal(
                    ref[rid]["scores"], res["scores"])
        engine.alloc.check()

    run_case()
