"""Serving: engine greedy generation, continuous batching, constrained GR."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.models import transformer
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    return params, cfg


def test_engine_matches_manual_greedy(small_lm):
    params, cfg = small_lm
    B, S, n_new = 2, 6, 4
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    eng = ServingEngine(params, cfg, batch_size=B, max_len=S + n_new + 1)
    got = eng.generate(prompts, n_new)
    # manual teacher-forced reference using full forwards
    toks = prompts.copy()
    want = []
    for _ in range(n_new):
        x, _, _ = transformer.forward(params, jnp.asarray(toks), cfg)
        w = params["unemb"]
        logits = np.asarray((x[:, -1, :] @ w).astype(jnp.float32))
        nxt = logits.argmax(-1).astype(np.int32)
        want.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)
    np.testing.assert_array_equal(got, want)


def test_continuous_batching_drains_queue(small_lm):
    params, cfg = small_lm
    eng = ServingEngine(params, cfg, batch_size=2, max_len=32)
    q = RequestQueue()
    rng = np.random.default_rng(1)
    rids = [
        q.submit(rng.integers(0, cfg.vocab_size, (5,)), n_tokens=3)
        for _ in range(5)
    ]
    results = eng.serve(q)
    assert len(q) == 0
    assert set(results) == set(rids)
    assert all(len(v) == 3 for v in results.values())


def test_generative_retriever_100pct_compliance(small_lm, rng):
    params, cfg = small_lm
    V, L = cfg.vocab_size, 4
    sids = make_sids(rng, 40, V, L, clustered=True)
    tm = TransitionMatrix.from_sids(sids, V)
    gr = GenerativeRetriever(params, cfg, tm, sid_length=L, sid_vocab=V,
                             beam_size=6)
    hist = rng.integers(0, V, (3, 8)).astype(np.int32)
    beams, scores = gr.retrieve(hist)
    assert beams.shape == (3, 6, L)
    valid = {tuple(r) for r in sids}
    for b in range(3):
        for m in range(6):
            if scores[b, m] > NEG_INF / 2:
                assert tuple(beams[b, m]) in valid


def test_request_queue_fairness_mixed_constraint_slots():
    """A tenant bursting the queue must not monopolize batched admission:
    pop rotates across constraint-id lanes, FIFO within a lane."""
    q = RequestQueue()
    p = np.zeros(4, np.int32)
    burst = [q.submit(p, 1, constraint_id=0) for _ in range(6)]
    late = [q.submit(p, 1, constraint_id=1) for _ in range(2)]
    assert len(q) == 8
    batch = q.pop_batch(4)
    # the first batch already mixes both tenants (strict FIFO would have
    # admitted four constraint-0 requests and starved tenant 1 for batches)
    assert [r.constraint_id for r in batch] == [0, 1, 0, 1]
    # arrival order preserved within each lane
    assert [r.rid for r in batch if r.constraint_id == 0] == burst[:2]
    assert [r.rid for r in batch if r.constraint_id == 1] == late
    rest = q.pop_batch(10)
    assert [r.rid for r in rest] == burst[2:] and len(q) == 0
    assert q.pop() is None


def test_request_queue_single_tenant_is_fifo():
    q = RequestQueue()
    p = np.zeros(4, np.int32)
    rids = [q.submit(p, 1) for _ in range(5)]
    assert [q.pop().rid for _ in range(5)] == rids


def test_generative_retriever_unconstrained_vs_constrained_scores(small_lm, rng):
    """Constrained top beam score <= unconstrained top beam score."""
    params, cfg = small_lm
    V, L = cfg.vocab_size, 3
    sids = make_sids(rng, 30, V, L)
    tm = TransitionMatrix.from_sids(sids, V)
    hist = rng.integers(0, V, (2, 8)).astype(np.int32)
    g_c = GenerativeRetriever(params, cfg, tm, L, V, beam_size=4)
    g_u = GenerativeRetriever(params, cfg, None, L, V, beam_size=4)
    _, s_c = g_c.retrieve(hist)
    _, s_u = g_u.retrieve(hist)
    assert (s_c[:, 0] <= s_u[:, 0] + 1e-4).all()
