"""Regenerate the golden-trace regression fixtures (DESIGN.md §6).

    PYTHONPATH=src python tests/golden/regenerate.py

Writes, next to this script:
  * ``inputs.npz``      — the frozen corpus (SIDs, decoy SIDs for the
                          stacked store) and the step-dependent logits table;
  * ``trie_small.npz``  — the serialized :class:`TransitionMatrix` built
                          from the corpus (catches save/load + builder
                          drift);
  * ``traces.npz``      — per backend: final top-M SIDs/scores AND the full
                          per-step beam trace (``beam_search``'s
                          ``return_trace``), so cross-backend drift is
                          caught at the step where it first diverges —
                          without recomputing the host-trie oracle.

Run this ONLY when an intentional semantic change invalidates the goldens,
and say so in the commit message.
"""
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

import jax.numpy as jnp  # noqa: E402

from repro.constraints import ConstraintStore  # noqa: E402
from repro.core import TransitionMatrix, beam_search  # noqa: E402
from repro.decoding import DecodePolicy  # noqa: E402

HERE = pathlib.Path(__file__).resolve().parent
V, L, B, M = 12, 4, 2, 4
SEED = 20260731


def build_inputs():
    rng = np.random.default_rng(SEED)
    heads = rng.integers(0, V, size=(6, 2))
    sids = np.unique(np.concatenate(
        [heads[rng.integers(0, 6, size=40)],
         rng.integers(0, V, size=(40, L - 2))], axis=1
    ).astype(np.int64), axis=0)
    decoy = np.unique(
        rng.integers(0, V, size=(15, L)).astype(np.int64), axis=0)
    table = rng.normal(size=(L, V, V)).astype(np.float32)
    return sids, decoy, table


def policies(sids, decoy, tm):
    store = ConstraintStore.from_matrices(
        [TransitionMatrix.from_sids(decoy, V, dense_d=2), tm], headroom=0.2)
    return {
        "static": (DecodePolicy.static(tm), False),
        "static_fused": (DecodePolicy.static(tm, fused=True), False),
        "static_d0": (DecodePolicy.static(
            TransitionMatrix.from_sids(sids, V, dense_d=0)), False),
        "stacked": (DecodePolicy.stacked(store), True),  # rows -> member 1
        "ppv_exact": (DecodePolicy.ppv(sids, V, exact=True), False),
        "cpu_trie": (DecodePolicy.cpu_trie(sids, V), False),
        "hash_bitmap": (DecodePolicy.hash_bitmap(sids, V, log2_bits=22),
                        False),
    }


def run_traced(policy, table, stacked):
    def logits_fn(carry, last, step):
        return jnp.asarray(table)[step][last], carry

    cids = jnp.ones((B,), jnp.int32) if stacked else None
    state, _, trace = beam_search(
        logits_fn, None, B, M, L, policy, constraint_ids=cids,
        return_trace=True,
    )
    return (np.asarray(state.tokens), np.asarray(state.scores),
            np.asarray(trace.tokens), np.asarray(trace.scores))


def main():
    sids, decoy, table = build_inputs()
    np.savez_compressed(HERE / "inputs.npz", sids=sids, decoy=decoy,
                        table=table)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=2)
    tm.save(HERE / "trie_small.npz")
    out = {}
    for name, (policy, stacked) in policies(sids, decoy, tm).items():
        tokens, scores, tr_tokens, tr_scores = run_traced(
            policy, table, stacked)
        out[f"{name}_tokens"] = tokens
        out[f"{name}_scores"] = scores
        out[f"{name}_trace_tokens"] = tr_tokens
        out[f"{name}_trace_scores"] = tr_scores
        print(f"{name}: top-1 {tokens[0, 0].tolist()} "
              f"score {scores[0, 0]:.4f}")
    np.savez_compressed(HERE / "traces.npz", **out)
    print(f"wrote {HERE / 'inputs.npz'}, {HERE / 'trie_small.npz'}, "
          f"{HERE / 'traces.npz'}")


if __name__ == "__main__":
    main()
