"""Unit tests for the offline trie builder and CSR flattening."""
import numpy as np
import pytest

from repro.core.trie import build_flat_trie, pack_bits, unpack_bits_word
from conftest import make_sids


def brute_force_children(sids, prefix):
    """Oracle: set of valid next tokens after `prefix`."""
    t = len(prefix)
    out = set()
    for row in sids:
        if tuple(row[:t]) == tuple(prefix):
            out.add(int(row[t]))
    return out


def test_paper_figure1_example():
    # V = {1,2,3} (vocab_size 4 incl. token 0), L=3,
    # C = {(1,2,1), (3,1,2), (3,1,3)} — the worked example of Fig. 1.
    sids = np.array([[1, 2, 1], [3, 1, 2], [3, 1, 3]])
    ft = build_flat_trie(sids, vocab_size=4, dense_d=0)
    # states: sink=0, root=1, level1: {1:2, 3:3}, level2: {12:4, 31:5},
    # level3 leaves: {121:6, 312:7, 313:8}
    assert ft.n_states == 9
    assert ft.n_edges == 7
    assert dict(ft.children(1)) == {1: 2, 3: 3}
    assert dict(ft.children(2)) == {2: 4}
    assert dict(ft.children(3)) == {1: 5}
    assert dict(ft.children(4)) == {1: 6}
    assert dict(ft.children(5)) == {2: 7, 3: 8}
    assert ft.children(6) == []  # leaf
    assert list(ft.level_bmax) == [2, 1, 2]


@pytest.mark.parametrize("n,vocab,length", [(50, 8, 3), (500, 16, 4), (2000, 32, 5)])
def test_trie_matches_bruteforce(rng, n, vocab, length):
    sids = make_sids(rng, n, vocab, length, clustered=True)
    ft = build_flat_trie(sids, vocab, dense_d=0)
    # walk every constraint through the CSR and confirm it reaches a leaf
    for row in sids[rng.choice(n, size=min(n, 64), replace=False)]:
        state = 1
        for t, tok in enumerate(row):
            trans = dict(ft.children(state))
            assert int(tok) in trans, f"missing edge at level {t}"
            state = trans[int(tok)]
        assert ft.children(state) == []  # leaf
    # spot-check children sets at random internal prefixes
    for _ in range(20):
        row = sids[rng.integers(0, sids.shape[0])]
        t = int(rng.integers(0, length - 1))
        prefix = row[: t + 1]
        state = 1
        for tok in prefix:
            state = dict(ft.children(state))[int(tok)]
        got = set(dict(ft.children(state)).keys())
        want = brute_force_children(sids, list(prefix))
        assert got == want


def test_duplicate_sids_deduped(rng):
    sids = make_sids(rng, 100, 8, 4)
    dup = np.concatenate([sids, sids[:50]], axis=0)
    a = build_flat_trie(sids, 8)
    b = build_flat_trie(dup, 8)
    assert a.n_states == b.n_states and a.n_edges == b.n_edges


def test_level_bmax_bounds_row_lengths(rng):
    sids = make_sids(rng, 300, 8, 4, clustered=True)
    ft = build_flat_trie(sids, 8, dense_d=0)
    rp = ft.row_pointers
    for lvl in range(ft.sid_length):
        lo = 1 if lvl == 0 else int(ft.level_offsets[lvl])
        hi = 2 if lvl == 0 else int(ft.level_offsets[lvl + 1])
        lens = rp[lo + 1 : hi + 1].astype(np.int64) - rp[lo:hi].astype(np.int64)
        if lens.size:
            assert lens.max() == ft.level_bmax[lvl]
            assert lens.min() >= 1  # internal nodes always have a child


def test_edges_padded_beyond_bmax(rng):
    sids = make_sids(rng, 100, 8, 4)
    ft = build_flat_trie(sids, 8)
    assert ft.edges.shape[0] >= ft.n_edges + int(ft.level_bmax.max())


def test_dense_tables_match_bruteforce(rng):
    sids = make_sids(rng, 200, 16, 4, clustered=True)
    ft = build_flat_trie(sids, 16, dense_d=2)
    l0 = unpack_bits_word(ft.l0_mask_packed, 16)
    assert set(np.nonzero(l0)[0]) == brute_force_children(sids, [])
    for tok in np.nonzero(l0)[0]:
        # virtual level-1 id convention under dense_d == 2
        assert ft.l0_states[tok] == tok + 1
        l1 = unpack_bits_word(ft.l1_mask_packed[tok], 16)
        want = brute_force_children(sids, [tok])
        assert set(np.nonzero(l1)[0]) == want
        for tok2 in want:
            # l1_states points into the trimmed CSR: its children must match
            # the brute-force 2-prefix continuation set.
            state2 = int(ft.l1_states[tok, tok2])
            assert state2 > 0
            got = set(dict(ft.children(state2)).keys())
            assert got == brute_force_children(sids, [tok, tok2])


def test_trimmed_trie_smaller(rng):
    sids = make_sids(rng, 500, 16, 5, clustered=True)
    full = build_flat_trie(sids, 16, dense_d=0)
    trimmed = build_flat_trie(sids, 16, dense_d=2)
    assert trimmed.n_states < full.n_states
    assert trimmed.n_edges < full.n_edges


@pytest.mark.parametrize("dense_d", [0, 1, 2])
@pytest.mark.parametrize("length", [2, 3, 4])
def test_dense_trim_accounting(rng, dense_d, length):
    """Levels < d_eff must NOT be double-stored: the CSR holds exactly the
    states/edges of levels >= d_eff == min(dense_d, L) — including the
    sid_length == dense_d case, where the old builder silently fell back
    to d_eff = 0 and kept every dense-covered level in the CSR on top of
    the bit-packed tables (inflating n_states against Appendix B)."""
    sids = make_sids(rng, 250, 16, length, clustered=True)
    ft = build_flat_trie(sids, 16, dense_d=dense_d)
    full = build_flat_trie(sids, 16, dense_d=0)
    d_eff = min(dense_d, length)
    # per-level unique-prefix counts from the untrimmed reference:
    # diff(level_offsets) = [root(=1), n_1, n_2, ..., n_L]
    lvl_counts = np.diff(full.level_offsets)
    want_states = (1 + int(lvl_counts[d_eff:].sum()) if d_eff
                   else full.n_states)
    want_edges = full.n_edges - int(lvl_counts[1 : d_eff + 1].sum())
    assert ft.n_states == want_states
    assert ft.n_edges == want_edges
    assert ft.row_pointers.shape == (ft.n_states + 1,)
    if d_eff == length:  # fully dense: leaves only, no CSR edges at all
        assert ft.n_edges == 0
        assert int(ft.row_pointers[-1]) == 0
    # dense tables still present whenever requested
    assert (ft.l0_mask_packed is not None) == (dense_d >= 1)
    assert (ft.l1_mask_packed is not None) == (dense_d >= 2 and length >= 2)
    # bmax is defined for every level regardless of trimming
    np.testing.assert_array_equal(ft.level_bmax, full.level_bmax)


def test_index_dtype_range_validation(rng):
    sids = make_sids(rng, 400, 16, 4)
    with pytest.raises(ValueError, match="int8"):
        build_flat_trie(sids, 16, index_dtype=np.int8)
    ft64 = build_flat_trie(sids, 16, index_dtype=np.int64)
    ft32 = build_flat_trie(sids, 16)
    assert ft64.edges.dtype == np.int64
    np.testing.assert_array_equal(ft64.edges, ft32.edges)
    np.testing.assert_array_equal(ft64.row_pointers, ft32.row_pointers)
    # vocab ids must fit the index dtype too (edges interleave tokens)
    big_vocab = rng.integers(0, 40_000, size=(50, 3))
    with pytest.raises(ValueError, match="int16"):
        build_flat_trie(big_vocab, 40_000, dense_d=0, index_dtype=np.int16)


def test_pack_unpack_roundtrip(rng):
    for n in (1, 7, 8, 9, 100, 2048):
        bits = rng.integers(0, 2, size=n).astype(bool)
        assert np.array_equal(unpack_bits_word(pack_bits(bits), n), bits)


def test_rejects_bad_input():
    with pytest.raises(ValueError):
        build_flat_trie(np.zeros((0, 4), int), 8)
    with pytest.raises(ValueError):
        build_flat_trie(np.full((3, 4), 9), vocab_size=8)
    with pytest.raises(ValueError):
        build_flat_trie(np.zeros((3, 4), int), 8, dense_d=3)
