"""HBM/host tiering (DESIGN.md §11).

Load-bearing properties: (1) tiered decoding — hot levels on device, cold
levels host-gathered and prefetched — is bit-identical to the untiered
:func:`beam_search` at EVERY split point, with and without the compressed
slab and the candidate-topk path; (2) the budget-driven split picks the
deepest boundary that fits and the byte accounting is exact; (3) the host
gather reproduces the oracle's ``mode="fill"`` speculative window.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constraints import ConstraintStore
from repro.constraints.tiering import (
    TieredTrie,
    TriePrefetcher,
    tiered_beam_search,
    vntk_pregathered,
)
from repro.core import TransitionMatrix, beam_search
from repro.decoding import DecodePolicy
from conftest import make_sids

V, L = 23, 6


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(17)
    sids = np.unique(make_sids(rng, 200, V, L, clustered=True), axis=0)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    table = jnp.asarray(rng.normal(size=(L, V, V)).astype(np.float32))
    return sids, tm, table


def table_logits_fn(table):
    def fn(carry, last, step):
        return table[step][last], carry
    return fn


def run_untiered(tm, table, policy=None, batch=3, beams=5):
    pol = DecodePolicy.static(tm) if policy is None else policy
    state, _ = beam_search(table_logits_fn(table), None, batch, beams, L, pol)
    return np.asarray(state.tokens), np.asarray(state.scores)


# ---------------------------------------------------------------------------
# bit-identity across every split point x compressed x topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("topk", [False, True])
@pytest.mark.parametrize("hot_steps", [1, 3, L])
def test_tiered_search_bit_identical(corpus, compressed, topk, hot_steps):
    _, tm, table = corpus
    want_t, want_s = run_untiered(
        tm, table, DecodePolicy.static(tm, topk=topk, compressed=compressed))
    tiered = TieredTrie.from_matrix(tm, hot_steps=hot_steps)
    assert tiered.hot_steps == max(hot_steps, tm.dense_d)
    state, _ = tiered_beam_search(
        table_logits_fn(table), None, 3, 5, L, tiered,
        policy=tiered.hot_policy(topk=topk, compressed=compressed))
    np.testing.assert_array_equal(np.asarray(state.tokens), want_t)
    np.testing.assert_array_equal(np.asarray(state.scores), want_s)


def test_prefetcher_reuse_across_searches(corpus):
    """A long-lived prefetcher (serving reuses one across requests) must
    not leak state between searches."""
    _, tm, table = corpus
    want_t, want_s = run_untiered(tm, table)
    tiered = TieredTrie.from_matrix(tm, hot_steps=2)
    with TriePrefetcher(tiered) as pf:
        for _ in range(2):
            state, _ = tiered_beam_search(
                table_logits_fn(table), None, 3, 5, L, tiered,
                prefetcher=pf)
            np.testing.assert_array_equal(np.asarray(state.tokens), want_t)
            np.testing.assert_array_equal(np.asarray(state.scores), want_s)


# ---------------------------------------------------------------------------
# split selection + byte accounting
# ---------------------------------------------------------------------------
def test_budget_driven_split_and_tier_bytes(corpus):
    _, tm, _ = corpus
    edges_nb = int(np.asarray(tm.edges).nbytes)
    fixed = tm.nbytes() - edges_nb
    # no budget / no hot_steps: fully resident
    full = TieredTrie.from_matrix(tm)
    assert full.hot_steps == L and full.edges_cold.shape[0] == 0
    assert full.tier_bytes()["host_bytes"] == 0
    # a budget below even the fixed cost clamps to the dense band
    tiny = TieredTrie.from_matrix(tm, hbm_budget=fixed)
    assert tiny.hot_steps == tm.dense_d
    # mid budget: the chosen boundary fits, one level deeper does not
    mid = TieredTrie.from_matrix(tm, hbm_budget=fixed + edges_nb // 2)
    tb = mid.tier_bytes()
    assert tm.dense_d <= mid.hot_steps < L
    assert tb["hbm_bytes"] <= fixed + edges_nb // 2
    deeper = int(mid.blocks.edge_offsets[mid.hot_steps + 1]) * 8
    assert fixed + deeper > fixed + edges_nb // 2
    # hot + cold cover exactly the real edges
    assert tb["cold_base"] * 8 + tb["host_bytes"] == tm.n_edges * 8


def test_gather_cold_matches_oracle_window(corpus):
    """The host gather must equal the zero-filled speculative window the
    device oracle reads — including rows whose window straddles the
    hot/cold boundary or runs past the slab end."""
    _, tm, _ = corpus
    tiered = TieredTrie.from_matrix(tm, hot_steps=2)
    step = 3
    bmax = max(tm.bmax_for_step(step), 1)
    rng = np.random.default_rng(5)
    lo, hi = int(tiered.blocks.state_offsets[step]), int(
        tiered.blocks.state_offsets[step + 1])
    nodes = rng.integers(lo, hi, size=(9,))
    g, lens = tiered.gather_cold(nodes, step)
    rp = np.asarray(tm.row_pointers, dtype=np.int64)
    edges = np.asarray(tm.edges, dtype=np.int32)
    for i, n in enumerate(nodes):
        assert lens[i] == rp[n + 1] - rp[n]
        for j in range(bmax):
            e = rp[n] + j
            want = (edges[e] if tiered.cold_base <= e < tm.n_edges
                    else np.zeros(2, np.int32))
            np.testing.assert_array_equal(g[i, j], want, err_msg=f"{i},{j}")
    with pytest.raises(ValueError, match="hot"):
        tiered.gather_cold(nodes, 0)


def test_vntk_pregathered_matches_reference(corpus):
    from repro.core.vntk import vntk_xla

    _, tm, _ = corpus
    tiered = TieredTrie.from_matrix(tm, hot_steps=2)
    step = 4
    bmax = max(tm.bmax_for_step(step), 1)
    rng = np.random.default_rng(6)
    lo, hi = int(tiered.blocks.state_offsets[step]), int(
        tiered.blocks.state_offsets[step + 1])
    nodes = jnp.asarray(rng.integers(lo, hi, size=(7,)), jnp.int32)
    lp = jnp.asarray(rng.normal(size=(7, V)).astype(np.float32))
    g, lens = tiered.gather_cold(np.asarray(nodes), step)
    got_lp, got_nx = vntk_pregathered(lp, jnp.asarray(g), jnp.asarray(lens), V)
    want_lp, want_nx = vntk_xla(lp, nodes, tm, bmax)
    np.testing.assert_array_equal(np.asarray(got_lp), np.asarray(want_lp))
    np.testing.assert_array_equal(np.asarray(got_nx), np.asarray(want_nx))


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
def test_tiering_rejects_stacked_and_pallas(corpus):
    _, tm, _ = corpus
    store = ConstraintStore.from_matrices([tm, tm])
    with pytest.raises(NotImplementedError, match="single TransitionMatrix"):
        TieredTrie.from_matrix(store)
    tiered = TieredTrie.from_matrix(tm, hot_steps=2)
    with pytest.raises(ValueError, match="pallas"):
        tiered.hot_policy(impl="pallas")
