"""Candidate-compressed decoding (DESIGN.md §8): unit + serving coverage.

The bit-exactness of the compressed path against the dense one is asserted
at scale in ``test_differential_fuzz`` / ``test_golden_traces``; this module
covers the contract pieces around it: the C sizing rule, the policy surface
(``supports_topk_at`` / ``step_topk`` / ``with_topk``), the HBM-traffic
model, registry hot-swaps staying zero-recompile under a topk plan, and the
retriever serving end-to-end through the compressed branch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.constraints import ConstraintStore
from repro.core import TransitionMatrix, beam_search
from repro.core.memory_model import decode_step_traffic
from repro.core.vntk import candidate_width
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    return params, cfg


# ---------------------------------------------------------------------------
# C sizing rule
# ---------------------------------------------------------------------------
def test_candidate_width_rule():
    # lane-rounded beam count, capped at V
    assert candidate_width(70, 2048, lane=128) == 128
    assert candidate_width(140, 32768, lane=128) == 256
    assert candidate_width(6, 2048, lane=8) == 8
    assert candidate_width(6, 5, lane=8) == 5  # V-cap: full dense row
    assert candidate_width(1, 1, lane=8) == 1
    # C >= min(M, V): the losslessness precondition (DESIGN.md §8)
    for m in (1, 3, 17, 140):
        for v in (2, 9, 2048):
            for lane in (8, 128):
                assert candidate_width(m, v, lane) >= min(m, v)
                assert candidate_width(m, v, lane) <= v


def test_policy_candidate_width_follows_impl_lane():
    sids = np.unique(np.random.default_rng(0).integers(
        0, 300, size=(50, 4)).astype(np.int64), axis=0)
    tm = TransitionMatrix.from_sids(sids, 300, dense_d=1)
    assert DecodePolicy.static(tm).candidate_width(6, 2) == 8  # xla sublane
    assert DecodePolicy.static(tm, impl="pallas").candidate_width(6, 2) == 128


# ---------------------------------------------------------------------------
# policy surface
# ---------------------------------------------------------------------------
def _toy(dense_d=1, V=24, L=4, seed=0):
    rng = np.random.default_rng(seed)
    sids = np.unique(rng.integers(0, V, size=(60, L)).astype(np.int64), axis=0)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=dense_d)
    return tm, sids, rng


def test_supports_topk_per_level_and_families():
    tm, sids, _ = _toy(dense_d=2)
    p = DecodePolicy.static(tm)
    assert [p.supports_topk_at(s) for s in range(4)] == [
        False, False, True, True]  # dense band opts out
    assert not any(
        DecodePolicy.static(tm, topk=False).supports_topk_at(s)
        for s in range(4))
    for baseline in (DecodePolicy.ppv(sids, 24),
                     DecodePolicy.hash_bitmap(sids, 24),
                     DecodePolicy.cpu_trie(sids, 24),
                     DecodePolicy.unconstrained()):
        assert not baseline.supports_topk_at(0)  # fall back to dense


def test_supports_topk_flag_is_the_opt_out():
    """The protocol's ``supports_topk`` flag must gate the candidate branch
    even when a backend exposes a ``topk_at`` method.  Since the sharded
    candidate-topk merge (DESIGN.md §11), ``RowShardedStatic`` *supports*
    the branch — the wrapper's ``topk_at`` must track the inner backend
    step-for-step, and ``with_topk(False)`` on the inner backend must still
    opt the wrapped policy out (the flag, not the method, is the gate)."""
    from repro.distributed.constraint_sharding import RowShardedStatic

    tm, _, _ = _toy(dense_d=1)
    policy = DecodePolicy.static(tm)
    inner = policy.backends[1]  # the sparse StaticBackend
    assert inner.topk_at(2)
    wrapped = RowShardedStatic(inner=inner)
    p = DecodePolicy.per_level((wrapped,), (0,) * 4)
    assert [p.supports_topk_at(s) for s in range(4)] == \
        [policy.supports_topk_at(s) for s in range(4)]
    assert p.supports_topk_at(2)
    # the opt-out still wins over the delegated topk_at method
    assert not any(p.with_topk(False).supports_topk_at(s) for s in range(4))


def test_step_topk_rejects_dense_band_and_missing_ids():
    tm, _, rng = _toy(dense_d=2)
    p = DecodePolicy.static(tm)
    lp = jnp.zeros((3, 24), jnp.float32)
    nodes = jnp.ones((3,), jnp.int32)
    with pytest.raises(ValueError, match="no candidate-compressed backend"):
        p.step_topk(lp, nodes, 0, 8)  # dense band
    with pytest.raises(ValueError, match="no candidate-compressed backend"):
        p.with_topk(False).step_topk(lp, nodes, 2, 8)
    store = ConstraintStore.from_matrices([tm, tm])
    with pytest.raises(ValueError, match="constraint_ids"):
        DecodePolicy.stacked(store).step_topk(lp, nodes, 2, 8)


def test_with_topk_changes_structure_but_swap_does_not():
    tm, _, _ = _toy()
    p = DecodePolicy.static(tm)
    s_on = jax.tree_util.tree_structure(p)
    assert jax.tree_util.tree_structure(p.with_topk(False)) != s_on
    assert jax.tree_util.tree_structure(p.with_constraints(tm)) == s_on


def test_describe_reports_topk():
    tm, _, _ = _toy()
    assert "+topk" in DecodePolicy.static(tm).describe()
    assert "+topk" not in DecodePolicy.static(tm, topk=False).describe()


# ---------------------------------------------------------------------------
# HBM traffic model
# ---------------------------------------------------------------------------
def test_decode_step_traffic_model():
    t = decode_step_traffic(2048, 2, 70, lane=128)
    assert t["width"] == 128
    # dense writes two (B*M, V) int32/f32 tensors
    assert t["dense_write_bytes"] == 140 * 2048 * 8
    # candidate writes three (B*M, C) tensors
    assert t["candidate_write_bytes"] == 140 * 128 * 12
    assert t["compression_ratio"] > 10
    # the win grows linearly with V while C stays pinned
    t2 = decode_step_traffic(32768, 2, 70, lane=128)
    assert t2["width"] == 128
    assert t2["compression_ratio"] > 15 * t["compression_ratio"] / 16


def test_decode_step_traffic_matches_array_sizes():
    """Model vs reality: the modeled write bytes equal the nbytes of the
    tensors each path actually materializes per step."""
    V, B, M = 512, 2, 6
    tm, _, _ = _toy(dense_d=0, V=V)

    p = DecodePolicy.static(tm)
    C = p.candidate_width(M, 0)
    lp = jnp.zeros((B, M, V), jnp.float32)
    nodes = jnp.ones((B, M), jnp.int32)
    d_lp, d_nx = p.step(lp, nodes, 0, normalized=True)
    sc, tok, nx = p.step_topk(lp, nodes, 0, C, normalized=True)
    t = decode_step_traffic(V, B, M, width=C)
    assert d_lp.nbytes + d_nx.nbytes == t["dense_write_bytes"]
    assert sc.nbytes + tok.nbytes + nx.nbytes == t["candidate_write_bytes"]


# ---------------------------------------------------------------------------
# hot-swap invariance under a topk plan
# ---------------------------------------------------------------------------
def test_hot_swap_zero_recompile_with_topk_plan(rng):
    """A jitted candidate-compressed beam step keyed on the policy must be
    reused as-is across a store hot-swap (same envelope, new leaves)."""
    V, L, K = 32, 4, 2
    mats = [
        TransitionMatrix.from_sids(make_sids(rng, 120, V, L, clustered=True),
                                   V, dense_d=1)
        for _ in range(K)
    ]
    store = ConstraintStore.from_matrices(mats, headroom=0.5)
    policy = DecodePolicy.stacked(store)
    assert policy.supports_topk_at(L - 1)
    table = jnp.asarray(rng.normal(size=(L, V, V)).astype(np.float32))
    cids = jnp.asarray([0, 1, 0], jnp.int32)

    @jax.jit
    def decode(pol):
        def logits_fn(carry, last, step):
            return table[step][last], carry

        state, _ = beam_search(logits_fn, None, 3, 5, L, pol,
                               constraint_ids=cids)
        return state.tokens, state.scores

    decode(policy)  # compile once
    swapped = policy.with_constraints(
        store.with_member(
            0,
            TransitionMatrix.from_sids(
                make_sids(rng, 130, V, L, clustered=True), V, dense_d=1),
        )
    )
    assert (jax.tree_util.tree_structure(swapped)
            == jax.tree_util.tree_structure(policy))
    compiles = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: compiles.append(name)
        if "backend_compile" in name else None
    )
    decode(swapped)
    assert len(compiles) == 0, f"topk hot-swap recompiled: {compiles}"


# ---------------------------------------------------------------------------
# serving end-to-end through the compressed branch
# ---------------------------------------------------------------------------
def test_retriever_candidate_path_matches_dense(small_lm, rng):
    """GenerativeRetriever with the default (topk) policy returns exactly
    the SIDs/scores of a dense-only retriever over the same model."""
    params, cfg = small_lm
    V, L = cfg.vocab_size, 4
    sids = make_sids(rng, 200, V, L, clustered=True)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    hist = rng.integers(0, V, size=(2, 6)).astype(np.int32)
    r_topk = GenerativeRetriever(
        params, cfg, DecodePolicy.static(tm), sid_length=L, sid_vocab=V,
        beam_size=5)
    r_dense = GenerativeRetriever(
        params, cfg, DecodePolicy.static(tm, topk=False), sid_length=L,
        sid_vocab=V, beam_size=5)
    assert r_topk.policy.supports_topk_at(L - 1)
    t_beams, t_scores = r_topk.retrieve(hist)
    d_beams, d_scores = r_dense.retrieve(hist)
    np.testing.assert_array_equal(t_beams, d_beams)
    np.testing.assert_allclose(t_scores, d_scores, rtol=1e-6, atol=1e-6)
    # 100% compliance: every emitted SID is in the corpus
    valid = {tuple(r) for r in sids}
    from repro.core.vntk import NEG_INF
    for b in range(t_beams.shape[0]):
        for m in range(t_beams.shape[1]):
            if t_scores[b, m] > NEG_INF / 2:
                assert tuple(t_beams[b, m]) in valid
