import os

# Smoke tests and benches must see exactly ONE device; only launch/dryrun.py
# sets xla_force_host_platform_device_count (before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_sids(rng, n, vocab, length, clustered=False):
    """Random constraint set; optionally clustered to mimic SID collisions."""
    if not clustered:
        return rng.integers(0, vocab, size=(n, length), dtype=np.int64)
    n_clusters = max(1, n // 8)
    heads = rng.integers(0, vocab, size=(n_clusters, max(1, length // 2)))
    idx = rng.integers(0, n_clusters, size=n)
    tails = rng.integers(0, vocab, size=(n, length - heads.shape[1]))
    return np.concatenate([heads[idx], tails], axis=1).astype(np.int64)
