"""Checkpointing: atomic write, restore, prune, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ck


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32),
                   "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
        "opt": {"m": {"w": jnp.zeros((4, 4))}},
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 7, t)
    assert ck.latest_step(str(tmp_path)) == 7
    template = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    r = ck.restore(str(tmp_path), 7, template)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_partial_files_on_disk(tmp_path):
    ck.save(str(tmp_path), 1, tree())
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)


def test_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, {"w": jnp.zeros((5,))})


def test_prune_keeps_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, {"w": jnp.zeros((2,))})
    ck.prune(str(tmp_path), keep=2)
    steps = sorted(
        int(f[5:-4]) for f in os.listdir(tmp_path) if f.endswith(".npz")
    )
    assert steps == [4, 5]


def test_async_checkpointer(tmp_path):
    c = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    t = tree()
    for s in (10, 20, 30):
        c.save(s, t)
    c.wait()
    assert ck.latest_step(str(tmp_path)) == 30


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto an explicit device placement (the re-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ck.save(str(tmp_path), 3, t)
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    r = ck.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, t), sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
