"""Differential fuzzing across every DecodePolicy backend (DESIGN.md §6).

The host pointer-chasing trie (``CpuTrieBackend``) is the semantics oracle:
whatever corpus shape the generator produces — depth, branch factor, vocab,
dense depth — every exact device backend must (1) admit the *same token set*
with the *same masked log-probs* at every step along random prefixes, and
(2) return the *same top-M SIDs and scores* from the full beam search.  SPMD
decoding over the mesh must additionally be **bit-identical** to
single-device decoding (scores included: the fuzz scorer is a pure gather,
so there is no reassociation wiggle room).

Cases are seeded ``numpy`` draws (always run, deterministic); when
``hypothesis`` is installed a property-based variant drives the same
differential harness from minimized counterexamples.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constraints import ConstraintStore
from repro.core import TransitionMatrix, beam_search
from repro.core.vntk import NEG_INF
from repro.decoding import DecodePolicy
from repro.distributed.constraint_sharding import spmd_beam_search
from repro.distributed.sharding import dp_size
from repro.launch.mesh import make_subset_mesh

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: seeded fuzz still runs
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# case generator: random tries / corpora of varying shape
# ---------------------------------------------------------------------------
def make_case(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    V = int(rng.integers(6, 25))
    L = int(rng.integers(2, 6))
    n = int(rng.integers(8, 260))
    dense_d = int(rng.choice([0, 1, 2]))
    if rng.random() < 0.5:  # clustered: shared heads => deep shared prefixes
        n_heads = max(1, n // 6)
        heads = rng.integers(0, V, size=(n_heads, max(1, L // 2)))
        tails = rng.integers(0, V, size=(n, L - heads.shape[1]))
        sids = np.concatenate(
            [heads[rng.integers(0, n_heads, size=n)], tails], axis=1)
    else:
        sids = rng.integers(0, V, size=(n, L))
    sids = np.unique(sids.astype(np.int64), axis=0)
    table = rng.normal(size=(L, V, V)).astype(np.float32)
    return dict(seed=seed, V=V, L=L, dense_d=min(dense_d, L), sids=sids,
                table=jnp.asarray(table))


def exact_policies(case, tm=None) -> dict:
    """Every backend family that must match the oracle exactly.

    ``tm`` overrides the STATIC matrix (the refresh tests pass one built
    through ``TrieSource.apply_delta`` instead of from scratch).
    """
    sids, V, L = case["sids"], case["V"], case["L"]
    if tm is None:
        tm = TransitionMatrix.from_sids(sids, V, dense_d=case["dense_d"])
    decoy = np.unique(
        np.random.default_rng(case["seed"] + 1).integers(
            0, V, size=(40, L)).astype(np.int64), axis=0)
    store = ConstraintStore.from_matrices(
        [TransitionMatrix.from_sids(decoy, V, dense_d=case["dense_d"]), tm],
        headroom=0.2,
    )
    return {
        "static": DecodePolicy.static(tm),
        "static_pallas": DecodePolicy.static(tm, impl="pallas"),
        "static_fused": DecodePolicy.static(tm, fused=True),
        # delta-compressed edge slab (DESIGN.md §11): same masks, bit for bit
        "static_compressed": DecodePolicy.static(tm, compressed=True),
        "static_pallas_compressed": DecodePolicy.static(
            tm, impl="pallas", compressed=True),
        "stacked": DecodePolicy.stacked(store),  # rows select member 1 == tm
        "stacked_compressed": DecodePolicy.stacked(store, compressed=True),
        "ppv_exact": DecodePolicy.ppv(sids, V, exact=True),
        "ppv_topk_full": DecodePolicy.ppv(sids, V, exact=False, top_k=V),
        # 2^24 bits vs <=~1.5k probed prefixes: collision-free at fuzz scale
        "hash_bitmap": DecodePolicy.hash_bitmap(sids, V, log2_bits=24),
    }


def run_beam(case, policy, stacked: bool, batch=3, beams=6):
    V, L, table = case["V"], case["L"], case["table"]

    def logits_fn(carry, last, step):
        return table[step][last], carry  # pure gather: bit-deterministic

    cids = (jnp.ones((batch,), jnp.int32) if stacked else None)
    state, _ = beam_search(logits_fn, None, batch, beams, L, policy,
                           constraint_ids=cids)
    return np.asarray(state.tokens), np.asarray(state.scores)


def masks_along_prefix(case, policy, prefixes, lp, step, stacked: bool):
    """(masked_lp, valid) at ``step`` after walking ``prefixes[:, :step]``.

    Drives every backend through the same ``policy.step`` chain the beam
    search uses: trie states advance by the vocab-aligned next-state gather,
    prefix backends read the history directly.
    """
    B, V = prefixes.shape[0], case["V"]
    pf = jnp.asarray(prefixes, jnp.int32)
    cids = jnp.ones((B,), jnp.int32) if stacked else None
    nodes = jnp.ones((B,), jnp.int32)
    zeros = jnp.zeros((B, V), jnp.float32)
    for s in range(step):
        _, nxt = policy.step(zeros, nodes, s, prefix_tokens=pf,
                             constraint_ids=cids, normalized=True)
        nodes = nxt[jnp.arange(B), pf[:, s]]
    masked, nxt = policy.step(lp, nodes, step, prefix_tokens=pf,
                              constraint_ids=cids, normalized=True)
    return np.asarray(masked), np.asarray(nxt) != 0


FUZZ_SEEDS = list(range(6))


def sample_prefixes(case, rng, n_valid=6, n_random=4):
    """Corpus prefixes (always walkable) + random ones (usually dead ends)."""
    sids = case["sids"]
    take = rng.integers(0, sids.shape[0], size=min(n_valid, sids.shape[0]))
    rand = rng.integers(0, case["V"], size=(n_random, case["L"]))
    return np.concatenate([sids[take], rand]).astype(np.int64)


# ---------------------------------------------------------------------------
# mask_step differential: every level, every backend vs the host-trie oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", FUZZ_SEEDS)
def test_fuzz_mask_step_matches_cpu_trie_oracle(seed):
    case = make_case(seed)
    rng = np.random.default_rng(seed + 1000)
    oracle = DecodePolicy.cpu_trie(case["sids"], case["V"])
    prefixes = sample_prefixes(case, rng)
    lp = jnp.asarray(
        rng.normal(size=(prefixes.shape[0], case["V"])).astype(np.float32))
    for step in range(case["L"]):
        want_lp, want_valid = masks_along_prefix(
            case, oracle, prefixes, lp, step, stacked=False)
        for name, policy in exact_policies(case).items():
            got_lp, got_valid = masks_along_prefix(
                case, policy, prefixes, lp, step,
                stacked=policy.requires_constraint_ids)
            np.testing.assert_array_equal(
                got_valid, want_valid,
                err_msg=f"seed={seed} step={step} backend={name}: "
                        "admitted token set diverged from the host trie")
            np.testing.assert_allclose(
                got_lp, want_lp, rtol=1e-6, atol=1e-6,
                err_msg=f"seed={seed} step={step} backend={name}")


# ---------------------------------------------------------------------------
# full-search differential: top-M SIDs and scores vs the oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", FUZZ_SEEDS)
def test_fuzz_beam_search_matches_cpu_trie_oracle(seed):
    case = make_case(seed)
    oracle = DecodePolicy.cpu_trie(case["sids"], case["V"])
    want_t, want_s = run_beam(case, oracle, stacked=False)
    valid = {tuple(r) for r in case["sids"]}
    for b in range(want_t.shape[0]):
        for m in range(want_t.shape[1]):
            if want_s[b, m] > NEG_INF / 2:
                assert tuple(want_t[b, m]) in valid  # oracle sanity
    for name, policy in exact_policies(case).items():
        got_t, got_s = run_beam(
            case, policy, stacked=policy.requires_constraint_ids)
        np.testing.assert_array_equal(
            got_t, want_t, err_msg=f"seed={seed} backend={name}")
        np.testing.assert_allclose(
            got_s, want_s, rtol=1e-5,
            err_msg=f"seed={seed} backend={name}")


# ---------------------------------------------------------------------------
# refresh differential: delta-rebuilt tries drive every backend correctly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", FUZZ_SEEDS)
def test_fuzz_delta_refresh_bit_identical_and_masks_agree(seed):
    """Seeded churn through ``TrieSource.apply_delta``: (1) the rebuilt
    FlatTrie equals a from-scratch ``build_flat_trie`` array-for-array,
    and (2) every exact backend built from the delta trie admits the same
    masks as the host-trie oracle over the post-churn corpus — refresh
    must be invisible to decode semantics (DESIGN.md §7)."""
    from repro.constraints import TrieSource
    from repro.core.trie import build_flat_trie

    case = make_case(seed)
    rng = np.random.default_rng(seed + 5000)
    V, L, dense_d = case["V"], case["L"], case["dense_d"]
    src = TrieSource.from_sids(case["sids"], V, dense_d=dense_d)
    pool = np.asarray(src.sids, dtype=np.int64)
    rm = pool[rng.integers(0, pool.shape[0],
                           size=max(1, pool.shape[0] // 5))]
    add = rng.integers(0, V, size=(max(4, pool.shape[0] // 5), L))
    ft = src.apply_delta(add, rm)
    assert ft is not None  # rm hits present rows: the slab changed
    new_sids = np.asarray(src.sids, dtype=np.int64)
    scratch = build_flat_trie(new_sids, V, dense_d=dense_d)
    assert ft.n_states == scratch.n_states and ft.n_edges == scratch.n_edges
    for f in ("row_pointers", "edges", "level_offsets", "level_bmax",
              "l0_mask_packed", "l0_states", "l1_mask_packed", "l1_states"):
        a, b = getattr(ft, f), getattr(scratch, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(
                a, b, err_msg=f"seed={seed}: delta vs from-scratch {f}")

    case2 = dict(case, sids=new_sids)
    delta_tm = TransitionMatrix.from_flat_trie(ft)
    oracle = DecodePolicy.cpu_trie(new_sids, V)
    prefixes = sample_prefixes(case2, rng)
    lp = jnp.asarray(
        rng.normal(size=(prefixes.shape[0], V)).astype(np.float32))
    for step in range(L):
        want_lp, want_valid = masks_along_prefix(
            case2, oracle, prefixes, lp, step, stacked=False)
        for name, policy in exact_policies(case2, tm=delta_tm).items():
            got_lp, got_valid = masks_along_prefix(
                case2, policy, prefixes, lp, step,
                stacked=policy.requires_constraint_ids)
            np.testing.assert_array_equal(
                got_valid, want_valid,
                err_msg=f"seed={seed} step={step} backend={name}: "
                        "post-refresh admitted token set diverged")
            np.testing.assert_allclose(
                got_lp, want_lp, rtol=1e-6, atol=1e-6,
                err_msg=f"seed={seed} step={step} backend={name}")


# ---------------------------------------------------------------------------
# candidate-compressed vs dense: bit-identical traces (DESIGN.md §8)
# ---------------------------------------------------------------------------
def topk_policy_pairs(case):
    """(candidate-compressed policy, dense-only twin, stacked?) per family.

    The stacked pair runs a K=3 store with rows on member 1, so the
    constraint-axis gather of the stacked topk kernel is exercised against
    live decoys on both sides.
    """
    sids, V, L, d = case["sids"], case["V"], case["L"], case["dense_d"]
    tm = TransitionMatrix.from_sids(sids, V, dense_d=d)
    decoy = np.unique(
        np.random.default_rng(case["seed"] + 7).integers(
            0, V, size=(30, L)).astype(np.int64), axis=0)
    store = ConstraintStore.from_matrices(
        [TransitionMatrix.from_sids(decoy, V, dense_d=d), tm,
         TransitionMatrix.from_sids(decoy, V, dense_d=d)],
        headroom=0.2,
    )
    return {
        "static": (DecodePolicy.static(tm),
                   DecodePolicy.static(tm, topk=False), False),
        "static_pallas": (DecodePolicy.static(tm, impl="pallas"),
                          DecodePolicy.static(tm, impl="pallas", topk=False),
                          False),
        "static_fused": (DecodePolicy.static(tm, fused=True),
                         DecodePolicy.static(tm, fused=True, topk=False),
                         False),
        "stacked_k3": (DecodePolicy.stacked(store),
                       DecodePolicy.stacked(store, topk=False), True),
        # compressed slab feeding the candidate path (DESIGN.md §11): the
        # cumsum-decoded burst must reproduce the dense trace bit for bit
        "static_compressed": (DecodePolicy.static(tm, compressed=True),
                              DecodePolicy.static(tm, topk=False), False),
        "static_pallas_compressed": (
            DecodePolicy.static(tm, impl="pallas", compressed=True),
            DecodePolicy.static(tm, impl="pallas", topk=False), False),
        "stacked_k3_compressed": (
            DecodePolicy.stacked(store, compressed=True),
            DecodePolicy.stacked(store, topk=False), True),
    }


def run_traced_beam(case, policy, stacked, table=None, batch=3, beams=6):
    tbl = case["table"] if table is None else table
    L = tbl.shape[0]

    def logits_fn(carry, last, step):
        return tbl[step][last], carry

    cids = jnp.ones((batch,), jnp.int32) if stacked else None
    _, _, trace = beam_search(logits_fn, None, batch, beams, L, policy,
                              constraint_ids=cids, return_trace=True)
    return (np.asarray(trace.tokens), np.asarray(trace.scores),
            np.asarray(trace.nodes))


@pytest.mark.parametrize("seed", FUZZ_SEEDS)
@pytest.mark.parametrize("tie_heavy", [False, True])
def test_fuzz_candidate_path_bit_identical_to_dense(seed, tie_heavy):
    """The §8 acceptance bar: per-STEP beam traces (tokens, scores, trie
    states) of the candidate-compressed search equal the dense search's,
    bit for bit — including under heavily tied logits, where only an exact
    reproduction of the dense flat-index tie-break can match."""
    case = make_case(seed)
    if tie_heavy:
        # integer-quantized logits: massive score ties at every level
        rng = np.random.default_rng(seed + 99)
        case["table"] = jnp.asarray(
            rng.integers(-2, 3, size=case["table"].shape).astype(np.float32))
    for name, (topk_pol, dense_pol, stacked) in topk_policy_pairs(case).items():
        tt, ts, tn = run_traced_beam(case, topk_pol, stacked)
        dt, ds, dn = run_traced_beam(case, dense_pol, stacked)
        np.testing.assert_array_equal(
            tt, dt, err_msg=f"seed={seed} {name}: tokens diverged")
        np.testing.assert_array_equal(
            tn, dn, err_msg=f"seed={seed} {name}: trie states diverged")
        if name in ("static", "stacked_k3",
                    "static_compressed", "stacked_k3_compressed"):
            # shared XLA log-softmax: scores must be bit-identical
            np.testing.assert_array_equal(
                ts, ds, err_msg=f"seed={seed} {name}: scores diverged")
        else:
            # kernel-side log-softmax may differ in the last ulp
            np.testing.assert_allclose(
                ts, ds, rtol=1e-6, atol=1e-6, err_msg=f"seed={seed} {name}")


@pytest.mark.parametrize("regime", ["bmax_lt_m", "bmax_gt_m"])
def test_candidate_path_branch_factor_regimes(regime):
    """bmax < M: candidate lists are mostly NEG_INF missing-token filler
    (rows cannot fill the top-M alone); bmax > M: genuine compression, the
    selection must drop low-rank valid children.  Both bit-identical."""
    rng = np.random.default_rng(42)
    V, L = 40, 4
    if regime == "bmax_lt_m":
        # near-chain corpus: few children per node, beams outnumber them
        heads = rng.integers(0, V, size=(3, 2))
        sids = np.concatenate(
            [heads[rng.integers(0, 3, size=12)],
             rng.integers(0, 3, size=(12, L - 2))], axis=1)
        beams = 10
    else:
        # wide fan-out at the root, tiny beam count
        sids = rng.integers(0, V, size=(300, L))
        beams = 3
    sids = np.unique(sids.astype(np.int64), axis=0)
    case = dict(seed=0, V=V, L=L, dense_d=1, sids=sids,
                table=jnp.asarray(
                    rng.normal(size=(L, V, V)).astype(np.float32)))
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    M = beams
    bmaxes = [tm.bmax_for_step(s) for s in range(1, L)]
    if regime == "bmax_lt_m":
        assert max(bmaxes) < M, (bmaxes, M)
    else:
        assert tm.bmax_for_step(0) > M or max(bmaxes) >= M
    for name, (topk_pol, dense_pol, stacked) in topk_policy_pairs(case).items():
        tt, ts, tn = run_traced_beam(case, topk_pol, stacked, beams=beams)
        dt, ds, dn = run_traced_beam(case, dense_pol, stacked, beams=beams)
        np.testing.assert_array_equal(tt, dt, err_msg=f"{regime} {name}")
        np.testing.assert_array_equal(tn, dn, err_msg=f"{regime} {name}")
        np.testing.assert_allclose(ts, ds, rtol=1e-6, atol=1e-6,
                                   err_msg=f"{regime} {name}")


# ---------------------------------------------------------------------------
# SPMD differential: mesh decoding bit-identical to single device
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", FUZZ_SEEDS[:4])
@pytest.mark.parametrize("rows", ["replicated", "model"])
def test_fuzz_spmd_bit_identical_to_single_device(seed, rows):
    case = make_case(seed)
    n = len(jax.devices())
    model = 2 if (rows == "model" and n % 2 == 0 and n >= 2) else 1
    mesh = make_subset_mesh(n // model, model)
    B = 2 * dp_size(mesh)
    table = case["table"]

    def logits_fn(carry, last, step):
        return table[step][last], carry

    tm = TransitionMatrix.from_sids(
        case["sids"], case["V"], dense_d=case["dense_d"])
    policy = DecodePolicy.static(tm)

    # jitted single-device reference: the SPMD path is jitted, and XLA may
    # legally order the log-softmax reduction differently from eager mode —
    # the bit-identity contract is compiled-vs-compiled
    @jax.jit
    def single(pol):
        state, _ = beam_search(logits_fn, None, B, 5, case["L"], pol)
        return state.tokens, state.scores

    want_t, want_s = single(policy)
    tokens, scores = spmd_beam_search(
        mesh, logits_fn, B, 5, case["L"], policy, rows=rows)
    np.testing.assert_array_equal(
        np.asarray(tokens), np.asarray(want_t), err_msg=f"seed={seed}")
    np.testing.assert_array_equal(
        np.asarray(scores), np.asarray(want_s), err_msg=f"seed={seed}")


@pytest.mark.parametrize("seed", FUZZ_SEEDS[:3])
def test_fuzz_spmd_candidate_bit_identical_to_dense(seed):
    """SPMD candidate-compressed decoding == single-device DENSE decoding,
    bit for bit: the (B, M*C) candidate reduce is dp-local (each shard ranks
    only its own rows, DESIGN.md §6/§8), so neither the mesh split nor the
    compression may shift a single token or score."""
    case = make_case(seed)
    n = len(jax.devices())
    mesh = make_subset_mesh(n, 1)
    B = 2 * dp_size(mesh)
    table = case["table"]

    def logits_fn(carry, last, step):
        return table[step][last], carry

    tm = TransitionMatrix.from_sids(
        case["sids"], case["V"], dense_d=case["dense_d"])
    topk_policy = DecodePolicy.static(tm)
    assert topk_policy.supports_topk_at(case["L"] - 1) or case["dense_d"] >= case["L"]

    @jax.jit
    def single_dense(pol):
        state, _ = beam_search(logits_fn, None, B, 5, case["L"], pol)
        return state.tokens, state.scores

    want_t, want_s = single_dense(DecodePolicy.static(tm, topk=False))
    tokens, scores = spmd_beam_search(
        mesh, logits_fn, B, 5, case["L"], topk_policy)
    np.testing.assert_array_equal(
        np.asarray(tokens), np.asarray(want_t), err_msg=f"seed={seed}")
    np.testing.assert_array_equal(
        np.asarray(scores), np.asarray(want_s), err_msg=f"seed={seed}")


@pytest.mark.parametrize("seed", FUZZ_SEEDS[:3])
@pytest.mark.parametrize("compressed", [False, True])
def test_fuzz_spmd_model_rows_sharded_topk_bit_identical(seed, compressed):
    """rows="model" now runs the candidate-compressed path (shard-local
    top-C + one-hop psum merge, DESIGN.md §11): the row-sharded policy must
    report ``supports_topk`` and be bit-identical to the single-device
    DENSE search — same contract as the dp-only candidate test above."""
    from repro.distributed.constraint_sharding import to_row_sharded

    case = make_case(seed)
    n = len(jax.devices())
    mesh = make_subset_mesh(1, n)  # every device on the model axis
    table = case["table"]
    B = 2

    def logits_fn(carry, last, step):
        return table[step][last], carry

    tm = TransitionMatrix.from_sids(
        case["sids"], case["V"], dense_d=case["dense_d"])
    policy = DecodePolicy.static(tm, compressed=compressed)
    # the acceptance bar: sharding the rows no longer forfeits the
    # candidate-compressed path
    sharded = to_row_sharded(policy, n_shards=mesh.shape["model"])
    for s in range(case["L"]):
        assert sharded.supports_topk_at(s) == policy.supports_topk_at(s)

    @jax.jit
    def single_dense(pol):
        state, _ = beam_search(logits_fn, None, B, 5, case["L"], pol)
        return state.tokens, state.scores

    want_t, want_s = single_dense(DecodePolicy.static(tm, topk=False))
    tokens, scores = spmd_beam_search(
        mesh, logits_fn, B, 5, case["L"], policy, rows="model")
    np.testing.assert_array_equal(
        np.asarray(tokens), np.asarray(want_t), err_msg=f"seed={seed}")
    np.testing.assert_array_equal(
        np.asarray(scores), np.asarray(want_s), err_msg=f"seed={seed}")


@pytest.mark.parametrize("seed", FUZZ_SEEDS[:3])
@pytest.mark.parametrize("n_shards", [3, 5, 7])
def test_fuzz_pad_rows_nondividing_with_compressed_slab(seed, n_shards):
    """Satellite: ``pad_policy_rows`` at shard counts that do NOT divide the
    edge count, composed with the compressed slab.  Pad rows are zeros past
    every CSR window and pad deltas decompress to the same masked garbage
    the speculative over-read produces — so the padded policy's per-step
    beam trace must equal the unpadded one's, bit for bit."""
    from repro.decoding.backends import StaticBackend
    from repro.distributed.constraint_sharding import pad_policy_rows

    case = make_case(seed)
    tm = TransitionMatrix.from_sids(
        case["sids"], case["V"], dense_d=case["dense_d"])
    if tm.edges.shape[0] % n_shards == 0:
        n_shards += 1  # force a real pad: the inert-pad claim is the test
    policy = DecodePolicy.static(tm, compressed=True)
    padded = pad_policy_rows(policy, n_shards)
    grew = False
    for b in padded.backends:
        if isinstance(b, StaticBackend) and b.levels != "dense":
            assert b.tm.edges.shape[0] % n_shards == 0
            grew = grew or b.tm.edges.shape[0] > tm.edges.shape[0]
            if b.slab is not None:
                # slab padded in lock-step with the CSR rows
                assert b.slab.tok_delta.shape[-1] == b.tm.edges.shape[0]
    assert grew
    tt, ts, tn = run_traced_beam(case, policy, stacked=False)
    pt, ps, pn = run_traced_beam(case, padded, stacked=False)
    np.testing.assert_array_equal(tt, pt, err_msg=f"seed={seed}")
    np.testing.assert_array_equal(ts, ps, err_msg=f"seed={seed}")
    np.testing.assert_array_equal(tn, pn, err_msg=f"seed={seed}")


# ---------------------------------------------------------------------------
# hypothesis-driven variant (runs where hypothesis is installed, e.g. CI)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_hypothesis_static_matches_cpu_trie(seed):
        case = make_case(seed)
        oracle = DecodePolicy.cpu_trie(case["sids"], case["V"])
        tm = TransitionMatrix.from_sids(
            case["sids"], case["V"], dense_d=case["dense_d"])
        want_t, want_s = run_beam(case, oracle, stacked=False)
        got_t, got_s = run_beam(case, DecodePolicy.static(tm), stacked=False)
        np.testing.assert_array_equal(got_t, want_t, err_msg=f"seed={seed}")
        np.testing.assert_allclose(got_s, want_s, rtol=1e-5,
                                   err_msg=f"seed={seed}")
