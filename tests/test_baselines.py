"""Baseline equivalence: PPV-exact and CPU-trie must agree with STATIC;
bitmap may only add false positives; PPV-approx only removes mass outside
its top-k."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NEG_INF, TransitionMatrix, constrain_log_probs
from repro.core.baselines import (
    CpuTrieBaseline,
    HashBitmapBaseline,
    PPVBaseline,
    unconstrained_mask,
)
from conftest import make_sids


def _static_mask(tm, sids, lp, prefixes, step):
    nb = prefixes.shape[0]
    nodes = jnp.ones((nb,), jnp.int32)
    for t in range(step):
        zeros = jnp.zeros_like(lp)
        _, nxt = constrain_log_probs(zeros, nodes, tm, t)
        nodes = nxt[jnp.arange(nb), prefixes[:, t]]
    masked, _ = constrain_log_probs(lp, nodes, tm, step)
    return masked


@pytest.mark.parametrize("vocab,length,n", [(16, 4, 200), (32, 5, 500)])
def test_ppv_exact_equals_static(rng, vocab, length, n):
    sids = make_sids(rng, n, vocab, length, clustered=True)
    tm = TransitionMatrix.from_sids(sids, vocab)
    ppv = PPVBaseline(sids, vocab, exact=True)
    nb = 12
    prefixes = np.concatenate(
        [sids[rng.integers(0, n, nb // 2)], make_sids(rng, nb // 2, vocab, length)]
    ).astype(np.int32)
    for step in range(length):
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        a = _static_mask(tm, sids, lp, jnp.asarray(prefixes), step)
        b = ppv.mask(lp, jnp.asarray(prefixes[:, :max(step, 1)]), step)
        np.testing.assert_array_equal(
            np.asarray(a) > NEG_INF / 2, np.asarray(b) > NEG_INF / 2
        )


def test_cpu_trie_equals_static(rng):
    vocab, length, n = 16, 4, 150
    sids = make_sids(rng, n, vocab, length, clustered=True)
    tm = TransitionMatrix.from_sids(sids, vocab)
    cpu = CpuTrieBaseline(sids, vocab)
    nb = 10
    prefixes = np.concatenate(
        [sids[rng.integers(0, n, nb // 2)], make_sids(rng, nb // 2, vocab, length)]
    ).astype(np.int32)
    for step in range(length):
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        a = _static_mask(tm, sids, lp, jnp.asarray(prefixes), step)
        b = cpu.mask(lp, jnp.asarray(prefixes[:, :max(step, 1)]), step)
        np.testing.assert_array_equal(
            np.asarray(a) > NEG_INF / 2, np.asarray(b) > NEG_INF / 2
        )


def test_ppv_approx_subset_of_exact(rng):
    vocab, length, n = 32, 4, 400
    sids = make_sids(rng, n, vocab, length)
    exact = PPVBaseline(sids, vocab, exact=True)
    approx = PPVBaseline(sids, vocab, exact=False, top_k=8)
    nb = 8
    prefixes = jnp.asarray(sids[rng.integers(0, n, nb), :].astype(np.int32))
    for step in range(length):
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        a = np.asarray(exact.mask(lp, prefixes, step)) > NEG_INF / 2
        b = np.asarray(approx.mask(lp, prefixes, step)) > NEG_INF / 2
        assert np.all(~b | a)  # approx-valid => exact-valid


def test_bitmap_superset_no_false_negatives(rng):
    vocab, length, n = 16, 4, 300
    sids = make_sids(rng, n, vocab, length)
    tm = TransitionMatrix.from_sids(sids, vocab)
    bmp = HashBitmapBaseline(sids, vocab, log2_bits=20)
    nb = 10
    prefixes = jnp.asarray(sids[rng.integers(0, n, nb), :].astype(np.int32))
    for step in range(length):
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        a = np.asarray(_static_mask(tm, sids, lp, prefixes, step)) > NEG_INF / 2
        b = np.asarray(bmp.mask(lp, prefixes, step)) > NEG_INF / 2
        assert np.all(~a | b)  # truly-valid => bitmap-valid (no false negatives)


def test_bitmap_fp_rate_small_bitmap(rng):
    vocab, length, n = 16, 4, 500
    sids = make_sids(rng, n, vocab, length)
    bmp = HashBitmapBaseline(sids, vocab, log2_bits=12)  # deliberately tight
    fpr = bmp.false_positive_rate(sids, n_probe=4000)
    assert 0.0 < fpr < 0.9  # nonzero false positives with a tight table


def test_unconstrained_identity(rng):
    lp = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    out = unconstrained_mask(lp, None, 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(lp))
