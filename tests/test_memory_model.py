"""Appendix B memory-model tests."""
import numpy as np
import pytest

from repro.core import TransitionMatrix
from repro.core.memory_model import capacity_rule_of_thumb, measure, u_max
from conftest import make_sids


def test_paper_youtube_numbers():
    # §B.2: V=2048, L=8, d=2, |C|=20M  => dense 17,301,504 B; sparse 1.44 GB.
    bound = u_max(2048, 20_000_000, 8, dense_d=2)
    dense = (0.125 + 4) * 2048 ** 2
    sparse = 6 * 20_000_000 * 12
    assert bound == int(dense + sparse)
    assert abs(bound - 1.46e9) / 1.46e9 < 0.01  # ≈1.46 GB as derived in §B.2


def test_paper_90mb_per_million_rule():
    # §B.3: 1M constraints -> 17.3 MB + 72 MB ≈ 90 MB.
    per_m = capacity_rule_of_thumb(1_000_000)
    assert 85e6 < per_m < 95e6


def test_actual_usage_below_bound(rng):
    for clustered in (False, True):
        sids = make_sids(rng, 5000, 64, 6, clustered=clustered)
        tm = TransitionMatrix.from_sids(sids, 64, dense_d=2)
        m = measure(tm)
        # Small slack: the bound ignores the +1 row-pointer and DMA padding.
        assert m["total_bytes"] <= m["u_max_bytes"] * 1.10
        if clustered:
            # prefix clustering keeps usage well under the bound (§B.2)
            assert m["utilization"] < 1.0


def test_bound_monotone_in_constraints():
    prev = 0
    for c in (10**4, 10**5, 10**6, 10**7):
        b = u_max(2048, c, 8)
        assert b > prev
        prev = b


def test_dense_d_tradeoff():
    # larger d trades dense-mask memory for fewer sparse levels
    b0 = u_max(2048, 10**6, 8, dense_d=0)
    b2 = u_max(2048, 10**6, 8, dense_d=2)
    dense_part = (0.125 + 4) * 2048 ** 2
    removed_sparse = 12 * (min(2048, 10**6) + min(2048 ** 2, 10**6))
    assert b2 - b0 == pytest.approx(dense_part - removed_sparse, rel=0.01)
