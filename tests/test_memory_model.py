"""Appendix B memory-model tests."""
import numpy as np
import pytest

from repro.core import TransitionMatrix
from repro.core.memory_model import (
    capacity_rule_of_thumb,
    decode_step_traffic,
    measure,
    u_max,
)
from conftest import make_sids


def test_paper_youtube_numbers():
    # §B.2: V=2048, L=8, d=2, |C|=20M  => dense 17,301,504 B; sparse 1.44 GB.
    bound = u_max(2048, 20_000_000, 8, dense_d=2)
    dense = (0.125 + 4) * 2048 ** 2
    sparse = 6 * 20_000_000 * 12
    assert bound == int(dense + sparse)
    assert abs(bound - 1.46e9) / 1.46e9 < 0.01  # ≈1.46 GB as derived in §B.2


def test_paper_90mb_per_million_rule():
    # §B.3: 1M constraints -> 17.3 MB + 72 MB ≈ 90 MB.
    per_m = capacity_rule_of_thumb(1_000_000)
    assert 85e6 < per_m < 95e6


def test_actual_usage_below_bound(rng):
    for clustered in (False, True):
        sids = make_sids(rng, 5000, 64, 6, clustered=clustered)
        tm = TransitionMatrix.from_sids(sids, 64, dense_d=2)
        m = measure(tm)
        # Small slack: the bound ignores the +1 row-pointer and DMA padding.
        assert m["total_bytes"] <= m["u_max_bytes"] * 1.10
        if clustered:
            # prefix clustering keeps usage well under the bound (§B.2)
            assert m["utilization"] < 1.0


def test_bound_monotone_in_constraints():
    prev = 0
    for c in (10**4, 10**5, 10**6, 10**7):
        b = u_max(2048, c, 8)
        assert b > prev
        prev = b


def test_dense_d_tradeoff():
    # larger d trades dense-mask memory for fewer sparse levels
    b0 = u_max(2048, 10**6, 8, dense_d=0)
    b2 = u_max(2048, 10**6, 8, dense_d=2)
    dense_part = (0.125 + 4) * 2048 ** 2
    removed_sparse = 12 * (min(2048, 10**6) + min(2048 ** 2, 10**6))
    assert b2 - b0 == pytest.approx(dense_part - removed_sparse, rel=0.01)


# ---------------------------------------------------------------------------
# corrected capacity rule (DESIGN.md §11 bugfix): no linear extrapolation
# ---------------------------------------------------------------------------
def test_capacity_rule_evaluates_u_max_directly():
    """The dense ``(1/8+K2)V^d`` term is catalog-size independent: the rule
    must equal ``u_max`` at the requested size, not a scaled ``u_max(1M)``
    (which overcounted the dense term 10x at 10M SIDs and buried the
    per-item cost at 10k)."""
    for n in (10**4, 10**6, 10**7, 10**8):
        assert capacity_rule_of_thumb(n) == float(u_max(2048, n, 8, dense_d=2))
    dense = (0.125 + 4) * 2048 ** 2
    # the old ``u_max(1M) * n/1M`` extrapolation at 10M: dense term 10x
    wrong = capacity_rule_of_thumb(10**6) * 10
    right = capacity_rule_of_thumb(10**7)
    assert wrong - right == pytest.approx(9 * dense, rel=1e-6)


@pytest.mark.parametrize("n", [10_000, 1_000_000])
def test_measured_usage_within_capacity_rule(n):
    """Satellite regression: a realistically clustered (RQ-VAE SIDs share
    prefixes) catalog built at the paper's V=2048, L=8, d=2 setting must
    fit the planning bound — actual <= u_max, no slack factor."""
    rng = np.random.default_rng(n)
    sids = np.unique(make_sids(rng, n, 2048, 8, clustered=True), axis=0)
    tm = TransitionMatrix.from_sids(sids, 2048, dense_d=2)
    m = measure(tm)
    assert m["total_bytes"] <= capacity_rule_of_thumb(tm.n_constraints)
    assert m["total_bytes"] <= m["u_max_bytes"]


def test_measure_handles_dense_d0_none_tables():
    """Satellite regression: ``measure`` used to crash on ``dense_d=0``
    tries whose ``l0_*``/``l1_*`` tables are None (the continuous engine's
    default registry builds exactly those)."""
    from repro.core.trie import build_flat_trie

    sids = np.unique(
        np.random.default_rng(2).integers(0, 11, size=(30, 4)), axis=0)
    ft = build_flat_trie(sids, 11, dense_d=0)
    assert ft.l0_mask_packed is None and ft.l1_mask_packed is None
    m = measure(ft)
    assert m["dense_bytes"] == 0
    assert m["total_bytes"] == m["sparse_bytes"] > 0


def test_measure_with_compressed_slab(rng):
    from repro.core.compressed_slab import CompressedSlab

    sids = make_sids(rng, 3000, 64, 6, clustered=True)
    tm = TransitionMatrix.from_sids(sids, 64, dense_d=1)
    slab = CompressedSlab.from_matrix(tm)
    m = measure(tm, slab=slab)
    assert m["compressed_bytes"] < m["sparse_bytes"]
    assert m["compression_ratio"] > 1.0
    # the tentpole bar: >= 30% slab-byte cut (int16 deltas + dropped dst)
    assert m["compressed_bytes"] <= 0.7 * m["sparse_bytes"]
    assert m["compressed_total_bytes"] == m["dense_bytes"] + m["compressed_bytes"]


# ---------------------------------------------------------------------------
# lane unification (DESIGN.md §8 bugfix): one constant, kernels and model
# ---------------------------------------------------------------------------
def test_decode_step_traffic_lane_matches_kernels():
    from repro.core.vntk import LANE_PALLAS, LANE_XLA, candidate_width, topk_lane

    assert topk_lane("pallas") == LANE_PALLAS == 128
    assert topk_lane("xla") == LANE_XLA == 8
    for impl in ("xla", "pallas"):
        t = decode_step_traffic(2048, batch=4, beams=10, impl=impl)
        assert t["lane"] == topk_lane(impl)
        assert t["width"] == candidate_width(10, 2048, lane=topk_lane(impl))
    # candidate traffic is V-independent; dense scales linearly (fig3)
    a = decode_step_traffic(2048, batch=4, beams=10)
    b = decode_step_traffic(4096, batch=4, beams=10)
    assert b["candidate_total_bytes"] == a["candidate_total_bytes"]
    assert b["dense_total_bytes"] == 2 * a["dense_total_bytes"]
    assert b["compression_ratio"] > a["compression_ratio"]


# ---------------------------------------------------------------------------
# compressed + tiered planning (DESIGN.md §11)
# ---------------------------------------------------------------------------
def test_u_max_compressed_halves_sparse_term():
    from repro.core.memory_model import k1_compressed, u_max_compressed

    assert k1_compressed(2048) == 6  # 4 rowptr + 2 int16 delta
    assert k1_compressed(100_000) == 8  # int32 deltas past 32768 vocab
    full = u_max(2048, 10**6, 8, dense_d=2)
    comp = u_max_compressed(2048, 10**6, 8, dense_d=2)
    dense = (0.125 + 4) * 2048 ** 2
    assert (comp - dense) / (full - dense) == pytest.approx(0.5, rel=1e-6)


def test_plan_tiers_finite_100m_and_budget_selection():
    from repro.core.memory_model import plan_tiers

    # a 100M-SID catalog: no budget => everything hot, finite bytes
    full = plan_tiers(2048, 10**8, 8, dense_d=2, compressed=True)
    assert full["hot_levels"] == 8 and full["host_bytes"] == 0
    assert 0 < full["total_bytes"] < 10**13
    # a 2 GB budget: deepest fitting boundary, accounting consistent
    plan = plan_tiers(2048, 10**8, 8, dense_d=2, compressed=True,
                      hbm_budget=2 * 2**30)
    assert 2 <= plan["hot_levels"] < 8
    assert plan["hbm_bytes"] <= 2 * 2**30
    over = plan["level_bytes"][plan["hot_levels"] + 1]
    assert plan["hbm_bytes"] + over > 2 * 2**30  # one level deeper busts it
    assert plan["host_bytes"] > 0 and plan["prefetch_bytes_per_step"] > 0
    hot_sparse = sum(v for k, v in plan["level_bytes"].items()
                     if k <= plan["hot_levels"])
    assert plan["total_bytes"] == (plan["dense_bytes"] + hot_sparse
                                   + plan["host_bytes"])
    # compression shrinks every sparse level by k1 ratio
    raw = plan_tiers(2048, 10**8, 8, dense_d=2, compressed=False,
                     hbm_budget=2 * 2**30)
    assert raw["level_bytes"][8] == 2 * plan["level_bytes"][8]
