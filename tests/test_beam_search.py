"""Constrained beam search invariants (Alg. 1 Phases 3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TransitionMatrix, beam_search, recall_at_k
from repro.core.vntk import NEG_INF
from conftest import make_sids


def static_logits_fn(table):
    """Decoder whose logits depend only on the step (shared across beams)."""

    def fn(carry, last_tokens, step):
        B, M = last_tokens.shape
        logits = jnp.broadcast_to(table[step], (B, M, table.shape[-1]))
        return logits, carry

    return fn


def test_constrained_beams_always_valid(rng):
    vocab, length, n = 16, 4, 60
    sids = make_sids(rng, n, vocab, length, clustered=True)
    tm = TransitionMatrix.from_sids(sids, vocab)
    table = jnp.asarray(rng.normal(size=(length, vocab)).astype(np.float32))
    state, _ = beam_search(
        static_logits_fn(table), None, batch_size=3, beam_size=8,
        length=length, policy=tm,
    )
    valid = {tuple(r) for r in sids}
    beams = np.asarray(state.tokens)
    scores = np.asarray(state.scores)
    n_valid_paths = len(valid)
    for b in range(3):
        for m in range(8):
            if scores[b, m] > NEG_INF / 2:
                assert tuple(beams[b, m]) in valid, "decoded an out-of-corpus SID"
    # 100% compliance (paper §5.4): every finite-score beam is in C.


def test_unconstrained_can_hallucinate(rng):
    """Sanity: without the constraint the same scorer leaves the corpus."""
    vocab, length, n = 16, 4, 5  # tiny corpus => near-certain hallucination
    sids = make_sids(rng, n, vocab, length)
    table = jnp.asarray(rng.normal(size=(length, vocab)).astype(np.float32))
    state, _ = beam_search(
        static_logits_fn(table), None, batch_size=1, beam_size=4,
        length=length, policy=None,
    )
    valid = {tuple(r) for r in sids}
    beams = np.asarray(state.tokens)
    assert any(tuple(beams[0, m]) not in valid for m in range(4))


def test_beam_scores_sorted_and_correct(rng):
    vocab, length = 8, 3
    sids = make_sids(rng, 30, vocab, length)
    tm = TransitionMatrix.from_sids(sids, vocab)
    table = jnp.asarray(rng.normal(size=(length, vocab)).astype(np.float32))
    state, _ = beam_search(
        static_logits_fn(table), None, batch_size=2, beam_size=6,
        length=length, policy=tm,
    )
    scores = np.asarray(state.scores)
    assert np.all(np.diff(scores, axis=1) <= 1e-6)  # descending
    # verify the top beam's score equals the sum of its per-step log-probs
    lp_table = np.asarray(jax.nn.log_softmax(table, axis=-1))
    top = np.asarray(state.tokens)[0, 0]
    want = sum(lp_table[t, top[t]] for t in range(length))
    np.testing.assert_allclose(scores[0, 0], want, rtol=1e-5)


def test_top_beam_is_global_argmax(rng):
    """With step-independent scores, beam search must find the argmax path in C."""
    vocab, length = 8, 3
    sids = np.unique(make_sids(rng, 40, vocab, length), axis=0)
    tm = TransitionMatrix.from_sids(sids, vocab)
    table = jnp.asarray(rng.normal(size=(length, vocab)).astype(np.float32))
    lp_table = np.asarray(jax.nn.log_softmax(table, axis=-1))
    # brute-force best valid SID
    best = max(
        (sum(lp_table[t, r[t]] for t in range(length)), tuple(r)) for r in sids
    )
    M = min(len(sids), 16)
    state, _ = beam_search(
        static_logits_fn(table), None, batch_size=1, beam_size=M,
        length=length, policy=tm,
    )
    assert tuple(np.asarray(state.tokens)[0, 0]) == best[1]


def test_recall_at_k():
    beams = jnp.asarray(
        [[[1, 2], [3, 4], [5, 6]],
         [[7, 8], [9, 1], [2, 3]]]
    )
    targets = jnp.asarray([[3, 4], [0, 0]])
    assert float(recall_at_k(beams, targets, 1)) == 0.0
    assert float(recall_at_k(beams, targets, 2)) == 0.5
    assert float(recall_at_k(beams, targets, 3)) == 0.5


def test_carry_gather_applied(rng):
    """The carry must be permuted with the surviving beams."""
    vocab, length = 8, 3
    sids = make_sids(rng, 30, vocab, length)
    tm = TransitionMatrix.from_sids(sids, vocab)
    B, M = 2, 4

    def logits_fn(carry, last, step):
        # carry counts, per beam, how many steps it survived
        logits = jnp.zeros((B, M, vocab)) + carry[..., None] * 0.0
        return logits + jnp.asarray(rng.normal(size=(vocab,)), jnp.float32), carry + 1

    def gather(carry, beam_idx):
        return jnp.take_along_axis(carry, beam_idx, axis=1)

    state, carry = beam_search(
        logits_fn, jnp.zeros((B, M)), B, M, length, tm, carry_gather_fn=gather
    )
    np.testing.assert_array_equal(np.asarray(carry), np.full((B, M), length))
