"""Property-based tests (hypothesis) on the system's core invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import NEG_INF, TransitionMatrix, constrain_log_probs
from repro.core.baselines import PPVBaseline
from repro.core.memory_model import measure, u_max
from repro.core.trie import build_flat_trie, pack_bits, unpack_bits_word


@st.composite
def sid_sets(draw):
    vocab = draw(st.sampled_from([4, 8, 16]))
    length = draw(st.integers(2, 5))
    n = draw(st.integers(1, 60))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    sids = rng.integers(0, vocab, size=(n, length))
    return vocab, length, np.unique(sids, axis=0), seed


@settings(max_examples=25, deadline=None)
@given(sid_sets())
def test_every_constraint_walkable_and_nothing_else(case):
    """Invariant: the trie accepts exactly the constraint set.

    Walking any SID in C reaches a leaf; walking any SID not in C dies at
    some level (mask False)."""
    vocab, length, sids, seed = case
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=min(2, length - 1))
    rng = np.random.default_rng(seed + 1)
    probes = np.concatenate(
        [sids, rng.integers(0, vocab, size=(20, length))], axis=0
    )
    in_c = np.array([tuple(r) in {tuple(s) for s in sids} for r in probes])
    nodes = jnp.ones((probes.shape[0],), jnp.int32)
    alive = np.ones(probes.shape[0], bool)
    for t in range(length):
        lp = jnp.zeros((probes.shape[0], vocab), jnp.float32)
        masked, nxt = constrain_log_probs(lp, nodes, tm, t)
        ok = np.asarray(masked)[np.arange(probes.shape[0]), probes[:, t]] > NEG_INF / 2
        alive &= ok
        nodes = jnp.asarray(nxt)[np.arange(probes.shape[0]), probes[:, t]]
    np.testing.assert_array_equal(alive, in_c)


@settings(max_examples=20, deadline=None)
@given(sid_sets())
def test_ppv_exact_agrees_with_static(case):
    vocab, length, sids, seed = case
    tm = TransitionMatrix.from_sids(sids, vocab)
    ppv = PPVBaseline(sids, vocab, exact=True)
    rng = np.random.default_rng(seed + 2)
    nb = 6
    probes = np.concatenate(
        [sids[rng.integers(0, sids.shape[0], nb // 2)],
         rng.integers(0, vocab, size=(nb - nb // 2, length))], axis=0
    ).astype(np.int32)
    nodes = jnp.ones((nb,), jnp.int32)
    for t in range(length):
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        a, nxt = constrain_log_probs(lp, nodes, tm, t)
        b = ppv.mask(lp, jnp.asarray(probes[:, : max(t, 1)]), t)
        np.testing.assert_array_equal(
            np.asarray(a) > NEG_INF / 2, np.asarray(b) > NEG_INF / 2
        )
        nodes = jnp.asarray(nxt)[np.arange(nb), probes[:, t]]


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=n).astype(bool)
    np.testing.assert_array_equal(unpack_bits_word(pack_bits(bits), n), bits)


@settings(max_examples=20, deadline=None)
@given(sid_sets())
def test_memory_bound_holds(case):
    """Invariant: actual structure bytes <= Appendix-B bound (+10% slack for
    the +1 row pointer and DMA padding)."""
    vocab, length, sids, _ = case
    d = min(2, length - 1)
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=d)
    m = measure(tm)
    slack = 4096  # pad rows + row_pointers[0] on tiny tries
    assert m["total_bytes"] <= m["u_max_bytes"] * 1.10 + slack


@settings(max_examples=20, deadline=None)
@given(sid_sets())
def test_level_bmax_is_tight_bound(case):
    vocab, length, sids, _ = case
    ft = build_flat_trie(sids, vocab, dense_d=0)
    rp = np.asarray(ft.row_pointers, np.int64)
    lens = rp[1:] - rp[:-1]
    for lvl in range(length):
        lo = 1 if lvl == 0 else int(ft.level_offsets[lvl])
        hi = 2 if lvl == 0 else int(ft.level_offsets[lvl + 1])
        if hi > lo:
            assert lens[lo:hi].max() == ft.level_bmax[lvl]
