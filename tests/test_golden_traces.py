"""Golden-trace regression: frozen fixtures catch cross-backend drift.

``tests/golden/`` holds a frozen corpus + logits table, a *serialized*
:class:`TransitionMatrix`, and per-backend expected top-M SID/score traces
(full per-step beam snapshots).  Backends are compared against the
**checked-in** traces — never against a recomputed oracle — so a silent
semantic change in any backend (or in the trie builder / serialization
format) fails here even if every backend drifts in unison with the others'
reimplementation.  Regenerate intentionally with
``python tests/golden/regenerate.py``.
"""
import pathlib

import numpy as np
import pytest

from repro.core import TransitionMatrix

from golden.regenerate import (  # the fixture recipe IS the test's builder
    B,
    L,
    M,
    V,
    policies,
    run_traced,
)

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"
BACKENDS = ["static", "static_fused", "static_d0", "stacked", "ppv_exact",
            "cpu_trie", "hash_bitmap"]


@pytest.fixture(scope="module")
def fixtures():
    inputs = np.load(GOLDEN / "inputs.npz")
    traces = np.load(GOLDEN / "traces.npz")
    return inputs, traces


def test_serialized_trie_matches_rebuilt(fixtures):
    """trie_small.npz loads to exactly the matrix the builder produces —
    save/load format and trie construction are both pinned."""
    inputs, _ = fixtures
    loaded = TransitionMatrix.load(GOLDEN / "trie_small.npz")
    rebuilt = TransitionMatrix.from_sids(inputs["sids"], V, dense_d=2)
    assert loaded.sid_length == L and loaded.vocab_size == V
    for f in ("vocab_size", "sid_length", "dense_d", "level_bmax",
              "n_states", "n_edges", "n_constraints"):
        assert getattr(loaded, f) == getattr(rebuilt, f), f
    for f in ("row_pointers", "edges", "l0_mask_packed", "l0_states",
              "l1_mask_packed", "l1_states"):
        np.testing.assert_array_equal(
            np.asarray(getattr(loaded, f)), np.asarray(getattr(rebuilt, f)),
            err_msg=f)


@pytest.mark.parametrize("name", BACKENDS)
def test_backend_matches_golden_trace(fixtures, name):
    inputs, traces = fixtures
    sids, decoy, table = inputs["sids"], inputs["decoy"], inputs["table"]
    tm = TransitionMatrix.load(GOLDEN / "trie_small.npz")  # serialized path
    policy, stacked = policies(sids, decoy, tm)[name]
    tokens, scores, tr_tokens, tr_scores = run_traced(policy, table, stacked)
    assert tokens.shape == (B, M, L)
    np.testing.assert_array_equal(
        tokens, traces[f"{name}_tokens"],
        err_msg=f"{name}: final top-M SIDs drifted from the golden fixture")
    np.testing.assert_allclose(
        scores, traces[f"{name}_scores"], atol=1e-5, err_msg=name)
    # per-step trace: pinpoints the decode level where drift starts
    want_tt = traces[f"{name}_trace_tokens"]
    for step in range(L):
        np.testing.assert_array_equal(
            tr_tokens[step], want_tt[step],
            err_msg=f"{name}: beams diverged first at decode step {step}")
    np.testing.assert_allclose(
        tr_scores, traces[f"{name}_trace_scores"], atol=1e-5, err_msg=name)


def test_goldens_cover_stacked_member_selection(fixtures):
    """The stacked fixture decodes under member 1 (the real corpus), not
    the decoy in slot 0 — guard the fixture itself against regeneration
    mistakes."""
    inputs, traces = fixtures
    valid = {tuple(r) for r in inputs["sids"]}
    decoy_only = {tuple(r) for r in inputs["decoy"]} - valid
    for b in range(B):
        top = tuple(traces["stacked_tokens"][b, 0])
        assert top in valid and top not in decoy_only
