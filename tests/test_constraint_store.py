"""ConstraintStore / ConstraintRegistry: stacked multi-tenant constraints.

The load-bearing property (DESIGN.md §4): masking a batch through the stacked
store with per-row constraint ids must be BIT-IDENTICAL, row for row, to
masking each row through its own standalone TransitionMatrix — across the
dense l0/l1 lookups and the sparse VNTK, on both the XLA and Pallas paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.constraints import (
    ConstraintRegistry,
    ConstraintStore,
    ItemCatalog,
    category_allowlist,
    freshness_window,
)
from repro.core import NEG_INF, TransitionMatrix, beam_search, constrain_log_probs
from repro.core.constrained import constrained_decoding_step
from repro.models import transformer
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids

V, L = 16, 4
SET_SIZES = (40, 120, 300)


def build_sets(rng, dense_d):
    sid_sets = [make_sids(rng, n, V, L, clustered=True) for n in SET_SIZES]
    mats = [TransitionMatrix.from_sids(s, V, dense_d=dense_d) for s in sid_sets]
    return sid_sets, mats


def walk_row(tm, prefix, step):
    """Trie state reached by ``prefix[:step]`` under a standalone matrix."""
    node = jnp.ones((1,), jnp.int32)
    for t in range(step):
        lp = jnp.zeros((1, V), jnp.float32)
        _, nxt = constrain_log_probs(lp, node, tm, t)
        node = nxt[jnp.arange(1), prefix[t : t + 1]]
    return int(node[0])


# ---------------------------------------------------------------------------
# construction / validation
# ---------------------------------------------------------------------------
def test_from_matrices_validation(rng):
    mats = [TransitionMatrix.from_sids(make_sids(rng, 20, V, L), V)]
    other_vocab = TransitionMatrix.from_sids(make_sids(rng, 20, 8, L), 8)
    with pytest.raises(ValueError, match="vocab"):
        ConstraintStore.from_matrices(mats + [other_vocab])
    other_dense = TransitionMatrix.from_sids(make_sids(rng, 20, V, L), V, dense_d=0)
    with pytest.raises(ValueError, match="dense_d"):
        ConstraintStore.from_matrices(mats + [other_dense])
    with pytest.raises(ValueError, match="at least one"):
        ConstraintStore.from_matrices([])


def test_envelope_covers_members(rng):
    _, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.25)
    assert store.num_sets == 3
    assert store.n_states >= max(m.n_states for m in mats)
    for l in range(L):
        assert store.level_bmax[l] >= max(m.level_bmax[l] for m in mats)
    assert store.row_pointers.shape == (3, store.n_states + 1)
    assert store.edges.shape == (3, store.n_edges, 2)
    np.testing.assert_array_equal(
        np.asarray(store.member_n_constraints),
        [m.n_constraints for m in mats],
    )


# ---------------------------------------------------------------------------
# the acceptance cross-check: bit-identical vs standalone matrices
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dense_d", [0, 1, 2])
def test_stacked_lookup_bit_identical_all_paths(rng, dense_d):
    """Store + constraint_ids == per-row standalone matrix, bit for bit,
    at every decode level (dense l0/l1 + VNTK) on XLA, Pallas and fused."""
    sid_sets, mats = build_sets(rng, dense_d)
    store = ConstraintStore.from_matrices(mats, headroom=0.3)
    nb = 9
    cids_np = np.array([0, 1, 2] * 3, np.int32)
    cids = jnp.asarray(cids_np)
    prefixes = np.stack(
        [sid_sets[c][rng.integers(0, len(sid_sets[c]))] for c in cids_np]
    ).astype(np.int32)

    for step in range(L):
        nodes = jnp.asarray(
            np.array(
                [walk_row(mats[c], prefixes[i], step)
                 for i, c in enumerate(cids_np)],
                np.int32,
            )
        )
        lp = jnp.asarray(rng.normal(size=(nb, V)).astype(np.float32))
        want_m = np.empty((nb, V), np.float32)
        want_n = np.empty((nb, V), np.int32)
        for i, c in enumerate(cids_np):
            m_, n_ = constrain_log_probs(lp[i : i + 1], nodes[i : i + 1],
                                         mats[c], step)
            want_m[i], want_n[i] = np.asarray(m_)[0], np.asarray(n_)[0]

        got_m, got_n = constrain_log_probs(lp, nodes, store, step,
                                           constraint_ids=cids)
        np.testing.assert_array_equal(np.asarray(got_m), want_m)
        np.testing.assert_array_equal(np.asarray(got_n), want_n)

        if step >= dense_d:  # sparse levels: also the kernel paths
            pm, pn = constrain_log_probs(lp, nodes, store, step,
                                         impl="pallas", constraint_ids=cids)
            np.testing.assert_array_equal(np.asarray(pm), want_m)
            np.testing.assert_array_equal(np.asarray(pn), want_n)
            fm, fn = constrained_decoding_step(lp, nodes, store, step,
                                               fused=True, constraint_ids=cids)
            np.testing.assert_array_equal(np.asarray(fn), want_n)
            # fused path normalizes first; masked positions must agree
            ref_lp = jax.nn.log_softmax(lp, axis=-1)
            valid = want_n > 0
            np.testing.assert_allclose(
                np.asarray(fm)[valid], np.asarray(ref_lp)[valid], rtol=1e-6
            )


def test_constraint_ids_guardrails(rng):
    _, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats)
    lp = jnp.zeros((2, V), jnp.float32)
    nodes = jnp.ones((2,), jnp.int32)
    with pytest.raises(ValueError, match="constraint_ids"):
        constrain_log_probs(lp, nodes, store, 0)  # store without ids
    with pytest.raises(ValueError, match="ConstraintStore"):
        constrain_log_probs(lp, nodes, mats[0], 0,
                            constraint_ids=jnp.zeros(2, jnp.int32))


# ---------------------------------------------------------------------------
# member slicing / persistence / hot-swap
# ---------------------------------------------------------------------------
def test_member_lookup_matches_original(rng):
    sid_sets, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.5)
    for k, tm in enumerate(mats):
        member = store.member(k)
        assert member.n_constraints == tm.n_constraints
        lp = jnp.asarray(rng.normal(size=(4, V)).astype(np.float32))
        nodes = jnp.ones((4,), jnp.int32)
        for step in range(2):
            a, an = constrain_log_probs(lp, nodes, tm, step)
            b, bn = constrain_log_probs(lp, nodes, member, step)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(an), np.asarray(bn))
            nodes = an[jnp.arange(4), jnp.argmax(a, axis=-1)]


def test_store_save_load_roundtrip(tmp_path, rng):
    _, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.4)
    path = str(tmp_path / "store.npz")
    store.save(path)
    loaded = ConstraintStore.load(path)
    assert loaded.level_bmax == store.level_bmax
    assert loaded.num_sets == store.num_sets
    assert jax.tree_util.tree_structure(loaded) == jax.tree_util.tree_structure(store)
    for a, b in zip(jax.tree.leaves(store), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_with_member_hot_swap(rng):
    _, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.5)
    fresh_sids = make_sids(rng, 150, V, L, clustered=True)
    fresh = TransitionMatrix.from_sids(fresh_sids, V, dense_d=2)
    swapped = store.with_member(1, fresh)
    # static metadata and tree structure are swap-invariant (=> no recompile)
    assert jax.tree_util.tree_structure(swapped) == jax.tree_util.tree_structure(store)
    assert swapped.level_bmax == store.level_bmax
    assert swapped.n_states == store.n_states
    # slot 1 now masks by the fresh set; other slots untouched
    lp = jnp.asarray(rng.normal(size=(3, V)).astype(np.float32))
    nodes = jnp.ones((3,), jnp.int32)
    cids = jnp.asarray([0, 1, 2], jnp.int32)
    got_m, _ = constrain_log_probs(lp, nodes, swapped, 0, constraint_ids=cids)
    for i, tm in enumerate([mats[0], fresh, mats[2]]):
        want_m, _ = constrain_log_probs(lp[i : i + 1], nodes[i : i + 1], tm, 0)
        np.testing.assert_array_equal(np.asarray(got_m)[i], np.asarray(want_m)[0])


def test_with_members_bulk_swap_matches_per_slot(rng):
    """The registry refresh path (one-shot bulk replace) must land the same
    store as chaining with_member per slot."""
    _, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.5)
    fresh = [TransitionMatrix.from_sids(make_sids(rng, n, V, L, clustered=True),
                                        V, dense_d=2)
             for n in (50, 90, 200)]
    bulk = store.with_members(fresh)
    chained = store
    for k, tm in enumerate(fresh):
        chained = chained.with_member(k, tm)
    for a, b in zip(jax.tree.leaves(bulk), jax.tree.leaves(chained)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="matrices"):
        store.with_members(fresh[:2])


def test_with_member_envelope_rejection(rng):
    from repro.constraints import EnvelopeOverflow

    mats = [TransitionMatrix.from_sids(make_sids(rng, 30, V, L), V)
            for _ in range(2)]
    store = ConstraintStore.from_matrices(mats)  # no headroom
    big = TransitionMatrix.from_sids(make_sids(rng, 2000, V, L), V)
    with pytest.raises(EnvelopeOverflow, match="headroom"):
        store.with_member(0, big)


def test_zero_headroom_store_accepts_its_own_members(rng):
    """Envelope self-roundtrip: the fit check and the from_matrices sizing
    share one formula, so re-installing a store's own members (what a
    refresh that leaves a slot unchanged amounts to) always fits — even
    with headroom=0.  The old check re-added the speculative-slice pad on
    top of the member's already-padded edge count and rejected it."""
    _, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.0)
    members = [store.member(k) for k in range(store.num_sets)]
    for k, m in enumerate(members):
        # member() reports the REAL counts, not the envelope's
        assert m.n_states == int(store.member_n_states[k])
        assert m.n_edges == int(store.member_n_edges[k])
    roundtrip = store.with_members(members)
    for a, b in zip(jax.tree.leaves(store), jax.tree.leaves(roundtrip)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    single = store.with_member(1, members[1])
    for a, b in zip(jax.tree.leaves(store), jax.tree.leaves(single)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the original (unpadded) matrices also still fit their own envelope
    roundtrip2 = store.with_members(mats)
    np.testing.assert_array_equal(np.asarray(store.edges),
                                  np.asarray(roundtrip2.edges))


def test_from_matrices_index_capacity_guard(rng):
    """The stacked envelope must fit the members' index dtype — headroom
    can push the edge envelope past what e.g. int16 CSR indices address."""
    from repro.core.trie import build_flat_trie

    sids = make_sids(rng, 2000, V, L)
    small = TransitionMatrix.from_flat_trie(
        build_flat_trie(sids, V, index_dtype=np.int16))
    with pytest.raises(ValueError, match="int16"):
        ConstraintStore.from_matrices([small], headroom=8.0)
    ok = ConstraintStore.from_matrices([small], headroom=0.1)
    assert ok.num_sets == 1


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def _catalog(rng, n=400):
    return ItemCatalog(
        sids=make_sids(rng, n, V, L, clustered=True),
        age_days=rng.uniform(0, 60, size=n),
        category=rng.integers(0, 4, size=n),
    )


def test_registry_slots_versions_and_predicates(rng):
    cat = _catalog(rng)
    reg = ConstraintRegistry(V, headroom=0.5)
    assert reg.register("fresh", freshness_window(10)) == 0
    assert reg.register("cats", category_allowlist(1, 2)) == 1
    store = reg.build(cat)
    assert reg.version == 1 and store.num_sets == 2
    # members reflect the predicate-selected SID subsets
    want_fresh = TransitionMatrix.from_sids(
        cat.sids[cat.age_days <= 10], V, dense_d=2
    )
    lp = jnp.asarray(rng.normal(size=(1, V)).astype(np.float32))
    nodes = jnp.ones((1,), jnp.int32)
    a, _ = constrain_log_probs(lp, nodes, want_fresh, 0)
    b, _ = constrain_log_probs(lp, nodes, store, 0,
                               constraint_ids=jnp.zeros(1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # versioned swap
    v = reg.swap(_catalog(rng, 420))
    assert v == 2 and reg.current()[1] == 2
    with pytest.raises(ValueError, match="already registered"):
        reg.register("fresh", freshness_window(5))
    with pytest.raises(RuntimeError, match="cannot register"):
        reg.register("late", freshness_window(5))


def test_registry_empty_predicate_rejected(rng):
    reg = ConstraintRegistry(V)
    reg.register("nothing", freshness_window(-1.0))
    with pytest.raises(ValueError, match="zero items"):
        reg.build(_catalog(rng))


# ---------------------------------------------------------------------------
# decode integration: beam search + engine + hot-swap without recompilation
# ---------------------------------------------------------------------------
def test_beam_search_mixed_constraints_compliance(rng):
    sid_sets, mats = build_sets(rng, dense_d=2)
    store = ConstraintStore.from_matrices(mats, headroom=0.3)
    B, M = 3, 5
    fixed = jnp.asarray(rng.normal(size=(B, M, V)).astype(np.float32))
    state, _ = beam_search(
        lambda carry, last, step: (fixed, carry), None, B, M, L, store,
        constraint_ids=jnp.arange(B, dtype=jnp.int32),
    )
    toks, scores = np.asarray(state.tokens), np.asarray(state.scores)
    for b in range(B):
        valid = {tuple(r) for r in sid_sets[b]}
        for m in range(M):
            if scores[b, m] > NEG_INF / 2:
                assert tuple(toks[b, m]) in valid


@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    return params, cfg


def test_engine_mixed_queue_and_hot_swap_zero_recompile(small_lm, rng):
    """Acceptance: 3+ constraint ids in one shared batch, 100% per-request
    compliance, and a registry hot-swap mid-serve compiles NOTHING new."""
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 4
    cat = ItemCatalog(
        sids=make_sids(rng, 300, Vm, Lm, clustered=True),
        age_days=rng.uniform(0, 60, size=300),
        category=rng.integers(0, 4, size=300),
    )
    reg = ConstraintRegistry(Vm, headroom=0.5)
    preds = {
        reg.register("fresh_20", freshness_window(20)): freshness_window(20),
        reg.register("fresh_45", freshness_window(45)): freshness_window(45),
        reg.register("cat_0_1", category_allowlist(0, 1)): category_allowlist(0, 1),
    }
    store = reg.build(cat)
    retr = GenerativeRetriever(params, cfg, store, sid_length=Lm,
                               sid_vocab=Vm, beam_size=4)
    eng = ServingEngine(params, cfg, batch_size=4, max_len=24,
                        retriever=retr, registry=reg)

    def check_compliance(results, catalog):
        for r in results.values():
            valid = {tuple(x)
                     for x in catalog.sids[preds[r["constraint_id"]](catalog)]}
            for m, sid in enumerate(r["sids"]):
                if r["scores"][m] > NEG_INF / 2:
                    assert tuple(sid) in valid, (r["constraint_id"], sid)

    q = RequestQueue()
    rids = [q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=i % 3)
            for i in range(7)]
    results = eng.serve(q)
    assert set(results) == set(rids) and len(q) == 0
    assert {r["constraint_id"] for r in results.values()} == {0, 1, 2}
    assert all(r["store_version"] == 1 for r in results.values())
    check_compliance(results, cat)

    # ---- hot-swap a refreshed snapshot, then count backend compiles ----
    cat2 = ItemCatalog(
        sids=make_sids(rng, 320, Vm, Lm, clustered=True),
        age_days=rng.uniform(0, 60, size=320),
        category=rng.integers(0, 4, size=320),
    )
    assert reg.swap(cat2) == 2
    compiles = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: compiles.append(name)
        if "backend_compile" in name else None
    )
    for i in range(5):
        q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=i % 3)
    results2 = eng.serve(q)
    assert len(compiles) == 0, f"hot-swap recompiled: {compiles}"
    assert all(r["store_version"] == 2 for r in results2.values())
    check_compliance(results2, cat2)

    # out-of-range constraint id is rejected, not silently clamped
    q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=99)
    with pytest.raises(ValueError, match="constraint_id 99"):
        eng.serve(q)


def test_engine_retrieval_mode_single_matrix(small_lm, rng):
    """Retrieval-mode serving with a plain TransitionMatrix (no store, no
    registry) must work — constraint ids stay host-side and must be 0."""
    params, cfg = small_lm
    Vm, Lm = cfg.vocab_size, 3
    sids = make_sids(rng, 60, Vm, Lm, clustered=True)
    tm = TransitionMatrix.from_sids(sids, Vm)
    retr = GenerativeRetriever(params, cfg, tm, sid_length=Lm, sid_vocab=Vm,
                               beam_size=4)
    eng = ServingEngine(params, cfg, batch_size=2, max_len=24, retriever=retr)
    q = RequestQueue()
    rids = [q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm) for _ in range(3)]
    results = eng.serve(q)
    assert set(results) == set(rids)
    valid = {tuple(r) for r in sids}
    for r in results.values():
        for m, sid in enumerate(r["sids"]):
            if r["scores"][m] > NEG_INF / 2:
                assert tuple(sid) in valid
    q.submit(rng.integers(0, Vm, (8,)), n_tokens=Lm, constraint_id=1)
    with pytest.raises(ValueError, match="constraint_id 1"):
        eng.serve(q)
