"""Incremental catalog refresh: TrieSource deltas + AsyncRefresher (§7).

Three layers of guarantees:
  1. ``TrieSource.apply_delta`` is BIT-IDENTICAL to a from-scratch
     ``build_flat_trie`` over the post-delta SID set (array for array,
     dtype for dtype) under arbitrary seeded churn — the from-scratch
     builder stays the oracle.
  2. ``ConstraintRegistry.swap_delta`` lands the same store as a full
     ``swap`` over the delta-applied catalog, and an envelope overflow
     becomes a cold *regrow* swap instead of an operator-facing error.
  3. At the engine level, async hot swaps recompile NOTHING and cold swaps
     recompile exactly once while the queue drains without dropped
     requests — single-device and SPMD.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.constraints import (
    AsyncRefresher,
    CatalogDelta,
    ConstraintRegistry,
    EnvelopeOverflow,
    ItemCatalog,
    TrieSource,
    category_allowlist,
    freshness_window,
)
from repro.core import NEG_INF, TransitionMatrix, beam_search
from repro.core.trie import build_flat_trie
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids

V, L = 16, 4


def assert_tries_equal(a, b):
    """Array-for-array, dtype-for-dtype FlatTrie equality."""
    assert a.n_states == b.n_states and a.n_edges == b.n_edges
    assert a.n_constraints == b.n_constraints
    for f in ("row_pointers", "edges", "level_offsets", "level_bmax"):
        x, y = getattr(a, f), getattr(b, f)
        np.testing.assert_array_equal(x, y, err_msg=f)
    for f in ("l0_mask_packed", "l0_states", "l1_mask_packed", "l1_states"):
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), f
        if x is not None:
            np.testing.assert_array_equal(x, y, err_msg=f)
            assert x.dtype == y.dtype, (f, x.dtype, y.dtype)


def assert_stores_equal(a, b):
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# TrieSource: delta == from-scratch, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dense_d", [0, 1, 2])
@pytest.mark.parametrize("length", [1, 2, 4, 6])
def test_flatten_matches_builder(rng, dense_d, length):
    sids = make_sids(rng, 200, V, length, clustered=True)
    src = TrieSource.from_sids(sids, V, dense_d=dense_d)
    assert_tries_equal(src.flatten(), build_flat_trie(sids, V, dense_d=dense_d))


@pytest.mark.parametrize("seed", range(8))
def test_apply_delta_bit_identical_under_churn(seed):
    """Seeded random add/remove churn: every delta rebuild must equal the
    from-scratch build over the post-delta set, across rounds."""
    rng = np.random.default_rng(seed)
    vocab = int(rng.integers(5, 30))
    length = int(rng.integers(1, 6))
    dense_d = int(rng.choice([0, 1, 2]))
    sids = rng.integers(0, vocab, size=(int(rng.integers(5, 250)), length))
    src = TrieSource.from_sids(sids, vocab, dense_d=dense_d)
    cur = {tuple(r) for r in sids.astype(np.int64)}
    for _ in range(5):
        n_add, n_rm = int(rng.integers(0, 40)), int(rng.integers(0, 40))
        add = rng.integers(0, vocab, size=(n_add, length)) if n_add else None
        rm = None
        if n_rm and cur:
            pool = np.array(sorted(cur), np.int64)
            rm = np.concatenate([
                pool[rng.integers(0, pool.shape[0], size=n_rm // 2 + 1)],
                rng.integers(0, vocab, size=(n_rm // 2, length)),
            ])  # mix of present rows and (mostly absent) random rows
        rm_set = {tuple(r) for r in rm} if rm is not None else set()
        add_set = ({tuple(r) for r in add.astype(np.int64)}
                   if add is not None else set())
        new = (cur - rm_set) | add_set
        if not new:
            with pytest.raises(ValueError, match="non-empty"):
                src.apply_delta(add, rm)
            continue
        ft = src.apply_delta(add, rm)
        want = np.array(sorted(new), np.int64)
        if ft is not None:
            assert_tries_equal(ft,
                               build_flat_trie(want, vocab, dense_d=dense_d))
        np.testing.assert_array_equal(
            np.asarray(src.sids, dtype=np.int64), want)
        cur = new


def test_apply_delta_noop_and_semantics(rng):
    sids = make_sids(rng, 80, V, L, clustered=True)
    src = TrieSource.from_sids(sids, V)
    present = np.asarray(src.sids, dtype=np.int64)
    # removing absent rows + re-adding present rows: slab untouched -> None
    absent = present.copy()
    absent[:, 0] = (absent[:, 0] + 1) % V
    key_set = {tuple(r) for r in present}
    absent = absent[[tuple(r) not in key_set for r in absent]]
    assert src.apply_delta(add_sids=present[:5], remove_sids=absent) is None
    assert src.apply_delta() is None
    # remove-then-readd of the same SID splices and returns an equal trie
    ft = src.apply_delta(add_sids=present[:3], remove_sids=present[:3])
    assert ft is not None
    assert_tries_equal(ft, build_flat_trie(present, V, dense_d=2))
    # membership helper
    assert present[0] in src and absent[0] not in src


def test_apply_delta_transactional_on_error(rng):
    sids = make_sids(rng, 50, V, L)
    src = TrieSource.from_sids(sids, V)
    before = np.asarray(src.sids, dtype=np.int64).copy()
    with pytest.raises(ValueError, match="non-empty"):
        src.apply_delta(remove_sids=before)  # would empty the set
    with pytest.raises(ValueError, match="range"):
        src.apply_delta(add_sids=np.full((2, L), V + 3))
    with pytest.raises(ValueError, match="must be"):
        src.apply_delta(add_sids=np.zeros((2, L + 1), int))
    np.testing.assert_array_equal(np.asarray(src.sids, np.int64), before)
    assert_tries_equal(src.flatten(), build_flat_trie(before, V, dense_d=2))


def test_clone_is_independent(rng):
    sids = make_sids(rng, 60, V, L)
    src = TrieSource.from_sids(sids, V)
    other = src.clone()
    other.apply_delta(remove_sids=np.asarray(src.sids[:10], np.int64))
    assert src.n_sids == np.unique(sids, axis=0).shape[0]
    assert other.n_sids == src.n_sids - 10


def test_virtual_id_boundary_vocab_raises():
    """Under dense_d >= 2, virtual l0 ids reach token + 1 == vocab_size;
    at the exact dtype boundary (V = 2^15, int16) that wraps silently —
    the capacity guard must therefore cover V itself, in BOTH builders."""
    sids = np.array([[32767, 1], [5, 2]])
    with pytest.raises(ValueError, match="int16"):
        build_flat_trie(sids, 32768, dense_d=2, index_dtype=np.int16)
    with pytest.raises(ValueError, match="int16"):
        TrieSource.from_sids(sids, 32768, dense_d=2,
                             index_dtype=np.int16).flatten()


def test_index_capacity_guard_small_dtypes(rng):
    sids = make_sids(rng, 300, V, L)
    with pytest.raises(ValueError, match="int8"):
        build_flat_trie(sids, V, dense_d=0, index_dtype=np.int8)
    with pytest.raises(ValueError, match="int8"):
        TrieSource.from_sids(sids, V, dense_d=0,
                             index_dtype=np.int8).flatten()
    big = TrieSource.from_sids(sids, V, dense_d=0,
                               index_dtype=np.int64).flatten()
    assert big.edges.dtype == np.int64
    assert_tries_equal(
        big, build_flat_trie(sids, V, dense_d=0, index_dtype=np.int64))


# ---------------------------------------------------------------------------
# registry: delta refresh + envelope regrowth
# ---------------------------------------------------------------------------
def unique_catalog(rng, n):
    """SID-unique catalog (the swap_delta equivalence contract)."""
    sids = np.unique(make_sids(rng, n, V, L, clustered=True), axis=0)
    m = sids.shape[0]
    return ItemCatalog(sids=sids, age_days=rng.uniform(0, 60, m),
                       category=rng.integers(0, 4, m))


def two_slot_registry(headroom=0.5):
    reg = ConstraintRegistry(V, headroom=headroom)
    reg.register("fresh", freshness_window(30))
    reg.register("cats", category_allowlist(0, 1))
    return reg


def make_delta(rng, cat, n_rm=10, n_add=25):
    rm = cat.sids[rng.choice(cat.sids.shape[0], n_rm, replace=False)]
    added = unique_catalog(rng, n_add)
    seen = {tuple(r) for r in cat.sids}
    added = added.select(np.array(
        [tuple(r) not in seen for r in added.sids], bool))
    return CatalogDelta(added=added, removed_sids=rm)


def test_swap_delta_matches_full_swap(rng):
    cat = unique_catalog(rng, 300)
    reg = two_slot_registry()
    reg.build(cat)
    delta = make_delta(rng, cat)
    assert reg.swap_delta(delta) == 2
    ref = two_slot_registry()
    ref.build(cat)
    ref.swap(cat.apply_delta(delta))
    assert_stores_equal(reg.current()[0], ref.current()[0])
    # a second delta chained on the retained sources still matches
    cat2 = cat.apply_delta(delta)
    delta2 = make_delta(rng, cat2)
    reg.swap_delta(delta2)
    ref.swap(cat2.apply_delta(delta2))
    assert_stores_equal(reg.current()[0], ref.current()[0])


def test_swap_delta_empty_is_versionless_noop(rng):
    cat = unique_catalog(rng, 200)
    reg = two_slot_registry()
    reg.build(cat)
    assert reg.swap_delta(CatalogDelta()) == 1
    assert reg.version == 1


def test_compose_equals_sequential(rng):
    cat = unique_catalog(rng, 250)
    d1 = make_delta(rng, cat)
    d2 = CatalogDelta(removed_sids=np.concatenate(
        [cat.sids[20:24], d1.added.sids[:2]]))
    seq = cat.apply_delta(d1).apply_delta(d2)
    comp = cat.apply_delta(d1.compose(d2))
    np.testing.assert_array_equal(np.unique(seq.sids, axis=0),
                                  np.unique(comp.sids, axis=0))
    reg_a = two_slot_registry(); reg_a.build(cat)
    reg_a.swap_delta(d1); reg_a.swap_delta(d2)
    reg_b = two_slot_registry(); reg_b.build(cat)
    reg_b.swap_delta(d1.compose(d2))
    assert_stores_equal(reg_a.current()[0], reg_b.current()[0])


def test_envelope_regrowth_cold_swap(rng):
    cat = unique_catalog(rng, 80)
    reg = two_slot_registry(headroom=0.0)  # no slack: growth must regrow
    store = reg.build(cat)
    assert reg.envelope_generation == 1
    big = unique_catalog(rng, 2000)
    v = reg.swap(big)  # default on_overflow="regrow"
    assert v == 2 and reg.envelope_generation == 2
    grown, _ = reg.current()
    assert grown.n_states > store.n_states
    # fail-fast mode still raises and leaves the front serving
    with pytest.raises(EnvelopeOverflow):
        reg.swap(unique_catalog(rng, 4000), on_overflow="raise")
    assert reg.current()[1] == 2


def test_failed_swap_delta_keeps_sources_consistent(rng):
    """A rejected refresh (envelope overflow, raise mode) must not advance
    the retained per-slot sources past the still-serving front buffer."""
    cat = unique_catalog(rng, 100)
    reg = two_slot_registry(headroom=0.0)
    reg.build(cat)
    huge = CatalogDelta(added=unique_catalog(rng, 3000))
    with pytest.raises(EnvelopeOverflow):
        reg.swap_delta(huge, on_overflow="raise")
    assert reg.version == 1
    # the same registry still refreshes correctly from the ORIGINAL state
    delta = make_delta(rng, cat)
    reg.swap_delta(delta)
    ref = two_slot_registry(headroom=0.0)
    ref.build(cat)
    ref.swap(cat.apply_delta(delta))
    assert_stores_equal(reg.current()[0], ref.current()[0])


# ---------------------------------------------------------------------------
# AsyncRefresher: futures, coalescing, backpressure, error propagation
# ---------------------------------------------------------------------------
def test_async_refresher_applies_and_propagates_errors(rng):
    cat = unique_catalog(rng, 250)
    reg = two_slot_registry()
    reg.build(cat)
    with AsyncRefresher(reg) as ref:
        d = make_delta(rng, cat)
        assert ref.apply_delta_async(d).result(timeout=30) == 2
        cat = cat.apply_delta(d)
        assert ref.swap_async(cat).result(timeout=30) == 3
        # a predicate failure propagates through the future; the front
        # buffer keeps serving the previous version
        stale = ItemCatalog(sids=cat.sids,
                            age_days=np.full(cat.sids.shape[0], 1e9),
                            category=cat.category)
        with pytest.raises(ValueError, match="zero items"):
            ref.swap_async(stale).result(timeout=30)
        assert ref.failed == 1 and reg.version == 3
        assert ref.apply_delta_async(make_delta(rng, cat)).result(30) == 4
    with pytest.raises(RuntimeError, match="closed"):
        ref.swap_async(cat)


def test_async_refresher_coalesces_superseded_snapshots(rng):
    cat = unique_catalog(rng, 200)
    reg = two_slot_registry()
    reg.build(cat)
    ref = AsyncRefresher(reg)
    try:
        with reg._refresh_lock:  # stall the worker mid-op
            futs = [ref.swap_async(unique_catalog(rng, 200 + 10 * i))
                    for i in range(4)]
            time.sleep(0.05)  # let the worker pick up the first op
        versions = {f.result(timeout=30) for f in futs}
        # first op may run alone; the rest collapse into ONE build
        assert ref.coalesced >= 2
        assert reg.version <= 3 and versions <= {2, 3}
    finally:
        ref.close()


def test_async_refresher_backpressure_blocks_when_full(rng):
    cat = unique_catalog(rng, 200)
    reg = two_slot_registry()
    reg.build(cat)
    ref = AsyncRefresher(reg, coalesce=False, max_pending=1)
    try:
        submitted = threading.Event()
        with reg._refresh_lock:  # worker stalls; queue fills
            f1 = ref.swap_async(unique_catalog(rng, 210))
            time.sleep(0.05)  # worker takes f1's op; queue empty again
            f2 = ref.swap_async(unique_catalog(rng, 220))  # queue = 1 = max

            def submit_third():
                ref.swap_async(unique_catalog(rng, 230))
                submitted.set()

            t = threading.Thread(target=submit_third, daemon=True)
            t.start()
            time.sleep(0.1)
            assert not submitted.is_set()  # blocked: queue full
        assert submitted.wait(timeout=30)  # unblocks once the worker drains
        assert f1.result(30) and f2.result(30)
        ref.drain(timeout=30)
    finally:
        ref.close()


def test_async_refresher_survives_cancelled_future(rng):
    """Cancelling a queued future must drop its notification, not kill the
    worker (set_result on a cancelled Future raises InvalidStateError)."""
    cat = unique_catalog(rng, 200)
    reg = two_slot_registry()
    reg.build(cat)
    with AsyncRefresher(reg) as ref:
        with reg._refresh_lock:  # stall the worker so ops stay queued
            f1 = ref.swap_async(unique_catalog(rng, 210))
            time.sleep(0.05)  # worker picks up f1's op
            f2 = ref.apply_delta_async(make_delta(rng, cat))
            assert f2.cancel()  # still queued: cancellable
        assert f1.result(timeout=30) == 2
        assert ref.drain(timeout=30)
        # the worker is still alive and processes new work
        f3 = ref.swap_async(unique_catalog(rng, 220))
        assert f3.result(timeout=30) >= 3


def test_catalog_delta_rejects_mismatched_sid_width(rng):
    """Byte row keys null-pad, so a narrower removed_sids would silently
    match (and delete) the wrong items — it must raise instead."""
    cat = unique_catalog(rng, 100)
    narrow = np.asarray(cat.sids[:, :L - 1])
    with pytest.raises(ValueError, match="sid_length"):
        cat.apply_delta(CatalogDelta(removed_sids=narrow))
    with pytest.raises(ValueError, match="sid_length"):
        CatalogDelta(added=unique_catalog(rng, 10), removed_sids=narrow)
    d1 = CatalogDelta(added=unique_catalog(rng, 10))
    with pytest.raises(ValueError, match="sid_length"):
        d1.compose(CatalogDelta(removed_sids=narrow))
    wide = ItemCatalog(sids=np.zeros((3, L + 1), np.int64),
                       age_days=np.zeros(3), category=np.zeros(3, np.int64))
    with pytest.raises(ValueError, match="sid_length"):
        cat.apply_delta(CatalogDelta(added=wide))


# ---------------------------------------------------------------------------
# engine level: hot swap = zero recompiles, cold swap = exactly one
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_lm():
    cfg = smoke_config("stablelm-12b")
    params = transformer.init_params(cfg, jax.random.key(0))
    return params, cfg


def _compile_listener():
    compiles = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: compiles.append(name)
        if "backend_compile" in name else None
    )
    return compiles


def _lm_catalog(rng, cfg, n):
    sids = np.unique(make_sids(rng, n, cfg.vocab_size, L, clustered=True),
                     axis=0)
    m = sids.shape[0]
    return ItemCatalog(sids=sids, age_days=rng.uniform(0, 60, m),
                       category=rng.integers(0, 4, m))


def test_engine_async_hot_swap_zero_recompile_and_drain(small_lm, rng):
    params, cfg = small_lm
    cat = _lm_catalog(rng, cfg, 300)
    reg = ConstraintRegistry(cfg.vocab_size, headroom=0.5)
    reg.register("fresh", freshness_window(45))
    reg.register("cats", category_allowlist(0, 1, 2))
    store = reg.build(cat)
    retr = GenerativeRetriever(params, cfg, store, sid_length=L,
                               sid_vocab=cfg.vocab_size, beam_size=4)
    eng = ServingEngine(params, cfg, batch_size=4, max_len=24,
                        retriever=retr, registry=reg)
    q = RequestQueue()
    rids = [q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                     constraint_id=i % 2) for i in range(6)]
    results = eng.serve(q)  # warm the executable on version 1
    assert set(results) == set(rids)

    with AsyncRefresher(reg) as ref:
        fut = ref.apply_delta_async(make_delta(rng, cat, n_rm=15, n_add=30))
        assert fut.result(timeout=60) == 2
    compiles = _compile_listener()
    rids2 = [q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                      constraint_id=i % 2) for i in range(6)]
    results2 = eng.serve(q)
    assert set(results2) == set(rids2) and len(q) == 0  # nothing dropped
    assert all(r["store_version"] == 2 for r in results2.values())
    assert len(compiles) == 0, f"async hot swap recompiled: {compiles}"
    assert eng.cold_swaps == 0


def test_engine_cold_swap_recompiles_exactly_once(small_lm, rng):
    params, cfg = small_lm
    cat = _lm_catalog(rng, cfg, 80)
    reg = ConstraintRegistry(cfg.vocab_size, headroom=0.0)
    reg.register("fresh", freshness_window(45))
    reg.register("cats", category_allowlist(0, 1, 2))
    store = reg.build(cat)
    retr = GenerativeRetriever(params, cfg, store, sid_length=L,
                               sid_vocab=cfg.vocab_size, beam_size=4)
    eng = ServingEngine(params, cfg, batch_size=2, max_len=24,
                        retriever=retr, registry=reg)
    q = RequestQueue()
    for i in range(3):
        q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                 constraint_id=i % 2)
    eng.serve(q)  # warm on the original envelope

    big = _lm_catalog(rng, cfg, 1500)  # outgrows the zero-headroom envelope
    with AsyncRefresher(reg) as ref:
        assert ref.swap_async(big).result(timeout=120) == 2
    assert reg.envelope_generation == 2
    compiles = _compile_listener()
    rids = [q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                     constraint_id=i % 2) for i in range(5)]
    results = eng.serve(q)
    assert set(results) == set(rids) and len(q) == 0  # drained, none dropped
    assert eng.cold_swaps == 1
    assert len(compiles) == 1, (
        f"cold swap must recompile exactly once, saw {len(compiles)}")
    # compliance under the regrown store
    valid = {tuple(x) for x in big.sids[big.age_days <= 45]}
    for r in results.values():
        if r["constraint_id"] != 0:
            continue
        for m, sid in enumerate(r["sids"]):
            if r["scores"][m] > NEG_INF / 2:
                assert tuple(sid) in valid
    # and the NEXT serve on the same version compiles nothing
    compiles.clear()
    q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L)
    eng.serve(q)
    assert len(compiles) == 0


# ---------------------------------------------------------------------------
# golden-trace check across one hot swap and one cold swap
# ---------------------------------------------------------------------------
def _traced(policy, table, B=2, M=4, cids=None):
    def logits_fn(carry, last, step):
        return table[step][last], carry

    state, _, trace = beam_search(logits_fn, None, B, M, L, policy,
                                  constraint_ids=cids, return_trace=True)
    return (np.asarray(state.tokens), np.asarray(state.scores),
            np.asarray(trace.tokens), np.asarray(trace.scores))


def test_traces_identical_across_hot_and_cold_swap(rng):
    """Per-step beam traces after a hot swap and after a cold (regrown)
    swap must be bit-identical to a from-scratch build of the same
    snapshot — the swap path must never perturb decode semantics."""
    cat = unique_catalog(rng, 150)
    reg = two_slot_registry(headroom=0.0)
    reg.build(cat)
    table = jnp.asarray(rng.normal(size=(L, V, V)).astype(np.float32))
    cids = jnp.zeros((2,), jnp.int32)

    # hot: delta refresh inside the envelope
    delta = make_delta(rng, cat, n_rm=8, n_add=5)
    reg.swap_delta(delta)
    cat = cat.apply_delta(delta)
    got = _traced(DecodePolicy.stacked(reg.current()[0]), table, cids=cids)
    fresh = two_slot_registry(headroom=0.0)
    want = _traced(DecodePolicy.stacked(fresh.build(cat)), table, cids=cids)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)

    # cold: outgrow the envelope -> regrown store, same traces
    big_delta = CatalogDelta(added=unique_catalog(rng, 2000))
    gen = reg.envelope_generation
    reg.swap_delta(big_delta)
    assert reg.envelope_generation == gen + 1
    cat = cat.apply_delta(big_delta)
    got = _traced(DecodePolicy.stacked(reg.current()[0]), table, cids=cids)
    fresh = two_slot_registry(headroom=0.0)
    want = _traced(DecodePolicy.stacked(fresh.build(cat)), table, cids=cids)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# SPMD: cold swap through the mesh engine
# ---------------------------------------------------------------------------
def test_spmd_engine_cold_swap_rebuilds_once_and_drains(small_lm, rng):
    from repro.launch.mesh import make_debug_mesh
    from repro.serving.spmd_engine import SpmdRetriever, SpmdServingEngine

    params, cfg = small_lm
    cat = _lm_catalog(rng, cfg, 80)
    # enough headroom for the small delta below to swap HOT; the 1500-item
    # delta afterwards still outgrows it and must regrow COLD
    reg = ConstraintRegistry(cfg.vocab_size, headroom=0.5)
    reg.register("fresh", freshness_window(45))
    reg.register("cats", category_allowlist(0, 1, 2))
    store = reg.build(cat)
    mesh = make_debug_mesh()
    retr = SpmdRetriever(params, cfg, DecodePolicy.stacked(store),
                         L, cfg.vocab_size, beam_size=4, mesh=mesh)
    eng = SpmdServingEngine(retr, registry=reg, slots=4, prompt_width=8)
    q = RequestQueue()
    for i in range(4):
        q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                 constraint_id=i % 2)
    eng.serve(q)  # warm on version 1

    # hot swap first: mesh executable reused
    reg.swap_delta(make_delta(rng, cat, n_rm=10, n_add=10))
    compiles = _compile_listener()
    q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L)
    eng.serve(q)
    assert len(compiles) == 0 and eng.cold_swaps == 0

    # cold swap: regrown envelope -> one shard_map rebuild, queue drains
    reg.swap_delta(CatalogDelta(added=_lm_catalog(rng, cfg, 1500)))
    compiles.clear()
    rids = [q.submit(rng.integers(0, cfg.vocab_size, (8,)), n_tokens=L,
                     constraint_id=i % 2) for i in range(5)]
    results = eng.serve(q)
    assert set(rids) <= set(results) and len(q) == 0
    assert eng.cold_swaps == 1
    assert len(compiles) == 1, (
        f"SPMD cold swap must recompile exactly once, saw {len(compiles)}")
