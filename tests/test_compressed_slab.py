"""Delta-compressed CSR edge slab (DESIGN.md §11).

Load-bearing properties: (1) the slab is a pure re-encoding — every decode
path fed from it (XLA and Pallas, mask and candidate-topk, single matrix
and stacked store) is bit-identical to the uncompressed CSR; (2) the
encoding is verified at construction (a non-canonical slab raises, never
silently decodes garbage); (3) the envelope contract holds — leaf shapes
are functions of the capacity envelope only, so hot-swaps keep the treedef;
(4) the byte accounting delivers the promised ~50% slab cut.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constraints import ConstraintStore
from repro.core import TransitionMatrix, beam_search
from repro.core.compressed_slab import INT16_MAX_VOCAB, CompressedSlab
from repro.decoding import DecodePolicy
from conftest import make_sids

V, L = 19, 5


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(3)
    sids = np.unique(make_sids(rng, 140, V, L, clustered=True), axis=0)
    table = jnp.asarray(rng.normal(size=(L, V, V)).astype(np.float32))
    return sids, table


def segment_decode(slab, tm):
    """Reference decompression: per-row cumsum of the delta slab."""
    rp = np.asarray(tm.row_pointers, dtype=np.int64)
    d = np.asarray(slab.tok_delta, dtype=np.int64)[: tm.n_edges]
    tok = np.empty_like(d)
    for s in range(tm.n_states):
        lo, hi = rp[s], rp[s + 1]
        tok[lo:hi] = np.cumsum(d[lo:hi])
    return tok


# ---------------------------------------------------------------------------
# encoding: round-trip, dtype selection, envelope, next-state bases
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dense_d", [0, 1, 2])
def test_from_matrix_round_trips_tokens(corpus, dense_d):
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=dense_d)
    slab = CompressedSlab.from_matrix(tm)
    np.testing.assert_array_equal(
        segment_decode(slab, tm), np.asarray(tm.edges[: tm.n_edges, 0]))
    # envelope contract: delta slab rides the same padded edge axis
    assert slab.tok_delta.shape == (tm.edges.shape[0],)
    assert not slab.is_stacked


def test_int16_vs_int32_dtype_selection(corpus):
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    assert CompressedSlab.from_matrix(tm).tok_delta.dtype == jnp.int16
    big = np.unique(
        np.random.default_rng(0).integers(
            0, INT16_MAX_VOCAB + 9, size=(25, 3)).astype(np.int64), axis=0)
    tm_big = TransitionMatrix.from_sids(big, INT16_MAX_VOCAB + 9, dense_d=0)
    slab_big = CompressedSlab.from_matrix(tm_big)
    assert slab_big.tok_delta.dtype == jnp.int32
    np.testing.assert_array_equal(
        segment_decode(slab_big, tm_big),
        np.asarray(tm_big.edges[: tm_big.n_edges, 0]))


def test_base_for_step_recovers_next_states(corpus):
    """``next = edge_idx + base[step]`` must equal the stored dst column
    on every non-leaf sparse level — the whole reason dst can be dropped."""
    from repro.core.trie import infer_level_blocks

    sids, _ = corpus
    d = 1
    tm = TransitionMatrix.from_sids(sids, V, dense_d=d)
    slab = CompressedSlab.from_matrix(tm)
    blocks = infer_level_blocks(
        np.asarray(tm.row_pointers), np.asarray(tm.edges),
        n_states=tm.n_states, n_edges=tm.n_edges, sid_length=L,
        dense_d=d, vocab_size=V)
    dst = np.asarray(tm.edges[: tm.n_edges, 1], dtype=np.int64)
    for step in range(d, L - 1):  # leaf level's dst is unused by decode
        lo, hi = int(blocks.edge_offsets[step]), int(
            blocks.edge_offsets[step + 1])
        base = int(slab.base_for_step(step))
        np.testing.assert_array_equal(
            dst[lo:hi], np.arange(lo, hi, dtype=np.int64) + base,
            err_msg=f"step={step}")


def test_from_store_stacked_and_hot_swap_treedef(corpus):
    sids, _ = corpus
    rng = np.random.default_rng(8)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    decoy = np.unique(make_sids(rng, 60, V, L), axis=0)
    store = ConstraintStore.from_matrices(
        [TransitionMatrix.from_sids(decoy, V, dense_d=1), tm], headroom=0.3)
    slab = CompressedSlab.from_store(store)
    assert slab.is_stacked
    assert slab.tok_delta.shape == (2, store.edges.shape[-2])
    assert slab.level_base.shape == (2, L)
    for k in range(2):
        m = store.member(k)
        sk = dataclasses.replace(
            slab, tok_delta=slab.tok_delta[k], level_base=slab.level_base[k])
        np.testing.assert_array_equal(
            segment_decode(sk, m), np.asarray(m.edges[: m.n_edges, 0]))
    # hot-swap: a member replacement inside the envelope keeps the treedef
    fresh = np.unique(make_sids(rng, 55, V, L), axis=0)
    swapped = store.with_member(
        0, TransitionMatrix.from_sids(fresh, V, dense_d=1))
    slab2 = CompressedSlab.from_store(swapped)
    assert (jax.tree_util.tree_structure(slab)
            == jax.tree_util.tree_structure(slab2))
    assert all(a.shape == b.shape and a.dtype == b.dtype
               for a, b in zip(jax.tree_util.tree_leaves(slab),
                               jax.tree_util.tree_leaves(slab2)))


def test_non_canonical_slab_raises(corpus):
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    # corrupt the next-state column: no longer consecutive per level block
    edges = np.asarray(tm.edges).copy()
    edges[: tm.n_edges, 1] = edges[: tm.n_edges, 1][::-1]
    bad = dataclasses.replace(tm, edges=jnp.asarray(edges))
    with pytest.raises(ValueError):
        CompressedSlab.from_matrix(bad)


def test_nbytes_halves_the_slab(corpus):
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    slab = CompressedSlab.from_matrix(tm)
    uncompressed = tm.edges.size * tm.edges.dtype.itemsize
    # int16 deltas + O(L) base table vs 8 B/edge: ~4x smaller
    assert slab.nbytes() < 0.3 * uncompressed


def test_build_dispatches_on_shape(corpus):
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    store = ConstraintStore.from_matrices([tm, tm])
    assert not CompressedSlab.build(tm).is_stacked
    assert CompressedSlab.build(store).is_stacked


# ---------------------------------------------------------------------------
# decode bit-identity: compressed policies == uncompressed, XLA and Pallas
# ---------------------------------------------------------------------------
def run_search(corpus, policy, stacked=False, batch=3, beams=6):
    sids, table = corpus

    def logits_fn(carry, last, step):
        return table[step][last], carry

    cids = jnp.ones((batch,), jnp.int32) if stacked else None
    state, _ = beam_search(logits_fn, None, batch, beams, L, policy,
                           constraint_ids=cids)
    return np.asarray(state.tokens), np.asarray(state.scores)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("topk", [False, True])
def test_compressed_policy_bit_identical(corpus, impl, topk):
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    base = DecodePolicy.static(tm, impl=impl, topk=topk)
    comp = DecodePolicy.static(tm, impl=impl, topk=topk, compressed=True)
    want_t, want_s = run_search(corpus, base)
    got_t, got_s = run_search(corpus, comp)
    np.testing.assert_array_equal(got_t, want_t)
    np.testing.assert_array_equal(got_s, want_s)


@pytest.mark.parametrize("topk", [False, True])
def test_compressed_stacked_bit_identical(corpus, topk):
    sids, _ = corpus
    rng = np.random.default_rng(21)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=1)
    decoy = np.unique(make_sids(rng, 70, V, L), axis=0)
    store = ConstraintStore.from_matrices(
        [TransitionMatrix.from_sids(decoy, V, dense_d=1), tm], headroom=0.2)
    base = DecodePolicy.stacked(store, topk=topk)
    comp = DecodePolicy.stacked(store, topk=topk, compressed=True)
    want_t, want_s = run_search(corpus, base, stacked=True)
    got_t, got_s = run_search(corpus, comp, stacked=True)
    np.testing.assert_array_equal(got_t, want_t)
    np.testing.assert_array_equal(got_s, want_s)


def test_compressed_opts_out_of_level_free(corpus):
    """The per-LEVEL next-state base cannot serve mixed-depth batches: a
    compressed all-sparse policy must refuse the level-free path rather
    than decode wrong next states."""
    sids, _ = corpus
    tm = TransitionMatrix.from_sids(sids, V, dense_d=0)
    assert DecodePolicy.static(tm).supports_level_free
    assert not DecodePolicy.static(tm, compressed=True).supports_level_free
