"""Continuous-batching engine: allocator/scheduler invariants, level-free
masking bit-identity, and the differential fuzz vs ``ServingEngine``.

The load-bearing assertion is the fuzz: per-request ``(sids, scores)`` out
of the step-boundary engine must equal the sequence-boundary engine's
output **bit-for-bit** — across mixed tenants, duplicate prompts (prefix
sharing), mid-flight admissions, and a registry hot-swap.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.constraints import (
    ConstraintRegistry,
    category_allowlist,
    freshness_window,
    synthetic_catalog,
)
from repro.core import TransitionMatrix
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.scenarios import gr_model_config
from repro.serving.continuous import (
    ContinuousServingEngine,
    PagedKVAllocator,
    PrefixShareTable,
    StepScheduler,
)
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever
from conftest import make_sids


# ---------------------------------------------------------------------------
# paged allocator: refcount invariant under arbitrary interleavings
# ---------------------------------------------------------------------------
def test_allocator_directed_errors():
    a = PagedKVAllocator(4)  # pages 1..3
    p = a.alloc(2)
    with pytest.raises(MemoryError):
        a.alloc(2)
    a.retain(p)
    a.release(p)
    a.check()
    a.release(p)
    with pytest.raises(ValueError):
        a.release([p[0]])  # double free
    with pytest.raises(ValueError):
        a.retain([p[0]])  # retain of unowned page
    a.check()
    assert a.n_free == 3 and a.n_referenced == 0


def test_allocator_property_random_interleavings():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(0, 2 ** 16), min_size=1, max_size=120),
           st.integers(4, 24))
    def run(ops, n_pages):
        a = PagedKVAllocator(n_pages)
        model: dict[int, int] = {}  # page -> refcount (the oracle)
        held: list[int] = []
        for op in ops:
            kind = op % 3
            if kind == 0:  # alloc 1..2 pages
                n = 1 + (op // 3) % 2
                if n <= a.n_free:
                    for pg in a.alloc(n):
                        model[pg] = 1
                        held.append(pg)
                else:
                    with pytest.raises(MemoryError):
                        a.alloc(n)
            elif kind == 1 and held:  # retain a random held page
                pg = held[(op // 3) % len(held)]
                a.retain([pg])
                model[pg] += 1
                held.append(pg)
            elif kind == 2 and held:  # release a random held ref
                pg = held.pop((op // 3) % len(held))
                a.release([pg])
                model[pg] -= 1
                if model[pg] == 0:
                    del model[pg]
            a.check()
            assert a.n_referenced == len(model)
            for pg, c in model.items():
                assert a.refcount(pg) == c
        # full drain: never leaks
        for pg in held:
            a.release([pg])
        a.check()
        assert a.n_free == n_pages - 1 and a.n_referenced == 0

    run()


def test_prefix_share_table_refcounts_and_lru():
    a = PagedKVAllocator(8)
    t = PrefixShareTable(a, capacity=2)
    rows = [np.full(4, i, np.int32) for i in range(3)]
    pages = [a.alloc(2) for _ in range(3)]
    logits = [np.full(5, float(i), np.float32) for i in range(3)]
    t.insert(rows[0], pages[0], logits[0])
    t.insert(rows[1], pages[1], logits[1])
    assert a.refcount(pages[0][0]) == 2  # caller + table
    assert t.contains(rows[0]) and not t.contains(rows[2])
    hit = t.lookup(rows[0])
    assert hit is not None
    got_pages, got_logits = hit
    assert tuple(got_pages) == tuple(pages[0])
    np.testing.assert_array_equal(got_logits, logits[0])
    assert a.refcount(pages[0][0]) == 3  # lookup retained for the caller
    a.release(got_pages)
    # row0 was just used (MRU): inserting row2 evicts row1
    t.insert(rows[2], pages[2], logits[2])
    assert not t.contains(rows[1]) and t.contains(rows[0])
    assert a.refcount(pages[1][0]) == 1  # table's ref released on eviction
    # drop_all releases every table ref; caller refs survive
    t.drop_all()
    a.check()
    for pg in pages:
        a.release(pg)
    a.check()
    assert a.n_free == 7


# ---------------------------------------------------------------------------
# step scheduler: chunked prefill + deadline shedding
# ---------------------------------------------------------------------------
def test_scheduler_chunked_admission_caps_fresh_prefills():
    sched = StepScheduler(n_slots=6, sid_length=3, prefill_chunk=2)
    q = RequestQueue()
    for i in range(6):
        q.submit(np.full(4, i, np.int32), 3)
    admissions, fresh = sched.plan_admissions(q, lambda r: False)
    assert len(fresh) == 2 and len(admissions) == 2  # chunk caps the step
    assert len(q) == 4  # the rest waits for the next step boundary
    # share hits bypass the chunk: everything left admits in one step
    for slot, r, _ in admissions:
        sched.admit(slot, r)
    admissions2, fresh2 = sched.plan_admissions(q, lambda r: True)
    assert len(admissions2) == 4 and not fresh2
    assert all(hit for _, _, hit in admissions2)


def test_scheduler_deadline_shedding_preserves_survivors():
    sched = StepScheduler(n_slots=2, sid_length=3, prefill_chunk=1,
                          deadline_s=10.0)
    q = RequestQueue()
    r0 = q.submit(np.zeros(4, np.int32), 3, 0)
    r1 = q.submit(np.ones(4, np.int32), 3, 1)
    # age request r0 past the deadline without sleeping
    import time
    for lane in q._lanes.values():
        for req in lane:
            if req.rid == r0:
                req.t_enqueue = time.monotonic() - 99.0
    shed = sched.shed_expired(q)
    assert [r.rid for r in shed] == [r0]
    assert len(q) == 1
    survivor = q.pop()
    assert survivor.rid == r1  # rid and enqueue time survive the re-queue
    assert time.monotonic() - survivor.t_enqueue < 5.0


def test_scheduler_levels_and_eviction_order():
    sched = StepScheduler(n_slots=3, sid_length=2, prefill_chunk=3)
    q = RequestQueue()
    q.submit(np.zeros(4, np.int32), 2)
    admissions, fresh = sched.plan_admissions(q, lambda r: False)
    sched.admit(admissions[0][0], admissions[0][1])
    assert sched.n_live == 1 and sched.completed() == []
    sched.advance()
    assert sched.slots[admissions[0][0]].t_first is not None
    sched.advance()
    done = sched.completed()
    assert done == [admissions[0][0]]
    st = sched.evict(done[0])
    assert st.level == 2 and sched.n_live == 0
    assert sched.slots[done[0]].served == 1


# ---------------------------------------------------------------------------
# level-free + shared-mask bit-identity (unit scale)
# ---------------------------------------------------------------------------
def test_shared_mask_step_bitwise_vs_per_level(rng):
    vocab, L = 24, 3
    sids = make_sids(rng, 60, vocab, L)
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=0)
    policy = DecodePolicy.static(tm)
    assert policy.supports_level_free
    B, M = 4, 3
    nodes = jnp.ones((B, M), jnp.int32)
    for step in range(L):
        logits = jnp.asarray(
            rng.standard_normal((B, M, vocab)), jnp.float32)
        want_lp, want_next = policy.step(logits, nodes, step)
        for share_width in (None, 2, B * M):
            got_lp, got_next, n_uni = policy.shared_mask_step(
                logits.reshape(B * M, vocab), nodes.reshape(B * M),
                share_width=share_width)
            np.testing.assert_array_equal(
                np.asarray(want_lp).reshape(B * M, vocab),
                np.asarray(got_lp))
            np.testing.assert_array_equal(
                np.asarray(want_next).reshape(B * M, vocab),
                np.asarray(got_next))
        assert int(n_uni) <= B * M
        # advance all rows along the best edge to reach the next level
        tok = jnp.argmax(want_lp, axis=-1)
        nodes = jnp.take_along_axis(
            want_next, tok[:, :, None], axis=-1)[:, :, 0].astype(jnp.int32)


def test_level_free_requires_all_sparse_index(rng):
    sids = make_sids(rng, 40, 16, 3)
    tm = TransitionMatrix.from_sids(sids, 16, dense_d=2)
    policy = DecodePolicy.static(tm)
    assert not policy.supports_level_free
    with pytest.raises(ValueError, match="dense_d=0"):
        policy.shared_mask_step(
            jnp.zeros((4, 16), jnp.float32), jnp.ones(4, jnp.int32))


# ---------------------------------------------------------------------------
# the engine: differential fuzz vs ServingEngine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def gr_stack():
    rng = np.random.default_rng(7)
    vocab, L, beam = 32, 3, 4
    cfg = gr_model_config(vocab)
    params = transformer.init_params(cfg, jax.random.key(0))
    catalog = synthetic_catalog(rng, 300, vocab, L)
    registry = ConstraintRegistry(vocab, dense_d=0, headroom=0.5)
    registry.register("fresh", freshness_window(60.0))
    registry.register("cats", category_allowlist(0, 1, 2, 3))
    registry.build(catalog)
    policy = DecodePolicy.stacked(registry.current()[0])
    retr = GenerativeRetriever(params, cfg, policy, L, vocab,
                               beam_size=beam)
    ref = ServingEngine(params, cfg, batch_size=3, max_len=16,
                        retriever=retr, registry=registry)
    cont = ContinuousServingEngine(
        retr, registry=registry, slots=5, prompt_width=8, page_size=4,
        prefill_chunk=2, share_width=12)
    return dict(vocab=vocab, L=L, registry=registry, catalog=catalog,
                ref=ref, cont=cont, rng=rng)


def _drive_both(stack, n_req, seed, dup_every=4):
    vocab, L = stack["vocab"], stack["L"]
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, size=(n_req, 8)).astype(np.int32)
    for i in range(dup_every, n_req, dup_every):
        prompts[i] = prompts[i - dup_every]  # exercise prompt sharing
    q1, q2 = RequestQueue(), RequestQueue()
    for i in range(n_req):
        cid = int(i % 2)
        q1.submit(prompts[i], L, cid)
        q2.submit(prompts[i], L, cid)
    return stack["ref"].serve(q1), stack["cont"].serve(q2)


def test_fuzz_bit_identical_to_serving_engine(gr_stack):
    a, b = _drive_both(gr_stack, 13, seed=11)
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(
            a[rid]["sids"], b[rid]["sids"],
            err_msg=f"rid {rid}: SID beams diverged")
        np.testing.assert_array_equal(
            a[rid]["scores"], b[rid]["scores"],
            err_msg=f"rid {rid}: beam scores diverged")
        assert b[rid]["constraint_id"] == a[rid]["constraint_id"]
        assert "latency_s" in b[rid] and "queue_s" in b[rid]


def test_fuzz_bit_identical_across_hot_swap(gr_stack):
    churned = synthetic_catalog(np.random.default_rng(13), 300,
                                gr_stack["vocab"], gr_stack["L"])
    gr_stack["registry"].swap(churned)
    a, b = _drive_both(gr_stack, 9, seed=17)
    for rid in a:
        np.testing.assert_array_equal(a[rid]["sids"], b[rid]["sids"])
        np.testing.assert_array_equal(a[rid]["scores"], b[rid]["scores"])
    cont = gr_stack["cont"]
    unexpected = cont.metrics.counter(
        "serving_recompiles_total").value(expected="false")
    assert int(unexpected) == 0, "hot swap recompiled the continuous step"


def test_mid_flight_admission_and_sharing_counters(gr_stack):
    cont = gr_stack["cont"]
    # more requests than slots forces step-boundary refills
    _drive_both(gr_stack, 12, seed=23)
    assert int(cont._slot_reuse.total()) > 0, \
        "no slot was ever refilled mid-flight"
    hits = cont.metrics.counter("serving_prefix_share_hits_total")
    assert int(hits.value(kind="prompt")) > 0, \
        "duplicate prompts never hit the prefix-share table"
    assert int(hits.value(kind="mask_row")) > 0, \
        "beams on one trie node never shared a mask row"
    cont.alloc.check()  # drained serve leaves the page pool consistent


def test_deadline_shedding_end_to_end(gr_stack):
    cont = gr_stack["cont"]
    vocab, L = gr_stack["vocab"], gr_stack["L"]
    cont.sched.deadline_s = 0.0  # every queued request is already late
    try:
        q = RequestQueue()
        rng = np.random.default_rng(29)
        rids = [q.submit(rng.integers(0, vocab, 8).astype(np.int32), L, 0)
                for _ in range(3)]
        before = int(cont._m.rejected.total())
        out = cont.serve(q)
        assert all("error" in out[rid] for rid in rids)
        assert all("sids" not in out[rid] for rid in rids)
        assert int(cont._m.rejected.total()) == before + 3
    finally:
        cont.sched.deadline_s = None


def test_continuous_rejects_non_level_free_policy(gr_stack):
    rng = np.random.default_rng(31)
    sids = make_sids(rng, 40, 16, 3)
    tm = TransitionMatrix.from_sids(sids, 16, dense_d=2)
    cfg = gr_model_config(16)
    params = transformer.init_params(cfg, jax.random.key(1))
    retr = GenerativeRetriever(params, cfg, DecodePolicy.static(tm), 3, 16,
                               beam_size=2)
    with pytest.raises(ValueError, match="dense_d=0"):
        ContinuousServingEngine(retr, slots=2)
