"""Optimizer unit tests (pure-JAX AdamW / Adafactor / SGD)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.optimizer import adafactor, adamw, global_norm, sgd_momentum


def quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    params = {"w": jnp.zeros((4, 8), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}

    def loss(p):
        return jnp.mean((p["w"] + p["b"][None, :] - target) ** 2)

    return params, loss


@pytest.mark.parametrize("opt_fn", [
    lambda: adamw(lr=5e-2), lambda: adafactor(lr=5e-2),
    lambda: sgd_momentum(lr=5e-2),
])
def test_optimizer_decreases_loss(opt_fn):
    opt = opt_fn()
    params, loss = quadratic_problem()
    state = opt.init(params)
    l0 = float(loss(params))
    for step in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(step))
    assert float(loss(params)) < l0 * 0.2


def test_adamw_bf16_params_f32_state():
    opt = adamw(lr=1e-2)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_p, state = opt.update(g, state, params, jnp.asarray(0))
    assert new_p["w"].dtype == jnp.bfloat16
    assert not np.allclose(np.asarray(new_p["w"], np.float32), 1.0)


def test_grad_clip_bounds_update():
    opt = adamw(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros((8,), jnp.float32)}
    state = opt.init(params)
    g = {"w": jnp.full((8,), 1e6, jnp.float32)}
    new_p, _ = opt.update(g, state, params, jnp.asarray(0))
    # clipped grad norm 1e-3 => first adam step is bounded by ~lr
    assert float(jnp.max(jnp.abs(new_p["w"]))) < 1.5


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((16, 32), jnp.float32), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st["w"]["vr"].shape == (16,)
    assert st["w"]["vc"].shape == (32,)
    assert st["b"]["v"].shape == (32,)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
