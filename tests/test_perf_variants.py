"""§Perf optimization flags must be numerically equivalent to baselines.

Every hillclimb optimization (EXPERIMENTS.md §Perf) is a *schedule/layout*
change, never a math change — asserted here on smoke configs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer
from repro.models.moe import moe_ffn


def test_deferred_commit_decode_equivalence():
    """defer_cache_write=True produces identical logits; pending k/v equal
    what the eager path wrote into the cache slot."""
    cfg = smoke_config("stablelm-12b")
    cfg_d = dataclasses.replace(cfg, defer_cache_write=True)
    params = transformer.init_params(cfg, jax.random.key(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    _, cache = transformer.prefill(params, tokens[:, :S], cfg, max_len=S + 4)
    logits_a, cache_a = transformer.decode_step(
        params, cache, tokens[:, S:S + 1], cfg)
    logits_b, cache_b, pending = transformer.decode_step(
        params, cache, tokens[:, S:S + 1], cfg_d)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=2e-4, atol=2e-4)
    # pending k/v == what the eager path wrote at slot S
    k_pend, v_pend = pending
    np.testing.assert_allclose(
        np.asarray(k_pend[:, :, 0], np.float32),
        np.asarray(cache_a.k[:, :, S], np.float32), rtol=2e-2, atol=2e-2)
    # deferred path leaves the cache array untouched at slot S
    np.testing.assert_array_equal(
        np.asarray(cache_b.k[:, :, S], np.float32),
        np.asarray(cache.k[:, :, S], np.float32))


def test_deferred_commit_mla_equivalence():
    cfg = smoke_config("deepseek-v2-lite-16b")
    cfg_d = dataclasses.replace(cfg, defer_cache_write=True)
    params = transformer.init_params(cfg, jax.random.key(0))
    B, S = 2, 6
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    _, cache = transformer.prefill(params, tokens[:, :S], cfg, max_len=S + 4)
    logits_a, _ = transformer.decode_step(params, cache, tokens[:, S:S + 1], cfg)
    logits_b, _, _ = transformer.decode_step(
        params, cache, tokens[:, S:S + 1], cfg_d)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v2-lite-16b"])
def test_grouped_dispatch_equivalence(arch):
    """Per-sequence dispatch groups == flat dispatch at high capacity."""
    cfg = smoke_config(arch)
    hi_cap = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg_flat = dataclasses.replace(cfg, moe=dataclasses.replace(
        hi_cap, dispatch_groups=0))
    cfg_grp = dataclasses.replace(cfg, moe=dataclasses.replace(
        hi_cap, dispatch_groups=4))
    params = transformer.init_params(cfg_flat, jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    xa, _, _ = transformer.forward(params, tok, cfg_flat)
    xb, _, _ = transformer.forward(params, tok, cfg_grp)
    np.testing.assert_allclose(
        np.asarray(xa, np.float32), np.asarray(xb, np.float32),
        rtol=3e-4, atol=3e-4)


def test_gr_batched_beam_layout_equivalence():
    """(L, B, M, S, KV, hd) beam layout == flat (L, B*M, S, KV, hd)."""
    cfg = smoke_config("static-gr")
    cfg_b = dataclasses.replace(cfg, gr_batched_beams=True)
    params = transformer.init_params(cfg, jax.random.key(0))
    L_layers = cfg.n_layers
    B, M, S_h, S_sid = 2, 3, 6, 4
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    rng = np.random.default_rng(0)
    hk = jnp.asarray(rng.normal(size=(L_layers, B, S_h, KV, hd)).astype(np.float32))
    hv = jnp.asarray(rng.normal(size=(L_layers, B, S_h, KV, hd)).astype(np.float32))
    bk = jnp.asarray(rng.normal(size=(L_layers, B, M, S_sid, KV, hd)).astype(np.float32))
    bv = jnp.asarray(rng.normal(size=(L_layers, B, M, S_sid, KV, hd)).astype(np.float32))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B * M, 1)).astype(np.int32))
    step = jnp.asarray(1, jnp.int32)

    logits_b, nbk, nbv = transformer.gr_decode_step(
        params, hk, hv, bk, bv, toks, step, cfg_b)
    flat = lambda a: a.reshape(L_layers, B * M, S_sid, KV, hd)
    logits_f, fbk, fbv = transformer.gr_decode_step(
        params, hk, hv, flat(bk), flat(bv), toks, step, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_b), np.asarray(logits_f), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(flat(nbk)), np.asarray(fbk), rtol=2e-4, atol=2e-4)


def test_split_k_flag_is_noop_without_mesh():
    """decode_split_k with empty sp_axes must not change single-device math."""
    cfg = smoke_config("qwen1.5-110b")
    cfg_s = dataclasses.replace(cfg, decode_split_k=True, sp_axes=())
    params = transformer.init_params(cfg, jax.random.key(0))
    B, S = 2, 6
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    _, cache = transformer.prefill(params, tokens[:, :S], cfg, max_len=S + 4)
    la, _ = transformer.decode_step(params, cache, tokens[:, S:S + 1], cfg)
    from repro.launch.mesh import make_mesh_compat, set_mesh_compat

    mesh = make_mesh_compat((1,), ("data",))
    with set_mesh_compat(mesh):
        cfg_s = dataclasses.replace(cfg_s, sp_axes=("data",))
        lb, _ = transformer.decode_step(params, cache, tokens[:, S:S + 1], cfg_s)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=2e-4,
                               atol=2e-4)
