"""Data pipeline: deterministic loader, cold-start protocol, graph sampler."""
import numpy as np
import pytest

from repro.data.amazon import make_cold_start_dataset
from repro.data.graph_sampler import CSRGraph, fanout_sample, random_graph
from repro.data.loader import ShardedBatcher
from repro.data.synthetic import make_item_corpus, make_user_sequences


def test_loader_deterministic_and_disjoint():
    data = {"x": np.arange(1000)}
    a = ShardedBatcher(data, 100, seed=7)
    b = ShardedBatcher(data, 100, seed=7)
    for _ in range(15):  # crosses an epoch boundary
        np.testing.assert_array_equal(next(a)["x"], next(b)["x"])
    # host shards partition the global batch
    h0 = ShardedBatcher(data, 100, seed=7, n_hosts=4, host_id=0)
    h1 = ShardedBatcher(data, 100, seed=7, n_hosts=4, host_id=1)
    x0, x1 = next(h0)["x"], next(h1)["x"]
    assert x0.shape == (25,) and not set(x0) & set(x1)


def test_loader_state_resume():
    data = {"x": np.arange(512)}
    a = ShardedBatcher(data, 64, seed=1)
    for _ in range(11):
        next(a)
    st = a.state()
    want = [next(a)["x"] for _ in range(5)]
    b = ShardedBatcher(data, 64, seed=1)
    b.restore(st)
    got = [next(b)["x"] for _ in range(5)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_epoch_reshuffles():
    data = {"x": np.arange(128)}
    a = ShardedBatcher(data, 128, seed=0)
    e0 = next(a)["x"]
    e1 = next(a)["x"]
    assert not np.array_equal(e0, e1)
    assert set(e0) == set(e1) == set(range(128))


def test_cold_start_protocol():
    d = make_cold_start_dataset(seed=0, n_items=1000, cold_frac=0.05)
    cold = set(d.cold_items.tolist())
    assert len(cold) == 50
    # train sequences contain NO cold item anywhere
    assert not np.isin(d.train_seqs, d.cold_items).any()
    # every test target is cold
    assert np.isin(d.test_seqs[:, -1], d.cold_items).all()
    # cold items are the newest
    assert d.item_age[d.cold_items].min() > np.median(d.item_age)


def test_synthetic_sequences_cluster_sticky():
    rng = np.random.default_rng(0)
    feats, cid = make_item_corpus(rng, 500, 10, 16)
    seqs = make_user_sequences(rng, 200, 20, cid, stay_prob=0.9)
    trans = cid[seqs]
    same = (trans[:, 1:] == trans[:, :-1]).mean()
    assert same > 0.6  # sticky


def test_fanout_sampler_shapes_and_validity():
    rng = np.random.default_rng(0)
    g = random_graph(rng, 500, avg_degree=8, feat_dim=12)
    seeds = rng.choice(500, 32, replace=False)
    out = fanout_sample(g, seeds, (5, 3), rng)
    n_exp = 32 * (1 + 5 + 15)
    e_exp = 32 * (5 + 15)
    assert out["node_feats"].shape == (n_exp, 12)
    assert out["senders"].shape == (e_exp,)
    # every real edge points from a sampled node to its parent
    em = out["edge_mask"]
    assert em.sum() > 0
    assert (out["senders"][em] < n_exp).all()
    assert (out["receivers"][em] < n_exp).all()
    assert out["node_mask"][out["receivers"][em]].all()
    assert out["node_mask"][out["senders"][em]].all()
    # hop-1 receivers are seeds
    hop1 = out["receivers"][: 32 * 5][out["edge_mask"][: 32 * 5]]
    assert (hop1 < 32).all()


def test_fanout_sampler_handles_low_degree():
    # graph where some nodes have degree < fanout
    indptr = np.array([0, 1, 1, 3])
    indices = np.array([1, 0, 2])
    g = CSRGraph(indptr, indices, np.ones((3, 4), np.float32))
    rng = np.random.default_rng(0)
    out = fanout_sample(g, np.array([0, 1, 2]), (4,), rng)
    assert out["node_mask"].shape == (3 * 5,)
    # node 1 has no neighbors -> no extra sampled nodes from it
    assert out["edge_mask"].sum() <= 3 * 4
