"""Direct unit tests for the KV cache layer (ring, slot writes, paged pools).

The ring/advance semantics were previously only exercised indirectly through
full decode runs; these pin them at the function level — including the
wraparound path and the decode_step/attention slot agreement that used to be
derived independently in two places.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import kvcache as kv


# ---------------------------------------------------------------------------
# advance_positions: ring wraparound + linear clamp
# ---------------------------------------------------------------------------
def test_advance_positions_ring_wraparound():
    n_slots = 4
    slot_pos = jnp.full((n_slots,), -1, jnp.int32)
    for pos in range(11):
        slot_pos, slot = kv.advance_positions(
            slot_pos, jnp.asarray(pos, jnp.int32), n_slots, ring=True)
        assert int(slot) == pos % n_slots
        assert int(slot_pos[pos % n_slots]) == pos
    # after wrapping, every slot holds the latest position that mapped to it
    want = [8, 9, 10, 7]  # pos % 4 -> slot; last writers of each slot
    assert slot_pos.tolist() == want


def test_advance_positions_linear_clamps_at_last_slot():
    n_slots = 4
    slot_pos = jnp.arange(n_slots, dtype=jnp.int32)
    for pos in (2, 3, 4, 9):
        _, slot = kv.advance_positions(
            slot_pos, jnp.asarray(pos, jnp.int32), n_slots, ring=False)
        assert int(slot) == min(pos, n_slots - 1)


# ---------------------------------------------------------------------------
# write_slot: only the target slot changes; values are dtype-cast
# ---------------------------------------------------------------------------
def test_write_slot_isolation_and_cast():
    B, S, H, D = 2, 5, 3, 4
    base = jnp.arange(B * S * H * D, dtype=jnp.bfloat16).reshape(B, S, H, D)
    new = jnp.full((B, 1, H, D), 2.5, jnp.float32)
    out = kv.write_slot(base, new, jnp.asarray(2, jnp.int32))
    assert out.dtype == base.dtype
    np.testing.assert_array_equal(
        np.asarray(out[:, [0, 1, 3, 4]], np.float32),
        np.asarray(base[:, [0, 1, 3, 4]], np.float32))
    np.testing.assert_array_equal(
        np.asarray(out[:, 2], np.float32),
        np.full((B, H, D), 2.5, np.float32))


# ---------------------------------------------------------------------------
# cache constructors
# ---------------------------------------------------------------------------
def test_init_kv_cache_ring_flag_semantics():
    c = kv.init_kv_cache(2, 1, max_len=16, n_kv_heads=2, head_dim=4,
                         window=8)
    assert c.ring and c.k.shape[2] == 8
    c = kv.init_kv_cache(2, 1, max_len=6, n_kv_heads=2, head_dim=4,
                         window=8)
    assert not c.ring and c.k.shape[2] == 6  # window never reached
    c = kv.init_kv_cache(2, 1, max_len=6, n_kv_heads=2, head_dim=4)
    assert not c.ring and c.k.shape[2] == 6
    assert c.slot_pos.tolist() == [-1] * 6 and int(c.pos) == 0


def test_init_mla_cache_shapes():
    c = kv.init_mla_cache(3, 2, max_len=7, kv_lora_rank=8, rope_dim=4,
                          dtype=jnp.float32)
    assert c.c_kv.shape == (3, 2, 7, 8)
    assert c.k_rope.shape == (3, 2, 7, 4)
    assert c.slot_pos.shape == (7,) and c.slot_pos.tolist() == [-1] * 7
    assert int(c.pos) == 0


# ---------------------------------------------------------------------------
# paged pools
# ---------------------------------------------------------------------------
def test_pages_for():
    assert kv.pages_for(0, 4) == 0
    assert kv.pages_for(1, 4) == 1
    assert kv.pages_for(4, 4) == 1
    assert kv.pages_for(5, 4) == 2


def test_scatter_gather_round_trip_exact_width():
    rng = np.random.default_rng(0)
    nl, B, S, H, D, ps = 2, 3, 6, 2, 4, 4  # S=6 needs 2 pages of 4
    n_per = kv.pages_for(S, ps)
    pool, _ = kv.init_page_pool(nl, 1 + B * n_per, ps, H, D)
    rows = jnp.asarray(rng.standard_normal((nl, B, S, H, D)), jnp.float32)
    page_ids = jnp.arange(1, 1 + B * n_per, dtype=jnp.int32).reshape(B, n_per)
    pool = kv.scatter_pages(pool, rows, page_ids)
    got = kv.gather_pages(pool[0], page_ids, S)
    # exact hist_len slice: page-granule padding never comes back
    assert got.shape == (B, S, H, D)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows[0]))


def test_gather_pages_null_page_reads_zeros():
    pool, _ = kv.init_page_pool(1, 4, 4, 2, 4)
    table = jnp.zeros((2, 1), jnp.int32)  # all slots -> NULL page
    got = kv.gather_pages(pool[0], table, 3)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_shared_page_is_stored_once():
    """Two slots pointing at the same page read identical storage."""
    nl, ps, H, D = 1, 4, 2, 3
    pool, _ = kv.init_page_pool(nl, 3, ps, H, D)
    rows = jnp.asarray(
        np.random.default_rng(1).standard_normal((nl, 1, 4, H, D)),
        jnp.float32)
    pool = kv.scatter_pages(pool, rows, jnp.asarray([[1]], jnp.int32))
    table = jnp.asarray([[1], [1]], jnp.int32)
    got = kv.gather_pages(pool[0], table, 4)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(got[1]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(rows[0, 0]))
