"""Algorithm 1/2 correctness: constrained masking vs brute-force oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NEG_INF, TransitionMatrix, constrain_log_probs
from repro.core.constrained import constrained_decoding_step
from conftest import make_sids


def oracle_mask(sids, prefixes, step, vocab):
    """valid[i, v] == (prefixes[i,:step] + [v]) is a prefix of some SID."""
    nb = prefixes.shape[0]
    out = np.zeros((nb, vocab), bool)
    pset = {tuple(r[: step + 1]) for r in sids}
    for i in range(nb):
        base = tuple(int(x) for x in prefixes[i, :step])
        for v in range(vocab):
            if base + (v,) in pset:
                out[i, v] = True
    return out


def walk_nodes(tm, sids_np, prefixes, step):
    """Drive constrain_log_probs step-by-step to obtain the node vector."""
    nb = prefixes.shape[0]
    nodes = jnp.ones((nb,), jnp.int32)
    vocab = tm.vocab_size
    for t in range(step):
        lp = jnp.zeros((nb, vocab), jnp.float32)
        _, nxt = constrain_log_probs(lp, nodes, tm, t)
        nodes = nxt[jnp.arange(nb), prefixes[:, t]]
    return nodes


@pytest.mark.parametrize("dense_d", [0, 1, 2])
@pytest.mark.parametrize("vocab,length,n", [(8, 3, 40), (16, 4, 300)])
def test_masking_matches_oracle(rng, dense_d, vocab, length, n):
    sids = make_sids(rng, n, vocab, length, clustered=True)
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=dense_d)
    nb = 24
    for step in range(length):
        # half the prefixes valid, half random (likely invalid)
        valid_rows = sids[rng.integers(0, sids.shape[0], nb // 2)][:, :length]
        rand_rows = make_sids(rng, nb - nb // 2, vocab, length)
        prefixes = np.concatenate([valid_rows, rand_rows], axis=0)
        nodes = walk_nodes(tm, sids, jnp.asarray(prefixes.astype(np.int32)), step)
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        masked, nxt = constrain_log_probs(lp, nodes, tm, step)
        want = oracle_mask(sids, prefixes, step, vocab)
        got = np.asarray(masked) > NEG_INF / 2
        assert np.array_equal(got, want), f"step={step} dense_d={dense_d}"
        # surviving entries keep their log-prob unchanged
        np.testing.assert_allclose(
            np.asarray(masked)[want], np.asarray(lp)[want], rtol=1e-6
        )
        # next state is sink exactly where invalid
        nxt = np.asarray(nxt)
        assert np.all((nxt > 0) == want)


def test_next_states_consistent_across_dense_paths(rng):
    """dense_d 0/1/2 must yield identical masks at every step."""
    vocab, length = 16, 4
    sids = make_sids(rng, 120, vocab, length, clustered=True)
    tms = [TransitionMatrix.from_sids(sids, vocab, dense_d=d) for d in (0, 1, 2)]
    nb = 16
    prefixes = sids[rng.integers(0, sids.shape[0], nb)].astype(np.int32)
    for step in range(length):
        lp = jnp.asarray(rng.normal(size=(nb, vocab)).astype(np.float32))
        outs = []
        for tm in tms:
            nodes = walk_nodes(tm, sids, jnp.asarray(prefixes), step)
            masked, _ = constrain_log_probs(lp, nodes, tm, step)
            outs.append(np.asarray(masked))
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])


def test_full_decoding_step_normalizes(rng):
    vocab, length = 16, 4
    sids = make_sids(rng, 50, vocab, length)
    tm = TransitionMatrix.from_sids(sids, vocab)
    logits = jnp.asarray(rng.normal(size=(4, 5, vocab)).astype(np.float32))
    nodes = jnp.ones((4, 5), jnp.int32)
    lp, nxt = constrained_decoding_step(logits, nodes, tm, step=0)
    # masked entries are NEG_INF; valid entries are proper log-probs
    valid = np.asarray(lp) > NEG_INF / 2
    ref = jax.nn.log_softmax(logits, axis=-1)
    np.testing.assert_allclose(
        np.asarray(lp)[valid], np.asarray(ref)[valid], rtol=1e-5
    )
    assert nxt.shape == logits.shape


def test_unconstrained_passthrough(rng):
    logits = jnp.asarray(rng.normal(size=(2, 3, 8)).astype(np.float32))
    nodes = jnp.ones((2, 3), jnp.int32)
    lp, _ = constrained_decoding_step(logits, nodes, None, step=0)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(jax.nn.log_softmax(logits, -1)), rtol=1e-6
    )


def test_sink_state_masks_everything(rng):
    vocab = 8
    sids = make_sids(rng, 20, vocab, 3)
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=0)
    lp = jnp.zeros((3, vocab), jnp.float32)
    nodes = jnp.zeros((3,), jnp.int32)  # SINK
    masked, nxt = constrain_log_probs(lp, nodes, tm, step=2)
    assert np.all(np.asarray(masked) <= NEG_INF / 2)
    assert np.all(np.asarray(nxt) == 0)


@pytest.mark.parametrize("dense_d", [0, 1, 2])
def test_save_load_roundtrip(tmp_path, rng, dense_d):
    """Full roundtrip incl. the dense_d==0 dummy-array path (all-ones l0
    mask, (1, 1) l1 tables) that ConstraintStore.save/load reuses."""
    sids = make_sids(rng, 100, 16, 4)
    tm = TransitionMatrix.from_sids(sids, 16, dense_d=dense_d)
    path = str(tmp_path / "tm.npz")
    tm.save(path)
    tm2 = TransitionMatrix.load(path)
    assert tm2.level_bmax == tm.level_bmax
    assert tm2.n_states == tm.n_states
    assert tm2.dense_d == tm.dense_d
    assert tm2.n_constraints == tm.n_constraints
    for f in ("row_pointers", "edges", "l0_mask_packed", "l0_states",
              "l1_mask_packed", "l1_states"):
        np.testing.assert_array_equal(
            np.asarray(getattr(tm, f)), np.asarray(getattr(tm2, f)), err_msg=f
        )
    lp = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    nodes = jnp.ones((2,), jnp.int32)
    for step in range(4):
        a, an = constrain_log_probs(lp, nodes, tm, step)
        b, bn = constrain_log_probs(lp, nodes, tm2, step)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(an), np.asarray(bn))
