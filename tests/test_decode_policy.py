"""DecodePolicy: one compiled constraint-backend API (DESIGN.md §5).

The load-bearing property of the redesign: every constraint method — STATIC
dense+VNTK on XLA / Pallas / fused, the stacked multi-tenant store, and the
§5.2 baselines — runs through the *same* policy-driven ``beam_search`` and,
when the method is exact, returns identical top-M SIDs and scores on a
shared synthetic trie.  Plus 100% corpus compliance (paper §5.4) for every
constrained backend, and the ``as_policy`` coercion surface.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constraints import ConstraintStore
from repro.core import NEG_INF, TransitionMatrix, beam_search
from repro.core.baselines import CpuTrieBaseline, PPVBaseline
from repro.decoding import (
    DecodePolicy,
    PPVBackend,
    StackedStaticBackend,
    StaticBackend,
    UnconstrainedBackend,
    as_policy,
)
from conftest import make_sids

V, L, N = 16, 4, 120
B, M = 3, 8


@pytest.fixture(scope="module")
def shared():
    """One synthetic trie + step-dependent toy scorer for every policy."""
    rng = np.random.default_rng(7)
    sids = np.unique(make_sids(rng, N, V, L, clustered=True), axis=0)
    tm = TransitionMatrix.from_sids(sids, V, dense_d=2)
    table = jnp.asarray(rng.normal(size=(L, V)).astype(np.float32))
    return sids, tm, table


def run_policy(policy, table, batch=B, beams=M, cids=None):
    def logits_fn(carry, last, step):
        b, m = last.shape
        return jnp.broadcast_to(table[step], (b, m, V)), carry

    state, _ = beam_search(
        logits_fn, None, batch, beams, L, policy, constraint_ids=cids
    )
    return np.asarray(state.tokens), np.asarray(state.scores)


def make_policy(name, sids, tm):
    if name == "dense_vntk_xla":
        return DecodePolicy.static(tm)
    if name == "vntk_pallas":
        return DecodePolicy.static(tm, impl="pallas")
    if name == "fused":
        return DecodePolicy.static(tm, fused=True)
    if name == "dense_d0":
        return DecodePolicy.static(
            TransitionMatrix.from_sids(sids, V, dense_d=0)
        )
    if name == "dense_d1":
        return DecodePolicy.static(
            TransitionMatrix.from_sids(sids, V, dense_d=1)
        )
    if name == "ppv_exact":
        return DecodePolicy.ppv(sids, V, exact=True)
    if name == "ppv_approx":
        # top_k >= V verifies every logit => exact despite the approx path
        return DecodePolicy.ppv(sids, V, exact=False, top_k=V)
    if name == "cpu_trie":
        return DecodePolicy.cpu_trie(sids, V)
    if name == "hash_bitmap":
        # 2^22 bits vs ~1e3 probed prefixes: FP-free at this corpus scale
        return DecodePolicy.hash_bitmap(sids, V, log2_bits=22)
    raise AssertionError(name)


ALL_EXACT = ["dense_vntk_xla", "vntk_pallas", "fused", "dense_d0", "dense_d1",
             "ppv_exact", "ppv_approx", "cpu_trie", "hash_bitmap"]


# ---------------------------------------------------------------------------
# cross-backend equivalence: identical top-M SIDs and scores
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_EXACT)
def test_cross_backend_equivalence(shared, name):
    sids, tm, table = shared
    want_tokens, want_scores = run_policy(DecodePolicy.static(tm), table)
    got_tokens, got_scores = run_policy(make_policy(name, sids, tm), table)
    np.testing.assert_array_equal(got_tokens, want_tokens, err_msg=name)
    np.testing.assert_allclose(got_scores, want_scores, rtol=1e-5,
                               err_msg=name)


def test_stacked_equivalence_per_row(shared, rng):
    """A K=3 store with per-row ids == each row under its standalone matrix."""
    sids, tm, table = shared
    sid_sets = [sids] + [
        np.unique(make_sids(rng, n, V, L, clustered=True), axis=0)
        for n in (60, 200)
    ]
    mats = [TransitionMatrix.from_sids(s, V, dense_d=2) for s in sid_sets]
    store = ConstraintStore.from_matrices(mats, headroom=0.25)
    cids = np.arange(3, dtype=np.int32)
    got_tokens, got_scores = run_policy(
        DecodePolicy.stacked(store), table, cids=jnp.asarray(cids)
    )
    for row, tm_row in enumerate(mats):
        want_tokens, want_scores = run_policy(
            DecodePolicy.static(tm_row), table, batch=1
        )
        np.testing.assert_array_equal(got_tokens[row], want_tokens[0])
        np.testing.assert_allclose(got_scores[row], want_scores[0], rtol=1e-5)


# ---------------------------------------------------------------------------
# 100% corpus compliance (paper §5.4) under the real beam search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_EXACT)
def test_compliance_all_constrained_backends(shared, name):
    sids, tm, table = shared
    tokens, scores = run_policy(make_policy(name, sids, tm), table)
    valid = {tuple(r) for r in sids}
    for b in range(B):
        for m in range(M):
            if scores[b, m] > NEG_INF / 2:
                assert tuple(tokens[b, m]) in valid, (name, tokens[b, m])


def test_unconstrained_policy_hallucinates(shared):
    """Sanity: the unconstrained lower bound leaves a tiny corpus."""
    _, _, table = shared
    rng = np.random.default_rng(1)
    tiny = make_sids(rng, 5, V, L)
    tokens, _ = run_policy(DecodePolicy.unconstrained(), table, batch=1)
    valid = {tuple(r) for r in tiny}
    assert any(tuple(tokens[0, m]) not in valid for m in range(M))


# ---------------------------------------------------------------------------
# plan construction / introspection
# ---------------------------------------------------------------------------
def test_static_plan_splits_dense_and_sparse(shared):
    _, tm, _ = shared
    p = DecodePolicy.static(tm)
    assert p.plan == (0, 0, 1, 1)  # dense_d=2, L=4
    assert isinstance(p.backend_for(0), StaticBackend)
    assert p.backend_for(0).levels == "dense"
    assert p.backend_for(3).levels == "sparse"
    assert p.sid_length == L and not p.requires_constraint_ids
    assert not p.needs_prefix and p.num_sets is None
    assert "dense-bitpack" in p.describe() and "vntk" in p.describe()


def test_policy_validation(shared):
    sids, tm, _ = shared
    with pytest.raises(ValueError, match="at least one"):
        DecodePolicy(backends=(), plan=(0,))
    with pytest.raises(ValueError, match="unknown backends"):
        DecodePolicy(backends=(UnconstrainedBackend(),), plan=(1,))
    # a dense-band backend consulted at a sparse step is a plan bug
    bad = DecodePolicy(
        backends=(StaticBackend(tm, levels="dense"),), plan=(0,) * L
    )
    lp = jnp.zeros((2, V), jnp.float32)
    nodes = jnp.ones((2,), jnp.int32)
    with pytest.raises(ValueError, match="fix the policy plan"):
        bad.step(lp, nodes, 3, normalized=True)
    # prefix backends refuse to run without emitted-token history
    with pytest.raises(ValueError, match="prefix"):
        DecodePolicy.ppv(sids, V).step(lp, nodes, 0, normalized=True)


def test_per_level_mixed_stacked_and_single(shared, rng):
    """The escape hatch may mix stacked and single-set backends per level:
    ids are handed only to the backends that consume them."""
    sids, tm, table = shared
    store = ConstraintStore.from_matrices([tm, tm])  # identical tenants
    mixed = DecodePolicy.per_level(
        backends=(
            StaticBackend(tm, levels="dense"),
            StackedStaticBackend(store, levels="sparse"),
        ),
        plan=(0, 0, 1, 1),
    )
    assert mixed.requires_constraint_ids
    cids = jnp.asarray(np.arange(B, dtype=np.int32) % 2)
    got_tokens, got_scores = run_policy(mixed, table, cids=cids)
    want_tokens, want_scores = run_policy(DecodePolicy.static(tm), table)
    np.testing.assert_array_equal(got_tokens, want_tokens)
    np.testing.assert_allclose(got_scores, want_scores, rtol=1e-5)


def test_constraint_ids_pairing(shared, rng):
    sids, tm, table = shared
    mats = [tm, TransitionMatrix.from_sids(make_sids(rng, 40, V, L), V)]
    store = ConstraintStore.from_matrices(mats)
    with pytest.raises(ValueError, match="constraint_ids"):
        run_policy(DecodePolicy.stacked(store), table)  # missing ids
    with pytest.raises(ValueError, match="ConstraintStore"):
        run_policy(DecodePolicy.static(tm), table,
                   cids=jnp.zeros(B, jnp.int32))  # ids without a store


# ---------------------------------------------------------------------------
# as_policy coercions (the documented non-deprecated surface)
# ---------------------------------------------------------------------------
def test_as_policy_coercions(shared, rng):
    sids, tm, _ = shared
    assert not as_policy(None).is_constrained
    assert as_policy(tm).constraints is tm
    store = ConstraintStore.from_matrices([tm, tm])
    assert isinstance(as_policy(store).backend_for(0), StackedStaticBackend)
    assert as_policy(CpuTrieBaseline(sids, V)).needs_prefix
    ppv = as_policy(PPVBaseline(sids, V))
    assert isinstance(ppv.backend_for(0), PPVBackend)
    p = DecodePolicy.static(tm)
    assert as_policy(p) is p
    with pytest.raises(TypeError, match="cannot build"):
        as_policy(object())


def test_legacy_kwarg_tunnel_removed(shared):
    """The PR 2 ``tm=``/``impl=``/``fused=`` shim is gone: bare carriers
    still coerce through ``policy=`` (via as_policy), but the deprecated
    kwarg names are plain TypeErrors now."""
    _, tm, table = shared
    want_tokens, want_scores = run_policy(DecodePolicy.static(tm), table)

    def logits_fn(carry, last, step):
        b, m = last.shape
        return jnp.broadcast_to(table[step], (b, m, V)), carry

    # a bare TransitionMatrix as policy= is the supported coercion
    state, _ = beam_search(logits_fn, None, B, M, L, policy=tm)
    np.testing.assert_array_equal(np.asarray(state.tokens), want_tokens)
    np.testing.assert_allclose(np.asarray(state.scores), want_scores,
                               rtol=1e-6)
    for legacy in ({"tm": tm}, {"impl": "xla"}, {"fused": True}):
        with pytest.raises(TypeError):
            beam_search(logits_fn, None, B, M, L, **legacy)


# ---------------------------------------------------------------------------
# hot-swap invariants at the policy level (the registry path's contract)
# ---------------------------------------------------------------------------
def test_with_constraints_preserves_treedef(shared, rng):
    sids, tm, _ = shared
    mats = [tm, TransitionMatrix.from_sids(
        make_sids(rng, 50, V, L, clustered=True), V)]
    store = ConstraintStore.from_matrices(mats, headroom=0.5)
    policy = DecodePolicy.stacked(store)
    fresh = TransitionMatrix.from_sids(
        make_sids(rng, 80, V, L, clustered=True), V)
    swapped = policy.with_constraints(store.with_member(0, fresh))
    assert jax.tree_util.tree_structure(swapped) == \
        jax.tree_util.tree_structure(policy)
    assert swapped.plan == policy.plan
    # type mismatches are rejected before any leaf moves
    with pytest.raises(TypeError, match="ConstraintStore"):
        policy.with_constraints(tm)
    with pytest.raises(TypeError, match="TransitionMatrix"):
        DecodePolicy.static(tm).with_constraints(store)
    with pytest.raises(TypeError, match="no swappable"):
        DecodePolicy.unconstrained().with_constraints(tm)


def test_policy_is_jit_argument_not_constant(shared, rng):
    """A hot-swap through a jitted step must not retrace: the policy is a
    pytree argument whose static metadata is swap-invariant."""
    sids, tm, _ = shared
    store = ConstraintStore.from_matrices([tm, tm], headroom=0.5)
    policy = DecodePolicy.stacked(store)
    traces = []

    @jax.jit
    def step0(lp, nodes, cids, pol):
        traces.append(1)
        return pol.step(lp, nodes, 0, constraint_ids=cids, normalized=True)

    lp = jnp.zeros((2, V), jnp.float32)
    nodes = jnp.ones((2,), jnp.int32)
    cids = jnp.asarray([0, 1], jnp.int32)
    step0(lp, nodes, cids, policy)
    fresh = TransitionMatrix.from_sids(
        make_sids(rng, 60, V, L, clustered=True), V)
    step0(lp, nodes, cids, policy.with_constraints(store.with_member(1, fresh)))
    assert len(traces) == 1, "registry hot-swap retraced the jitted step"


def test_explicit_is_stacked_property(shared, rng):
    _, tm, _ = shared
    assert tm.is_stacked is False
    store = ConstraintStore.from_matrices([tm, tm])
    assert store.is_stacked is True
