"""Quickstart: build a STATIC constraint index and run constrained decoding.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    NEG_INF, TransitionMatrix, beam_search, constrained_decoding_step,
)
from repro.decoding import DecodePolicy


def main():
    rng = np.random.default_rng(0)
    vocab, length = 64, 4

    # 1. The restricted vocabulary C: 200 Semantic IDs (e.g. "fresh items").
    sids = rng.integers(0, vocab, size=(200, length))
    print(f"|C| = {len(np.unique(sids, axis=0))} SIDs, |V| = {vocab}, L = {length}")

    # 2. Offline: flatten the prefix tree into the CSR transition matrix.
    tm = TransitionMatrix.from_sids(sids, vocab, dense_d=2)
    print(f"trie: {tm.n_states} states, {tm.n_edges} edges, "
          f"per-level max branch factors B = {tm.level_bmax}")

    # 3. One constrained decoding step (Algorithm 1): mask model logits.
    logits = jnp.asarray(rng.normal(size=(2, 3, vocab)).astype(np.float32))
    nodes = jnp.ones((2, 3), jnp.int32)  # all beams at the trie root
    masked, next_nodes = constrained_decoding_step(logits, nodes, tm, step=0)
    n_valid = int((np.asarray(masked[0, 0]) > NEG_INF / 2).sum())
    print(f"step 0: {n_valid} valid first tokens out of {vocab}")

    # 4. Full constrained beam search under a DecodePolicy: the per-level
    # plan binds dense bit-packed lookups for the first dense_d levels and
    # the VNTK for the rest (swap in impl="pallas" / fused=True, a stacked
    # ConstraintStore, or a §5.2 baseline without touching the loop).
    policy = DecodePolicy.static(tm)
    print(f"decode policy: {policy.describe()}")
    table = jnp.asarray(rng.normal(size=(length, vocab)).astype(np.float32))

    def logits_fn(carry, last, step):
        B, M = last.shape
        return jnp.broadcast_to(table[step], (B, M, vocab)), carry

    state, _ = beam_search(logits_fn, None, batch_size=2, beam_size=8,
                           length=length, policy=policy)
    valid = {tuple(r) for r in sids}
    beams = np.asarray(state.tokens)
    ok = all(
        tuple(beams[b, m]) in valid
        for b in range(2) for m in range(8)
        if state.scores[b, m] > NEG_INF / 2
    )
    print(f"top beam: {beams[0, 0].tolist()}  score {float(state.scores[0,0]):.3f}")
    print(f"100% compliance with C: {ok}")


if __name__ == "__main__":
    main()
