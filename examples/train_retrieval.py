"""Train a generative-retrieval model with the fault-tolerant trainer.

Demonstrates the full training substrate: sharded deterministic loader,
microbatch accumulation, int8 error-feedback gradient compression, atomic
async checkpointing, and exact resume after a simulated crash.  Quickstart::

    PYTHONPATH=src python examples/train_retrieval.py

The tokenizer/model plumbing here comes from the scenario stage layer
(``repro.scenarios``): ``train_rqvae`` + ``assign_dedup_tokens`` build the
Semantic IDs and ``gr_model_config`` sizes the retrieval transformer — the
same builders the ``cold_start_amazon`` scenario composes.  For the full
declarative pipeline (constraint index + serving + eval) use::

    PYTHONPATH=src python -m repro.launch.run_scenario \\
        --scenario cold_start_amazon --smoke
"""
import os
import shutil

import numpy as np

import jax
from repro.data.loader import ShardedBatcher
from repro.data.synthetic import make_item_corpus, make_user_sequences
from repro.models import transformer
from repro.scenarios import gr_model_config, train_rqvae
from repro.configs.base import RQVAEConfig
from repro.models import rqvae
from repro.training.optimizer import adamw
from repro.training.trainer import Trainer, TrainerConfig

CKPT = "/tmp/repro_train_retrieval_ckpt"


def main():
    rng = np.random.default_rng(0)
    feats, cid = make_item_corpus(rng, 1_000, 32, 64)
    seqs = make_user_sequences(rng, 3_000, 10, cid)

    rq_cfg = RQVAEConfig(feat_dim=64, n_levels=4, codebook_size=256)
    rq = train_rqvae(feats, rq_cfg, steps=200, log=print)
    sids = np.asarray(rqvae.encode_to_sids(rq, feats, rq_cfg))
    tokens = sids[seqs].reshape(seqs.shape[0], -1).astype(np.int32)

    cfg = gr_model_config(256)
    params = transformer.init_params(cfg, jax.random.key(0))

    def loss_fn(p, batch):
        return transformer.lm_loss(p, batch["tokens"], cfg)

    shutil.rmtree(CKPT, ignore_errors=True)
    tcfg = TrainerConfig(
        n_steps=120, microbatches=2, ckpt_dir=CKPT, ckpt_every=40,
        ckpt_async=True, grad_compression=True, log_every=20,
    )
    trainer = Trainer(loss_fn, adamw(lr=1e-3), params, tcfg)
    batches = ShardedBatcher({"tokens": tokens}, global_batch=64, seed=0)

    print("--- phase 1: train to step 80, then simulate a crash ---")
    trainer.cfg.n_steps = 80
    trainer.fit(batches, log=print)
    trainer.maybe_checkpoint(data_state=batches.state(), force=True)
    print(f"'crash' at step {trainer.step}; straggler events: "
          f"{trainer.straggler_events}")

    print("--- phase 2: fresh trainer, resume from checkpoint ---")
    t2 = Trainer(loss_fn, adamw(lr=1e-3), params, tcfg)
    assert t2.resume(), "no checkpoint found"
    print(f"resumed at step {t2.step}")
    b2 = ShardedBatcher({"tokens": tokens}, global_batch=64, seed=0)
    b2.restore(batches.state())
    t2.cfg.n_steps = 120
    losses = t2.fit(b2, log=print)
    print(f"final loss {losses[-1]:.4f} after exact resume "
          f"(ckpts in {CKPT}: {sorted(os.listdir(CKPT))[-2:]})")


if __name__ == "__main__":
    main()
