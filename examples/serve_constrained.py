"""Constrained generative-retrieval serving with batched requests.

Builds a small GR model, a 50k-item restricted corpus, and serves batched
retrieval requests through the ServingEngine / GenerativeRetriever stack,
reporting per-request latency and constraint compliance.

    PYTHONPATH=src python examples/serve_constrained.py
"""
import time

import jax
import numpy as np

from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.scenarios import gr_model_config
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever


def main():
    rng = np.random.default_rng(0)
    V, L, M = 256, 4, 8
    cfg = gr_model_config(V)
    params = transformer.init_params(cfg, jax.random.key(0))

    # Restricted corpus ("in-stock items"): 50k SIDs.
    sids = rng.integers(0, V, size=(50_000, L))
    t0 = time.time()
    tm = TransitionMatrix.from_sids(sids, V, dense_d=2)
    print(f"built CSR constraint index for |C|=50k in {time.time()-t0:.2f}s "
          f"({tm.n_states} states)")

    policy = DecodePolicy.static(tm)
    print(f"decode policy: {policy.describe()}")
    retriever = GenerativeRetriever(params, cfg, policy, sid_length=L,
                                    sid_vocab=V, beam_size=M)
    B = 4
    hist = rng.integers(0, V, size=(B, 16)).astype(np.int32)
    t0 = time.time()
    beams, scores = retriever.retrieve(hist)  # includes jit compile
    print(f"first batch (compile) {time.time()-t0:.2f}s")
    t0 = time.time()
    n = 5
    for _ in range(n):
        beams, scores = retriever.retrieve(hist)
    dt = (time.time() - t0) / n
    valid = {tuple(r) for r in sids}
    ok = all(
        tuple(beams[b, m]) in valid
        for b in range(B) for m in range(M)
        if scores[b, m] > NEG_INF / 2
    )
    print(f"batched retrieval: {dt*1e3:.1f} ms/batch of {B} "
          f"({M} beams x {L} SID levels); 100% compliance: {ok}")

    # plain token serving through the continuous-batching engine
    eng = ServingEngine(params, cfg, batch_size=4, max_len=64)
    q = RequestQueue()
    for _ in range(8):
        q.submit(rng.integers(0, V, size=(12,)), n_tokens=6)
    t0 = time.time()
    results = eng.serve(q)
    print(f"continuous batching drained 8 requests in {time.time()-t0:.2f}s; "
          f"lengths: {sorted(len(v) for v in results.values())}")


if __name__ == "__main__":
    main()
