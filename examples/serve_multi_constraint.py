"""Multi-tenant constrained serving: one batch, many business constraints.

Builds an item catalog with freshness/category metadata, registers three
business predicates in the ConstraintRegistry, and serves a queue whose
requests carry different constraint ids — all masked inside ONE shared
constrained beam-search batch (DESIGN.md §4).  Then hot-swaps a refreshed
catalog snapshot mid-serve and shows (a) the new constraint sets take effect
at the next batch boundary and (b) zero recompilation happened.

    PYTHONPATH=src python examples/serve_multi_constraint.py
"""
import time

import jax
import numpy as np

from repro.constraints import (
    ConstraintRegistry,
    ItemCatalog,
    category_allowlist,
    freshness_window,
)
from repro.core.vntk import NEG_INF
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.scenarios import gr_model_config
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever


def make_catalog(rng, n_items, V, L):
    return ItemCatalog(
        sids=rng.integers(0, V, size=(n_items, L)),
        age_days=rng.uniform(0.0, 90.0, size=n_items),
        category=rng.integers(0, 4, size=n_items),
    )


def compliant_fraction(results, registry, catalog, predicates):
    total = ok = 0
    for r in results.values():
        mask = predicates[r["constraint_id"]](catalog)
        valid = {tuple(x) for x in catalog.sids[mask]}
        for m, sid in enumerate(r["sids"]):
            if r["scores"][m] > NEG_INF / 2:
                total += 1
                ok += tuple(sid) in valid
    return ok, total


def main():
    rng = np.random.default_rng(0)
    V, L, M, B = 256, 4, 8, 4
    cfg = gr_model_config(V)
    params = transformer.init_params(cfg, jax.random.key(0))

    catalog = make_catalog(rng, 20_000, V, L)
    registry = ConstraintRegistry(V, headroom=0.5)
    predicates = {}
    predicates[registry.register("fresh_7d", freshness_window(7))] = \
        freshness_window(7)
    predicates[registry.register("fresh_30d", freshness_window(30))] = \
        freshness_window(30)
    predicates[registry.register("cat_0_1", category_allowlist(0, 1))] = \
        category_allowlist(0, 1)
    t0 = time.time()
    store = registry.build(catalog)
    print(f"registry v{registry.version}: {store.num_sets} constraint sets, "
          f"{store.nbytes()/1e6:.2f} MB stacked store "
          f"({time.time()-t0:.2f}s build)")

    policy = DecodePolicy.stacked(store)
    print(f"decode policy: {policy.describe()}")
    retriever = GenerativeRetriever(params, cfg, policy, sid_length=L,
                                    sid_vocab=V, beam_size=M)
    engine = ServingEngine(params, cfg, batch_size=B, max_len=32,
                           retriever=retriever, registry=registry)

    # Count backend compiles to demonstrate the swap costs none.
    compiles = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: compiles.append(name)
        if "backend_compile" in name else None
    )

    queue = RequestQueue()
    rids = [
        queue.submit(rng.integers(0, V, size=(12,)), n_tokens=L,
                     constraint_id=i % 3)
        for i in range(9)
    ]
    t0 = time.time()
    results = engine.serve(queue)
    ok, total = compliant_fraction(results, registry, catalog, predicates)
    print(f"served {len(rids)} mixed-constraint requests in "
          f"{time.time()-t0:.2f}s (incl. compile); "
          f"compliance {ok}/{total} beams")

    # ---- hot-swap: nightly corpus refresh (new items, re-aged inventory) ----
    catalog2 = make_catalog(rng, 21_000, V, L)
    t0 = time.time()
    v = registry.swap(catalog2)
    print(f"hot-swapped to registry v{v} in {time.time()-t0:.2f}s")

    n_before = len(compiles)  # swap preserved all shapes/statics, so the
    # post-swap serve must not compile anything new
    for i in range(6):
        queue.submit(rng.integers(0, V, size=(12,)), n_tokens=L,
                     constraint_id=i % 3)
    t0 = time.time()
    results2 = engine.serve(queue)
    ok2, total2 = compliant_fraction(results2, registry, catalog2, predicates)
    versions = {r["store_version"] for r in results2.values()}
    print(f"post-swap batch served in {time.time()-t0:.2f}s against store "
          f"v{versions}; compliance {ok2}/{total2} beams; "
          f"recompiles since swap: {len(compiles) - n_before}")


if __name__ == "__main__":
    main()
