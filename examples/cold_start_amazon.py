"""End-to-end driver (paper §6): cold-start generative retrieval.

Trains the full stack on CPU in a few minutes:
  synthetic Amazon-like corpus -> RQ-VAE Semantic IDs -> generative-retrieval
  transformer (several hundred steps) -> Recall@1 with
  {unconstrained, constrained-random, STATIC} decoding.

    PYTHONPATH=src python examples/cold_start_amazon.py [--quick]
"""
import argparse

from repro.pipelines import run_cold_start_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--cold-frac", type=float, default=0.02)
    args = ap.parse_args()

    res = run_cold_start_experiment(
        cold_frac=args.cold_frac,
        train_steps=150 if args.quick else 500,
        log=print,
    )
    print("\n=== Table 3 (reproduced on synthetic Amazon-like data) ===")
    print(f"cold-start fraction : {res['cold_frac']*100:.0f}% "
          f"({res['n_cold']} items, {res['n_test']} test sequences)")
    print(f"Unconstrained        Recall@1: {res['recall@1_unconstrained']*100:6.2f}%")
    print(f"Constrained Random   Recall@1: {res['recall@1_constrained_random']*100:6.2f}%")
    print(f"STATIC (ours)        Recall@1: {res['recall@1_static']*100:6.2f}%")


if __name__ == "__main__":
    main()
