"""End-to-end driver (paper §6): cold-start generative retrieval.

Launches the ``cold_start_amazon`` scenario through the ScenarioRegistry —
synthetic Amazon-like corpus -> RQ-VAE Semantic IDs -> generative-retrieval
transformer -> STATIC serving on the cold-only ConstraintRegistry slot,
reporting Recall@1 and hit-rate@M for {unconstrained, constrained-random,
STATIC}.  Quickstart::

    PYTHONPATH=src python examples/cold_start_amazon.py [--quick]

    # equivalent, via the unified launcher (any config field overridable):
    PYTHONPATH=src python -m repro.launch.run_scenario \\
        --scenario cold_start_amazon --smoke --set data.cold_frac=0.05

or from Python::

    from repro.scenarios import get_default_registry
    run = get_default_registry().resolve("cold_start_amazon", smoke=True)
    result = run.run(log=print)["result"]
"""
import argparse

from repro.scenarios import get_default_registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-size corpus + short training")
    ap.add_argument("--cold-frac", type=float, default=0.02)
    ap.add_argument("--trie-aware", type=float, default=0.0, metavar="W",
                    help="weight of the trie-aware admissible-mass "
                         "auxiliary loss (0 = off)")
    args = ap.parse_args()

    run = get_default_registry().resolve(
        "cold_start_amazon",
        smoke=args.quick,
        overrides={
            "data.cold_frac": args.cold_frac,
            "train.trie_aware_weight": args.trie_aware,
        },
    )
    res = run.run(log=print)["result"]
    m = res["beam_size"]
    print("\n=== Table 3 (reproduced on synthetic Amazon-like data) ===")
    print(f"cold-start fraction : {res['cold_frac']*100:.0f}% "
          f"({res['n_cold']} items, {res['n_test']} test sequences)")
    print(f"Unconstrained        Recall@1: "
          f"{res['recall@1_unconstrained']*100:6.2f}%   "
          f"hit@{m}: {res['hit@M_unconstrained']*100:6.2f}%")
    print(f"Constrained Random   Recall@1: "
          f"{res['recall@1_constrained_random']*100:6.2f}%")
    print(f"STATIC (ours)        Recall@1: "
          f"{res['recall@1_static']*100:6.2f}%   "
          f"hit@{m}: {res['hit@M_static']*100:6.2f}%")
    print(f"gates: {res['gates']}")


if __name__ == "__main__":
    main()
