"""Scenario registry: one declarative launch surface (DESIGN.md §12).

A scenario is a frozen :class:`ScenarioConfig` resolved by the
:class:`ScenarioRegistry` into a composed, resumable pipeline of stages
(``Data -> Tokenizer -> Index -> Train -> Serve -> Eval``).  Quickstart::

    from repro.scenarios import get_default_registry

    run = get_default_registry().resolve("cold_start_amazon", smoke=True)
    ctx = run.run(log=print)
    print(ctx["result"])          # metrics + gates

or from the CLI::

    PYTHONPATH=src python -m repro.launch.run_scenario \\
        --scenario cold_start_amazon --smoke --json BENCH_coldstart.json
"""
from repro.scenarios import trie_signal
from repro.scenarios.config import (
    DataConfig,
    EvalConfig,
    IndexConfig,
    ScenarioConfig,
    ServeConfig,
    SlotSpec,
    TokenizerConfig,
    TrainConfig,
    apply_overrides,
    config_to_dict,
    parse_override,
)
from repro.scenarios.registry import (
    ScenarioRegistry,
    ScenarioRun,
    ScenarioSpec,
    get_default_registry,
)
from repro.scenarios.stages import (
    DataStage,
    EvalStage,
    IndexStage,
    ServeStage,
    Stage,
    TokenizerStage,
    TrainStage,
    default_stages,
    gr_model_config,
    run_pipeline,
    train_rqvae,
)

__all__ = [
    "ScenarioConfig", "DataConfig", "TokenizerConfig", "IndexConfig",
    "TrainConfig", "ServeConfig", "EvalConfig", "SlotSpec",
    "apply_overrides", "parse_override", "config_to_dict",
    "ScenarioRegistry", "ScenarioRun", "ScenarioSpec",
    "get_default_registry",
    "Stage", "DataStage", "TokenizerStage", "IndexStage", "TrainStage",
    "ServeStage", "EvalStage", "default_stages", "run_pipeline",
    "gr_model_config", "train_rqvae", "trie_signal",
]
