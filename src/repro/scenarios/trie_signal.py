"""Trie-aware training signal: per-prefix admissible-set statistics.

Trie-Aware Transformers (arxiv 2602.21677, PAPERS.md) feed the decoding
trie's structure back into *training*: at every SID position the model is
told (or regularized toward) the set of tokens the constrained decoder will
actually admit.  This module derives those statistics from the same sorted
SID slab the refresh layer retains (:class:`~repro.constraints.refresh
.TrieSource`) — the trie is never materialized; everything falls out of
run-length structure over the lexsorted rows, the exact technique
``TrieSource._assemble`` uses to rebuild the CSR:

  * a row starts a new ``(l+1)``-prefix iff it differs from its predecessor
    in some column ``<= l``;
  * the admissible set after an ``l``-prefix is the set of distinct
    ``(l+1)``-prefix starts inside that prefix's row range;
  * so per-level sizes are ``searchsorted`` diffs and per-level masks are
    one scatter per level — O(N·L) + O(groups·V) host work, run once per
    tokenization.

The :class:`~repro.scenarios.stages.TrainStage` gates this behind
``TrainConfig.trie_aware_weight`` (default 0.0 = off): when on, the stats
are computed over the WARM-item trie (cold items are invisible at train
time, matching the serving-side information the model could legitimately
see) and fed to :func:`~repro.models.transformer.lm_loss_trie_aware` as the
admissible-mass auxiliary loss.
"""
from __future__ import annotations

import numpy as np

from repro.constraints.refresh import TrieSource, row_keys

__all__ = [
    "admissible_stats",
    "source_admissible",
    "map_items_to_slab",
    "item_admissible",
]


def _stats_sorted(s: np.ndarray, vocab_size: int):
    """Stats over LEXSORTED rows ``s`` (N, L) -> (sizes (N, L), masks
    (N, L, V)).

    ``sizes[i, l]`` = |admissible tokens after prefix ``s[i, :l]``|;
    ``masks[i, l, t]`` = True iff token ``t`` is admissible there (i.e. some
    row extends ``s[i, :l]`` with ``t``).  Level 0 is the root: one group
    spanning every row.
    """
    N, L = s.shape
    sizes = np.empty((N, L), dtype=np.int32)
    masks = np.zeros((N, L, vocab_size), dtype=bool)
    # new[l, i]: row i starts a new (l+1)-prefix
    new = np.ones((L, N), dtype=bool)
    for lvl in range(L):
        if N > 1:
            new[lvl, 1:] = (
                s[1:, : lvl + 1] != s[:-1, : lvl + 1]
            ).any(axis=1)
    for lvl in range(L):
        if lvl == 0:
            pos_prev = np.zeros(1, dtype=np.int64)  # the root group
            g_of_row = np.zeros(N, dtype=np.int64)
        else:
            pos_prev = np.flatnonzero(new[lvl - 1])
            g_of_row = np.cumsum(new[lvl - 1]) - 1
        pos_l = np.flatnonzero(new[lvl])  # starts of distinct children
        counts = np.diff(np.searchsorted(pos_l, np.append(pos_prev, N)))
        sizes[:, lvl] = counts[g_of_row]
        g_of_start = np.searchsorted(pos_prev, pos_l, side="right") - 1
        gm = np.zeros((pos_prev.shape[0], vocab_size), dtype=bool)
        gm[g_of_start, s[pos_l, lvl]] = True
        masks[:, lvl] = gm[g_of_row]
    return sizes, masks


def admissible_stats(sids: np.ndarray, vocab_size: int):
    """Per-row admissible stats of the trie over ``sids``, in input order.

    Returns ``(sizes (N, L) int32, masks (N, L, V) bool)`` where row ``i``
    describes the decoder's view along item ``i``'s own SID path:
    ``masks[i, l]`` is the admissible token set after emitting
    ``sids[i, :l]``.  Rows need not be sorted or unique.
    """
    s = np.asarray(sids, dtype=np.int64)
    if s.ndim != 2:
        raise ValueError(f"sids must be (N, L), got shape {s.shape}")
    order = np.lexsort(tuple(s[:, c] for c in range(s.shape[1] - 1, -1, -1)))
    inv = np.empty_like(order)
    inv[order] = np.arange(order.shape[0])
    sizes, masks = _stats_sorted(s[order], vocab_size)
    return sizes[inv], masks[inv]


def source_admissible(source: TrieSource):
    """Stats over a TrieSource's retained slab, in slab (sorted) order.

    Returns ``(slab_sids (N, L) int64, sizes (N, L), masks (N, L, V))`` —
    the slab view is already lexsorted and unique, so this skips the sort.
    """
    slab = np.asarray(source.sids, dtype=np.int64)
    sizes, masks = _stats_sorted(slab, source.vocab_size)
    return slab, sizes, masks


def map_items_to_slab(item_sids: np.ndarray,
                      slab_sids: np.ndarray) -> np.ndarray:
    """Catalog-order item SIDs -> their row indices in the sorted slab.

    Raises if any item is absent from the slab: feeding a cold item's
    prefix statistics into training would leak the held-out set.
    """
    item_sids = np.asarray(item_sids, dtype=np.int64)
    slab_sids = np.asarray(slab_sids, dtype=np.int64)
    slab_keys = row_keys(slab_sids)
    item_keys = row_keys(item_sids)
    rows = np.searchsorted(slab_keys, item_keys)
    rows = np.clip(rows, 0, max(slab_keys.shape[0] - 1, 0))
    if slab_keys.shape[0] == 0 or not (slab_keys[rows] == item_keys).all():
        missing = int((slab_keys[rows] != item_keys).sum()) if \
            slab_keys.shape[0] else item_keys.shape[0]
        raise ValueError(
            f"{missing} item SID(s) not present in the trie slab"
        )
    return rows


def item_admissible(item_sids: np.ndarray, source: TrieSource):
    """Per-item stats in CATALOG order, from a TrieSource slab.

    Returns ``(sizes (N, L) int32, masks (N, L, V) bool)`` aligned with
    ``item_sids`` — the shape the TrainStage gathers per batch.
    """
    slab, sizes, masks = source_admissible(source)
    rows = map_items_to_slab(item_sids, slab)
    return sizes[rows], masks[rows]
