"""Composable pipeline stages: Data -> Tokenizer -> Index -> Train -> Serve
-> Eval (DESIGN.md §12).

Each stage consumes the frozen :class:`~repro.scenarios.config
.ScenarioConfig` plus a mutable context dict and deposits the artifacts it
``provides``.  :func:`run_pipeline` composes them and makes the pipeline
*resumable*: a stage whose provided keys are already in the context is
skipped, so a caller can re-enter with a partially populated context (e.g.
re-serve under a new constraint slot without re-training) — asserted in
``tests/test_scenarios.py``.

This is the refactored ``pipelines.py`` monolith: the cold-start loop now
runs through the production stack — RQ-VAE Semantic IDs
(:mod:`repro.models.rqvae`), trie build via
:class:`~repro.constraints.ConstraintRegistry` (predicates select the
servable subset, including the cold-items-only slot), and serving through
:class:`~repro.decoding.DecodePolicy` + :class:`~repro.serving
.generative_retrieval.GenerativeRetriever` behind a serving engine — so the
Table 3 evaluation exercises byte-for-byte the same jitted decode path as
``loadgen``.  No hand-rolled NEG_INF masking anywhere.

Seed discipline: every stochastic component derives its stream from
``cfg.seed`` plus a documented offset (the ``SEED_*`` constants), making two
runs of the same config bit-reproducible.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import RQVAEConfig, TransformerConfig
from repro.constraints import (
    AsyncRefresher,
    CatalogDelta,
    ConstraintRegistry,
    ItemCatalog,
    TrieSource,
    category_allowlist,
    freshness_window,
    synthetic_catalog,
)
from repro.core.vntk import NEG_INF
from repro.data.amazon import make_cold_start_dataset
from repro.data.loader import ShardedBatcher
from repro.decoding import DecodePolicy
from repro.models import rqvae, transformer
from repro.scenarios import trie_signal
from repro.scenarios.config import ScenarioConfig, SlotSpec
from repro.serving.engine import RequestQueue, ServingEngine
from repro.serving.generative_retrieval import GenerativeRetriever
from repro.training.optimizer import adamw
from repro.training.trainer import Trainer, TrainerConfig

__all__ = [
    "Stage",
    "DataStage",
    "TokenizerStage",
    "IndexStage",
    "TrainStage",
    "ServeStage",
    "EvalStage",
    "default_stages",
    "run_pipeline",
    "gr_model_config",
    "train_rqvae",
]

# One config seed, documented per-component offsets (bit-reproducibility):
SEED_DATA = 0  # corpus + split synthesis
SEED_RQVAE = 1  # RQ-VAE init + its training batch stream
SEED_MODEL = 2  # transformer init
SEED_BATCHER = 3  # ShardedBatcher epoch shuffles
SEED_REQUESTS = 5  # synthetic serving requests (catalog scenarios)
SEED_CHURN = 6  # refresh-churn delta sampling
SEED_BASELINE = 7  # constrained-random guessing baseline


def _noop_log(*a):  # pragma: no cover - default sink
    pass


def gr_model_config(vocab: int = 256, *, n_layers: int = 4,
                    d_model: int = 128, n_heads: int = 4, d_ff: int = 256,
                    name: str = "gr-coldstart") -> TransformerConfig:
    """The reduced generative-retrieval transformer (paper §6 scale)."""
    return TransformerConfig(
        name=name,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        d_ff=d_ff,
        vocab_size=vocab,
        head_dim=d_model // n_heads,
        tie_embeddings=True,
        dtype="float32",
        attn_chunk_q=64,
        attn_chunk_kv=64,
    )


def train_rqvae(feats: np.ndarray, cfg: RQVAEConfig, steps: int = 400,
                seed: int = 0, lr: float = 3e-3, batch: int = 256,
                log=_noop_log):
    """Train the RQ-VAE tokenizer on item features; returns its params."""
    params = rqvae.init_params(cfg, jax.random.key(seed))
    opt = adamw(lr=lr, weight_decay=0.0)
    state = opt.init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, batch, i):
        loss, g = jax.value_and_grad(
            lambda p: rqvae.rqvae_loss(p, batch, cfg)
        )(params)
        params, state = opt.update(g, state, params, i)
        return params, state, loss

    for i in range(steps):
        idx = rng.integers(0, feats.shape[0], batch)
        params, state, loss = step(
            params, state, jnp.asarray(feats[idx]), jnp.asarray(i)
        )
        if i % 100 == 0:
            log(f"rqvae step {i}: loss {float(loss):.4f}")
    return params


# ---------------------------------------------------------------------------
# stage protocol
# ---------------------------------------------------------------------------
class Stage:
    """One pipeline step: reads config + context, deposits ``provides``."""

    name = "stage"

    def provides(self, cfg: ScenarioConfig) -> tuple:
        """Context keys this stage deposits (the resume/skip contract)."""
        return ()

    def run(self, cfg: ScenarioConfig, ctx: dict, log) -> None:
        raise NotImplementedError


def run_pipeline(stages, cfg: ScenarioConfig, log=_noop_log,
                 ctx: dict | None = None) -> dict:
    """Run ``stages`` in order over a shared context; returns the context.

    A stage whose ``provides`` keys are all present is skipped — pass a
    pre-populated ``ctx`` to resume mid-pipeline (e.g. the artifacts of a
    previous run up to TrainStage, then re-serve with different serving
    config).
    """
    ctx = {} if ctx is None else ctx
    for stage in stages:
        keys = stage.provides(cfg)
        if keys and all(k in ctx for k in keys):
            log(f"[{cfg.name}] {stage.name}: resumed from context, skipping")
            continue
        log(f"[{cfg.name}] running stage: {stage.name}")
        stage.run(cfg, ctx, log)
        missing = [k for k in keys if k not in ctx]
        if missing:
            raise RuntimeError(
                f"stage {stage.name!r} did not provide {missing}"
            )
    return ctx


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------
class DataStage(Stage):
    name = "data"

    def provides(self, cfg):
        if cfg.data.kind == "amazon_cold_start":
            return ("data",)
        return ("catalog",)

    def run(self, cfg, ctx, log):
        d = cfg.data
        if d.kind == "amazon_cold_start":
            data = make_cold_start_dataset(
                seed=cfg.seed + SEED_DATA, n_items=d.n_items,
                n_clusters=d.n_clusters, feat_dim=d.feat_dim,
                n_users=d.n_users, seq_len=d.seq_len, cold_frac=d.cold_frac,
            )
            ctx["data"] = data
            log(f"  {d.n_items} items, {data.cold_items.shape[0]} cold, "
                f"{data.train_seqs.shape[0]} train / "
                f"{data.test_seqs.shape[0]} test sequences")
        elif d.kind == "synthetic_catalog":
            rng = np.random.default_rng(cfg.seed + SEED_DATA)
            ctx["catalog"] = synthetic_catalog(
                rng, d.n_items, cfg.tokenizer.codebook_size,
                cfg.tokenizer.resolved_sid_length,
                n_categories=d.n_categories, max_age_days=d.max_age_days,
            )
            log(f"  synthetic catalog: {d.n_items} items, "
                f"{d.n_categories} categories")
        else:
            raise ValueError(f"unknown data kind {d.kind!r}")


class TokenizerStage(Stage):
    name = "tokenizer"

    def provides(self, cfg):
        base = ("sids", "vocab", "sid_length")
        if cfg.tokenizer.kind == "rqvae":
            return base + ("rq_params", "rq_cfg")
        return base

    def run(self, cfg, ctx, log):
        t = cfg.tokenizer
        if t.kind == "rqvae":
            data = ctx["data"]
            rq_cfg = RQVAEConfig(
                feat_dim=data.item_feats.shape[1], latent_dim=t.latent_dim,
                n_levels=t.n_levels, codebook_size=t.codebook_size,
            )
            rq_params = train_rqvae(
                data.item_feats, rq_cfg, steps=t.train_steps,
                seed=cfg.seed + SEED_RQVAE, lr=t.lr, batch=t.batch, log=log,
            )
            levels = np.asarray(rqvae.encode_to_sids(
                rq_params, jnp.asarray(data.item_feats), rq_cfg
            ))
            # TIGER's collision fix: L = n_levels RQ codes + 1 dedup token
            sids = rqvae.assign_dedup_tokens(
                levels, t.codebook_size).astype(np.int64)
            ctx["rq_params"], ctx["rq_cfg"] = rq_params, rq_cfg
            ctx["sids"] = sids
            ctx["vocab"] = t.codebook_size
            ctx["sid_length"] = sids.shape[1]
            n_unique = np.unique(sids, axis=0).shape[0]
            log(f"  unique SIDs: {n_unique}/{sids.shape[0]}")
        elif t.kind == "random":
            cat = ctx["catalog"]
            ctx["sids"] = np.asarray(cat.sids)
            ctx["vocab"] = t.codebook_size
            ctx["sid_length"] = cat.sids.shape[1]
        else:
            raise ValueError(f"unknown tokenizer kind {t.kind!r}")


class IndexStage(Stage):
    name = "index"

    def provides(self, cfg):
        return ("registry", "store", "slots", "catalog", "predicates")

    def _predicate(self, spec: SlotSpec, ctx):
        if spec.kind == "all":
            return lambda cat: np.ones(cat.sids.shape[0], dtype=bool)
        if spec.kind == "cold_only":
            data = ctx.get("data")
            if data is None:
                raise ValueError(
                    "cold_only slots need the amazon_cold_start data kind"
                )
            # age_days maps the newest (cold) band to [0, n_cold), so a
            # freshness window at n_cold - 0.5 selects exactly the cold set
            return freshness_window(data.cold_items.shape[0] - 0.5)
        if spec.kind == "freshness":
            return freshness_window(float(spec.arg[0]))
        if spec.kind == "category":
            return category_allowlist(*(int(c) for c in spec.arg))
        raise ValueError(f"unknown slot kind {spec.kind!r}")

    def run(self, cfg, ctx, log):
        if "catalog" not in ctx:
            data = ctx["data"]
            ctx["catalog"] = ItemCatalog(
                sids=ctx["sids"], age_days=data.age_days,
                category=data.item_cluster.astype(np.int64),
            )
        reg = ConstraintRegistry(
            ctx["vocab"], dense_d=cfg.index.dense_d,
            headroom=cfg.index.headroom,
        )
        predicates = {}
        for spec in cfg.index.slots:
            pred = self._predicate(spec, ctx)
            reg.register(spec.name, pred)
            predicates[spec.name] = pred
        store = reg.build(ctx["catalog"])
        ctx["registry"] = reg
        ctx["store"] = store
        ctx["slots"] = {name: i for i, name in enumerate(reg.names)}
        ctx["predicates"] = predicates
        log(f"  registry v{reg.version}: slots {list(reg.names)}, "
            f"envelope {store.n_states} states")


class TrainStage(Stage):
    name = "train"

    def provides(self, cfg):
        return ("params", "model_cfg")

    def run(self, cfg, ctx, log):
        tr = cfg.train
        V, L = ctx["vocab"], ctx["sid_length"]
        mcfg = gr_model_config(
            V, n_layers=tr.n_layers, d_model=tr.d_model,
            n_heads=tr.n_heads, d_ff=tr.d_ff,
        )
        params = transformer.init_params(
            mcfg, jax.random.key(cfg.seed + SEED_MODEL))
        ctx["model_cfg"] = mcfg
        data = ctx.get("data")
        if data is None or tr.steps <= 0:
            # catalog-only scenarios exercise the serving path, not model
            # quality — an initialized model is all they need
            ctx["params"] = params
            return
        sids = ctx["sids"]
        train_tokens = sids[data.train_seqs].reshape(
            data.train_seqs.shape[0], -1).astype(np.int32)
        arrays = {"tokens": train_tokens}
        if tr.trie_aware_weight > 0.0:
            # admissible sets from the WARM-item trie slab only — the cold
            # set is invisible at train time, exactly as at serve time
            warm = np.ones(data.n_items, dtype=bool)
            warm[data.cold_items] = False
            warm_idx = np.flatnonzero(warm)
            source = TrieSource.from_sids(
                sids[warm_idx], V, dense_d=cfg.index.dense_d)
            sizes_w, masks_w = trie_signal.item_admissible(
                sids[warm_idx], source)
            masks = np.ones((data.n_items, L, V), dtype=bool)
            masks[warm_idx] = masks_w  # cold rows never appear in train_seqs
            masks_dev = jnp.asarray(masks)
            arrays["items"] = data.train_seqs.astype(np.int32)
            weight = float(tr.trie_aware_weight)
            log(f"  trie-aware signal on (weight {weight}); mean admissible "
                f"set size by level: "
                f"{np.round(sizes_w.mean(axis=0), 1).tolist()}")

            def loss_fn(p, batch):
                adm = masks_dev[batch["items"]]  # (B, T, L, V)
                adm = adm.reshape(adm.shape[0], -1, V)
                return transformer.lm_loss_trie_aware(
                    p, batch["tokens"], mcfg, adm, weight)
        else:
            def loss_fn(p, batch):
                return transformer.lm_loss(p, batch["tokens"], mcfg)

        trainer = Trainer(
            loss_fn, adamw(lr=tr.lr, weight_decay=0.0), params,
            TrainerConfig(n_steps=tr.steps, log_every=tr.log_every),
        )
        batches = ShardedBatcher(arrays, global_batch=tr.batch,
                                 seed=cfg.seed + SEED_BATCHER)
        trainer.fit(batches, log=log)
        ctx["params"] = trainer.params


class ServeStage(Stage):
    """Serve eval traffic through a real engine over the registry store."""

    name = "serve"

    def provides(self, cfg):
        return ("serve_results", "serve_meta")

    # -- engine construction ------------------------------------------------
    def _retriever_and_engine(self, cfg, ctx, prompt_width: int,
                              constrained: bool):
        sv = cfg.serve
        L, V = ctx["sid_length"], ctx["vocab"]
        policy = (
            DecodePolicy.stacked(ctx["store"], impl=sv.impl, fused=sv.fused,
                                 topk=sv.topk)
            if constrained else DecodePolicy.unconstrained()
        )
        registry = ctx["registry"] if constrained else None
        if sv.engine == "spmd":
            from repro.launch.mesh import make_debug_mesh
            from repro.serving.spmd_engine import (
                SpmdRetriever,
                SpmdServingEngine,
            )

            mesh = make_debug_mesh(
                model=2 if sv.spmd_rows == "model" else 1)
            retr = SpmdRetriever(
                ctx["params"], ctx["model_cfg"], policy, L, V,
                beam_size=sv.beam, mesh=mesh, rows=sv.spmd_rows)
            engine = SpmdServingEngine(
                retr, registry=registry, slots=sv.batch_size,
                prompt_width=prompt_width)
        elif sv.engine == "batch":
            retr = GenerativeRetriever(
                ctx["params"], ctx["model_cfg"], policy, L, V,
                beam_size=sv.beam)
            engine = ServingEngine(
                ctx["params"], ctx["model_cfg"], sv.batch_size,
                max_len=2 * prompt_width, retriever=retr, registry=registry)
        else:
            raise ValueError(f"unknown serve engine {sv.engine!r}")
        return retr, engine

    @staticmethod
    def _serve(engine, hist: np.ndarray, n_out: int,
               cids: np.ndarray | None):
        queue = RequestQueue()
        rids = [
            queue.submit(hist[i], n_out,
                         constraint_id=0 if cids is None else int(cids[i]))
            for i in range(hist.shape[0])
        ]
        res = engine.serve(queue)
        beams = np.stack([res[r]["sids"] for r in rids])
        scores = np.stack([res[r]["scores"] for r in rids])
        return beams, scores

    # -- scenario families --------------------------------------------------
    def _run_cold_start(self, cfg, ctx, log):
        sv, data, sids = cfg.serve, ctx["data"], ctx["sids"]
        L = ctx["sid_length"]
        test = data.test_seqs
        if test.shape[0] > cfg.eval.max_eval:
            test = test[: cfg.eval.max_eval]
        hist = sids[test[:, :-1]].reshape(test.shape[0], -1).astype(np.int32)
        ctx["eval_targets"] = sids[test[:, -1]]
        cid = ctx["slots"][sv.eval_slot]
        cids = np.full(hist.shape[0], cid, dtype=np.int32)
        _, engine = self._retriever_and_engine(
            cfg, ctx, hist.shape[1], constrained=True)
        results = {"static": self._serve(engine, hist, L, cids)}
        meta = {
            "engine": sv.engine,
            "eval_slot": sv.eval_slot,
            "n_test": int(hist.shape[0]),
            "store_version": ctx["registry"].version,
            "unexpected_recompiles": int(engine.metrics.counter(
                "serving_recompiles_total").value(expected="false")),
        }
        if cfg.eval.with_unconstrained:
            _, engine_u = self._retriever_and_engine(
                cfg, ctx, hist.shape[1], constrained=False)
            results["unconstrained"] = self._serve(engine_u, hist, L, None)
        ctx["serve_results"] = results
        ctx["serve_meta"] = meta
        log(f"  served {hist.shape[0]} test requests through "
            f"{sv.engine} engine (slot {sv.eval_slot!r})")

    def _run_catalog(self, cfg, ctx, log):
        sv = cfg.serve
        V, L = ctx["vocab"], ctx["sid_length"]
        reg = ctx["registry"]
        n_slots = len(ctx["slots"])
        rng = np.random.default_rng(cfg.seed + SEED_REQUESTS)
        hist = rng.integers(
            0, V, (sv.n_requests, sv.hist_len)).astype(np.int32)
        cids = (np.arange(sv.n_requests) % n_slots).astype(np.int32)
        ctx["request_cids"] = cids
        _, engine = self._retriever_and_engine(
            cfg, ctx, sv.hist_len, constrained=True)
        beams, scores = self._serve(engine, hist, L, cids)
        versions = [reg.version]
        current = ctx["catalog"]
        if sv.refresh_cycles > 0:
            churn_rng = np.random.default_rng(cfg.seed + SEED_CHURN)
            with AsyncRefresher(reg) as refresher:
                for cycle in range(sv.refresh_cycles):
                    churn = max(
                        1, int(current.sids.shape[0] * sv.churn_frac))
                    rm = current.sids[churn_rng.choice(
                        current.sids.shape[0], churn, replace=False)]
                    added = synthetic_catalog(
                        churn_rng, churn, V, L,
                        n_categories=cfg.data.n_categories,
                        max_age_days=cfg.data.max_age_days)
                    delta = CatalogDelta(added=added, removed_sids=rm)
                    fut = refresher.apply_delta_async(delta)
                    current = current.apply_delta(delta)
                    # serving continues while the rebuild runs off-thread
                    beams, scores = self._serve(engine, hist, L, cids)
                    versions.append(int(fut.result(timeout=120)))
                    # post-swap serve: the engine installs the new store at
                    # its batch boundary — this is the batch that must NOT
                    # recompile (hot swap) for the invariant gate below
                    beams, scores = self._serve(engine, hist, L, cids)
                    log(f"  refresh cycle {cycle}: ±{churn} items -> "
                        f"registry v{versions[-1]}")
        ctx["final_catalog"] = current
        ctx["serve_results"] = {"constrained": (beams, scores)}
        ctx["serve_meta"] = {
            "engine": sv.engine,
            "n_requests": int(sv.n_requests),
            "versions": versions,
            "cold_swaps": int(engine.cold_swaps),
            "unexpected_recompiles": int(engine.metrics.counter(
                "serving_recompiles_total").value(expected="false")),
        }
        if sv.engine == "spmd":
            # bit-identity reference: the same policy + params on one device
            retr = GenerativeRetriever(
                ctx["params"], ctx["model_cfg"],
                DecodePolicy.stacked(reg.current()[0], impl=sv.impl,
                                     fused=sv.fused, topk=sv.topk),
                L, V, beam_size=sv.beam)
            ctx["reference_results"] = retr.retrieve(
                hist, constraint_ids=cids)
        log(f"  served {sv.n_requests} mixed-constraint requests over "
            f"{n_slots} slots ({sv.engine} engine)")

    def run(self, cfg, ctx, log):
        if "data" in ctx:
            self._run_cold_start(cfg, ctx, log)
        else:
            self._run_catalog(cfg, ctx, log)


class EvalStage(Stage):
    name = "eval"

    def provides(self, cfg):
        return ("result",)

    @staticmethod
    def _hits(beams: np.ndarray, scores: np.ndarray, targets: np.ndarray):
        """(hit@M, recall@1) — a hit is the target SID in any ALIVE beam."""
        alive = scores > NEG_INF / 2
        match = (beams == targets[:, None, :]).all(axis=2) & alive
        hit_m = float(match.any(axis=1).mean())
        r1 = float(match[:, 0].mean())
        return hit_m, r1

    def _eval_cold_start(self, cfg, ctx, log):
        data, sids = ctx["data"], ctx["sids"]
        targets = ctx["eval_targets"]
        beams_s, scores_s = ctx["serve_results"]["static"]
        hit_s, r1_s = self._hits(beams_s, scores_s, targets)
        result = {
            "scenario": cfg.name,
            "cold_frac": cfg.data.cold_frac,
            "n_cold": int(data.cold_items.shape[0]),
            "n_test": int(targets.shape[0]),
            "beam_size": cfg.serve.beam,
            "recall@1_static": r1_s,
            "hit@M_static": hit_s,
        }
        if "unconstrained" in ctx["serve_results"]:
            beams_u, scores_u = ctx["serve_results"]["unconstrained"]
            hit_u, r1_u = self._hits(beams_u, scores_u, targets)
            result["recall@1_unconstrained"] = r1_u
            result["hit@M_unconstrained"] = hit_u
        if cfg.eval.with_random:
            # constrained random guessing: uniform over the cold corpus
            rng = np.random.default_rng(cfg.seed + SEED_BASELINE)
            cold_sids = sids[data.cold_items]
            guesses = cold_sids[rng.integers(
                0, cold_sids.shape[0], targets.shape[0])]
            result["recall@1_constrained_random"] = float(
                (guesses == targets).all(axis=1).mean())
        gates = {}
        if "hit@M_unconstrained" in result:
            gates["static_beats_unconstrained"] = (
                result["hit@M_static"] > result["hit@M_unconstrained"])
        gates["zero_unexpected_recompiles"] = (
            ctx["serve_meta"]["unexpected_recompiles"] == 0)
        gates["passed"] = all(gates.values())
        result["gates"] = gates
        result["serve_meta"] = ctx["serve_meta"]
        ctx["result"] = result
        log(f"  hit@M static {result['hit@M_static']:.3f} vs unconstrained "
            f"{result.get('hit@M_unconstrained', float('nan')):.3f}; "
            f"gates passed: {gates['passed']}")

    def _eval_catalog(self, cfg, ctx, log):
        beams, scores = ctx["serve_results"]["constrained"]
        cids = ctx["request_cids"]
        catalog = ctx.get("final_catalog", ctx["catalog"])
        names = list(ctx["slots"])
        valid_per_slot = []
        for name in names:
            mask = ctx["predicates"][name](catalog)
            valid_per_slot.append(
                {tuple(int(t) for t in row) for row in catalog.sids[mask]})
        alive = scores > NEG_INF / 2
        total, ok = 0, 0
        for b in range(beams.shape[0]):
            valid = valid_per_slot[int(cids[b])]
            for m in range(beams.shape[1]):
                if alive[b, m]:
                    total += 1
                    ok += tuple(int(t) for t in beams[b, m]) in valid
        compliance = ok / total if total else 0.0
        meta = ctx["serve_meta"]
        gates = {
            "full_compliance": compliance == 1.0 and total > 0,
            "zero_unexpected_recompiles":
                meta["unexpected_recompiles"] == 0,
        }
        result = {
            "scenario": cfg.name,
            "n_requests": meta["n_requests"],
            "n_slots": len(names),
            "alive_beams": total,
            "compliance": compliance,
            "serve_meta": meta,
        }
        if "reference_results" in ctx:
            ref_beams, ref_scores = ctx["reference_results"]
            identical = (
                np.array_equal(ref_beams, beams)
                and np.array_equal(ref_scores, scores)
            )
            gates["spmd_bit_identical"] = identical
            result["spmd_bit_identical"] = identical
        gates["passed"] = all(gates.values())
        result["gates"] = gates
        ctx["result"] = result
        log(f"  compliance {compliance:.3f} over {total} alive beams; "
            f"gates passed: {gates['passed']}")

    def run(self, cfg, ctx, log):
        if "data" in ctx:
            self._eval_cold_start(cfg, ctx, log)
        else:
            self._eval_catalog(cfg, ctx, log)


def default_stages() -> tuple:
    """The canonical Data -> ... -> Eval stage chain."""
    return (DataStage(), TokenizerStage(), IndexStage(), TrainStage(),
            ServeStage(), EvalStage())
