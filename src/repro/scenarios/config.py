"""Declarative scenario configs (DESIGN.md §12).

A :class:`ScenarioConfig` is a frozen tree of per-stage configs — data,
tokenizer, index, train, serve, eval — plus ONE explicit ``seed`` from which
every stochastic component derives its stream (dataset synthesis, RQ-VAE
init/batching, transformer init, the training batcher, and the
constrained-random eval baseline).  Two runs of the same config are
bit-reproducible (asserted in ``tests/test_scenarios.py``).

Configs are *declarative*: nothing here touches JAX or builds arrays.  The
:class:`~repro.scenarios.registry.ScenarioRegistry` resolves a named config
into composed pipeline stages (the builder/``build_config`` idiom); callers
specialize a scenario with dotted-path overrides::

    cfg = apply_overrides(cfg, {"data.cold_frac": 0.05, "train.steps": 200})

which keeps the CLI (``--set data.cold_frac=0.05``), the benchmark harness,
and the tests on one override surface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

__all__ = [
    "SlotSpec",
    "DataConfig",
    "TokenizerConfig",
    "IndexConfig",
    "TrainConfig",
    "ServeConfig",
    "EvalConfig",
    "ScenarioConfig",
    "apply_overrides",
    "parse_override",
    "config_to_dict",
]


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    """One named constraint slot: a predicate kind + its parameters.

    Kinds (resolved by the IndexStage into registry predicates):

      * ``all``        — every catalog item is servable.
      * ``cold_only``  — the held-out cold-start items (newest ``age_days``
                         band; the paper's Table 3 serving set).
      * ``freshness``  — ``arg[0]`` = max age in days
                         (:func:`~repro.constraints.freshness_window`).
      * ``category``   — ``arg`` = allow-listed category ids
                         (:func:`~repro.constraints.category_allowlist`).
    """

    name: str
    kind: str = "all"
    arg: tuple = ()


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """DataStage: which corpus, and its shape."""

    kind: str = "amazon_cold_start"  # | "synthetic_catalog"
    n_items: int = 2_000
    n_clusters: int = 64
    feat_dim: int = 64
    n_users: int = 6_000
    seq_len: int = 12
    cold_frac: float = 0.02
    # synthetic_catalog only: per-item metadata ranges
    n_categories: int = 8
    max_age_days: float = 90.0


@dataclasses.dataclass(frozen=True)
class TokenizerConfig:
    """TokenizerStage: item -> Semantic ID.

    ``rqvae`` trains the residual quantizer on item features and appends the
    TIGER dedup token (SID length = ``n_levels + 1``); ``random`` draws SIDs
    uniformly (catalog-only scenarios that never train a model).
    """

    kind: str = "rqvae"  # | "random"
    n_levels: int = 3
    codebook_size: int = 256
    latent_dim: int = 32
    train_steps: int = 400
    batch: int = 256
    lr: float = 3e-3
    sid_length: int = 4  # "random" kind only; rqvae derives n_levels + 1

    @property
    def resolved_sid_length(self) -> int:
        return self.n_levels + 1 if self.kind == "rqvae" else self.sid_length


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """IndexStage: catalog -> ConstraintRegistry slots -> ConstraintStore."""

    dense_d: int = 2
    headroom: float = 0.5
    slots: tuple = (SlotSpec("servable", "all"),)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """TrainStage: the reduced generative-retrieval transformer."""

    steps: int = 500
    batch: int = 64
    lr: float = 1e-3
    log_every: int = 100
    # reduced GR transformer dims (gr_model_config)
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    # Trie-aware auxiliary signal (DESIGN.md §12): weight on the
    # admissible-mass loss derived from the warm-item TrieSource slab's
    # per-prefix admissible sets.  0.0 = off (the default: plain LM loss).
    trie_aware_weight: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """ServeStage: which engine fronts the constrained beam search."""

    engine: str = "batch"  # | "spmd"
    beam: int = 20
    batch_size: int = 16
    impl: str = "xla"
    fused: bool = False
    topk: bool = True
    spmd_rows: str = "replicated"
    eval_slot: str = "servable"  # slot whose constraint masks eval requests
    n_requests: int = 32  # catalog-only scenarios: synthetic request count
    hist_len: int = 16  # catalog-only scenarios: synthetic history width
    # refresh_churn scenario: async delta-refresh cycles between batches
    refresh_cycles: int = 0
    churn_frac: float = 0.01


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """EvalStage: metric protocol."""

    max_eval: int = 256  # cap on eval sequences (static serve shapes)
    with_unconstrained: bool = True  # serve the unconstrained baseline arm
    with_random: bool = True  # constrained-random guessing baseline


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """The full declarative launch surface for one scenario."""

    name: str
    seed: int = 0
    data: DataConfig = DataConfig()
    tokenizer: TokenizerConfig = TokenizerConfig()
    index: IndexConfig = IndexConfig()
    train: TrainConfig = TrainConfig()
    serve: ServeConfig = ServeConfig()
    eval: EvalConfig = EvalConfig()


# ---------------------------------------------------------------------------
# dotted-path overrides
# ---------------------------------------------------------------------------
def _replace_path(obj, parts: list[str], value):
    name = parts[0]
    names = {f.name for f in dataclasses.fields(obj)}
    if name not in names:
        raise KeyError(
            f"unknown config field {name!r} on {type(obj).__name__} "
            f"(known: {sorted(names)})"
        )
    if len(parts) == 1:
        return dataclasses.replace(obj, **{name: value})
    child = getattr(obj, name)
    if not dataclasses.is_dataclass(child):
        raise KeyError(
            f"{type(obj).__name__}.{name} is a leaf; cannot descend into "
            f"{'.'.join(parts[1:])!r}"
        )
    return dataclasses.replace(obj, **{name: _replace_path(child, parts[1:],
                                                           value)})


def apply_overrides(cfg: ScenarioConfig,
                    overrides: Mapping[str, Any]) -> ScenarioConfig:
    """A new config with dotted-path fields replaced.

    ``{"data.cold_frac": 0.05}`` replaces ``cfg.data.cold_frac``; unknown
    paths raise ``KeyError`` with the known field names (typos must fail
    loudly — a silently ignored override would run the WRONG experiment).
    """
    for path, value in overrides.items():
        cfg = _replace_path(cfg, path.split("."), value)
    return cfg


def parse_override(text: str) -> tuple[str, Any]:
    """CLI ``key=value`` -> (dotted path, typed value).

    Values parse as bool ("true"/"false"), int, float, then fall back to
    string — matching the scalar leaves of the config tree.
    """
    if "=" not in text:
        raise ValueError(f"override must be key=value, got {text!r}")
    path, raw = text.split("=", 1)
    low = raw.strip().lower()
    if low in ("true", "false"):
        return path.strip(), low == "true"
    for cast in (int, float):
        try:
            return path.strip(), cast(raw)
        except ValueError:
            pass
    return path.strip(), raw


def _jsonify(value):
    if dataclasses.is_dataclass(value):
        return {f.name: _jsonify(getattr(value, f.name))
                for f in dataclasses.fields(value)}
    if isinstance(value, (list, tuple)):
        return [_jsonify(v) for v in value]
    return value


def config_to_dict(cfg) -> dict:
    """JSON-ready nested dict (tuples -> lists, dataclasses -> dicts)."""
    return _jsonify(cfg)
