"""ScenarioRegistry: named declarative configs -> composed pipelines.

The registry is the single launch surface (DESIGN.md §12): a scenario is a
frozen :class:`~repro.scenarios.config.ScenarioConfig` plus the stage chain
that realizes it; ``resolve`` specializes it (``--smoke`` shrink, dotted
``--set`` overrides, seed) into a :class:`ScenarioRun` whose ``run()``
executes the pipeline and returns the artifact context (``ctx["result"]``
carries the metrics + gates).

Which scenario when (also in DESIGN.md §12):

==================  =====================================================
cold_start_amazon   The paper's Table 3 protocol end-to-end: RQ-VAE SIDs,
                    GR training on no-cold sequences, STATIC serving on
                    the cold-only registry slot, hit@M vs unconstrained.
multi_constraint    Mixed-tenant serving: one batch decoded under K
                    staggered freshness slots + a category slot, 100%
                    per-request compliance required.
refresh_churn       multi_constraint under live catalog churn: an
                    AsyncRefresher splices deltas between batches; swaps
                    must stay zero-recompile.
spmd_smoke          The multi-constraint batch served through the SPMD
                    engine over a debug mesh, bit-identical to the
                    single-device reference.
==================  =====================================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

from repro.scenarios.config import (
    DataConfig,
    EvalConfig,
    IndexConfig,
    ScenarioConfig,
    ServeConfig,
    SlotSpec,
    TokenizerConfig,
    TrainConfig,
    apply_overrides,
)
from repro.scenarios.stages import default_stages, run_pipeline

__all__ = [
    "ScenarioSpec",
    "ScenarioRun",
    "ScenarioRegistry",
    "get_default_registry",
]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A registered scenario: full-size config + its smoke shrink."""

    name: str
    description: str
    config: ScenarioConfig
    smoke_overrides: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)
    stages: Callable[[], tuple] = default_stages


@dataclasses.dataclass(frozen=True)
class ScenarioRun:
    """A resolved scenario, ready to execute (and re-enter via ``ctx``)."""

    config: ScenarioConfig
    stages: tuple

    def run(self, log=lambda *a: None, ctx: Optional[dict] = None) -> dict:
        """Execute the pipeline; returns the artifact context.

        ``ctx["result"]`` holds the metrics + gates dict.  Pass a context
        from a previous run to resume: stages whose artifacts are present
        are skipped (see :func:`~repro.scenarios.stages.run_pipeline`).
        """
        return run_pipeline(self.stages, self.config, log=log, ctx=ctx)


class ScenarioRegistry:
    def __init__(self):
        self._specs: dict[str, ScenarioSpec] = {}

    def register(self, spec: ScenarioSpec) -> None:
        if spec.name in self._specs:
            raise ValueError(f"scenario {spec.name!r} already registered")
        if spec.config.name != spec.name:
            raise ValueError(
                f"spec name {spec.name!r} != config name "
                f"{spec.config.name!r}")
        self._specs[spec.name] = spec

    @property
    def names(self) -> tuple:
        return tuple(self._specs)

    def get(self, name: str) -> ScenarioSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown scenario {name!r}; registered: "
                f"{sorted(self._specs)}"
            ) from None

    def describe(self) -> dict:
        return {n: s.description for n, s in self._specs.items()}

    def resolve(self, name: str, *, smoke: bool = False,
                overrides: Optional[Mapping[str, Any]] = None,
                seed: Optional[int] = None) -> ScenarioRun:
        """Specialize a named scenario into a runnable pipeline.

        Order: base config -> smoke shrink -> caller overrides -> seed, so
        an explicit ``--set`` beats the smoke preset and ``--seed`` beats
        both.
        """
        spec = self.get(name)
        cfg = spec.config
        if smoke:
            cfg = apply_overrides(cfg, spec.smoke_overrides)
        if overrides:
            cfg = apply_overrides(cfg, overrides)
        if seed is not None:
            cfg = dataclasses.replace(cfg, seed=seed)
        return ScenarioRun(config=cfg, stages=tuple(spec.stages()))


# ---------------------------------------------------------------------------
# built-in scenarios
# ---------------------------------------------------------------------------
def _cold_start_amazon() -> ScenarioSpec:
    cfg = ScenarioConfig(
        name="cold_start_amazon",
        data=DataConfig(kind="amazon_cold_start", n_items=2_000,
                        cold_frac=0.02),
        tokenizer=TokenizerConfig(kind="rqvae", n_levels=3,
                                  codebook_size=256, train_steps=400),
        index=IndexConfig(slots=(
            SlotSpec("servable", "all"),
            SlotSpec("cold_only", "cold_only"),
        )),
        train=TrainConfig(steps=500, batch=64),
        serve=ServeConfig(engine="batch", beam=20, batch_size=16,
                          eval_slot="cold_only"),
        eval=EvalConfig(max_eval=256),
    )
    return ScenarioSpec(
        name="cold_start_amazon",
        description=("Table 3 end-to-end: RQ-VAE SIDs -> GR training -> "
                     "STATIC serving on the cold-only slot, hit@M vs "
                     "unconstrained"),
        config=cfg,
        smoke_overrides={
            "data.n_items": 400,
            "data.n_users": 1_200,
            "tokenizer.train_steps": 60,
            "train.steps": 60,
            "train.batch": 32,
            "serve.batch_size": 8,
            "eval.max_eval": 48,
        },
    )


def _multi_constraint() -> ScenarioSpec:
    cfg = ScenarioConfig(
        name="multi_constraint",
        data=DataConfig(kind="synthetic_catalog", n_items=5_000,
                        n_categories=8, max_age_days=90.0),
        tokenizer=TokenizerConfig(kind="random", codebook_size=256,
                                  sid_length=4),
        index=IndexConfig(slots=(
            SlotSpec("fresh_22", "freshness", (22.5,)),
            SlotSpec("fresh_45", "freshness", (45.0,)),
            SlotSpec("fresh_67", "freshness", (67.5,)),
            SlotSpec("fresh_90", "freshness", (90.0,)),
            SlotSpec("cat_01", "category", (0, 1)),
        )),
        train=TrainConfig(steps=0),
        serve=ServeConfig(engine="batch", beam=8, batch_size=8,
                          n_requests=32, hist_len=16),
        eval=EvalConfig(with_unconstrained=False, with_random=False),
    )
    return ScenarioSpec(
        name="multi_constraint",
        description=("mixed-tenant batch under staggered freshness + "
                     "category slots; 100% per-request compliance"),
        config=cfg,
        smoke_overrides={
            "data.n_items": 800,
            "serve.n_requests": 16,
        },
    )


def _refresh_churn() -> ScenarioSpec:
    base = _multi_constraint().config
    cfg = dataclasses.replace(
        base, name="refresh_churn",
        serve=dataclasses.replace(base.serve, refresh_cycles=3,
                                  churn_frac=0.01),
    )
    return ScenarioSpec(
        name="refresh_churn",
        description=("multi_constraint under live churn: AsyncRefresher "
                     "deltas between batches, zero-recompile hot swaps"),
        config=cfg,
        smoke_overrides={
            "data.n_items": 600,
            "serve.n_requests": 8,
            "serve.refresh_cycles": 2,
        },
    )


def _spmd_smoke() -> ScenarioSpec:
    base = _multi_constraint().config
    cfg = dataclasses.replace(
        base, name="spmd_smoke",
        serve=dataclasses.replace(base.serve, engine="spmd", n_requests=8,
                                  batch_size=8),
    )
    return ScenarioSpec(
        name="spmd_smoke",
        description=("the mixed-constraint batch through the SPMD engine "
                     "over a debug mesh, bit-identical to single-device"),
        config=cfg,
        smoke_overrides={
            "data.n_items": 600,
        },
    )


_DEFAULT: Optional[ScenarioRegistry] = None


def get_default_registry() -> ScenarioRegistry:
    """The process-wide registry with the built-in scenarios installed."""
    global _DEFAULT
    if _DEFAULT is None:
        reg = ScenarioRegistry()
        for build in (_cold_start_amazon, _multi_constraint,
                      _refresh_churn, _spmd_smoke):
            reg.register(build())
        _DEFAULT = reg
    return _DEFAULT
