"""Jitted dispatchers for the Pallas kernels.

``impl`` resolution:
  * "pallas"  — the Pallas kernel (compiled on TPU, interpret-mode on CPU).
  * "xla"     — the pure-jnp oracle (always available, used for training-time
                code paths where XLA fusion is already optimal).
  * None      — "pallas" on TPU, "xla" elsewhere (interpret mode is a
                correctness tool, not a fast path, so CPU defaults to XLA).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref as _ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.vntk import (
    vntk_compressed_pallas,
    vntk_compressed_topk_pallas,
    vntk_fused_logsoftmax_pallas,
    vntk_pallas,
    vntk_stacked_compressed_pallas,
    vntk_stacked_compressed_topk_pallas,
    vntk_stacked_fused_logsoftmax_pallas,
    vntk_stacked_pallas,
    vntk_stacked_topk_pallas,
    vntk_topk_pallas,
)

__all__ = ["vntk", "vntk_fused_logsoftmax", "vntk_topk", "vntk_compressed",
           "vntk_compressed_topk", "embedding_bag"]


def _resolve(impl: str | None) -> str:
    if impl is None:
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


@partial(jax.jit, static_argnames=("bmax", "vocab", "impl"))
def vntk(log_probs, nodes, row_pointers, edges, bmax: int, vocab: int,
         impl: str | None = None, constraint_ids=None):
    """Alg. 2 (VNTK): (masked_log_probs, next_states), both vocab-aligned.

    With ``constraint_ids`` (per-row int32), ``row_pointers``/``edges`` must
    carry a leading constraint axis — (K, S+1) / (K, E, 2) — and each row is
    masked by its own set (DESIGN.md §4).  ``None`` keeps the single-matrix
    path untouched (the branch is resolved at trace time).
    """
    if constraint_ids is None:
        if _resolve(impl) == "pallas":
            return vntk_pallas(log_probs, nodes, row_pointers, edges, bmax, vocab)
        return _ref.vntk_ref(log_probs, nodes, row_pointers, edges, bmax, vocab)
    if _resolve(impl) == "pallas":
        return vntk_stacked_pallas(
            log_probs, nodes, constraint_ids, row_pointers, edges, bmax, vocab
        )
    return _ref.vntk_stacked_ref(
        log_probs, nodes, constraint_ids, row_pointers, edges, bmax, vocab
    )


@partial(jax.jit, static_argnames=("bmax", "vocab", "impl"))
def vntk_fused_logsoftmax(logits, nodes, row_pointers, edges, bmax: int,
                          vocab: int, impl: str | None = None,
                          constraint_ids=None):
    """Fused LogSoftmax + VNTK masking (single HBM pass over logits)."""
    if constraint_ids is None:
        if _resolve(impl) == "pallas":
            return vntk_fused_logsoftmax_pallas(
                logits, nodes, row_pointers, edges, bmax, vocab
            )
        return _ref.vntk_fused_logsoftmax_ref(
            logits, nodes, row_pointers, edges, bmax, vocab
        )
    if _resolve(impl) == "pallas":
        return vntk_stacked_fused_logsoftmax_pallas(
            logits, nodes, constraint_ids, row_pointers, edges, bmax, vocab
        )
    return _ref.vntk_stacked_fused_logsoftmax_ref(
        logits, nodes, constraint_ids, row_pointers, edges, bmax, vocab
    )


@partial(jax.jit, static_argnames=("bmax", "vocab", "width", "impl",
                                   "fused_logsoftmax"))
def vntk_topk(values, nodes, row_pointers, edges, bmax: int, vocab: int,
              width: int, impl: str | None = None, constraint_ids=None,
              fused_logsoftmax: bool = False):
    """Candidate-compressed VNTK (DESIGN.md §8): per-beam dense-rank top-C.

    Returns ``(scores, tokens, next_states)``, each ``(..., width)`` — the
    compressed per-beam candidate lists the sparse beam-advance consumes.
    ``values`` are normalized log-probs, or raw logits with
    ``fused_logsoftmax=True`` (the kernel then normalizes in-register).  With
    ``constraint_ids`` the tables carry the stacked leading constraint axis.
    """
    if constraint_ids is None:
        if _resolve(impl) == "pallas":
            return vntk_topk_pallas(
                values, nodes, row_pointers, edges, bmax, vocab, width,
                fused_logsoftmax=fused_logsoftmax,
            )
        return _ref.vntk_topk_ref(
            values, nodes, row_pointers, edges, bmax, vocab, width,
            fused_logsoftmax=fused_logsoftmax,
        )
    if _resolve(impl) == "pallas":
        return vntk_stacked_topk_pallas(
            values, nodes, constraint_ids, row_pointers, edges, bmax, vocab,
            width, fused_logsoftmax=fused_logsoftmax,
        )
    return _ref.vntk_stacked_topk_ref(
        values, nodes, constraint_ids, row_pointers, edges, bmax, vocab,
        width, fused_logsoftmax=fused_logsoftmax,
    )


@partial(jax.jit, static_argnames=("bmax", "vocab", "impl",
                                   "fused_logsoftmax"))
def vntk_compressed(values, nodes, row_pointers, tok_delta, base, bmax: int,
                    vocab: int, impl: str | None = None, constraint_ids=None,
                    fused_logsoftmax: bool = False):
    """VNTK over the compressed slab (DESIGN.md §11): vocab-aligned outputs.

    ``tok_delta``/``base`` come from a
    :class:`repro.core.compressed_slab.CompressedSlab` (``base`` is that
    step's ``level_base`` entry — scalar, or per-member ``(K,)`` with
    ``constraint_ids``).  Bit-identical to :func:`vntk` /
    :func:`vntk_fused_logsoftmax` on the same trie.
    """
    if constraint_ids is None:
        if _resolve(impl) == "pallas":
            return vntk_compressed_pallas(
                values, nodes, row_pointers, tok_delta, base, bmax, vocab,
                fused_logsoftmax=fused_logsoftmax,
            )
        return _ref.vntk_compressed_ref(
            values, nodes, row_pointers, tok_delta, base, bmax, vocab,
            fused_logsoftmax=fused_logsoftmax,
        )
    if _resolve(impl) == "pallas":
        return vntk_stacked_compressed_pallas(
            values, nodes, constraint_ids, row_pointers, tok_delta, base,
            bmax, vocab, fused_logsoftmax=fused_logsoftmax,
        )
    return _ref.vntk_stacked_compressed_ref(
        values, nodes, constraint_ids, row_pointers, tok_delta, base, bmax,
        vocab, fused_logsoftmax=fused_logsoftmax,
    )


@partial(jax.jit, static_argnames=("bmax", "vocab", "width", "impl",
                                   "fused_logsoftmax"))
def vntk_compressed_topk(values, nodes, row_pointers, tok_delta, base,
                         bmax: int, vocab: int, width: int,
                         impl: str | None = None, constraint_ids=None,
                         fused_logsoftmax: bool = False):
    """Candidate-compressed VNTK over the compressed slab (§8 x §11)."""
    if constraint_ids is None:
        if _resolve(impl) == "pallas":
            return vntk_compressed_topk_pallas(
                values, nodes, row_pointers, tok_delta, base, bmax, vocab,
                width, fused_logsoftmax=fused_logsoftmax,
            )
        return _ref.vntk_compressed_topk_ref(
            values, nodes, row_pointers, tok_delta, base, bmax, vocab, width,
            fused_logsoftmax=fused_logsoftmax,
        )
    if _resolve(impl) == "pallas":
        return vntk_stacked_compressed_topk_pallas(
            values, nodes, constraint_ids, row_pointers, tok_delta, base,
            bmax, vocab, width, fused_logsoftmax=fused_logsoftmax,
        )
    return _ref.vntk_stacked_compressed_topk_ref(
        values, nodes, constraint_ids, row_pointers, tok_delta, base, bmax,
        vocab, width, fused_logsoftmax=fused_logsoftmax,
    )


@partial(jax.jit, static_argnames=("mode", "impl"))
def embedding_bag(table, indices, mode: str = "sum", impl: str | None = None):
    """Fixed-arity EmbeddingBag: (B, K) indices -> (B, D) reduced rows."""
    if _resolve(impl) == "pallas":
        return embedding_bag_pallas(table, indices, mode=mode)
    return _ref.embedding_bag_ref(table, indices, mode=mode)
