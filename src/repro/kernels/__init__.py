"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships three layers: ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted dispatcher), ``ref.py`` (pure-jnp oracle used by the
shape/dtype sweep tests in tests/test_kernels_pallas.py).

  vntk           — Alg. 2: stacked-CSR burst DMA + compare-reduce masking,
                   plus the fused masked-logsoftmax variant
  embedding_bag  — recsys fixed-arity gather+reduce over HBM tables
"""
