"""Pallas TPU embedding-bag kernel (recsys hot path).

JAX has no native ``EmbeddingBag``; the XLA formulation is
``jnp.take`` + ``segment_sum`` (see ``repro.models.recsys``).  This kernel is
the TPU-native version of the *fixed-arity* bag lookup that dominates DLRM-
style models: ``indices (B, K)`` rows are fetched from the HBM-resident table
with per-row async DMAs into VMEM and reduced on the VPU, so the (potentially
many-GB) table is never streamed — only the K·D working set per bag.

Out-of-range indices (== n_rows sentinel) contribute zero, which implements
both padding-to-K and frequency-capped multi-hot features.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag_pallas"]


def _bag_body(
    idx_ref,  # (bag_tile, K) int32 VMEM
    table_hbm,  # (R + 1, D) in ANY (row R is a zero pad row)
    out_ref,  # (bag_tile, D) VMEM
    row_scratch,  # (bag_tile, K, D) VMEM
    sem,
    *,
    bag_tile: int,
    k: int,
    mode: str,
):
    for i in range(bag_tile):
        for j in range(k):
            cp = pltpu.make_async_copy(
                table_hbm.at[pl.ds(idx_ref[i, j], 1)],
                row_scratch.at[i, pl.ds(j, 1)],
                sem,
            )
            cp.start()
            cp.wait()
    acc = jnp.sum(row_scratch[...].astype(jnp.float32), axis=1)
    if mode == "mean":
        acc = acc / k
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_pallas(
    table: jax.Array,  # (R, D); caller appends a zero row => sentinel R
    indices: jax.Array,  # (B, K) int32 in [0, R]
    mode: str = "sum",
    bag_tile: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    if mode not in ("sum", "mean"):
        raise ValueError(mode)
    B, K = indices.shape
    R, D = table.shape
    bag_tile = min(bag_tile, B)
    while B % bag_tile:
        bag_tile -= 1
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kern = functools.partial(_bag_body, bag_tile=bag_tile, k=K, mode=mode)
    return pl.pallas_call(
        kern,
        grid=(B // bag_tile,),
        in_specs=[
            pl.BlockSpec((bag_tile, K), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bag_tile, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        scratch_shapes=[
            pltpu.VMEM((bag_tile, K, D), table.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(indices, table)
