"""Pallas TPU kernel for the Vectorized Node Transition Kernel (paper Alg. 2).

TPU-native adaptation of the paper's GPU-friendly gather/scatter formulation
(see DESIGN.md §3):

  * Phase 1/2 (boundary lookup + speculative slicing) become explicit
    HBM->VMEM **async DMAs** of the stacked ``(B_l, 2)`` edge slab — the
    "single coalesced memory transaction" of paper §A.1.1 made literal.
  * Phase 4 (scatter projection) becomes a **compare-broadcast reduction**:
    ``mask[v] = any_j (cols[j] == v & j < n_child)``.  TPUs have no efficient
    VMEM scatter; an elementwise compare over the lane-aligned vocab axis is
    branch-free and VPU-friendly.  Next-state ids are produced vocab-aligned
    by the same reduction (``sum_j hit[j] * next[j]`` — token columns within
    a CSR row are unique, so the sum has at most one non-zero term).

Slots are processed in fixed chunks through a ``fori_loop`` so VMEM pressure
stays at ``O(beam_tile * vocab)`` regardless of the branch factor, and the
edge DMA length is the chunk-rounded branch factor (the edges tensor is
padded accordingly by the trie builder).

The fused variant additionally normalizes raw logits with an in-register
log-softmax before masking, eliminating one full HBM round-trip over the
``(B*M, V)`` tensor per decode step (a beyond-paper optimization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e10

__all__ = [
    "vntk_pallas",
    "vntk_fused_logsoftmax_pallas",
    "vntk_stacked_pallas",
    "vntk_stacked_fused_logsoftmax_pallas",
]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _project_and_write(
    rp_scratch,
    edge_scratch,
    logits_ref,
    out_lp_ref,
    out_next_ref,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    """Phases 3+4 (+ optional fused log-softmax): shared by both DMA fronts."""
    n_child = rp_scratch[:, 1] - rp_scratch[:, 0]  # (beam_tile,)

    # ---- Phase 3+4: chunked sanitize + compare-broadcast projection ----
    n_chunks = bmax_padded // slot_chunk
    iota_slot = jax.lax.broadcasted_iota(jnp.int32, (beam_tile, slot_chunk), 1)
    iota_v = jax.lax.broadcasted_iota(
        jnp.int32, (beam_tile, slot_chunk, vocab), 2
    )

    def chunk_body(c, carry):
        mask, nxt = carry
        sl = edge_scratch[:, pl.ds(c * slot_chunk, slot_chunk), :]  # (beam_tile, slot_chunk, 2)
        cols = sl[:, :, 0]
        vals = sl[:, :, 1]
        valid = (c * slot_chunk + iota_slot) < n_child[:, None]
        hit = (cols[:, :, None] == iota_v) & valid[:, :, None]
        mask = mask | jnp.any(hit, axis=1)
        nxt = nxt + jnp.sum(
            hit.astype(jnp.int32) * vals[:, :, None], axis=1, dtype=jnp.int32
        )
        return mask, nxt

    mask0 = jnp.zeros((beam_tile, vocab), bool)
    nxt0 = jnp.zeros((beam_tile, vocab), jnp.int32)
    mask, nxt = jax.lax.fori_loop(0, n_chunks, chunk_body, (mask0, nxt0))

    x = logits_ref[...]
    if fused_logsoftmax:
        xf = x.astype(jnp.float32)
        m = jnp.max(xf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(xf - m), axis=-1, keepdims=True))
        lp = (xf - m - lse).astype(out_lp_ref.dtype)
    else:
        lp = x.astype(out_lp_ref.dtype)
    out_lp_ref[...] = jnp.where(mask, lp, jnp.asarray(NEG_INF, out_lp_ref.dtype))
    out_next_ref[...] = nxt


def _vntk_body(
    nodes_ref,
    logits_ref,
    rowptr_hbm,
    edges_hbm,
    out_lp_ref,
    out_next_ref,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    # ---- Phase 1+2: per-beam boundary lookup + speculative burst DMA ----
    # Start all row-pointer DMAs, then all edge DMAs (edge start depends on
    # the row pointer, so the second wave waits on the first per-beam).
    for i in range(beam_tile):
        cp = pltpu.make_async_copy(
            rowptr_hbm.at[pl.ds(nodes_ref[i], 2)], rp_scratch.at[i], sem_rp
        )
        cp.start()
        cp.wait()
        start = rp_scratch[i, 0]
        cp2 = pltpu.make_async_copy(
            edges_hbm.at[pl.ds(start, bmax_padded)], edge_scratch.at[i], sem_edge
        )
        cp2.start()
    for i in range(beam_tile):
        pltpu.make_async_copy(
            edges_hbm.at[pl.ds(0, bmax_padded)], edge_scratch.at[i], sem_edge
        ).wait()

    _project_and_write(
        rp_scratch, edge_scratch, logits_ref, out_lp_ref, out_next_ref,
        bmax_padded=bmax_padded, slot_chunk=slot_chunk, vocab=vocab,
        beam_tile=beam_tile, fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_stacked_body(
    nodes_ref,
    cids_ref,
    logits_ref,
    rowptr_hbm,
    edges_hbm,
    out_lp_ref,
    out_next_ref,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    """Multi-constraint front end (DESIGN.md §4): the row-pointer and edge
    DMAs index one extra leading constraint axis — ``rowptr (K, S+1)`` and
    ``edges (K, E, 2)`` — by each beam's constraint id.  Everything after the
    fetch is the shared single-matrix projection."""
    for i in range(beam_tile):
        cid = cids_ref[i]
        cp = pltpu.make_async_copy(
            rowptr_hbm.at[cid, pl.ds(nodes_ref[i], 2)], rp_scratch.at[i], sem_rp
        )
        cp.start()
        cp.wait()
        start = rp_scratch[i, 0]
        cp2 = pltpu.make_async_copy(
            edges_hbm.at[cid, pl.ds(start, bmax_padded)],
            edge_scratch.at[i],
            sem_edge,
        )
        cp2.start()
    for i in range(beam_tile):
        pltpu.make_async_copy(
            edges_hbm.at[0, pl.ds(0, bmax_padded)], edge_scratch.at[i], sem_edge
        ).wait()

    _project_and_write(
        rp_scratch, edge_scratch, logits_ref, out_lp_ref, out_next_ref,
        bmax_padded=bmax_padded, slot_chunk=slot_chunk, vocab=vocab,
        beam_tile=beam_tile, fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_call(
    logits: jax.Array,  # (nb, V)
    nodes: jax.Array,  # (nb,)
    row_pointers: jax.Array,  # (S+1,)
    edges: jax.Array,  # (E+pad, 2) stacked
    bmax: int,
    vocab: int,
    *,
    fused_logsoftmax: bool,
    beam_tile: int = 8,
    slot_chunk: int = 8,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
):
    nb = nodes.shape[0]
    beam_tile = min(beam_tile, nb)
    while nb % beam_tile:
        beam_tile -= 1
    bmax_padded = _round_up(max(bmax, 1), slot_chunk)
    if edges.shape[0] < bmax_padded:
        raise ValueError("edges tensor smaller than one speculative burst")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (nb // beam_tile,)
    kern = functools.partial(
        _vntk_body,
        bmax_padded=bmax_padded,
        slot_chunk=slot_chunk,
        vocab=vocab,
        beam_tile=beam_tile,
        fused_logsoftmax=fused_logsoftmax,
    )
    out_lp, out_next = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((beam_tile,), lambda i: (i,)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, vocab), out_dtype),
            jax.ShapeDtypeStruct((nb, vocab), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((beam_tile, 2), jnp.int32),
            pltpu.VMEM((beam_tile, bmax_padded, 2), jnp.int32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(nodes, logits, row_pointers, edges)
    return out_lp, out_next


def _vntk_stacked_call(
    logits: jax.Array,  # (nb, V)
    nodes: jax.Array,  # (nb,)
    cids: jax.Array,  # (nb,)
    row_pointers: jax.Array,  # (K, S+1)
    edges: jax.Array,  # (K, E, 2) stacked per constraint set
    bmax: int,
    vocab: int,
    *,
    fused_logsoftmax: bool,
    beam_tile: int = 8,
    slot_chunk: int = 8,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
):
    nb = nodes.shape[0]
    beam_tile = min(beam_tile, nb)
    while nb % beam_tile:
        beam_tile -= 1
    bmax_padded = _round_up(max(bmax, 1), slot_chunk)
    if edges.shape[1] < bmax_padded:
        raise ValueError("edges tensor smaller than one speculative burst")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (nb // beam_tile,)
    kern = functools.partial(
        _vntk_stacked_body,
        bmax_padded=bmax_padded,
        slot_chunk=slot_chunk,
        vocab=vocab,
        beam_tile=beam_tile,
        fused_logsoftmax=fused_logsoftmax,
    )
    out_lp, out_next = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((beam_tile,), lambda i: (i,)),
            pl.BlockSpec((beam_tile,), lambda i: (i,)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, vocab), out_dtype),
            jax.ShapeDtypeStruct((nb, vocab), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((beam_tile, 2), jnp.int32),
            pltpu.VMEM((beam_tile, bmax_padded, 2), jnp.int32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(nodes, cids, logits, row_pointers, edges)
    return out_lp, out_next


def vntk_pallas(
    log_probs: jax.Array,
    nodes: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 on pre-normalized log-probs. Shapes: (..., V) / (...,)."""
    batch_shape = nodes.shape
    lp, nxt = _vntk_call(
        log_probs.reshape(-1, vocab),
        nodes.reshape(-1),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=False,
        out_dtype=log_probs.dtype,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_fused_logsoftmax_pallas(
    logits: jax.Array,
    nodes: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Fused LogSoftmax + Alg. 2 masking in a single HBM pass."""
    batch_shape = nodes.shape
    lp, nxt = _vntk_call(
        logits.reshape(-1, vocab),
        nodes.reshape(-1),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=True,
        out_dtype=jnp.float32,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_stacked_pallas(
    log_probs: jax.Array,
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,  # (K, S+1)
    edges: jax.Array,  # (K, E, 2)
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 over a stacked constraint store, pre-normalized log-probs."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    lp, nxt = _vntk_stacked_call(
        log_probs.reshape(-1, vocab),
        nodes.reshape(-1),
        cids.astype(jnp.int32),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=False,
        out_dtype=log_probs.dtype,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_stacked_fused_logsoftmax_pallas(
    logits: jax.Array,
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Fused LogSoftmax + stacked Alg. 2 masking in a single HBM pass."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    lp, nxt = _vntk_stacked_call(
        logits.reshape(-1, vocab),
        nodes.reshape(-1),
        cids.astype(jnp.int32),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=True,
        out_dtype=jnp.float32,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))
