"""Pallas TPU kernel for the Vectorized Node Transition Kernel (paper Alg. 2).

TPU-native adaptation of the paper's GPU-friendly gather/scatter formulation
(see DESIGN.md §3):

  * Phase 1/2 (boundary lookup + speculative slicing) become explicit
    HBM->VMEM **async DMAs** of the stacked ``(B_l, 2)`` edge slab — the
    "single coalesced memory transaction" of paper §A.1.1 made literal.
  * Phase 4 (scatter projection) becomes a **compare-broadcast reduction**:
    ``mask[v] = any_j (cols[j] == v & j < n_child)``.  TPUs have no efficient
    VMEM scatter; an elementwise compare over the lane-aligned vocab axis is
    branch-free and VPU-friendly.  Next-state ids are produced vocab-aligned
    by the same reduction (``sum_j hit[j] * next[j]`` — token columns within
    a CSR row are unique, so the sum has at most one non-zero term).

Slots are processed in fixed chunks through a ``fori_loop`` so VMEM pressure
stays at ``O(beam_tile * vocab)`` regardless of the branch factor, and the
edge DMA length is the chunk-rounded branch factor (the edges tensor is
padded accordingly by the trie builder).

The fused variant additionally normalizes raw logits with an in-register
log-softmax before masking, eliminating one full HBM round-trip over the
``(B*M, V)`` tensor per decode step (a beyond-paper optimization).

The **candidate-compressed** kernels (``vntk_topk_pallas`` /
``vntk_stacked_topk_pallas``, DESIGN.md §8) go one step further: instead of
writing the vocab-aligned ``(nb, V)`` masked log-probs *and* next-state map
back to HBM, they select each beam's dense-rank top-``C`` **in VMEM** — via
the same compare-broadcast machinery, now reducing over the vocab axis to
gather candidate log-probs — and emit only ``(nb, C)`` scores/tokens/states.
HBM write traffic per step drops from ``O(nb * V)`` to ``O(nb * C)``.
Selection is a branch-free rank-by-counting pass (TPUs have no in-VMEM sort):
``rank[j] = #{j' : key[j'] > key[j] or (key[j'] == key[j] and j' < j)}``
followed by a compare-broadcast scatter into the ``C`` output lanes; the
index tie-break reproduces the dense path's flat-index tie order exactly
(candidate slots are token-ascending, see ``core.vntk._topk_from_candidates``).

The **compressed-slab** kernels (``vntk_compressed_*``, DESIGN.md §11) swap
the ``(E, 2)`` int32 edge slab for the delta-encoded token array of
:class:`repro.core.compressed_slab.CompressedSlab` — int16 where the vocab
permits — so the speculative burst moves 2 B/slot over the DMA instead of
8 B.  Decompression is fused into the same wave: an int32 cumsum over the
burst (which always begins at a CSR row start, so the absolute anchor is
slot 0) recovers the token columns, and next states are rebuilt as
``row_start + slot + level_base`` with the per-beam base arriving as a tiny
blocked input.  Everything downstream of the decode is the shared
projection/selection machinery, so outputs are bit-identical to the
uncompressed kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e10

__all__ = [
    "vntk_pallas",
    "vntk_fused_logsoftmax_pallas",
    "vntk_stacked_pallas",
    "vntk_stacked_fused_logsoftmax_pallas",
    "vntk_topk_pallas",
    "vntk_stacked_topk_pallas",
    "vntk_compressed_pallas",
    "vntk_stacked_compressed_pallas",
    "vntk_compressed_topk_pallas",
    "vntk_stacked_compressed_topk_pallas",
]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _beam_padding(nb: int, beam_tile: int) -> tuple[int, int]:
    """Grid tiling for ``nb`` beam rows: ``(beam_tile, nb_padded)``.

    The beam axis is padded UP to a tile multiple instead of degrading the
    tile (the old ``while nb % beam_tile: beam_tile -= 1`` walked prime row
    counts all the way down to tile=1, serializing the whole grid).  Pad rows
    decode from the SINK state (node 0, an empty CSR row) so their DMAs stay
    in bounds and their outputs are sliced away by the caller.
    """
    beam_tile = max(1, min(beam_tile, nb))
    return beam_tile, _round_up(nb, beam_tile)


def _pad_rows(arr, nb_padded: int, fill=0):
    """Pad axis 0 of ``arr`` to ``nb_padded`` rows with ``fill``."""
    nb = arr.shape[0]
    if nb == nb_padded:
        return arr
    pad = [(0, nb_padded - nb)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad, constant_values=fill)


def _dma_front(
    nodes_ref,
    rowptr_hbm,
    edges_hbm,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    beam_tile: int,
    bmax_padded: int,
    cids_ref=None,
):
    """Phases 1+2: pipelined per-beam boundary lookup + speculative burst.

    Two overlapped waves: ALL row-pointer copies are issued before any is
    waited on, so beam i+1's rowptr fetch rides under beam i's edge burst
    (the old inline start()+wait() serialized the whole front: no rowptr
    DMA could overlap anything).  Edge bursts still wait on their own beam's
    row pointer — the burst start address depends on it, which is why
    ``sem_rp`` is a PER-BEAM semaphore array: a shared DMA semaphore counts
    completions without identifying which copy signaled, so beam j landing
    first could otherwise unblock beam i's wait while beam i's row pointer
    is still in flight.  The edge wave may share one semaphore — nothing
    reads ``edge_scratch`` until every edge wait has returned, and
    ``beam_tile`` waits can only be satisfied by ``beam_tile`` completions.
    With ``cids_ref`` both tensors carry a leading constraint axis (stacked
    store, §4).  The front is shape-agnostic in the trailing slot layout:
    the same two waves move the raw ``(slot, 2)`` int32 burst or the
    compressed slab's flat int16/int32 delta burst (§11) — only the scratch
    destination's shape/dtype differ.
    """
    def rp_src(i):
        sl = pl.ds(nodes_ref[i], 2)
        return (rowptr_hbm.at[cids_ref[i], sl] if cids_ref is not None
                else rowptr_hbm.at[sl])

    def edge_src(i, start):
        sl = pl.ds(start, bmax_padded)
        return (edges_hbm.at[cids_ref[i], sl] if cids_ref is not None
                else edges_hbm.at[sl])

    rp_copies = [
        pltpu.make_async_copy(rp_src(i), rp_scratch.at[i], sem_rp.at[i])
        for i in range(beam_tile)
    ]
    for cp in rp_copies:
        cp.start()
    edge_copies = []
    for i in range(beam_tile):
        rp_copies[i].wait()  # semaphore i: signaled only by copy i
        cp2 = pltpu.make_async_copy(
            edge_src(i, rp_scratch[i, 0]), edge_scratch.at[i], sem_edge
        )
        cp2.start()
        edge_copies.append(cp2)
    for cp2 in edge_copies:
        cp2.wait()


def _decode_delta_slots(rp_scratch, tok_scratch, base_ref):
    """Fused slab decompression (DESIGN.md §11): delta burst -> slot arrays.

    The burst in ``tok_scratch`` starts at this beam's CSR row start, whose
    delta IS the absolute token, so one int32 cumsum along the slot axis
    recovers every column (the cast happens BEFORE the cumsum: int16 partial
    sums would wrap for vocabularies near the int16 limit).  Slots past the
    row end decode to garbage exactly like the uncompressed speculative
    over-read — the shared ``iota < n_child`` sanitization masks both.  Next
    states need no stored bytes at all: destinations are consecutive over
    each level's edge block, so ``next = row_start + slot + level_base``.
    """
    beam_tile, bmax_padded = tok_scratch.shape
    n_child = rp_scratch[:, 1] - rp_scratch[:, 0]  # (beam_tile,)
    cols_all = jnp.cumsum(tok_scratch[...].astype(jnp.int32), axis=1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (beam_tile, bmax_padded), 1)
    next_all = rp_scratch[:, 0][:, None] + iota + base_ref[...][:, None]
    return n_child, cols_all, next_all


def _raw_slots(rp_scratch, edge_scratch):
    """Slot arrays of the uncompressed ``(beam_tile, bmax_padded, 2)`` burst."""
    n_child = rp_scratch[:, 1] - rp_scratch[:, 0]  # (beam_tile,)
    return n_child, edge_scratch[:, :, 0], edge_scratch[:, :, 1]


def _project_and_write(
    n_child,
    cols_all,
    next_all,
    logits_ref,
    out_lp_ref,
    out_next_ref,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    """Phases 3+4 (+ optional fused log-softmax): shared by all DMA fronts.

    Consumes the decoded slot arrays ``(n_child, cols_all, next_all)`` so the
    same projection serves both the raw ``(slot, 2)`` burst and the
    delta-decompressed compressed slab."""
    # ---- Phase 3+4: chunked sanitize + compare-broadcast projection ----
    n_chunks = bmax_padded // slot_chunk
    iota_slot = jax.lax.broadcasted_iota(jnp.int32, (beam_tile, slot_chunk), 1)
    iota_v = jax.lax.broadcasted_iota(
        jnp.int32, (beam_tile, slot_chunk, vocab), 2
    )

    def chunk_body(c, carry):
        mask, nxt = carry
        cols = jax.lax.dynamic_slice_in_dim(
            cols_all, c * slot_chunk, slot_chunk, axis=1
        )
        vals = jax.lax.dynamic_slice_in_dim(
            next_all, c * slot_chunk, slot_chunk, axis=1
        )
        valid = (c * slot_chunk + iota_slot) < n_child[:, None]
        hit = (cols[:, :, None] == iota_v) & valid[:, :, None]
        mask = mask | jnp.any(hit, axis=1)
        nxt = nxt + jnp.sum(
            hit.astype(jnp.int32) * vals[:, :, None], axis=1, dtype=jnp.int32
        )
        return mask, nxt

    mask0 = jnp.zeros((beam_tile, vocab), bool)
    nxt0 = jnp.zeros((beam_tile, vocab), jnp.int32)
    mask, nxt = jax.lax.fori_loop(0, n_chunks, chunk_body, (mask0, nxt0))

    x = logits_ref[...]
    if fused_logsoftmax:
        xf = x.astype(jnp.float32)
        m = jnp.max(xf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(xf - m), axis=-1, keepdims=True))
        lp = (xf - m - lse).astype(out_lp_ref.dtype)
    else:
        lp = x.astype(out_lp_ref.dtype)
    out_lp_ref[...] = jnp.where(mask, lp, jnp.asarray(NEG_INF, out_lp_ref.dtype))
    out_next_ref[...] = nxt


def _project_and_select(
    n_child,
    cols_all,
    next_all,
    logits_ref,
    out_sc_ref,
    out_tok_ref,
    out_next_ref,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    width: int,
    fused_logsoftmax: bool,
):
    """Phases 3+4' of the candidate-compressed step (DESIGN.md §8).

    Instead of projecting the candidates to a vocab-aligned mask, the same
    chunked compare-broadcast now runs the OTHER way — reducing over the
    vocab axis to gather each CSR slot's log-prob — and an in-VMEM
    rank-by-counting pass selects each beam's dense-rank top-``width``:
    valid children by (lp desc, token asc), then the smallest missing tokens
    at NEG_INF (the dense tie-break's invalid-continuation order), exactly
    as in :func:`repro.core.vntk._topk_from_candidates`.  Only the
    ``(beam_tile, width)`` winners ever leave VMEM.  Like
    :func:`_project_and_write` it consumes decoded slot arrays, serving both
    the raw and the compressed DMA fronts.
    """
    x = logits_ref[...]
    xf = x.astype(jnp.float32)
    if fused_logsoftmax:
        m = jnp.max(xf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(xf - m), axis=-1, keepdims=True))
        lp = xf - m - lse
    else:
        lp = xf

    # ---- candidate log-prob gather: chunked compare-broadcast reduction ----
    n_chunks = bmax_padded // slot_chunk
    iota_slot = jax.lax.broadcasted_iota(jnp.int32, (beam_tile, slot_chunk), 1)
    iota_v = jax.lax.broadcasted_iota(
        jnp.int32, (beam_tile, slot_chunk, vocab), 2
    )

    def chunk_body(c, cand):
        cols = jax.lax.dynamic_slice_in_dim(
            cols_all, c * slot_chunk, slot_chunk, axis=1
        )
        valid = (c * slot_chunk + iota_slot) < n_child[:, None]
        hit = (cols[:, :, None] == iota_v) & valid[:, :, None]
        # token columns within a CSR row are unique: <= 1 non-zero term
        vals = jnp.sum(hit.astype(jnp.float32) * lp[:, None, :], axis=2)
        return jax.lax.dynamic_update_slice(cand, vals, (0, c * slot_chunk))

    cand_lp = jax.lax.fori_loop(
        0, n_chunks, chunk_body,
        jnp.zeros((beam_tile, bmax_padded), jnp.float32),
    )

    # ---- per-beam dense-rank top-C over candidates + missing-token fill ----
    minf = jnp.float32(jnp.finfo(jnp.float32).min)
    iota_full = jax.lax.broadcasted_iota(
        jnp.int32, (beam_tile, bmax_padded), 1
    )
    valid_full = iota_full < n_child[:, None]
    real_key = jnp.where(valid_full, cand_lp, minf)
    real_tok = jnp.where(valid_full, cols_all, 0)
    real_next = jnp.where(valid_full, next_all, 0)

    # i-th missing token = i + |{j : cols[j] - j <= i}| (sorted distinct cols)
    adj = jnp.where(valid_full, cols_all - iota_full, vocab + bmax_padded + 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (beam_tile, width), 1)
    cnt = jnp.sum(
        (adj[:, None, :] <= iota_c[:, :, None]).astype(jnp.int32), axis=2
    )
    fill_tok = iota_c + cnt
    in_range = fill_tok < vocab
    fill_key = jnp.where(in_range, jnp.float32(NEG_INF), minf)
    fill_tok = jnp.where(in_range, fill_tok, 0)

    keys = jnp.concatenate([real_key, fill_key], axis=1)  # (beam_tile, J)
    toks = jnp.concatenate([real_tok, fill_tok], axis=1)
    nxts = jnp.concatenate(
        [real_next, jnp.zeros((beam_tile, width), next_all.dtype)], axis=1
    )
    J = bmax_padded + width

    # rank[j] = #{j' : key[j'] > key[j] or (== and j' < j)} — branch-free
    # selection sort rank; the index tie-break IS the dense flat-index tie
    # order (slots are token-ascending).  The competitor axis is chunked so
    # VMEM stays O(J * chunk) rather than O(J^2).
    idx_j = jax.lax.broadcasted_iota(jnp.int32, (beam_tile, J), 1)
    ka = keys[:, :, None]
    ia = idx_j[:, :, None]
    rank = jnp.zeros((beam_tile, J), jnp.int32)
    rchunk = max(slot_chunk * 16, width)
    for c0 in range(0, J, rchunk):
        c1 = min(c0 + rchunk, J)
        kb = keys[:, None, c0:c1]
        ib = idx_j[:, None, c0:c1]
        beats = (kb > ka) | ((kb == ka) & (ib < ia))
        rank = rank + jnp.sum(beats.astype(jnp.int32), axis=2)

    # compare-broadcast scatter of the rank-< width winners into the C lanes
    sel = rank[:, None, :] == iota_c[:, :, None]  # (beam_tile, width, J)
    out_sc = jnp.sum(sel.astype(jnp.float32) * keys[:, None, :], axis=2)
    out_tok = jnp.sum(sel.astype(toks.dtype) * toks[:, None, :], axis=2)
    out_next = jnp.sum(sel.astype(nxts.dtype) * nxts[:, None, :], axis=2)

    out_sc_ref[...] = out_sc.astype(out_sc_ref.dtype)
    out_tok_ref[...] = out_tok.astype(jnp.int32)
    out_next_ref[...] = out_next.astype(jnp.int32)


def _vntk_topk_body(
    nodes_ref,
    logits_ref,
    rowptr_hbm,
    edges_hbm,
    out_sc_ref,
    out_tok_ref,
    out_next_ref,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    width: int,
    fused_logsoftmax: bool,
):
    _dma_front(
        nodes_ref, rowptr_hbm, edges_hbm, rp_scratch, edge_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
    )
    _project_and_select(
        *_raw_slots(rp_scratch, edge_scratch), logits_ref, out_sc_ref,
        out_tok_ref, out_next_ref, bmax_padded=bmax_padded,
        slot_chunk=slot_chunk, vocab=vocab, beam_tile=beam_tile, width=width,
        fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_stacked_topk_body(
    nodes_ref,
    cids_ref,
    logits_ref,
    rowptr_hbm,
    edges_hbm,
    out_sc_ref,
    out_tok_ref,
    out_next_ref,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    width: int,
    fused_logsoftmax: bool,
):
    _dma_front(
        nodes_ref, rowptr_hbm, edges_hbm, rp_scratch, edge_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
        cids_ref=cids_ref,
    )
    _project_and_select(
        *_raw_slots(rp_scratch, edge_scratch), logits_ref, out_sc_ref,
        out_tok_ref, out_next_ref, bmax_padded=bmax_padded,
        slot_chunk=slot_chunk, vocab=vocab, beam_tile=beam_tile, width=width,
        fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_body(
    nodes_ref,
    logits_ref,
    rowptr_hbm,
    edges_hbm,
    out_lp_ref,
    out_next_ref,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    _dma_front(
        nodes_ref, rowptr_hbm, edges_hbm, rp_scratch, edge_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
    )
    _project_and_write(
        *_raw_slots(rp_scratch, edge_scratch), logits_ref, out_lp_ref,
        out_next_ref, bmax_padded=bmax_padded, slot_chunk=slot_chunk,
        vocab=vocab, beam_tile=beam_tile, fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_stacked_body(
    nodes_ref,
    cids_ref,
    logits_ref,
    rowptr_hbm,
    edges_hbm,
    out_lp_ref,
    out_next_ref,
    rp_scratch,
    edge_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    """Multi-constraint front end (DESIGN.md §4): the row-pointer and edge
    DMAs index one extra leading constraint axis — ``rowptr (K, S+1)`` and
    ``edges (K, E, 2)`` — by each beam's constraint id.  Everything after the
    fetch is the shared single-matrix projection.  The DMA front is pipelined
    exactly like :func:`_vntk_body`: every rowptr copy is in flight before
    the first edge burst is issued."""
    _dma_front(
        nodes_ref, rowptr_hbm, edges_hbm, rp_scratch, edge_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
        cids_ref=cids_ref,
    )
    _project_and_write(
        *_raw_slots(rp_scratch, edge_scratch), logits_ref, out_lp_ref,
        out_next_ref, bmax_padded=bmax_padded, slot_chunk=slot_chunk,
        vocab=vocab, beam_tile=beam_tile, fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_compressed_body(
    nodes_ref,
    base_ref,
    logits_ref,
    rowptr_hbm,
    tok_hbm,
    out_lp_ref,
    out_next_ref,
    rp_scratch,
    tok_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    """Compressed-slab front end (DESIGN.md §11): the edge wave DMAs the
    delta token burst (2 B/slot at int16) and decompression is fused right
    behind the wait — cumsum for columns, ``row_start + slot + base`` for
    next states — before the shared projection."""
    _dma_front(
        nodes_ref, rowptr_hbm, tok_hbm, rp_scratch, tok_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
    )
    _project_and_write(
        *_decode_delta_slots(rp_scratch, tok_scratch, base_ref), logits_ref,
        out_lp_ref, out_next_ref, bmax_padded=bmax_padded,
        slot_chunk=slot_chunk, vocab=vocab, beam_tile=beam_tile,
        fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_stacked_compressed_body(
    nodes_ref,
    cids_ref,
    base_ref,
    logits_ref,
    rowptr_hbm,
    tok_hbm,
    out_lp_ref,
    out_next_ref,
    rp_scratch,
    tok_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    fused_logsoftmax: bool,
):
    _dma_front(
        nodes_ref, rowptr_hbm, tok_hbm, rp_scratch, tok_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
        cids_ref=cids_ref,
    )
    _project_and_write(
        *_decode_delta_slots(rp_scratch, tok_scratch, base_ref), logits_ref,
        out_lp_ref, out_next_ref, bmax_padded=bmax_padded,
        slot_chunk=slot_chunk, vocab=vocab, beam_tile=beam_tile,
        fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_compressed_topk_body(
    nodes_ref,
    base_ref,
    logits_ref,
    rowptr_hbm,
    tok_hbm,
    out_sc_ref,
    out_tok_ref,
    out_next_ref,
    rp_scratch,
    tok_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    width: int,
    fused_logsoftmax: bool,
):
    _dma_front(
        nodes_ref, rowptr_hbm, tok_hbm, rp_scratch, tok_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
    )
    _project_and_select(
        *_decode_delta_slots(rp_scratch, tok_scratch, base_ref), logits_ref,
        out_sc_ref, out_tok_ref, out_next_ref, bmax_padded=bmax_padded,
        slot_chunk=slot_chunk, vocab=vocab, beam_tile=beam_tile, width=width,
        fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_stacked_compressed_topk_body(
    nodes_ref,
    cids_ref,
    base_ref,
    logits_ref,
    rowptr_hbm,
    tok_hbm,
    out_sc_ref,
    out_tok_ref,
    out_next_ref,
    rp_scratch,
    tok_scratch,
    sem_rp,
    sem_edge,
    *,
    bmax_padded: int,
    slot_chunk: int,
    vocab: int,
    beam_tile: int,
    width: int,
    fused_logsoftmax: bool,
):
    _dma_front(
        nodes_ref, rowptr_hbm, tok_hbm, rp_scratch, tok_scratch,
        sem_rp, sem_edge, beam_tile=beam_tile, bmax_padded=bmax_padded,
        cids_ref=cids_ref,
    )
    _project_and_select(
        *_decode_delta_slots(rp_scratch, tok_scratch, base_ref), logits_ref,
        out_sc_ref, out_tok_ref, out_next_ref, bmax_padded=bmax_padded,
        slot_chunk=slot_chunk, vocab=vocab, beam_tile=beam_tile, width=width,
        fused_logsoftmax=fused_logsoftmax,
    )


def _vntk_call(
    logits: jax.Array,  # (nb, V)
    nodes: jax.Array,  # (nb,)
    row_pointers: jax.Array,  # (S+1,)
    edges: jax.Array,  # (E+pad, 2) stacked
    bmax: int,
    vocab: int,
    *,
    fused_logsoftmax: bool,
    beam_tile: int = 8,
    slot_chunk: int = 8,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
):
    nb = nodes.shape[0]
    beam_tile, nb_pad = _beam_padding(nb, beam_tile)
    logits = _pad_rows(logits, nb_pad)
    nodes = _pad_rows(nodes, nb_pad)  # pad rows decode from SINK (node 0)
    bmax_padded = _round_up(max(bmax, 1), slot_chunk)
    if edges.shape[0] < bmax_padded:
        raise ValueError("edges tensor smaller than one speculative burst")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (nb_pad // beam_tile,)
    kern = functools.partial(
        _vntk_body,
        bmax_padded=bmax_padded,
        slot_chunk=slot_chunk,
        vocab=vocab,
        beam_tile=beam_tile,
        fused_logsoftmax=fused_logsoftmax,
    )
    out_lp, out_next = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((beam_tile,), lambda i: (i,)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_pad, vocab), out_dtype),
            jax.ShapeDtypeStruct((nb_pad, vocab), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((beam_tile, 2), jnp.int32),
            pltpu.VMEM((beam_tile, bmax_padded, 2), jnp.int32),
            pltpu.SemaphoreType.DMA((beam_tile,)),  # per-beam rowptr sems
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(nodes, logits, row_pointers, edges)
    return out_lp[:nb], out_next[:nb]


def _vntk_stacked_call(
    logits: jax.Array,  # (nb, V)
    nodes: jax.Array,  # (nb,)
    cids: jax.Array,  # (nb,)
    row_pointers: jax.Array,  # (K, S+1)
    edges: jax.Array,  # (K, E, 2) stacked per constraint set
    bmax: int,
    vocab: int,
    *,
    fused_logsoftmax: bool,
    beam_tile: int = 8,
    slot_chunk: int = 8,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
):
    nb = nodes.shape[0]
    beam_tile, nb_pad = _beam_padding(nb, beam_tile)
    logits = _pad_rows(logits, nb_pad)
    nodes = _pad_rows(nodes, nb_pad)  # pad rows decode from SINK (node 0)
    cids = _pad_rows(cids, nb_pad)
    bmax_padded = _round_up(max(bmax, 1), slot_chunk)
    if edges.shape[1] < bmax_padded:
        raise ValueError("edges tensor smaller than one speculative burst")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (nb_pad // beam_tile,)
    kern = functools.partial(
        _vntk_stacked_body,
        bmax_padded=bmax_padded,
        slot_chunk=slot_chunk,
        vocab=vocab,
        beam_tile=beam_tile,
        fused_logsoftmax=fused_logsoftmax,
    )
    out_lp, out_next = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((beam_tile,), lambda i: (i,)),
            pl.BlockSpec((beam_tile,), lambda i: (i,)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_pad, vocab), out_dtype),
            jax.ShapeDtypeStruct((nb_pad, vocab), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((beam_tile, 2), jnp.int32),
            pltpu.VMEM((beam_tile, bmax_padded, 2), jnp.int32),
            pltpu.SemaphoreType.DMA((beam_tile,)),  # per-beam rowptr sems
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(nodes, cids, logits, row_pointers, edges)
    return out_lp[:nb], out_next[:nb]


def _vntk_topk_call(
    logits: jax.Array,  # (nb, V)
    nodes: jax.Array,  # (nb,)
    cids: jax.Array | None,  # (nb,) or None for the single-matrix path
    row_pointers: jax.Array,  # (S+1,) or (K, S+1)
    edges: jax.Array,  # (E+pad, 2) or (K, E, 2)
    bmax: int,
    vocab: int,
    width: int,
    *,
    fused_logsoftmax: bool,
    beam_tile: int = 8,
    slot_chunk: int = 8,
    interpret: bool | None = None,
):
    """Shared driver for the candidate-compressed kernels: three ``(nb, C)``
    outputs instead of two ``(nb, V)`` ones."""
    nb = nodes.shape[0]
    beam_tile, nb_pad = _beam_padding(nb, beam_tile)
    logits = _pad_rows(logits, nb_pad)
    nodes = _pad_rows(nodes, nb_pad)  # pad rows decode from SINK (node 0)
    stacked = cids is not None
    if stacked:
        cids = _pad_rows(cids, nb_pad)
    bmax_padded = _round_up(max(bmax, 1), slot_chunk)
    if edges.shape[-2] < bmax_padded:
        raise ValueError("edges tensor smaller than one speculative burst")
    if not 1 <= width <= vocab:
        raise ValueError(f"width must be in [1, {vocab}], got {width}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (nb_pad // beam_tile,)
    kern = functools.partial(
        _vntk_stacked_topk_body if stacked else _vntk_topk_body,
        bmax_padded=bmax_padded,
        slot_chunk=slot_chunk,
        vocab=vocab,
        beam_tile=beam_tile,
        width=width,
        fused_logsoftmax=fused_logsoftmax,
    )
    row_specs = [pl.BlockSpec((beam_tile,), lambda i: (i,))]
    if stacked:
        row_specs.append(pl.BlockSpec((beam_tile,), lambda i: (i,)))
    out_sc, out_tok, out_next = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=row_specs + [
            pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((beam_tile, width), lambda i: (i, 0)),
            pl.BlockSpec((beam_tile, width), lambda i: (i, 0)),
            pl.BlockSpec((beam_tile, width), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_pad, width), jnp.float32),
            jax.ShapeDtypeStruct((nb_pad, width), jnp.int32),
            jax.ShapeDtypeStruct((nb_pad, width), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((beam_tile, 2), jnp.int32),
            pltpu.VMEM((beam_tile, bmax_padded, 2), jnp.int32),
            pltpu.SemaphoreType.DMA((beam_tile,)),  # per-beam rowptr sems
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(*((nodes, cids) if stacked else (nodes,)), logits, row_pointers, edges)
    return out_sc[:nb], out_tok[:nb], out_next[:nb]


def _vntk_compressed_call(
    logits: jax.Array,  # (nb, V)
    nodes: jax.Array,  # (nb,)
    cids: jax.Array | None,  # (nb,) or None for the single-matrix path
    base: jax.Array,  # (nb,) int32 per-beam next-state base for this step
    row_pointers: jax.Array,  # (S+1,) or (K, S+1)
    tok_delta: jax.Array,  # (E+pad,) or (K, E+pad) int16/int32
    bmax: int,
    vocab: int,
    width: int | None,
    *,
    fused_logsoftmax: bool,
    beam_tile: int = 8,
    slot_chunk: int = 8,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
):
    """Shared driver for the compressed-slab kernels (DESIGN.md §11).

    ``width=None`` runs the vocab-projection body (two ``(nb, V)`` outputs);
    an integer runs the candidate-compressed selection (three ``(nb, width)``
    outputs).  The edge scratch is the slab's own dtype — int16 where the
    vocab permits — which is the whole HBM-bytes win."""
    nb = nodes.shape[0]
    beam_tile, nb_pad = _beam_padding(nb, beam_tile)
    logits = _pad_rows(logits, nb_pad)
    nodes = _pad_rows(nodes, nb_pad)  # pad rows decode from SINK (node 0)
    base = _pad_rows(base, nb_pad)
    stacked = cids is not None
    if stacked:
        cids = _pad_rows(cids, nb_pad)
    bmax_padded = _round_up(max(bmax, 1), slot_chunk)
    if tok_delta.shape[-1] < bmax_padded:
        raise ValueError("token slab smaller than one speculative burst")
    if width is not None and not 1 <= width <= vocab:
        raise ValueError(f"width must be in [1, {vocab}], got {width}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (nb_pad // beam_tile,)
    topk = width is not None
    bodies = {
        (False, False): _vntk_compressed_body,
        (True, False): _vntk_stacked_compressed_body,
        (False, True): _vntk_compressed_topk_body,
        (True, True): _vntk_stacked_compressed_topk_body,
    }
    static = dict(
        bmax_padded=bmax_padded, slot_chunk=slot_chunk, vocab=vocab,
        beam_tile=beam_tile, fused_logsoftmax=fused_logsoftmax,
    )
    if topk:
        static["width"] = width
    kern = functools.partial(bodies[(stacked, topk)], **static)
    row_spec = pl.BlockSpec((beam_tile,), lambda i: (i,))
    in_specs = [row_spec] + ([row_spec] if stacked else []) + [
        row_spec,  # base
        pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0)),
        pl.BlockSpec(memory_space=pl.ANY),
        pl.BlockSpec(memory_space=pl.ANY),
    ]
    if topk:
        out_specs = [pl.BlockSpec((beam_tile, width), lambda i: (i, 0))] * 3
        out_shape = [
            jax.ShapeDtypeStruct((nb_pad, width), jnp.float32),
            jax.ShapeDtypeStruct((nb_pad, width), jnp.int32),
            jax.ShapeDtypeStruct((nb_pad, width), jnp.int32),
        ]
    else:
        out_specs = [pl.BlockSpec((beam_tile, vocab), lambda i: (i, 0))] * 2
        out_shape = [
            jax.ShapeDtypeStruct((nb_pad, vocab), out_dtype),
            jax.ShapeDtypeStruct((nb_pad, vocab), jnp.int32),
        ]
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((beam_tile, 2), jnp.int32),
            pltpu.VMEM((beam_tile, bmax_padded), tok_delta.dtype),
            pltpu.SemaphoreType.DMA((beam_tile,)),  # per-beam rowptr sems
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(*((nodes, cids) if stacked else (nodes,)), base, logits,
      row_pointers, tok_delta)
    return tuple(o[:nb] for o in outs)


def vntk_pallas(
    log_probs: jax.Array,
    nodes: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 on pre-normalized log-probs. Shapes: (..., V) / (...,)."""
    batch_shape = nodes.shape
    lp, nxt = _vntk_call(
        log_probs.reshape(-1, vocab),
        nodes.reshape(-1),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=False,
        out_dtype=log_probs.dtype,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_fused_logsoftmax_pallas(
    logits: jax.Array,
    nodes: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Fused LogSoftmax + Alg. 2 masking in a single HBM pass."""
    batch_shape = nodes.shape
    lp, nxt = _vntk_call(
        logits.reshape(-1, vocab),
        nodes.reshape(-1),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=True,
        out_dtype=jnp.float32,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_stacked_pallas(
    log_probs: jax.Array,
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,  # (K, S+1)
    edges: jax.Array,  # (K, E, 2)
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 over a stacked constraint store, pre-normalized log-probs."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    lp, nxt = _vntk_stacked_call(
        log_probs.reshape(-1, vocab),
        nodes.reshape(-1),
        cids.astype(jnp.int32),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=False,
        out_dtype=log_probs.dtype,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_stacked_fused_logsoftmax_pallas(
    logits: jax.Array,
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Fused LogSoftmax + stacked Alg. 2 masking in a single HBM pass."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    lp, nxt = _vntk_stacked_call(
        logits.reshape(-1, vocab),
        nodes.reshape(-1),
        cids.astype(jnp.int32),
        row_pointers,
        edges,
        bmax,
        vocab,
        fused_logsoftmax=True,
        out_dtype=jnp.float32,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_topk_pallas(
    values: jax.Array,  # (..., V) log-probs, or raw logits when fused
    nodes: jax.Array,
    row_pointers: jax.Array,
    edges: jax.Array,
    bmax: int,
    vocab: int,
    width: int,
    *,
    fused_logsoftmax: bool = False,
    **kw,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Candidate-compressed Alg. 2 (DESIGN.md §8): per-beam dense-rank top-C
    selected in VMEM.  Returns ``(scores, tokens, next_states)``, each
    ``(..., width)``; with ``fused_logsoftmax`` the inputs are raw logits and
    normalization happens in-register before selection."""
    batch_shape = nodes.shape
    sc, tok, nxt = _vntk_topk_call(
        values.reshape(-1, vocab),
        nodes.reshape(-1),
        None,
        row_pointers,
        edges,
        bmax,
        vocab,
        width,
        fused_logsoftmax=fused_logsoftmax,
        **kw,
    )
    shp = batch_shape + (width,)
    return sc.reshape(shp), tok.reshape(shp), nxt.reshape(shp)


def vntk_compressed_pallas(
    values: jax.Array,  # (..., V) log-probs, or raw logits when fused
    nodes: jax.Array,
    row_pointers: jax.Array,  # (S+1,)
    tok_delta: jax.Array,  # (E+pad,) int16/int32
    base,  # scalar or (...,) int32 level base for this step
    bmax: int,
    vocab: int,
    *,
    fused_logsoftmax: bool = False,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 over the compressed slab (DESIGN.md §11): the speculative burst
    DMAs delta tokens (int16 where the vocab permits) and decompression is
    fused behind the wave.  Bit-identical to :func:`vntk_pallas` /
    :func:`vntk_fused_logsoftmax_pallas` on the same trie."""
    batch_shape = nodes.shape
    base_b = jnp.broadcast_to(
        jnp.asarray(base, jnp.int32), batch_shape
    ).reshape(-1)
    lp, nxt = _vntk_compressed_call(
        values.reshape(-1, vocab),
        nodes.reshape(-1),
        None,
        base_b,
        row_pointers,
        tok_delta,
        bmax,
        vocab,
        None,
        fused_logsoftmax=fused_logsoftmax,
        out_dtype=jnp.float32 if fused_logsoftmax else values.dtype,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_stacked_compressed_pallas(
    values: jax.Array,
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,  # (K, S+1)
    tok_delta: jax.Array,  # (K, E+pad)
    base_k: jax.Array,  # (K,) int32 per-member level base for this step
    bmax: int,
    vocab: int,
    *,
    fused_logsoftmax: bool = False,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    """Stacked-store compressed Alg. 2: the delta burst indexes one extra
    leading constraint axis; each beam's base is gathered host-of-kernel."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    cids = cids.astype(jnp.int32)
    lp, nxt = _vntk_compressed_call(
        values.reshape(-1, vocab),
        nodes.reshape(-1),
        cids,
        base_k.astype(jnp.int32)[cids],
        row_pointers,
        tok_delta,
        bmax,
        vocab,
        None,
        fused_logsoftmax=fused_logsoftmax,
        out_dtype=jnp.float32 if fused_logsoftmax else values.dtype,
        **kw,
    )
    return lp.reshape(batch_shape + (vocab,)), nxt.reshape(batch_shape + (vocab,))


def vntk_compressed_topk_pallas(
    values: jax.Array,
    nodes: jax.Array,
    row_pointers: jax.Array,
    tok_delta: jax.Array,
    base,
    bmax: int,
    vocab: int,
    width: int,
    *,
    fused_logsoftmax: bool = False,
    **kw,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Candidate-compressed selection over the compressed slab: §8's
    ``(nb, C)`` outputs fed by §11's 2 B/slot DMA burst — the cheapest
    decode step in the file.  Bit-identical to :func:`vntk_topk_pallas`."""
    batch_shape = nodes.shape
    base_b = jnp.broadcast_to(
        jnp.asarray(base, jnp.int32), batch_shape
    ).reshape(-1)
    sc, tok, nxt = _vntk_compressed_call(
        values.reshape(-1, vocab),
        nodes.reshape(-1),
        None,
        base_b,
        row_pointers,
        tok_delta,
        bmax,
        vocab,
        width,
        fused_logsoftmax=fused_logsoftmax,
        **kw,
    )
    shp = batch_shape + (width,)
    return sc.reshape(shp), tok.reshape(shp), nxt.reshape(shp)


def vntk_stacked_compressed_topk_pallas(
    values: jax.Array,
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,  # (K, S+1)
    tok_delta: jax.Array,  # (K, E+pad)
    base_k: jax.Array,  # (K,) int32
    bmax: int,
    vocab: int,
    width: int,
    *,
    fused_logsoftmax: bool = False,
    **kw,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stacked-store compressed candidate-compressed Alg. 2."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    cids = cids.astype(jnp.int32)
    sc, tok, nxt = _vntk_compressed_call(
        values.reshape(-1, vocab),
        nodes.reshape(-1),
        cids,
        base_k.astype(jnp.int32)[cids],
        row_pointers,
        tok_delta,
        bmax,
        vocab,
        width,
        fused_logsoftmax=fused_logsoftmax,
        **kw,
    )
    shp = batch_shape + (width,)
    return sc.reshape(shp), tok.reshape(shp), nxt.reshape(shp)


def vntk_stacked_topk_pallas(
    values: jax.Array,  # (..., V) log-probs, or raw logits when fused
    nodes: jax.Array,
    constraint_ids: jax.Array,
    row_pointers: jax.Array,  # (K, S+1)
    edges: jax.Array,  # (K, E, 2)
    bmax: int,
    vocab: int,
    width: int,
    *,
    fused_logsoftmax: bool = False,
    **kw,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stacked-store candidate-compressed Alg. 2 over a ConstraintStore."""
    batch_shape = nodes.shape
    cids = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
    sc, tok, nxt = _vntk_topk_call(
        values.reshape(-1, vocab),
        nodes.reshape(-1),
        cids.astype(jnp.int32),
        row_pointers,
        edges,
        bmax,
        vocab,
        width,
        fused_logsoftmax=fused_logsoftmax,
        **kw,
    )
    shp = batch_shape + (width,)
    return sc.reshape(shp), tok.reshape(shp), nxt.reshape(shp)
