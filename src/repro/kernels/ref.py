"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the numerically-plain XLA formulation the kernels are tested
against (``tests/test_kernels_pallas.py`` sweeps shapes/dtypes and asserts
allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vntk import (
    NEG_INF,
    vntk_compressed_reference,
    vntk_compressed_topk_reference,
    vntk_reference_scatter,
    vntk_stacked_compressed_reference,
    vntk_stacked_compressed_topk_reference,
    vntk_stacked_reference_scatter,
    vntk_stacked_topk_reference,
    vntk_topk_reference,
)

__all__ = [
    "vntk_ref",
    "vntk_fused_logsoftmax_ref",
    "vntk_stacked_ref",
    "vntk_stacked_fused_logsoftmax_ref",
    "vntk_topk_ref",
    "vntk_stacked_topk_ref",
    "vntk_compressed_ref",
    "vntk_stacked_compressed_ref",
    "vntk_compressed_topk_ref",
    "vntk_stacked_compressed_topk_ref",
    "embedding_bag_ref",
]


def vntk_ref(log_probs, nodes, row_pointers, edges, bmax, vocab):
    """Paper Appendix E scatter formulation (the faithful oracle)."""
    return vntk_reference_scatter(log_probs, nodes, row_pointers, edges, bmax, vocab)


def vntk_fused_logsoftmax_ref(logits, nodes, row_pointers, edges, bmax, vocab):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return vntk_reference_scatter(lp, nodes, row_pointers, edges, bmax, vocab)


def vntk_stacked_ref(log_probs, nodes, constraint_ids, row_pointers, edges,
                     bmax, vocab):
    """Stacked-store scatter oracle: one extra constraint-axis gather."""
    return vntk_stacked_reference_scatter(
        log_probs, nodes, constraint_ids, row_pointers, edges, bmax, vocab
    )


def vntk_stacked_fused_logsoftmax_ref(logits, nodes, constraint_ids,
                                      row_pointers, edges, bmax, vocab):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return vntk_stacked_reference_scatter(
        lp, nodes, constraint_ids, row_pointers, edges, bmax, vocab
    )


def vntk_topk_ref(values, nodes, row_pointers, edges, bmax, vocab, width,
                  fused_logsoftmax=False):
    """Candidate-compressed oracle: per-beam dense-rank top-``width``."""
    lp = (jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
          if fused_logsoftmax else values)
    return vntk_topk_reference(
        lp, nodes, row_pointers, edges, bmax, vocab, width
    )


def vntk_stacked_topk_ref(values, nodes, constraint_ids, row_pointers, edges,
                          bmax, vocab, width, fused_logsoftmax=False):
    """Stacked candidate-compressed oracle (constraint-axis gather)."""
    lp = (jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
          if fused_logsoftmax else values)
    return vntk_stacked_topk_reference(
        lp, nodes, constraint_ids, row_pointers, edges, bmax, vocab, width
    )


def vntk_compressed_ref(values, nodes, row_pointers, tok_delta, base, bmax,
                        vocab, fused_logsoftmax=False):
    """Compressed-slab oracle (DESIGN.md §11): delta-decode + scatter."""
    lp = (jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
          if fused_logsoftmax else values)
    return vntk_compressed_reference(
        lp, nodes, row_pointers, tok_delta, base, bmax, vocab
    )


def vntk_stacked_compressed_ref(values, nodes, constraint_ids, row_pointers,
                                tok_delta, base_k, bmax, vocab,
                                fused_logsoftmax=False):
    lp = (jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
          if fused_logsoftmax else values)
    return vntk_stacked_compressed_reference(
        lp, nodes, constraint_ids, row_pointers, tok_delta, base_k, bmax, vocab
    )


def vntk_compressed_topk_ref(values, nodes, row_pointers, tok_delta, base,
                             bmax, vocab, width, fused_logsoftmax=False):
    """Compressed-slab candidate-compressed oracle."""
    lp = (jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
          if fused_logsoftmax else values)
    return vntk_compressed_topk_reference(
        lp, nodes, row_pointers, tok_delta, base, bmax, vocab, width
    )


def vntk_stacked_compressed_topk_ref(values, nodes, constraint_ids,
                                     row_pointers, tok_delta, base_k, bmax,
                                     vocab, width, fused_logsoftmax=False):
    lp = (jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
          if fused_logsoftmax else values)
    return vntk_stacked_compressed_topk_reference(
        lp, nodes, constraint_ids, row_pointers, tok_delta, base_k, bmax,
        vocab, width
    )


def embedding_bag_ref(table, indices, mode="sum"):
    """take + reduce formulation; sentinel row R must be zero."""
    rows = jnp.take(table, indices, axis=0)  # (B, K, D)
    acc = jnp.sum(rows.astype(jnp.float32), axis=1)
    if mode == "mean":
        acc = acc / indices.shape[1]
    return acc.astype(table.dtype)
