"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400; MLA kv_lora=512; 2 shared + 64 routed experts top-6; first
layer dense (d_ff=10944).  [arXiv:2405.04434; hf]"""
from repro.configs.base import ArchBundle, LM_SHAPES, MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # dense-layer FFN width
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        d_shared=2 * 1408,
        first_dense_layers=1,
        d_ff_dense=10944,
    ),
)

SHAPES = LM_SHAPES

BUNDLE = ArchBundle(
    arch_id="deepseek-v2-lite-16b",
    family="lm",
    config=CONFIG,
    shapes=SHAPES,
    notes=(
        "MLA latent KV cache makes 500k-token decode memory-light "
        "(~0.6 GB latents) — long_500k run as a BONUS cell; per the shape "
        "rules MLA is still full attention, so the cell is marked bonus in "
        "EXPERIMENTS.md rather than a sub-quadratic substitute."
    ),
)
