"""mind [recsys] — embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest.  [arXiv:1904.08030; unverified]"""
from repro.configs.base import ArchBundle, RECSYS_SHAPES, RecsysConfig

CONFIG = RecsysConfig(
    name="mind",
    model="mind",
    n_sparse=1,  # single item-id table
    embed_dim=64,
    vocab_sizes=(10_000_000,),  # item corpus
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
    interaction="multi-interest",
)

SHAPES = RECSYS_SHAPES

BUNDLE = ArchBundle(
    arch_id="mind",
    family="recsys",
    config=CONFIG,
    shapes=SHAPES,
    notes=(
        "retrieval_cand scores 1M candidates with a single batched "
        "max-over-interests dot (no loop). STATIC inapplicable."
    ),
)
