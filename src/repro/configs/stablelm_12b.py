"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352.  [hf:stabilityai/stablelm-2-12b family; hf]"""
from repro.configs.base import ArchBundle, LM_SHAPES, TransformerConfig

CONFIG = TransformerConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
)

SHAPES = LM_SHAPES

BUNDLE = ArchBundle(
    arch_id="stablelm-12b",
    family="lm",
    config=CONFIG,
    shapes=SHAPES,
    notes="Pure full attention: long_500k skipped (DESIGN.md §4).",
)
