"""fm [recsys] — n_sparse=39 embed_dim=10 interaction=fm-2way; pairwise
<v_i, v_j> x_i x_j via the O(nk) sum-square trick.  [ICDM'10 (Rendle); paper]"""
from repro.configs.base import ArchBundle, RECSYS_SHAPES, RecsysConfig

# Criteo-style 39 features (26 categorical + 13 bucketized integer).
_VOCABS = tuple([1_000_000] * 26 + [1_000] * 13)

CONFIG = RecsysConfig(
    name="fm",
    model="fm",
    n_sparse=39,
    embed_dim=10,
    vocab_sizes=_VOCABS,
    interaction="fm-2way",
    multi_hot=1,
)

SHAPES = RECSYS_SHAPES

BUNDLE = ArchBundle(
    arch_id="fm",
    family="recsys",
    config=CONFIG,
    shapes=SHAPES,
    notes="STATIC inapplicable (non-autoregressive scorer).",
)
