"""wide-deep [recsys] — n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat.  [arXiv:1606.07792; paper]"""
from repro.configs.base import ArchBundle, RECSYS_SHAPES, RecsysConfig

# 40 hashed categorical features, production-representative row counts.
_VOCABS = tuple([10_000, 100_000, 1_000_000, 10_000_000] * 10)

CONFIG = RecsysConfig(
    name="wide-deep",
    model="wide_deep",
    n_sparse=40,
    embed_dim=32,
    vocab_sizes=_VOCABS,
    mlp=(1024, 512, 256),
    interaction="concat",
    multi_hot=1,
)

SHAPES = RECSYS_SHAPES

BUNDLE = ArchBundle(
    arch_id="wide-deep",
    family="recsys",
    config=CONFIG,
    shapes=SHAPES,
    notes="STATIC inapplicable (non-autoregressive scorer).",
)
