"""dlrm-mlperf [recsys] — n_dense=13 n_sparse=26 embed_dim=128
bot_mlp=13-512-256-128 top_mlp=1024-1024-512-256-1 interaction=dot.
MLPerf DLRM benchmark config (Criteo 1TB).  [arXiv:1906.00091; paper]"""
from repro.configs.base import ArchBundle, RECSYS_SHAPES, RecsysConfig
from repro.models.recsys import DLRM_CRITEO_VOCABS

CONFIG = RecsysConfig(
    name="dlrm-mlperf",
    model="dlrm",
    n_dense=13,
    n_sparse=26,
    embed_dim=128,
    vocab_sizes=DLRM_CRITEO_VOCABS,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot",
    multi_hot=1,
)

SHAPES = RECSYS_SHAPES

BUNDLE = ArchBundle(
    arch_id="dlrm-mlperf",
    family="recsys",
    config=CONFIG,
    shapes=SHAPES,
    notes=(
        "Embedding tables (~188M rows x 128) vocab-sharded over the model "
        "axis; MLPs data-parallel. STATIC inapplicable."
    ),
)
