"""static-gr — the paper's own generative-retrieval serving stack (§5.1).

A PLUM-like dense decoder (~3B params) over Semantic-ID tokens:
L=8 SID levels, token cardinality |V|=2048, beam M=70, batch 2 per chip,
dense-mask depth d=2, constrained to a 20M-item restricted vocabulary.

This is the paper-representative roofline/hillclimb cell: serve_step =
one decode step + Algorithm 1 (LogSoftmax -> dense/VNTK masking -> beam
top-k -> state gather).
"""
import dataclasses

from repro.configs.base import ArchBundle, TransformerConfig


@dataclasses.dataclass(frozen=True)
class GRShape:
    name: str
    kind: str  # "train" | "serve_constrained" | "serve_unconstrained"
    global_batch: int
    beam_size: int = 70
    sid_length: int = 8
    history_len: int = 256  # user-history tokens fed at prefill/train


# ~3B dense params (26L x 3072, GQA 24H/kv8), SID vocab 2048 + BOS/pad.
CONFIG = TransformerConfig(
    name="static-gr-3b",
    n_layers=26,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=2050,
    tie_embeddings=True,
)

SID_VOCAB = 2048
SID_LENGTH = 8
DENSE_D = 2
N_CONSTRAINTS = 20_000_000  # "fresh video" corpus of §5.2

SHAPES = (
    GRShape("gr_train", "train", global_batch=1024),
    GRShape("gr_serve_constrained", "serve_constrained", global_batch=512),
    GRShape("gr_serve_unconstrained", "serve_unconstrained", global_batch=512),
)

BUNDLE = ArchBundle(
    arch_id="static-gr",
    family="gr",
    config=CONFIG,
    shapes=SHAPES,
    notes=(
        "The paper's exact setting: batch 2/chip x 256 chips = 512 global, "
        "M=70, L=8, |V|=2048, d=2, |C|=20M. Constraint matrix replicated "
        "per chip (paper §A.3)."
    ),
)
