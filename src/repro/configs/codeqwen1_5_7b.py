"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32 == MHA)
d_ff=13440 vocab=92416, qwen1.5 arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import ArchBundle, LM_SHAPES, TransformerConfig

CONFIG = TransformerConfig(
    name="codeqwen1.5-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
)

SHAPES = LM_SHAPES

BUNDLE = ArchBundle(
    arch_id="codeqwen1.5-7b",
    family="lm",
    config=CONFIG,
    shapes=SHAPES,
    notes="Pure full attention: long_500k skipped (DESIGN.md §4).",
)
