"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-110B family; hf]"""
from repro.configs.base import ArchBundle, LM_SHAPES, TransformerConfig

CONFIG = TransformerConfig(
    name="qwen1.5-110b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
)

SHAPES = LM_SHAPES

BUNDLE = ArchBundle(
    arch_id="qwen1.5-110b",
    family="lm",
    config=CONFIG,
    shapes=SHAPES,
    notes="Pure full attention: long_500k skipped (DESIGN.md §4).",
)
