"""meshgraphnet [gnn] — n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2.
[arXiv:2010.03409; unverified]"""
from repro.configs.base import ArchBundle, GNN_SHAPES, GNNConfig

CONFIG = GNNConfig(
    name="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    mlp_layers=2,
    aggregator="sum",
    node_feat_dim=16,  # overridden per shape (d_feat)
    edge_feat_dim=8,
    out_dim=3,
)

SHAPES = GNN_SHAPES

BUNDLE = ArchBundle(
    arch_id="meshgraphnet",
    family="gnn",
    config=CONFIG,
    shapes=SHAPES,
    notes=(
        "STATIC inapplicable (no autoregressive decode) — see DESIGN.md "
        "§Arch-applicability. minibatch_lg uses the fanout 15-10 neighbor "
        "sampler in repro.data.graph_sampler."
    ),
)
