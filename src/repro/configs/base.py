"""Config dataclasses for every architecture family + input-shape specs.

Each assigned architecture gets one ``configs/<id>.py`` exposing ``CONFIG``
(the exact published hyper-parameters) and ``SHAPES`` (its assigned
input-shape set).  ``smoke_config()`` returns the reduced same-family config
used by CPU smoke tests; the full config is exercised only via the dry-run
(ShapeDtypeStructs, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# --------------------------------------------------------------------------
# Transformer LM family
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # expert FFN hidden width
    n_shared: int = 0  # shared (always-on) experts
    d_shared: int = 0  # shared-expert hidden width (n_shared * d_expert if 0)
    first_dense_layers: int = 0  # leading layers that use a dense FFN
    d_ff_dense: int = 0  # width of those dense FFNs
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # GShard-style dispatch groups PER SEQUENCE. 0 = flat global dispatch
    # (position cumsum runs over the full sharded token axis — forces
    # cross-shard prefix sums). g >= 1 splits (B, S) into B*g groups so the
    # cumsum/scatter stay shard-local (EXPERIMENTS.md §Perf hillclimb B).
    dispatch_groups: int = 0


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    qkv_bias: bool = False
    attention: str = "gqa"  # "gqa" (covers MHA/MQA/SWA) | "mla"
    sliding_window: Optional[int] = None  # SWA window (Mixtral: 4096)
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # sequence parallelism: dp axis names for activation sharding constraints
    # (set by the launcher per mesh; () = off). The residual stream between
    # layers is sharded (batch=sp_axes, seq="model") so per-chip activation
    # storage under remat scales 1/TP.
    sp_axes: tuple = ()
    use_sp: bool = True  # launcher hint: allow setting sp_axes for train
    train_microbatches: int = 1  # grad-accumulation inside the train cell
    # roofline accounting: XLA cost_analysis counts a while-loop body ONCE,
    # not x trip-count. layer_unroll=k inlines k layer bodies per iteration;
    # the roofline runner lowers k=1 and k=2 and extrapolates exact totals.
    # inner_unroll=True fully unrolls the attention-chunk and CE-chunk scans
    # so their flops are inside the (counted) layer body.
    layer_unroll: int = 1
    inner_unroll: bool = False
    ce_chunk: int = 256  # sequence-chunked CE loss (see transformer.lm_loss)
    # Deferred KV commit: decode does NOT dynamic-update-slice into the
    # sequence-sharded cache (which forces GSPMD "involuntary full
    # rematerialization" = a full cache all-gather). Instead attention runs
    # over [read-only cache | fresh k/v] and the per-layer k/v are returned
    # for the serving layer to commit in blocks (EXPERIMENTS.md §Perf C).
    defer_cache_write: bool = False
    # GR beam caches as (L, B, M, S, KV, hd) instead of flat (L, B*M, ...):
    # the beam-permute gather becomes batch-local (take_along_axis over M)
    # instead of a gather across the dp-sharded flat axis, which GSPMD can
    # only serve by all-gathering the whole beam cache (§Perf hillclimb A).
    gr_batched_beams: bool = False
    # Flash-decoding split-K: constrain decode q/k/v projections to be
    # replicated over `model` so GSPMD keeps the KV cache sequence-sharded
    # and contracts shard-locally (partial softmax + tiny combine), instead
    # of resharding the whole cache to head sharding every step (§Perf C).
    # Uses sp_axes as the batch sharding of the small per-token tensors.
    decode_split_k: bool = False
    # Weight-replicated serving: for models whose weights fit one chip
    # (static-gr 3B = 6 GB bf16), replicate params and shard the request
    # batch over EVERY mesh axis — all TP psums vanish from the serve step
    # (§Perf hillclimb A, iteration 2).
    serve_replicate_weights: bool = False

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        D, V, L = self.d_model, self.vocab_size, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.attention == "mla":
            hd = self.qk_nope_head_dim + self.qk_rope_head_dim
            attn = (
                D * self.n_heads * hd  # q proj
                + D * (self.kv_lora_rank + self.qk_rope_head_dim)  # kv down
                + self.kv_lora_rank * self.n_heads
                * (self.qk_nope_head_dim + self.v_head_dim)  # kv up
                + self.n_heads * self.v_head_dim * D  # o proj
            )
        else:
            hd = self.resolved_head_dim()
            attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * D
        if self.moe is None:
            ffn = 3 * D * self.d_ff
            layers = L * (attn + ffn)
        else:
            m = self.moe
            moe_ffn = 3 * D * m.d_expert * m.n_experts + D * m.n_experts
            shared = 3 * D * (m.d_shared or m.n_shared * m.d_expert) if m.n_shared else 0
            dense = 3 * D * (m.d_ff_dense or self.d_ff)
            layers = (
                m.first_dense_layers * (attn + dense)
                + (L - m.first_dense_layers) * (attn + moe_ffn + shared)
            )
        return emb + layers

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if self.moe is None:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        m = self.moe
        full = self.param_count()
        moe_total = 3 * D * m.d_expert * m.n_experts
        moe_active = 3 * D * m.d_expert * m.top_k
        return full - (L - m.first_dense_layers) * (moe_total - moe_active)


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES = (
    LMShape("train_4k", "train", 4_096, 256),
    LMShape("prefill_32k", "prefill", 32_768, 32),
    LMShape("decode_32k", "decode", 32_768, 128),
    LMShape("long_500k", "decode", 524_288, 1),
)


# --------------------------------------------------------------------------
# GNN family
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    aggregator: str = "sum"
    node_feat_dim: int = 16
    edge_feat_dim: int = 8
    out_dim: int = 3
    dtype: str = "bfloat16"
    remat: bool = True
    layer_unroll: int = 1  # see TransformerConfig.layer_unroll


@dataclasses.dataclass(frozen=True)
class GraphShape:
    name: str
    kind: str  # "full" | "sampled" | "batched"
    n_nodes: int
    n_edges: int
    d_feat: int
    batch: int = 1
    batch_nodes: int = 0
    fanout: tuple = ()


GNN_SHAPES = (
    GraphShape("full_graph_sm", "full", 2_708, 10_556, 1_433),
    GraphShape(
        "minibatch_lg", "sampled", 232_965, 114_615_892, 602,
        batch_nodes=1_024, fanout=(15, 10),
    ),
    GraphShape("ogb_products", "full", 2_449_029, 61_859_140, 100),
    GraphShape("molecule", "batched", 30, 64, 16, batch=128),
)


# --------------------------------------------------------------------------
# RecSys family
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str  # "wide_deep" | "mind" | "dlrm" | "fm"
    n_dense: int = 0
    n_sparse: int = 26
    embed_dim: int = 32
    vocab_sizes: tuple = ()  # per-sparse-feature rows
    bot_mlp: tuple = ()
    top_mlp: tuple = ()
    mlp: tuple = ()
    interaction: str = "concat"
    # MIND-specific
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    multi_hot: int = 1  # indices per sparse feature (bag arity K)
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str  # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = (
    RecsysShape("train_batch", "train", 65_536),
    RecsysShape("serve_p99", "serve", 512),
    RecsysShape("serve_bulk", "serve", 262_144),
    RecsysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


# --------------------------------------------------------------------------
# RQ-VAE (Semantic-ID tokenizer for the paper's generative retrieval stack)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RQVAEConfig:
    feat_dim: int = 64
    latent_dim: int = 32
    n_levels: int = 4  # SID length L
    codebook_size: int = 256  # |V|
    enc_hidden: tuple = (128, 64)
    commitment_weight: float = 0.25


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    """What the registry hands to the launcher: config + shapes + family."""

    arch_id: str
    family: str  # "lm" | "gnn" | "recsys" | "gr"
    config: object
    shapes: tuple
    notes: str = ""
