"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff(expert)=14336
vocab=32000; 8 experts top-2; sliding-window attention (4096).
[arXiv:2401.04088; hf]"""
from repro.configs.base import ArchBundle, LM_SHAPES, MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
)

SHAPES = LM_SHAPES

BUNDLE = ArchBundle(
    arch_id="mixtral-8x7b",
    family="lm",
    config=CONFIG,
    shapes=SHAPES,
    notes=(
        "SWA (window 4096) + ring KV cache => long_500k decode is O(window) "
        "memory and RUNS (the only assigned LM arch with sub-quadratic attn)."
    ),
)
