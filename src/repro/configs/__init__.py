"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs.

10 assigned architectures + the paper's own (static-gr).
"""
from __future__ import annotations

import dataclasses

from repro.configs import (
    codeqwen1_5_7b,
    deepseek_v2_lite_16b,
    dlrm_mlperf,
    fm,
    meshgraphnet,
    mind,
    mixtral_8x7b,
    qwen1_5_110b,
    stablelm_12b,
    static_gr,
    wide_deep,
)
from repro.configs.base import (
    ArchBundle,
    GNNConfig,
    GraphShape,
    LMShape,
    MoEConfig,
    RecsysConfig,
    RecsysShape,
    RQVAEConfig,
    TransformerConfig,
)

ARCHS: dict[str, ArchBundle] = {
    b.arch_id: b
    for b in [
        stablelm_12b.BUNDLE,
        qwen1_5_110b.BUNDLE,
        codeqwen1_5_7b.BUNDLE,
        deepseek_v2_lite_16b.BUNDLE,
        mixtral_8x7b.BUNDLE,
        meshgraphnet.BUNDLE,
        wide_deep.BUNDLE,
        mind.BUNDLE,
        dlrm_mlperf.BUNDLE,
        fm.BUNDLE,
        static_gr.BUNDLE,
    ]
}

ASSIGNED = [a for a in ARCHS if a != "static-gr"]


def get_bundle(arch_id: str) -> ArchBundle:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def supports_shape(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """Shape-cell applicability (DESIGN.md §4 skip rules)."""
    b = get_bundle(arch_id)
    if b.family == "lm" and shape_name == "long_500k":
        cfg: TransformerConfig = b.config
        if cfg.sliding_window is not None:
            return True, "SWA ring cache: O(window) decode"
        if cfg.attention == "mla":
            return True, "BONUS cell: MLA latent cache (~0.6 GB at 500k)"
        return False, "pure full attention — skipped per shape rules"
    return True, ""


def smoke_config(arch_id: str):
    """Reduced same-family config for CPU smoke tests (full configs are
    exercised only via the dry-run)."""
    b = get_bundle(arch_id)
    if b.family in ("lm", "gr"):
        cfg: TransformerConfig = b.config
        moe = cfg.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe,
                n_experts=4,
                top_k=min(2, moe.top_k),
                d_expert=64,
                d_shared=(128 if moe.n_shared else 0),
                d_ff_dense=(96 if moe.first_dense_layers else 0),
            )
        return dataclasses.replace(
            cfg,
            name=cfg.name + "-smoke",
            n_layers=2 + (moe.first_dense_layers if moe else 0),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
            d_ff=96,
            vocab_size=128,
            head_dim=16,
            kv_lora_rank=32 if cfg.attention == "mla" else 0,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
            sliding_window=8 if cfg.sliding_window else None,
            moe=moe,
            attn_chunk_q=8,
            attn_chunk_kv=8,
            dtype="float32",
        )
    if b.family == "gnn":
        return dataclasses.replace(
            b.config, name=b.config.name + "-smoke", n_layers=2, d_hidden=16,
            node_feat_dim=5, edge_feat_dim=3, out_dim=2, dtype="float32",
        )
    if b.family == "recsys":
        cfg: RecsysConfig = b.config
        return dataclasses.replace(
            cfg,
            name=cfg.name + "-smoke",
            vocab_sizes=tuple(min(v, 50) for v in cfg.vocab_sizes),
            embed_dim=8,
            mlp=tuple(16 for _ in cfg.mlp),
            bot_mlp=tuple([16] * (len(cfg.bot_mlp) - 1) + [8]) if cfg.bot_mlp else (),
            top_mlp=tuple([16] * (len(cfg.top_mlp) - 1) + [1]) if cfg.top_mlp else (),
            hist_len=6,
        )
    raise ValueError(b.family)


__all__ = [
    "ARCHS", "ASSIGNED", "get_bundle", "supports_shape", "smoke_config",
    "ArchBundle", "TransformerConfig", "MoEConfig", "GNNConfig", "GraphShape",
    "LMShape", "RecsysConfig", "RecsysShape", "RQVAEConfig",
]
