"""Foundational layers: initializers, RMSNorm, RoPE, SwiGLU, MLPs.

No flax — parameters are plain pytrees (nested dicts of jax.Arrays), and
every layer is a pure function ``f(params, x, ...)``.  Initializers take an
explicit PRNG key and return the param subtree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense", "rms_norm_init", "rms_norm", "mlp_init", "mlp",
    "rope_frequencies", "apply_rope", "swiglu_init", "swiglu",
]


def _he(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) * (2.0 / fan_in) ** 0.5).astype(dtype)


def dense_init(key, d_in, d_out, dtype=jnp.bfloat16, bias=False):
    p = {"w": _he(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rms_norm_init(d, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def mlp_init(key, dims, dtype=jnp.bfloat16, bias=True):
    """dims = (d_in, h1, ..., d_out); ReLU between layers."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"l{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype, bias=bias)
        for i in range(len(dims) - 1)
    }


def mlp(p, x, act=jax.nn.relu):
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x)
        if i < n - 1:
            x = act(x)
    return x


def swiglu_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _he(k1, (d_model, d_ff), dtype),
        "w3": _he(k2, (d_model, d_ff), dtype),
        "w2": _he(k3, (d_ff, d_model), dtype),
    }


def swiglu(p, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0):
    """x: (..., S, H, Dh) or (..., S, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if x.ndim == angles.ndim + 1:  # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)
