"""Decoder-only transformer family covering all five assigned LM archs.

One implementation, configured by :class:`TransformerConfig`:
  * GQA / MHA (+ optional QKV bias — qwen1.5 family)     — stablelm, qwen,
    codeqwen
  * sliding-window attention with a ring KV cache        — mixtral
  * MLA (multi-head latent attention, DeepSeek-V2)       — deepseek-v2-lite,
    with the *absorbed* decode path (latent-space scores; the full K/V are
    never materialized at decode time)
  * MoE FFNs (Mixtral 8x top-2; DeepSeek 64x top-6 + 2 shared, first layer
    dense)

Layers are stacked and driven by ``lax.scan`` (O(1) HLO size in depth) with
``jax.checkpoint`` inside the scan body for activation remat; training CE is
computed in sequence chunks so the (tokens, vocab) logits tensor is never
materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.models import kvcache as kv_lib
from repro.models.attention import chunked_causal_attention, decode_attention
from repro.models.layers import (
    apply_rope,
    dense_init,
    rms_norm,
    rms_norm_init,
    swiglu,
    swiglu_init,
    _he,
)
from repro.models.moe import moe_ffn, moe_init

__all__ = [
    "init_params", "param_specs", "forward", "lm_loss",
    "lm_loss_trie_aware", "prefill", "decode_step", "paged_decode_step",
    "init_cache",
]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------


def _attn_init(key, cfg: TransformerConfig, dtype):
    D = cfg.d_model
    if cfg.attention == "mla":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        return {
            "wq": _he(k1, (D, cfg.n_heads * qk_dim), dtype),
            "w_kv_a": _he(k2, (D, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype),
            "kv_norm": rms_norm_init(cfg.kv_lora_rank, dtype),
            "w_kv_b": _he(
                k3,
                (cfg.kv_lora_rank,
                 cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
                dtype,
            ),
            "wo": _he(k4, (cfg.n_heads * cfg.v_head_dim, D), dtype,
                      fan_in=cfg.n_heads * cfg.v_head_dim),
        }
    hd = cfg.resolved_head_dim()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, D, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(k2, D, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(k3, D, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": {"w": _he(k4, (cfg.n_heads * hd, D), dtype, fan_in=cfg.n_heads * hd)},
    }
    return p


def _layer_init(key, cfg: TransformerConfig, moe_layer: bool, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln_attn": rms_norm_init(cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln_ffn": rms_norm_init(cfg.d_model, dtype),
    }
    if moe_layer:
        p["moe"] = moe_init(k2, cfg.d_model, cfg.moe, dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.d_ff_dense:
            d_ff = cfg.moe.d_ff_dense
        p["ffn"] = swiglu_init(k2, cfg.d_model, d_ff, dtype)
    return p


def init_params(cfg: TransformerConfig, key: jax.Array):
    dtype = _dtype(cfg)
    k_emb, k_unemb, k_dense, k_moe = jax.random.split(key, 4)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_scan = cfg.n_layers - n_dense if cfg.moe else cfg.n_layers
    if cfg.moe is None:
        n_dense, n_scan = cfg.n_layers, 0

    params = {
        "emb": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02
                ).astype(dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unemb"] = _he(k_unemb, (cfg.d_model, cfg.vocab_size), dtype)
    if n_dense:
        keys = jax.random.split(k_dense, n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe_layer=False, dtype=dtype)
        )(keys)
    if n_scan:
        keys = jax.random.split(k_moe, n_scan)
        params["moe_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe_layer=True, dtype=dtype)
        )(keys)
    return params


def param_specs(cfg: TransformerConfig):
    """Shape/dtype pytree without allocating (for the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0)
    )


# --------------------------------------------------------------------------
# Attention application (full-sequence path)
# --------------------------------------------------------------------------


def _attn_full(p, x, cfg: TransformerConfig, q_offset=0):
    B, S, D = x.shape
    if cfg.attention == "mla":
        nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        H, lora, vd = cfg.n_heads, cfg.kv_lora_rank, cfg.v_head_dim
        q = (x @ p["wq"]).reshape(B, S, H, nope + rope)
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        kv_a = x @ p["w_kv_a"]  # (B, S, lora + rope)
        c_kv = rms_norm(p["kv_norm"], kv_a[..., :lora])
        k_rope = kv_a[..., lora:][:, :, None, :]  # (B, S, 1, rope)
        pos = q_offset + jnp.arange(S)
        q_rope = apply_rope(q_rope, pos[None], cfg.rope_theta)
        k_rope = apply_rope(k_rope, pos[None], cfg.rope_theta)
        kv_b = (c_kv @ p["w_kv_b"]).reshape(B, S, H, nope + vd)
        k_nope, v = kv_b[..., :nope], kv_b[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_causal_attention(
            q, k, v, chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            window=cfg.sliding_window, q_offset=q_offset,
            unroll=cfg.inner_unroll,
        )
        cache_kv = (c_kv, k_rope[:, :, 0, :])
        return out.reshape(B, S, H * vd) @ p["wo"], cache_kv
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads

    def proj(pp, width):
        y = x @ pp["w"]
        if "b" in pp:
            y = y + pp["b"]
        return y.reshape(B, S, width, hd)

    q = proj(p["wq"], H)
    k = proj(p["wk"], KV)
    v = proj(p["wv"], KV)
    pos = q_offset + jnp.arange(S)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)
    out = chunked_causal_attention(
        q, k, v, chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        window=cfg.sliding_window, q_offset=q_offset,
        unroll=cfg.inner_unroll,
    )
    return out.reshape(B, S, H * hd) @ p["wo"]["w"], (k, v)


def _layer_fwd(p, x, cfg: TransformerConfig, moe_layer: bool, q_offset=0):
    attn_out, cache_kv = _attn_with_norm(p, x, cfg, q_offset)
    x = x + attn_out
    h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
    if moe_layer:
        y, aux = moe_ffn(p["moe"], h, cfg.moe)
        x = x + y
        return x, cache_kv, aux
    x = x + swiglu(p["ffn"], h)
    return x, cache_kv, jnp.zeros((), jnp.float32)


def _attn_with_norm(p, x, cfg, q_offset):
    h = rms_norm(p["ln_attn"], x, cfg.norm_eps)
    return _attn_full(p["attn"], h, cfg, q_offset)


# --------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# --------------------------------------------------------------------------


def _sp_constraint(x, cfg: TransformerConfig):
    """Sequence-parallel residual-stream sharding (batch=dp, seq=model)."""
    if not cfg.sp_axes:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(tuple(cfg.sp_axes), "model", None)
    )


def forward(params, tokens: jax.Array, cfg: TransformerConfig,
            collect_cache: bool = False):
    """tokens (B, S) -> hidden (B, S, D) [+ per-layer cache stacks, aux loss]."""
    x = jnp.take(params["emb"], tokens, axis=0)
    aux_total = jnp.zeros((), jnp.float32)

    def make_body(moe_layer: bool):
        def body(x, p):
            x = _sp_constraint(x, cfg)
            y, cache_kv, aux = _layer_fwd(p, x, cfg, moe_layer)
            y = _sp_constraint(y, cfg)
            ys = cache_kv if collect_cache else None
            return y, (ys, aux)

        if cfg.remat:
            body = jax.checkpoint(body)
        return body

    caches = []
    if "dense_layers" in params:
        x, (c, aux) = jax.lax.scan(make_body(False), x, params["dense_layers"],
                                   unroll=cfg.layer_unroll)
        caches.append(c)
        aux_total = aux_total + jnp.sum(aux)
    if "moe_layers" in params:
        x, (c, aux) = jax.lax.scan(make_body(True), x, params["moe_layers"],
                                   unroll=cfg.layer_unroll)
        caches.append(c)
        aux_total = aux_total + jnp.sum(aux)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, caches, aux_total


def _unemb(params, cfg):
    return params["emb"].T if cfg.tie_embeddings else params["unemb"]


def lm_loss(params, tokens: jax.Array, cfg: TransformerConfig,
            ce_chunk: int | None = None):
    """Next-token CE, computed in sequence chunks (no (T, V) logits tensor).

    The full sequence is forwarded (keeping S power-of-two aligned with the
    shard grid — slicing to S-1 would break sequence sharding and MoE group
    alignment); the final position is masked out of the loss instead.
    """
    x, _, aux = forward(params, tokens, cfg)
    labels = jnp.roll(tokens, -1, axis=1)
    B, S, D = x.shape
    valid = (jnp.arange(S) < S - 1).astype(jnp.float32)
    w = _unemb(params, cfg)
    chunk = min(ce_chunk or cfg.ce_chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    vs = valid.reshape(n, 1, chunk)

    @jax.checkpoint
    def body(tot, inp):
        xc, lc, vc = inp
        logits = (xc @ w).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum((lse - ll) * vc), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls, vs),
                          unroll=n if cfg.inner_unroll else 1)
    return tot / (B * (S - 1)) + aux


def lm_loss_trie_aware(params, tokens: jax.Array, cfg: TransformerConfig,
                       adm_mask: jax.Array, weight: float):
    """Next-token CE + the trie-aware admissible-mass auxiliary loss.

    ``adm_mask`` is (B, S, V) bool: the constrained decoder's admissible
    token set at the position of the token AT each index (the per-prefix
    sets from :mod:`repro.scenarios.trie_signal`, gathered per item).  The
    auxiliary term is the probability mass the model puts OUTSIDE the
    admissible set, in log space::

        logsumexp(logits) - logsumexp(logits[admissible])

    i.e. -log P(admissible) — zero when the model concentrates on tokens
    the trie will accept, so training pushes mass toward decodable SIDs
    (Trie-Aware Transformers, arxiv 2602.21677).  Targets drawn from the
    trie are always admissible, so the CE target never sits outside its
    own mask.  Dense (B, S, V) logits — this loss serves the small GR
    retrieval model (V = a few hundred), not the chunked-CE giants.
    """
    x, _, aux = forward(params, tokens, cfg)
    labels = jnp.roll(tokens, -1, axis=1)
    # align masks with labels: position p scores the token at p+1
    mask = jnp.roll(adm_mask, -1, axis=1)
    B, S, D = x.shape
    valid = (jnp.arange(S) < S - 1).astype(jnp.float32)
    w = _unemb(params, cfg)
    logits = (x @ w).astype(jnp.float32)  # (B, S, V)
    lse_full = jax.nn.logsumexp(logits, axis=-1)
    # -1e30 (not -inf): an all-False row would otherwise yield nan grads
    lse_adm = jax.nn.logsumexp(
        jnp.where(mask, logits, jnp.float32(-1e30)), axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    denom = B * (S - 1)
    ce = jnp.sum((lse_full - ll) * valid) / denom
    trie_aux = jnp.sum((lse_full - lse_adm) * valid) / denom
    return ce + aux + weight * trie_aux


# --------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV caches
# --------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    dtype = _dtype(cfg)
    if cfg.attention == "mla":
        return kv_lib.init_mla_cache(
            cfg.n_layers, batch, max_len, cfg.kv_lora_rank,
            cfg.qk_rope_head_dim, dtype,
        )
    return kv_lib.init_kv_cache(
        cfg.n_layers, batch, max_len, cfg.n_kv_heads,
        cfg.resolved_head_dim(), v_dim=None, dtype=dtype,
        window=cfg.sliding_window,
    )


def prefill(params, tokens: jax.Array, cfg: TransformerConfig,
            max_len: int | None = None):
    """Full-sequence pass that also builds the decode cache.

    Returns (last_token_logits, cache).  ``max_len`` reserves extra decode
    slots; for ring (SWA) caches only the last ``window`` positions are
    retained regardless.
    """
    B, S = tokens.shape
    max_len = max_len or S
    x, caches, _ = forward(params, tokens, cfg, collect_cache=True)
    logits = (x[:, -1:, :] @ _unemb(params, cfg)).astype(jnp.float32)

    def pad_to(arr, n_slots):
        pad = n_slots - arr.shape[2]
        if pad <= 0:
            return arr
        cfg_pad = [(0, 0)] * arr.ndim
        cfg_pad[2] = (0, pad)
        return jnp.pad(arr, cfg_pad)

    if cfg.attention == "mla":
        (c_kv, k_rope) = _merge(caches)
        slot_pos = jnp.concatenate(
            [jnp.arange(S, dtype=jnp.int32),
             jnp.full((max_len - S,), -1, jnp.int32)]
        ) if max_len > S else jnp.arange(S, dtype=jnp.int32)
        cache = kv_lib.MLACache(
            c_kv=pad_to(c_kv, max_len), k_rope=pad_to(k_rope, max_len),
            slot_pos=slot_pos, pos=jnp.asarray(S, jnp.int32),
        )
        return logits, cache
    ks, vs = _merge(caches)
    window = cfg.sliding_window
    if window and window < max_len:
        # keep last `window` positions at their ring slots (slot = pos % window)
        keep = min(window, S)
        positions = jnp.arange(S - keep, S)
        slots = positions % window
        k_ring = jnp.zeros(ks.shape[:2] + (window,) + ks.shape[3:], ks.dtype)
        v_ring = jnp.zeros(vs.shape[:2] + (window,) + vs.shape[3:], vs.dtype)
        k_ring = k_ring.at[:, :, slots].set(ks[:, :, S - keep:])
        v_ring = v_ring.at[:, :, slots].set(vs[:, :, S - keep:])
        slot_pos = jnp.full((window,), -1, jnp.int32).at[slots].set(positions)
        cache = kv_lib.KVCache(k=k_ring, v=v_ring, slot_pos=slot_pos,
                               pos=jnp.asarray(S, jnp.int32), ring=True)
    else:
        slot_pos = jnp.concatenate(
            [jnp.arange(S, dtype=jnp.int32),
             jnp.full((max_len - S,), -1, jnp.int32)]
        ) if max_len > S else jnp.arange(S, dtype=jnp.int32)
        cache = kv_lib.KVCache(
            k=pad_to(ks, max_len), v=pad_to(vs, max_len),
            slot_pos=slot_pos, pos=jnp.asarray(S, jnp.int32), ring=False,
        )
    return logits, cache


def _merge(caches):
    """Concatenate per-layer-group cache stacks along the layer axis."""
    if len(caches) == 1:
        return caches[0]
    parts = list(zip(*caches))
    return tuple(jnp.concatenate(p, axis=0) for p in parts)


def _decode_attn_gqa(p, x, cfg, k_cache, v_cache, slot_pos, pos, slot=None):
    B = x.shape[0]
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads

    def proj(pp, width):
        y = x @ pp["w"]
        if "b" in pp:
            y = y + pp["b"]
        return y.reshape(B, 1, width, hd)

    q = proj(p["wq"], H)
    k_new = proj(p["wk"], KV)
    v_new = proj(p["wv"], KV)
    q = apply_rope(q, pos[None, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[None, None], cfg.rope_theta)
    if cfg.decode_split_k:
        # replicate the tiny per-token tensors over `model`; the cache stays
        # sequence-sharded and attention contracts shard-locally (split-K).
        from jax.sharding import PartitionSpec as P

        spec = P(tuple(cfg.sp_axes) or None, None, None, None)
        q = jax.lax.with_sharding_constraint(q, spec)
        k_new = jax.lax.with_sharding_constraint(k_new, spec)
        v_new = jax.lax.with_sharding_constraint(v_new, spec)
    if cfg.defer_cache_write:
        # Read-only cache + separate fresh-token score: no dynamic write into
        # the sequence-sharded cache (which would force a full all-gather).
        # Grouped einsum: never materialize the G-times repeated cache.
        groups = H // KV
        qg = q.reshape(B, 1, KV, groups, hd)
        s_c = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, k_cache,
            preferred_element_type=jnp.float32,
        ) * hd ** -0.5  # (B, KV, G, 1, S)
        mask = (slot_pos >= 0) & (slot_pos < pos)
        if cfg.sliding_window is not None:
            mask = mask & (slot_pos > pos - cfg.sliding_window)
        s_c = jnp.where(mask[None, None, None, None, :], s_c, -1e30)
        s_n = jnp.einsum(
            "bqkgd,bqkd->bkgq", qg, k_new,
            preferred_element_type=jnp.float32,
        )[..., None] * hd ** -0.5  # (B, KV, G, 1, 1)
        prob = jax.nn.softmax(jnp.concatenate([s_c, s_n], -1), axis=-1)
        out_c = jnp.einsum(
            "bkgqs,bskd->bqkgd", prob[..., :-1].astype(v_cache.dtype),
            v_cache, preferred_element_type=jnp.float32,
        )  # (B, 1, KV, G, hd) f32
        p_new = prob[..., 0, -1]  # (B, KV, G)
        out_n = p_new[:, None, :, :, None] \
            * v_new.astype(jnp.float32)[:, :, :, None, :]
        out = (out_c + out_n).reshape(B, 1, H, hd).astype(x.dtype)
        return out.reshape(B, 1, H * hd) @ p["wo"]["w"], (k_new, v_new)
    if slot is None:
        # standalone call: derive the write slot from the config (decode_step
        # passes the cache-derived slot so the two can never disagree)
        slots = k_cache.shape[1]
        ring = cfg.sliding_window is not None and cfg.sliding_window <= slots
        slot = jnp.where(ring, pos % slots, jnp.minimum(pos, slots - 1))
    k_cache = kv_lib.write_slot(k_cache, k_new, slot)
    v_cache = kv_lib.write_slot(v_cache, v_new, slot)
    out = decode_attention(
        q, k_cache, v_cache, slot_pos, pos, window=cfg.sliding_window
    )
    return out.reshape(B, 1, H * hd) @ p["wo"]["w"], (k_cache, v_cache)


def _decode_attn_mla(p, x, cfg, c_kv_cache, k_rope_cache, slot_pos, pos):
    """Absorbed MLA decode: scores and context stay in latent space."""
    B = x.shape[0]
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    H, lora, vd = cfg.n_heads, cfg.kv_lora_rank, cfg.v_head_dim
    q = (x @ p["wq"]).reshape(B, 1, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos[None, None], cfg.rope_theta)
    kv_a = x @ p["w_kv_a"]
    c_new = rms_norm(p["kv_norm"], kv_a[..., :lora])  # (B, 1, lora)
    kr_new = apply_rope(kv_a[..., lora:], pos[None, None], cfg.rope_theta)
    if not cfg.defer_cache_write:
        slots = c_kv_cache.shape[1]
        slot = jnp.minimum(pos, slots - 1)
        c_kv_cache = kv_lib.write_slot(c_kv_cache, c_new, slot)
        k_rope_cache = kv_lib.write_slot(k_rope_cache, kr_new, slot)

    w_kv_b = p["w_kv_b"].reshape(lora, H, nope + vd)
    w_uk, w_uv = w_kv_b[..., :nope], w_kv_b[..., nope:]
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)  # (B,1,H,lora)
    s = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat.astype(jnp.float32),
                   c_kv_cache.astype(jnp.float32))
        + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                     k_rope_cache.astype(jnp.float32))
    ) * ((nope + rope) ** -0.5)
    mask = (slot_pos >= 0) & (
        (slot_pos < pos) if cfg.defer_cache_write else (slot_pos <= pos)
    )
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    if cfg.defer_cache_write:
        # separate fresh-token score/context term (read-only cache)
        s_n = (
            jnp.einsum("bqhl,bql->bhq", q_lat.astype(jnp.float32),
                       c_new.astype(jnp.float32))
            + jnp.einsum("bqhr,bqr->bhq", q_rope.astype(jnp.float32),
                         kr_new.astype(jnp.float32))
        )[..., None] * ((nope + rope) ** -0.5)
        probs = jax.nn.softmax(jnp.concatenate([s, s_n], -1), axis=-1)
        ctx = jnp.einsum("bhqs,bsl->bqhl", probs[..., :-1],
                         c_kv_cache.astype(jnp.float32))
        ctx = ctx + probs[:, :, 0, -1][:, None, :, None] \
            * c_new.astype(jnp.float32)[:, :, None, :]
        out = jnp.einsum("bqhl,lhv->bqhv", ctx.astype(x.dtype), w_uv)
        return out.reshape(B, 1, H * vd) @ p["wo"], (c_new, kr_new)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bqhl", probs, c_kv_cache.astype(jnp.float32))
    out = jnp.einsum("bqhl,lhv->bqhv", ctx.astype(x.dtype), w_uv)
    return out.reshape(B, 1, H * vd) @ p["wo"], (c_kv_cache, k_rope_cache)


def gr_decode_step(
    params,
    hist_k: jax.Array,  # (L, B, S_h, KV, Dh) shared user-history cache
    hist_v: jax.Array,
    beam_k: jax.Array,  # (L, B*M, S_sid, KV, Dh) per-beam SID cache
    beam_v: jax.Array,
    tokens: jax.Array,  # (B*M, 1)
    sid_step: jax.Array,  # () current SID decode step (0..L_sid-1)
    cfg: TransformerConfig,
):
    """Prefix-shared generative-retrieval decode (beyond-paper serving opt).

    The user-history KV is computed once per request and *shared* across all
    M beams; only the short per-beam SID suffix is beam-private.  Attention
    runs over the concatenation [history | suffix] with a single softmax.
    Cuts GR decode KV memory by ~M/(1 + L_sid/S_h) (~64x at M=70, S_h=256).
    """
    BM = tokens.shape[0]
    B = hist_k.shape[1]
    M = BM // B
    x = jnp.take(params["emb"], tokens, axis=0)  # (BM, 1, D)
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    groups = H // KV
    s_hist = hist_k.shape[2]
    s_sid = beam_k.shape[3] if cfg.gr_batched_beams else beam_k.shape[2]
    pos = s_hist + sid_step

    def body(x, inp):
        p, hk, hv, bk, bv = inp
        a = p["attn"]
        h = rms_norm(p["ln_attn"], x, cfg.norm_eps)

        def proj(pp, width):
            y = h @ pp["w"]
            if "b" in pp:
                y = y + pp["b"]
            return y.reshape(BM, 1, width, hd)

        q = apply_rope(proj(a["wq"], H), pos[None, None], cfg.rope_theta)
        k_new = apply_rope(proj(a["wk"], KV), pos[None, None], cfg.rope_theta)
        v_new = proj(a["wv"], KV)
        slot = jnp.minimum(sid_step, s_sid - 1)
        if cfg.gr_batched_beams:
            # bk/bv: (B, M, S_sid, KV, hd) — slot write along axis 2
            bk = jax.lax.dynamic_update_slice_in_dim(
                bk, k_new.reshape(B, M, 1, KV, hd).astype(bk.dtype), slot, 2)
            bv = jax.lax.dynamic_update_slice_in_dim(
                bv, v_new.reshape(B, M, 1, KV, hd).astype(bv.dtype), slot, 2)
        else:
            bk = kv_lib.write_slot(bk, k_new, slot)
            bv = kv_lib.write_slot(bv, v_new, slot)

        def rep(t, axis=2):
            return jnp.repeat(t, groups, axis=axis) if groups > 1 else t

        # scores over shared history (broadcast across beams) + own suffix
        qb = q.reshape(B, M, H, hd)
        s1 = jnp.einsum(
            "bmhd,bkhd->bmhk", qb, rep(hk), preferred_element_type=jnp.float32
        ) * hd ** -0.5  # (B, M, H, S_h)
        if cfg.gr_batched_beams:
            s2 = jnp.einsum(
                "bmhd,bmshd->bmhs", qb, rep(bk, axis=3),
                preferred_element_type=jnp.float32,
            ) * hd ** -0.5
        else:
            s2 = jnp.einsum(
                "nqhd,nkhd->nhqk", q, rep(bk), preferred_element_type=jnp.float32
            )[:, :, 0, :].reshape(B, M, H, s_sid) * hd ** -0.5
        sid_mask = jnp.arange(s_sid) <= sid_step
        s2 = jnp.where(sid_mask[None, None, None, :], s2, -1e30)
        s = jnp.concatenate([s1, s2], axis=-1)
        prob = jax.nn.softmax(s, axis=-1)
        p1, p2 = prob[..., :s_hist], prob[..., s_hist:]
        o1 = jnp.einsum("bmhk,bkhd->bmhd", p1.astype(hv.dtype), rep(hv),
                        preferred_element_type=jnp.float32)
        if cfg.gr_batched_beams:
            o2 = jnp.einsum(
                "bmhs,bmshd->bmhd", p2.astype(bv.dtype), rep(bv, axis=3),
                preferred_element_type=jnp.float32,
            )
        else:
            o2 = jnp.einsum(
                "nhk,nkhd->nhd",
                p2.reshape(BM, H, s_sid).astype(bv.dtype), rep(bv),
                preferred_element_type=jnp.float32,
            ).reshape(B, M, H, hd)
        out = (o1 + o2).reshape(BM, 1, H * hd).astype(x.dtype)
        x = x + out @ a["wo"]["w"]
        hh = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_ffn(p["moe"], hh, cfg.moe)
            x = x + y
        else:
            x = x + swiglu(p["ffn"], hh)
        return x, (bk, bv)

    x, (new_bk, new_bv) = jax.lax.scan(
        body, x, (params["dense_layers"], hist_k, hist_v, beam_k, beam_v),
        unroll=cfg.layer_unroll,
    )
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ _unemb(params, cfg)).astype(jnp.float32)  # (BM, 1, V)
    return logits, new_bk, new_bv


def decode_step(params, cache, tokens: jax.Array, cfg: TransformerConfig):
    """One autoregressive step. tokens (B, 1) -> (logits (B,1,V), new cache)."""
    B = tokens.shape[0]
    x = jnp.take(params["emb"], tokens, axis=0)  # (B, 1, D)
    pos = cache.pos
    mla = cfg.attention == "mla"
    if mla:
        slots = cache.c_kv.shape[2]
        ring = False
    else:
        slots = cache.k.shape[2]
        ring = cache.ring
    slot_pos, write_slot = kv_lib.advance_positions(
        cache.slot_pos, pos, slots, ring=False if mla else ring
    )

    def body(x, inp):
        if mla:
            p, ck, kr = inp
            h = rms_norm(p["ln_attn"], x, cfg.norm_eps)
            attn_out, (ck, kr) = _decode_attn_mla(
                p["attn"], h, cfg, ck, kr, slot_pos, pos
            )
            new_cache = (ck, kr)
        else:
            p, kc, vc = inp
            h = rms_norm(p["ln_attn"], x, cfg.norm_eps)
            attn_out, (kc, vc) = _decode_attn_gqa(
                p["attn"], h, cfg, kc, vc, slot_pos, pos, slot=write_slot
            )
            new_cache = (kc, vc)
        x = x + attn_out
        h = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_ffn(p["moe"], h, cfg.moe)
            x = x + y
        else:
            x = x + swiglu(p["ffn"], h)
        return x, new_cache

    n_dense = (cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers)
    if cfg.moe is None:
        n_dense = cfg.n_layers
    arrays = (cache.c_kv, cache.k_rope) if mla else (cache.k, cache.v)
    new_arrays = []
    x_cur = x
    offset = 0
    for group, count in (("dense_layers", n_dense),
                         ("moe_layers", cfg.n_layers - n_dense)):
        if count == 0 or group not in params:
            continue
        sl = tuple(a[offset:offset + count] for a in arrays)
        x_cur, outs = jax.lax.scan(body, x_cur, (params[group],) + sl,
                                   unroll=cfg.layer_unroll)
        new_arrays.append(outs)
        offset += count
    merged = tuple(
        jnp.concatenate([g[i] for g in new_arrays], axis=0)
        for i in range(2)
    )
    x_cur = rms_norm(params["final_norm"], x_cur, cfg.norm_eps)
    logits = (x_cur @ _unemb(params, cfg)).astype(jnp.float32)
    if cfg.defer_cache_write:
        # caches untouched; pending per-layer k/v stacks returned for the
        # serving layer to commit at block granularity.
        if mla:
            new_cache = kv_lib.MLACache(
                c_kv=cache.c_kv, k_rope=cache.k_rope,
                slot_pos=slot_pos, pos=pos + 1,
            )
        else:
            new_cache = kv_lib.KVCache(
                k=cache.k, v=cache.v, slot_pos=slot_pos, pos=pos + 1,
                ring=ring,
            )
        return logits, new_cache, merged
    if mla:
        new_cache = kv_lib.MLACache(
            c_kv=merged[0], k_rope=merged[1], slot_pos=slot_pos, pos=pos + 1
        )
    else:
        new_cache = kv_lib.KVCache(
            k=merged[0], v=merged[1], slot_pos=slot_pos, pos=pos + 1,
            ring=ring,
        )
    return logits, new_cache


def paged_decode_step(
    params,
    k_pool: jax.Array,  # (n_layers, P, page_size, KVH, Dh) shared history
    v_pool: jax.Array,  # (n_layers, P, page_size, KVH, Dh)
    page_table: jax.Array,  # (slots, n_pages) int32 page ids per slot
    suffix_k: jax.Array,  # (n_layers, slots, M, Ls, KVH, Dh) decoded KV
    suffix_v: jax.Array,  # (n_layers, slots, M, Ls, KVH, Dh)
    tokens: jax.Array,  # (slots, M) int32 last emitted token per beam
    pos: jax.Array,  # (slots,) int32 attention position (= S + level - 1)
    write_col: jax.Array,  # (slots,) int32 suffix column receiving this k/v
    cfg: TransformerConfig,
    *,
    hist_len: int,  # static S: history columns attended per slot
):
    """One continuous-batching decode step through the paged KV cache.

    Rows may sit at *different* decode levels: ``pos`` and ``write_col`` are
    per-slot vectors, and attention masks each row to its own ``[0, pos]``
    window.  History KV is read through ``page_table`` (one stored copy per
    slot — or per shared prompt — instead of per beam); per-beam decoded
    suffixes live in the dense ``suffix_k/v`` arrays where beam permutation
    is a plain gather.

    Bit-identity contract (DESIGN.md §10, fuzz-asserted in
    ``tests/test_continuous.py``): for a row at level ``l >= 1`` with
    ``pos = S + l - 1`` this computes exactly what the ``l``-th sequential
    :func:`decode_step` computes for that row — the gathered history is
    sliced to exactly ``hist_len`` columns and concatenated with the
    ``Ls = L + 1``-column suffix, so the attention width ``S + L + 1``
    matches the sequence-boundary engine's ``max_len`` and every reduction
    keeps its shape.  Rows whose output is unused (level-0 or dead slots)
    must point ``write_col`` at the trash column ``Ls - 1``, which no
    in-range ``pos`` can ever attend to.

    Returns ``(logits (slots*M, 1, vocab), new_suffix_k, new_suffix_v)``.
    """
    if (cfg.attention == "mla" or cfg.sliding_window is not None
            or cfg.defer_cache_write or cfg.moe is not None
            or cfg.decode_split_k):
        raise NotImplementedError(
            "paged_decode_step supports dense GQA models without sliding "
            "window / MLA / MoE / deferred writes"
        )
    slots, M = tokens.shape
    N = slots * M
    S = int(hist_len)
    Ls = suffix_k.shape[3]
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ps = k_pool.shape[2]
    n_pages = page_table.shape[1]
    if n_pages * ps < S:
        raise ValueError(
            f"page table covers {n_pages * ps} columns < hist_len {S}"
        )
    x = jnp.take(params["emb"], tokens.reshape(N, 1), axis=0)  # (N, 1, D)
    pos_row = jnp.repeat(pos, M)  # (N,)
    pages = page_table.reshape(-1)
    # synthetic slot positions: history cols 0..S-1 then suffix cols at
    # S..S+Ls-1 — identical to the sequential cache's slot_pos for every
    # column <= pos (prefill stamps 0..S-1, step l writes S+l-1), and the
    # trash column S+Ls-1 > pos is always masked.
    slot_positions = jnp.arange(S + Ls, dtype=jnp.int32)
    col_mask = (jnp.arange(Ls, dtype=jnp.int32)[None, None, :]
                == write_col[:, None, None])  # (slots, 1, Ls)

    def body(x, inp):
        p, kp, vp, sk, sv = inp
        h = rms_norm(p["ln_attn"], x, cfg.norm_eps)
        a = p["attn"]

        def proj(pp, width):
            y = h @ pp["w"]
            if "b" in pp:
                y = y + pp["b"]
            return y.reshape(N, 1, width, hd)

        q = proj(a["wq"], H)
        k_new = proj(a["wk"], KV)
        v_new = proj(a["wv"], KV)
        q = apply_rope(q, pos_row[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos_row[:, None], cfg.rope_theta)
        # write this step's k/v into the per-beam suffix BEFORE attention
        # (decode_step order), at each slot's own column
        sk = jnp.where(
            col_mask[..., None, None],
            k_new.reshape(slots, M, 1, KV, hd).astype(sk.dtype), sk,
        )
        sv = jnp.where(
            col_mask[..., None, None],
            v_new.reshape(slots, M, 1, KV, hd).astype(sv.dtype), sv,
        )
        # history through the page table: one stored copy per slot, fanned
        # out across beams only as a transient gather
        hk = kv_lib.gather_pages(kp, page_table, S)
        hv = kv_lib.gather_pages(vp, page_table, S)
        hk = jnp.repeat(hk, M, axis=0)  # (N, S, KV, hd)
        hv = jnp.repeat(hv, M, axis=0)
        kc = jnp.concatenate(
            [hk, sk.reshape(N, Ls, KV, hd).astype(hk.dtype)], axis=1
        )
        vc = jnp.concatenate(
            [hv, sv.reshape(N, Ls, KV, hd).astype(hv.dtype)], axis=1
        )
        out = decode_attention(q, kc, vc, slot_positions, pos_row)
        x = x + out.reshape(N, 1, H * hd) @ a["wo"]["w"]
        hh = rms_norm(p["ln_ffn"], x, cfg.norm_eps)
        x = x + swiglu(p["ffn"], hh)
        return x, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        body, x,
        (params["dense_layers"], k_pool, v_pool, suffix_k, suffix_v),
        unroll=cfg.layer_unroll,
    )
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ _unemb(params, cfg)).astype(jnp.float32)  # (N, 1, V)
    return logits, new_sk, new_sv
