"""Mixture-of-Experts FFN with scatter-based token dispatch.

Covers both assigned MoE archs:
  * Mixtral-8x7B      — 8 experts, top-2, no shared experts.
  * DeepSeek-V2-Lite  — 64 fine-grained routed experts top-6 + 2 shared
                        experts, first layer dense.

Dispatch is position-in-expert scatter (not the GShard (T,E,C) one-hot
einsum) so peak memory is O(E*C*D) for the expert buffer instead of
O(T*E*C): positions are computed with a cumsum over the (T*k, E) assignment
one-hot, tokens beyond the static capacity are dropped (capacity_factor
1.25), and expert FFNs run as a single batched einsum over the (E, C, D)
buffer.  Expert-parallel sharding partitions that leading E axis over the
"model" mesh axis; XLA inserts the dispatch all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import _he, swiglu, swiglu_init

__all__ = ["moe_init", "moe_ffn", "expert_capacity"]


def expert_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _he(k1, (d_model, cfg.n_experts), jnp.float32),
        "w1": _he(k2, (cfg.n_experts, d_model, cfg.d_expert), dtype),
        "w3": _he(k3, (cfg.n_experts, d_model, cfg.d_expert), dtype),
        "w2": _he(k4, (cfg.n_experts, cfg.d_expert, d_model), dtype,
                  fan_in=cfg.d_expert),
    }
    if cfg.n_shared:
        d_sh = cfg.d_shared or cfg.n_shared * cfg.d_expert
        p["shared"] = swiglu_init(k5, d_model, d_sh, dtype)
    return p


def moe_ffn(params, x: jax.Array, cfg: MoEConfig):
    """x: (..., D) -> (..., D), plus the router aux (load-balancing) loss.

    With ``cfg.dispatch_groups = G > 1`` and 3D input (B, S, D), the token
    axis splits into B*G groups of S/G tokens.  Because activations are
    sharded (batch=data, seq=model), every group lives inside ONE shard, so
    the position cumsum / scatter / gather of the dispatch run shard-locally
    via vmap — no cross-shard prefix sums, no involuntary resharding
    (EXPERIMENTS.md §Perf hillclimb B).
    """
    G = cfg.dispatch_groups
    if G >= 1 and x.ndim == 3 and x.shape[1] % G == 0:
        B, S, D = x.shape
        xg = x.reshape(B * G, S // G, D)
        yg, aux = jax.vmap(
            lambda xs: _moe_ffn_single(params, xs, cfg)
        )(xg)
        out = yg.reshape(B, S, D)
        aux = jnp.mean(aux)
    else:
        flat = x.reshape(-1, x.shape[-1])
        out, aux = _moe_ffn_single(params, flat, cfg)
        out = out.reshape(x.shape)
    if "shared" in params:
        out = out + swiglu(params["shared"], x)
    return out, aux


def _moe_ffn_single(params, x: jax.Array, cfg: MoEConfig):
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = expert_capacity(T, cfg)

    router_logits = x.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)  # (T, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Aux load-balancing loss (Switch-style): E * sum_e f_e * P_e.
    me = jnp.mean(probs, axis=0)  # (E,)
    assign = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (T, K, E)
    ce = jnp.mean(jnp.sum(assign, axis=1), axis=0) / K  # fraction per expert
    aux_loss = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # Position-in-expert via cumsum over flattened (T*K) assignments.
    flat_e = top_i.reshape(-1)  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # (T*K,)
    keep = pos < C
    slot = jnp.where(keep, pos, 0)

    token_idx = jnp.repeat(jnp.arange(T), K)
    xk = x[token_idx]  # (T*K, D)
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[flat_e, slot].add(jnp.where(keep[:, None], xk, 0))

    # Batched expert FFN on the (E, C, D) buffer.
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, params["w1"])
    ) * jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    y = jnp.einsum("ecf,efd->ecd", h, params["w2"])  # (E, C, D)

    out_k = y[flat_e, slot] * keep[:, None]  # (T*K, D)
    out_k = out_k * top_w.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[token_idx].add(out_k)
    return out, aux_loss
