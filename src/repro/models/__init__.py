"""Model zoo: transformer LM family (GQA/MLA/SWA/MoE), MeshGraphNet,
recsys (Wide&Deep / MIND / DLRM / FM), and the RQ-VAE SID tokenizer."""
from repro.models import gnn, recsys, rqvae, transformer

__all__ = ["gnn", "recsys", "rqvae", "transformer"]
