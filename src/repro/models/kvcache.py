"""KV caches: full, ring-buffered (sliding-window), MLA latent, and paged.

All caches are per-layer-stacked pytrees (leading axis = n_layers) so the
decode step can ``lax.scan`` over layers carrying the matching cache slice.

The ring cache keeps only ``window`` slots; insertion is at ``pos % window``
and every slot remembers its absolute position for masking — this is what
makes mixtral long_500k decode O(window) in memory instead of O(S).

Paged pools (DESIGN.md §10) back the continuous-batching engine: history KV
lives in a flat pool of fixed-size pages indexed through a per-slot page
table, so shared prompt prefixes are stored once and join/evict is a
host-side free-list operation — never a device reshape.  The device-side
helpers here (``init_page_pool`` / ``scatter_pages`` / ``gather_pages``) are
pure shape plumbing; ownership and refcounts are host state
(:class:`repro.serving.continuous.PagedKVAllocator`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "KVCache", "MLACache", "init_kv_cache", "init_mla_cache",
    "init_page_pool", "scatter_pages", "gather_pages", "pages_for",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array  # (L, B, S_slots, KVH, Dh)
    v: jax.Array  # (L, B, S_slots, KVH, Dv)
    slot_pos: jax.Array  # (S_slots,) absolute position per slot, -1 = empty
    pos: jax.Array  # () next position to write
    ring: bool = dataclasses.field(metadata=dict(static=True), default=False)

    def layer(self, i):
        return self.k[i], self.v[i]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array  # (L, B, S, kv_lora) compressed latents
    k_rope: jax.Array  # (L, B, S, rope_dim) shared decoupled keys
    slot_pos: jax.Array  # (S,)
    pos: jax.Array  # ()


def init_kv_cache(
    n_layers, batch, max_len, n_kv_heads, head_dim, v_dim=None,
    dtype=jnp.bfloat16, window=None,
) -> KVCache:
    slots = min(max_len, window) if window else max_len
    v_dim = v_dim or head_dim
    return KVCache(
        k=jnp.zeros((n_layers, batch, slots, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, n_kv_heads, v_dim), dtype),
        slot_pos=jnp.full((slots,), -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
        ring=window is not None and slots == window,
    )


def init_mla_cache(
    n_layers, batch, max_len, kv_lora_rank, rope_dim, dtype=jnp.bfloat16
) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((n_layers, batch, max_len, kv_lora_rank), dtype),
        k_rope=jnp.zeros((n_layers, batch, max_len, rope_dim), dtype),
        slot_pos=jnp.full((max_len,), -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
    )


def write_slot(cache_arr: jax.Array, new: jax.Array, slot: jax.Array):
    """cache_arr (B, S, ...) <- new (B, 1, ...) at index ``slot``."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache_arr, new.astype(cache_arr.dtype), slot, axis=1
    )


def advance_positions(slot_pos: jax.Array, pos: jax.Array, n_slots: int, ring: bool):
    """Mark the slot written at this step with its absolute position."""
    slot = jnp.where(ring, pos % n_slots, jnp.minimum(pos, n_slots - 1))
    return slot_pos.at[slot].set(pos), slot


# ---------------------------------------------------------------------------
# Paged history pools (continuous batching, DESIGN.md §10)
# ---------------------------------------------------------------------------
def pages_for(seq_len: int, page_size: int) -> int:
    """Pages needed to hold ``seq_len`` KV columns."""
    return -(-int(seq_len) // int(page_size))


def init_page_pool(
    n_layers, n_pages, page_size, n_kv_heads, head_dim, v_dim=None,
    dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """(k_pool, v_pool), each (n_layers, n_pages, page_size, KVH, Dh).

    Page 0 is conventionally the allocator's NULL page (never handed out),
    so an all-zero page table is always safe to gather through.
    """
    v_dim = v_dim or head_dim
    return (
        jnp.zeros((n_layers, n_pages, page_size, n_kv_heads, head_dim),
                  dtype),
        jnp.zeros((n_layers, n_pages, page_size, n_kv_heads, v_dim), dtype),
    )


def scatter_pages(pool: jax.Array, rows: jax.Array,
                  page_ids: jax.Array) -> jax.Array:
    """Commit prefilled KV rows into the pool at ``page_ids``.

    pool (n_layers, P, ps, KVH, Dh); rows (n_layers, B, S, KVH, Dh) with
    ``S`` padded by zeros up to ``n_pages_per_row * ps``; page_ids
    (B, n_pages_per_row) int32.  Rows sharing a page id (refcounted prompt
    sharing) must carry identical content — the scatter order is undefined.
    """
    L, P, ps = pool.shape[0], pool.shape[1], pool.shape[2]
    B, S = rows.shape[1], rows.shape[2]
    n_per = page_ids.shape[1]
    pad = n_per * ps - S
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    paged = rows.reshape(L, B * n_per, ps, *rows.shape[3:])
    return pool.at[:, page_ids.reshape(-1)].set(paged.astype(pool.dtype))


def gather_pages(pool_layer: jax.Array, page_table: jax.Array,
                 hist_len: int) -> jax.Array:
    """Read ``hist_len`` history columns per slot through the page table.

    pool_layer (P, ps, KVH, Dh); page_table (slots, n_pages) ->
    (slots, hist_len, KVH, Dh).  The trailing ``n_pages*ps - hist_len``
    columns are sliced off, so page-granule padding never reaches attention
    (exact-width gathers keep the softmax reduction bit-identical to the
    contiguous cache).
    """
    slots, n_pages = page_table.shape
    ps = pool_layer.shape[1]
    flat = jnp.take(pool_layer, page_table.reshape(-1), axis=0)
    return flat.reshape(slots, n_pages * ps, *pool_layer.shape[2:])[
        :, :hist_len
    ]
