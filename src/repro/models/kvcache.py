"""KV caches: full, ring-buffered (sliding-window), and MLA latent.

All caches are per-layer-stacked pytrees (leading axis = n_layers) so the
decode step can ``lax.scan`` over layers carrying the matching cache slice.

The ring cache keeps only ``window`` slots; insertion is at ``pos % window``
and every slot remembers its absolute position for masking — this is what
makes mixtral long_500k decode O(window) in memory instead of O(S).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["KVCache", "MLACache", "init_kv_cache", "init_mla_cache"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array  # (L, B, S_slots, KVH, Dh)
    v: jax.Array  # (L, B, S_slots, KVH, Dv)
    slot_pos: jax.Array  # (S_slots,) absolute position per slot, -1 = empty
    pos: jax.Array  # () next position to write
    ring: bool = dataclasses.field(metadata=dict(static=True), default=False)

    def layer(self, i):
        return self.k[i], self.v[i]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array  # (L, B, S, kv_lora) compressed latents
    k_rope: jax.Array  # (L, B, S, rope_dim) shared decoupled keys
    slot_pos: jax.Array  # (S,)
    pos: jax.Array  # ()


def init_kv_cache(
    n_layers, batch, max_len, n_kv_heads, head_dim, v_dim=None,
    dtype=jnp.bfloat16, window=None,
) -> KVCache:
    slots = min(max_len, window) if window else max_len
    v_dim = v_dim or head_dim
    return KVCache(
        k=jnp.zeros((n_layers, batch, slots, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, n_kv_heads, v_dim), dtype),
        slot_pos=jnp.full((slots,), -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
        ring=window is not None and slots == window,
    )


def init_mla_cache(
    n_layers, batch, max_len, kv_lora_rank, rope_dim, dtype=jnp.bfloat16
) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((n_layers, batch, max_len, kv_lora_rank), dtype),
        k_rope=jnp.zeros((n_layers, batch, max_len, rope_dim), dtype),
        slot_pos=jnp.full((max_len,), -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
    )


def write_slot(cache_arr: jax.Array, new: jax.Array, slot: jax.Array):
    """cache_arr (B, S, ...) <- new (B, 1, ...) at index ``slot``."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache_arr, new.astype(cache_arr.dtype), slot, axis=1
    )


def advance_positions(slot_pos: jax.Array, pos: jax.Array, n_slots: int, ring: bool):
    """Mark the slot written at this step with its absolute position."""
    slot = jnp.where(ring, pos % n_slots, jnp.minimum(pos, n_slots - 1))
    return slot_pos.at[slot].set(pos), slot
