"""Attention kernels: chunked (flash-style) causal attention + decode paths.

``chunked_causal_attention`` streams KV in fixed chunks with an online
log-sum-exp accumulator so the (Sq, Skv) score matrix is never materialized —
required to fit train_4k / prefill_32k activation memory under remat (see
DESIGN.md §7).  Supports GQA head grouping and sliding windows (Mixtral).

``decode_attention`` is the single-token path against a (possibly ring-
buffered) KV cache: one matvec per head, with slot-validity masking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunked_causal_attention", "decode_attention"]

NEG = -1.0e30


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=2)


def chunked_causal_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, KVH, Dh)
    v: jax.Array,  # (B, Skv, KVH, Dv)
    *,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
    unroll: bool = False,
) -> jax.Array:
    B, Sq, H, Dh = q.shape
    _, Skv, KVH, Dv = v.shape
    groups = H // KVH
    scale = scale if scale is not None else Dh ** -0.5
    chunk_q = min(chunk_q, Sq)
    chunk_kv = min(chunk_kv, Skv)
    while Sq % chunk_q:
        chunk_q //= 2
    while Skv % chunk_kv:
        chunk_kv //= 2
    nq, nk = Sq // chunk_q, Skv // chunk_kv

    # (nk, B, chunk_kv, KVH, D*) scan inputs
    ks = k.reshape(B, nk, chunk_kv, KVH, Dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, chunk_kv, KVH, Dv).transpose(1, 0, 2, 3, 4)
    qs = q.reshape(B, nq, chunk_q, H, Dh).transpose(1, 0, 2, 3, 4)

    def q_chunk_body(qi, q_c):
        q_pos = q_offset + qi * chunk_q + jnp.arange(chunk_q)

        def kv_body(carry, inp):
            m, l, acc = carry
            kj, k_c, v_c = inp
            k_pos = kj * chunk_kv + jnp.arange(chunk_kv)
            k_rep = _repeat_kv(k_c, groups)
            v_rep = _repeat_kv(v_c, groups)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q_c, k_rep,
                preferred_element_type=jnp.float32,
            ) * scale  # (B, H, cq, ck) f32
            mask = k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_rep.dtype), v_rep,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, chunk_q), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, H, chunk_q, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(nk), ks, vs),
            unroll=nk if unroll else 1,
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, H, cq, Dv)
        return out.transpose(0, 2, 1, 3)  # (B, cq, H, Dv)

    # checkpoint per q-chunk: the backward recomputes the (cq, ck) probability
    # blocks instead of storing them — the flash-attention memory recipe.
    body = jax.checkpoint(lambda args: q_chunk_body(*args))
    _, outs = jax.lax.scan(
        lambda _, args: (None, body(args)), None, (jnp.arange(nq), qs),
        unroll=nq if unroll else 1,
    )
    # (nq, B, cq, H, Dv) -> (B, Sq, H, Dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, Dh)
    k_cache: jax.Array,  # (B, S, KVH, Dh)
    v_cache: jax.Array,  # (B, S, KVH, Dv)
    slot_positions: jax.Array,  # (S,) or (B, S): absolute position per slot, -1 invalid
    cur_pos: jax.Array,  # scalar or (B,): position of the query token
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """GQA decode via grouped einsum — the KV cache is contracted directly
    with the (KV, G)-factored query, never materializing the G-times
    repeated cache (for kv=8 -> 64 heads that repeat would 8x the largest
    tensor of the whole decode step)."""
    B, S, KVH, Dh = k_cache.shape
    H = q.shape[2]
    Dv = v_cache.shape[-1]
    groups = H // KVH
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    qg = q.reshape(B, 1, KVH, groups, Dh)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # (B, KV, G, 1, S)
    pos = jnp.broadcast_to(slot_positions, (B, S))
    cur = jnp.broadcast_to(cur_pos, (B,))[:, None]
    mask = (pos >= 0) & (pos <= cur)
    if window is not None:
        mask = mask & (pos > cur - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ).reshape(B, 1, H, Dv)
    return out.astype(q.dtype)  # (B, 1, H, Dv)
