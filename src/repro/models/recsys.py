"""RecSys model zoo: Wide&Deep, MIND, DLRM (MLPerf), FM.

Shared substrate: huge per-feature embedding tables with a sentinel zero row
(row ``rows``) and fixed-arity EmbeddingBag lookups — ``jnp.take`` +
reduce in XLA (``repro.kernels.embedding_bag`` is the Pallas TPU variant of
the same op).  Tables are vocab-sharded over the "model" mesh axis at scale
(model-parallel embeddings + data-parallel MLPs, the classic DLRM hybrid).

Batch layout (all models):
  dense  : (B, n_dense) float32                    [dlrm only]
  sparse : (B, n_sparse, K) int32   multi-hot ids  [K = cfg.multi_hot]
  hist   : (B, hist_len) int32                     [mind only]
  target : (B,) int32 candidate item               [mind only]
  label  : (B,) float32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.layers import mlp, mlp_init, _he

__all__ = [
    "init_params", "param_specs", "forward", "recsys_loss",
    "mind_retrieval_scores", "DLRM_CRITEO_VOCABS",
]

# MLPerf DLRM (Criteo Terabyte) per-table row counts.
DLRM_CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def embedding_bag(table: jax.Array, idx: jax.Array) -> jax.Array:
    """(rows+1, D) table, (B, K) ids -> (B, D) summed rows (sentinel = rows).

    mode="clip" guards against out-of-vocab ids (production ids are hashed
    into the table range; jnp.take would otherwise fill OOB rows with NaN).
    """
    return jnp.sum(jnp.take(table, idx, axis=0, mode="clip"), axis=1)


def _table_init(key, rows, dim, dtype):
    """Rows padded to a 128-multiple: vocab-sharding requires divisibility by
    the model axis and MXU lanes like 128-aligned leading dims.  Row ``rows``
    is the zero sentinel; the extra pad rows are zero too."""
    n = -(-(rows + 1) // 128) * 128
    t = (jax.random.normal(key, (n, dim)) * (1.0 / dim ** 0.5)).astype(dtype)
    return t.at[rows:].set(0.0)


def _sparse_embeds(params, sparse, n_feats):
    """-> (B, n_feats, D) stacked bag outputs."""
    outs = [
        embedding_bag(params[f"table_{i}"], sparse[:, i, :])
        for i in range(n_feats)
    ]
    return jnp.stack(outs, axis=1)


# --------------------------------------------------------------------------
# Wide & Deep (arXiv:1606.07792)
# --------------------------------------------------------------------------


def _wide_deep_init(cfg: RecsysConfig, key):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 2 * cfg.n_sparse + 1)
    p = {}
    for i, rows in enumerate(cfg.vocab_sizes):
        p[f"table_{i}"] = _table_init(keys[2 * i], rows, cfg.embed_dim, dt)
        p[f"wide_{i}"] = _table_init(keys[2 * i + 1], rows, 1, dt)
    p["deep"] = mlp_init(
        keys[-1], (cfg.n_sparse * cfg.embed_dim,) + tuple(cfg.mlp) + (1,), dt
    )
    return p


def _wide_deep_fwd(params, batch, cfg):
    sparse = batch["sparse"]
    B = sparse.shape[0]
    emb = _sparse_embeds(params, sparse, cfg.n_sparse)  # (B, F, D)
    deep = mlp(params["deep"], emb.reshape(B, -1))[:, 0]
    wide = sum(
        embedding_bag(params[f"wide_{i}"], sparse[:, i, :])[:, 0]
        for i in range(cfg.n_sparse)
    )
    return (deep + wide).astype(jnp.float32)


# --------------------------------------------------------------------------
# DLRM (arXiv:1906.00091, MLPerf config)
# --------------------------------------------------------------------------


def _dlrm_init(cfg: RecsysConfig, key):
    dt = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_sparse + 2)
    p = {
        f"table_{i}": _table_init(keys[i], rows, cfg.embed_dim, dt)
        for i, rows in enumerate(cfg.vocab_sizes)
    }
    p["bot"] = mlp_init(keys[-2], (cfg.n_dense,) + tuple(cfg.bot_mlp), dt)
    n_vec = cfg.n_sparse + 1
    n_int = n_vec * (n_vec - 1) // 2
    p["top"] = mlp_init(
        keys[-1], (n_int + cfg.embed_dim,) + tuple(cfg.top_mlp), dt
    )
    return p


def _dlrm_fwd(params, batch, cfg):
    dense, sparse = batch["dense"], batch["sparse"]
    B = dense.shape[0]
    bot = mlp(params["bot"], dense.astype(_dtype(cfg)))  # (B, D)
    emb = _sparse_embeds(params, sparse, cfg.n_sparse)  # (B, F, D)
    vecs = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, F+1, D)
    inter = jnp.einsum("bfd,bgd->bfg", vecs, vecs)  # (B, F+1, F+1)
    n_vec = cfg.n_sparse + 1
    iu, ju = jnp.triu_indices(n_vec, k=1)
    flat = inter[:, iu, ju]  # (B, n_int) lower-triangle dots
    top_in = jnp.concatenate([flat, bot], axis=-1)
    return mlp(params["top"], top_in)[:, 0].astype(jnp.float32)


# --------------------------------------------------------------------------
# FM (Rendle, ICDM'10) — O(nk) sum-square trick
# --------------------------------------------------------------------------


def _fm_init(cfg: RecsysConfig, key):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 2 * cfg.n_sparse + 1)
    p = {"bias": jnp.zeros((), jnp.float32)}
    for i, rows in enumerate(cfg.vocab_sizes):
        p[f"table_{i}"] = _table_init(keys[2 * i], rows, cfg.embed_dim, dt)
        p[f"wide_{i}"] = _table_init(keys[2 * i + 1], rows, 1, dt)
    return p


def _fm_fwd(params, batch, cfg):
    sparse = batch["sparse"]
    emb = _sparse_embeds(params, sparse, cfg.n_sparse).astype(jnp.float32)
    first = sum(
        embedding_bag(params[f"wide_{i}"], sparse[:, i, :])[:, 0]
        for i in range(cfg.n_sparse)
    ).astype(jnp.float32)
    s = jnp.sum(emb, axis=1)  # (B, D)
    second = 0.5 * jnp.sum(s * s - jnp.sum(emb * emb, axis=1), axis=-1)
    return params["bias"] + first + second


# --------------------------------------------------------------------------
# MIND (arXiv:1904.08030) — multi-interest capsule routing
# --------------------------------------------------------------------------


def _mind_init(cfg: RecsysConfig, key):
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    n_items = cfg.vocab_sizes[0]
    return {
        "table_0": _table_init(k1, n_items, cfg.embed_dim, dt),
        "bilinear": _he(k2, (cfg.embed_dim, cfg.embed_dim), dt),
        "routing_init": (jax.random.normal(
            k3, (cfg.n_interests, cfg.hist_len)) * 0.1).astype(jnp.float32),
    }


def _squash(x, axis=-1):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(params, hist: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """(B, T) item ids -> (B, n_interests, D) interest capsules."""
    table = params["table_0"]
    pad = table.shape[0] - 1
    e = jnp.take(table, hist, axis=0, mode="clip").astype(jnp.float32)  # (B, T, D)
    valid = (hist != pad)[:, :, None].astype(jnp.float32)
    u = (e @ params["bilinear"].astype(jnp.float32)) * valid  # (B, T, D)
    b = jnp.broadcast_to(
        params["routing_init"][None], (hist.shape[0],) + params["routing_init"].shape
    )  # (B, K, T)

    def routing_iter(b, _):
        w = jax.nn.softmax(b, axis=1)  # over interests
        caps = _squash(jnp.einsum("bkt,btd->bkd", w, u))  # (B, K, D)
        b = b + jnp.einsum("bkd,btd->bkt", caps, u)
        return b, caps

    b, caps_seq = jax.lax.scan(routing_iter, b, None,
                               length=cfg.capsule_iters, unroll=True)
    return caps_seq[-1]  # (B, K, D)


def _mind_fwd(params, batch, cfg):
    caps = mind_interests(params, batch["hist"], cfg)  # (B, K, D)
    tgt = jnp.take(params["table_0"], batch["target"], axis=0, mode="clip").astype(jnp.float32)
    scores = jnp.einsum("bkd,bd->bk", caps, tgt)
    return jnp.max(scores, axis=-1)  # label-aware hard attention


def mind_retrieval_scores(params, hist, cand_ids, cfg) -> jax.Array:
    """(B, T) history x (N,) candidates -> (B, N) max-over-interest scores."""
    caps = mind_interests(params, hist, cfg)  # (B, K, D)
    cand = jnp.take(params["table_0"], cand_ids, axis=0, mode="clip").astype(jnp.float32)
    scores = jnp.einsum("bkd,nd->bkn", caps, cand)
    return jnp.max(scores, axis=1)


# --------------------------------------------------------------------------
# Dispatch
# --------------------------------------------------------------------------

_INIT = {"wide_deep": _wide_deep_init, "dlrm": _dlrm_init, "fm": _fm_init,
         "mind": _mind_init}
_FWD = {"wide_deep": _wide_deep_fwd, "dlrm": _dlrm_fwd, "fm": _fm_fwd,
        "mind": _mind_fwd}


def init_params(cfg: RecsysConfig, key: jax.Array):
    return _INIT[cfg.model](cfg, key)


def param_specs(cfg: RecsysConfig):
    return jax.eval_shape(functools.partial(init_params, cfg), jax.random.key(0))


def forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    return _FWD[cfg.model](params, batch, cfg)


def recsys_loss(params, batch, cfg: RecsysConfig) -> jax.Array:
    logits = forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    # numerically-stable BCE-with-logits
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
