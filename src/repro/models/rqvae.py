"""RQ-VAE Semantic-ID tokenizer (paper §3.1, following TIGER arXiv:2305.05065).

Item features are encoded to a latent, then residual-quantized across L
level-specific codebooks; the codeword indices (y_1..y_L) are the Semantic ID.
Training uses straight-through estimation with reconstruction + commitment
losses; dead codes are avoided with uniform codebook init over the data range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RQVAEConfig
from repro.models.layers import mlp, mlp_init

__all__ = [
    "init_params",
    "rqvae_loss",
    "encode_to_sids",
    "decode_from_sids",
    "assign_dedup_tokens",
]


def init_params(cfg: RQVAEConfig, key: jax.Array):
    k_enc, k_dec, k_cb = jax.random.split(key, 3)
    enc_dims = (cfg.feat_dim,) + cfg.enc_hidden + (cfg.latent_dim,)
    dec_dims = (cfg.latent_dim,) + tuple(reversed(cfg.enc_hidden)) + (cfg.feat_dim,)
    return {
        "encoder": mlp_init(k_enc, enc_dims, jnp.float32),
        "decoder": mlp_init(k_dec, dec_dims, jnp.float32),
        "codebooks": jax.random.normal(
            k_cb, (cfg.n_levels, cfg.codebook_size, cfg.latent_dim)
        ) * 0.5,
    }


def _quantize(residual: jax.Array, codebook: jax.Array):
    """Nearest-codeword lookup. residual (B, Z), codebook (V, Z)."""
    d = (
        jnp.sum(residual ** 2, -1, keepdims=True)
        - 2.0 * residual @ codebook.T
        + jnp.sum(codebook ** 2, -1)[None, :]
    )
    idx = jnp.argmin(d, axis=-1)
    return idx, codebook[idx]


def _residual_quantize(params, z: jax.Array, cfg: RQVAEConfig):
    def level(carry, codebook):
        r, q_sum = carry
        idx, q = _quantize(r, codebook)
        return (r - q, q_sum + q), (idx, q)

    (r, q_sum), (idx, qs) = jax.lax.scan(
        level, (z, jnp.zeros_like(z)), params["codebooks"]
    )
    return idx.T, q_sum, r  # (B, L), (B, Z), final residual


def rqvae_loss(params, feats: jax.Array, cfg: RQVAEConfig):
    z = mlp(params["encoder"], feats)
    sids, q, _ = _residual_quantize(params, z, cfg)
    # straight-through: decoder sees z + sg(q - z)
    z_q = z + jax.lax.stop_gradient(q - z)
    recon = mlp(params["decoder"], z_q)
    recon_loss = jnp.mean((recon - feats) ** 2)
    commit = jnp.mean((z - jax.lax.stop_gradient(q)) ** 2)
    codebook_loss = jnp.mean((jax.lax.stop_gradient(z) - q) ** 2)
    return recon_loss + codebook_loss + cfg.commitment_weight * commit


def encode_to_sids(params, feats: jax.Array, cfg: RQVAEConfig) -> jax.Array:
    """(B, F) item features -> (B, L) Semantic IDs."""
    z = mlp(params["encoder"], feats)
    sids, _, _ = _residual_quantize(params, z, cfg)
    return sids.astype(jnp.int32)


def decode_from_sids(params, sids: jax.Array, cfg: RQVAEConfig) -> jax.Array:
    """(B, L) Semantic IDs -> reconstructed (B, F) features."""
    q = jnp.zeros((sids.shape[0], cfg.latent_dim))
    for lvl in range(cfg.n_levels):
        q = q + params["codebooks"][lvl][sids[:, lvl]]
    return mlp(params["decoder"], q)


def assign_dedup_tokens(sids: np.ndarray, codebook_size: int) -> np.ndarray:
    """(N, L') RQ-level codes -> (N, L'+1) with the TIGER dedup token.

    Items that collide on all L' quantizer levels get distinct final tokens
    (their 0-based rank within the collision group, mod ``codebook_size``),
    so every item has a unique Semantic ID as long as no group exceeds the
    codebook (``tests/test_rqvae_data.py`` pins that bound).  Host-side
    helper — runs once per tokenization, not inside jit.
    """
    sids = np.asarray(sids)
    n = sids.shape[0]
    order = np.lexsort(tuple(sids[:, c] for c in
                             range(sids.shape[1] - 1, -1, -1)))
    s = sids[order]
    new_group = np.ones(n, dtype=bool)
    if n > 1:
        new_group[1:] = (s[1:] != s[:-1]).any(axis=1)
    group_start = np.maximum.accumulate(
        np.where(new_group, np.arange(n), 0))
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n) - group_start
    return np.concatenate(
        [sids, (rank % codebook_size)[:, None].astype(sids.dtype)], axis=1)
