"""MeshGraphNet (encode-process-decode GNN, arXiv:2010.03409).

Message passing is implemented with ``jax.ops.segment_sum`` over an
edge-index scatter (JAX has no CSR SpMM) — this IS part of the system per the
assignment.  Edge update: e' = MLP([e, x_src, x_dst]) + e; node update:
x' = MLP([x, sum_in(e')]) + x; `n_layers` processor steps via lax.scan over
stacked processor params with remat.

Supports all four assigned graph shapes: full-graph, sampled minibatch
(padded subgraphs from the fanout sampler in ``repro.data.graph_sampler``),
and batched small graphs (leading batch axis via vmap).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.layers import mlp, mlp_init, rms_norm, rms_norm_init

__all__ = ["init_params", "param_specs", "forward", "gnn_loss"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _mlp_dims(cfg, d_in):
    return (d_in,) + (cfg.d_hidden,) * cfg.mlp_layers


def init_params(cfg: GNNConfig, key: jax.Array):
    dt = _dtype(cfg)
    k_ne, k_ee, k_proc, k_dec = jax.random.split(key, 4)
    H = cfg.d_hidden
    params = {
        "node_enc": mlp_init(k_ne, _mlp_dims(cfg, cfg.node_feat_dim), dt),
        "edge_enc": mlp_init(k_ee, _mlp_dims(cfg, cfg.edge_feat_dim), dt),
        "decoder": mlp_init(k_dec, (H, H, cfg.out_dim), dt),
    }

    def proc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge_mlp": mlp_init(k1, (3 * H,) + (H,) * cfg.mlp_layers, dt),
            "node_mlp": mlp_init(k2, (2 * H,) + (H,) * cfg.mlp_layers, dt),
            "edge_norm": rms_norm_init(H, dt),
            "node_norm": rms_norm_init(H, dt),
        }

    params["processor"] = jax.vmap(proc_layer)(jax.random.split(k_proc, cfg.n_layers))
    return params


def param_specs(cfg: GNNConfig):
    return jax.eval_shape(functools.partial(init_params, cfg), jax.random.key(0))


def forward(
    params,
    node_feats: jax.Array,  # (N, F_n)
    edge_feats: jax.Array,  # (E, F_e)
    senders: jax.Array,  # (E,) int32
    receivers: jax.Array,  # (E,) int32
    cfg: GNNConfig,
    node_mask: jax.Array | None = None,  # (N,) bool for padded subgraphs
) -> jax.Array:
    n_nodes = node_feats.shape[0]
    x = mlp(params["node_enc"], node_feats)
    e = mlp(params["edge_enc"], edge_feats)

    def step(carry, p):
        x, e = carry
        x_src = jnp.take(x, senders, axis=0)
        x_dst = jnp.take(x, receivers, axis=0)
        e_in = jnp.concatenate([e, x_src, x_dst], axis=-1)
        e = e + rms_norm(p["edge_norm"], mlp(p["edge_mlp"], e_in))
        agg = jax.ops.segment_sum(e, receivers, num_segments=n_nodes)
        if cfg.aggregator == "mean":
            deg = jax.ops.segment_sum(
                jnp.ones((e.shape[0], 1), e.dtype), receivers, num_segments=n_nodes
            )
            agg = agg / jnp.maximum(deg, 1.0)
        x_in = jnp.concatenate([x, agg.astype(x.dtype)], axis=-1)
        x = x + rms_norm(p["node_norm"], mlp(p["node_mlp"], x_in))
        return (x, e), None

    body = jax.checkpoint(step) if cfg.remat else step
    (x, e), _ = jax.lax.scan(body, (x, e), params["processor"],
                             unroll=cfg.layer_unroll)
    out = mlp(params["decoder"], x)
    if node_mask is not None:
        out = out * node_mask[:, None].astype(out.dtype)
    return out


def gnn_loss(params, batch, cfg: GNNConfig) -> jax.Array:
    """L2 regression on node targets (MeshGraphNet's training objective)."""
    fwd = forward
    if batch["node_feats"].ndim == 3:  # batched small graphs
        fwd = jax.vmap(
            lambda nf, ef, s, r: forward(params, nf, ef, s, r, cfg),
            in_axes=(0, 0, 0, 0),
        )
        pred = fwd(batch["node_feats"], batch["edge_feats"],
                   batch["senders"], batch["receivers"])
    else:
        pred = forward(
            params, batch["node_feats"], batch["edge_feats"],
            batch["senders"], batch["receivers"], cfg,
            node_mask=batch.get("node_mask"),
        )
    err = (pred.astype(jnp.float32) - batch["targets"].astype(jnp.float32)) ** 2
    if "node_mask" in batch:
        m = batch["node_mask"].astype(jnp.float32)
        return jnp.sum(err * m[..., None]) / (jnp.sum(m) * err.shape[-1] + 1e-9)
    return jnp.mean(err)
