"""Continuous-batching serving engine (DESIGN.md §10).

``ServingEngine._serve_retrieval`` joins and evicts at *sequence*
boundaries: a batch of B requests runs all L beam-search levels in
lock-step, and a slot that finishes early idles until the whole batch
drains.  This engine joins and evicts at *step* boundaries — every engine
step decodes one SID level for every live slot, slots freed by completion
are refilled from the queue on the very next step, and all of it happens at
fixed static shapes through exactly four jitted functions compiled once at
warmup (the PR 6 recompile monitor asserts zero unexpected compiles across
admissions, evictions and registry hot-swaps).

The three subsystems:

* **Paged history KV** — each slot's prompt KV lives in pool pages indexed
  through a per-slot page table (``repro.models.kvcache``); ownership is a
  host-side free list with refcounts (:class:`PagedKVAllocator`).  The M
  beams of a slot read ONE stored history copy, and identical prompts
  share pages across slots via :class:`PrefixShareTable` — a hit also
  skips the prefill entirely (prefill is row-independent, so the donor's
  pages and first-token logits are bitwise what the skipped prefill would
  have produced).
* **Step scheduler** (:class:`StepScheduler`) — chunked prefill (at most
  ``prefill_chunk`` fresh prefills per step, so long-prompt bursts never
  stall running decodes), SLO deadline shedding at admission, and
  round-robin tenant fairness inherited from ``RequestQueue``'s lanes.
* **Trie-prefix sharing** — rows at heterogeneous decode levels are masked
  in one call via the policy's level-free path (``dense_d == 0`` node ids
  are globally unique, so ``(constraint_id, node)`` alone keys the
  admissible set), and ``DecodePolicy.shared_mask_step`` dedups mask rows
  across beams sitting on the same trie node.

Bit-identity contract: per-request ``(sids, scores)`` equal
``ServingEngine``'s output bit-for-bit (differential-fuzz asserted in
``tests/test_continuous.py``).  The decode step mirrors the sequential
engine's arithmetic exactly — see ``transformer.paged_decode_step`` — and
the beam advance below is the dense advance of ``core.beam_search``
verbatim.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.models import kvcache as kv_lib
from repro.models import transformer
from repro.observability import (
    MetricsRegistry,
    annotate,
    compile_events,
    record_policy,
)
from repro.reliability.faults import InjectedFault, fire
from repro.serving.continuous.paged_kv import (
    PagedKVAllocator,
    PrefixShareTable,
)
from repro.serving.continuous.scheduler import StepScheduler, queue_push_back
from repro.serving.engine import _EngineMetrics

__all__ = ["ContinuousServingEngine"]

NEG_INF = -1e30


class ContinuousServingEngine:
    """Step-boundary continuous batching over a constrained retriever.

    Built from the same :class:`GenerativeRetriever` the other engines
    serve (the retriever contributes params/config/policy and the SID
    geometry; its own jitted path is not used).  The policy must support
    level-free masking — build its constraint index with ``dense_d=0``.
    """

    def __init__(self, retriever, *, registry=None, slots: int = 8,
                 prompt_width: int = 8, page_size: int = 8,
                 prefill_chunk: int = 2, share_width: Optional[int] = None,
                 share_capacity: int = 64, deadline_s: Optional[float] = None,
                 metrics: Optional[MetricsRegistry] = None, breaker=None,
                 admit_retry_budget: int = 3):
        self.retriever = retriever
        self.breaker = breaker
        self.admit_retry_budget = int(admit_retry_budget)
        self.params = retriever.params
        self.cfg: TransformerConfig = retriever.cfg
        self.policy = retriever.policy
        self.L, self.V, self.M = retriever.L, retriever.V, retriever.M
        self.S = int(prompt_width)
        self.n_slots = int(slots)
        self.page_size = int(page_size)
        self.share_width = share_width
        self.registry = registry
        self._installed_version = None
        if not self.policy.supports_level_free:
            raise ValueError(
                "continuous batching requires a level-free-capable policy: "
                "build the constraint index with dense_d=0 "
                f"(got [{self.policy.describe()}])"
            )

        self._m = _EngineMetrics(metrics)
        r = self._m.registry
        record_policy(r, self.policy, beams=self.M)
        self._page_util = r.gauge(
            "serving_kv_page_pool_utilization",
            "referenced fraction of the paged history KV pool")
        self._slot_reuse = r.counter(
            "serving_slot_reuse_total",
            "admissions into a slot that already served a request "
            "(continuous batching working: > 0 under any sustained load)")
        self._share_hits = r.counter(
            "serving_prefix_share_hits_total",
            "work units saved by sharing: kind=\"prompt\" = prefills "
            "skipped via the prompt-prefix table; kind=\"mask_row\" = "
            "VNTK mask rows deduped across beams on the same trie node")
        self._admissions = r.counter(
            "serving_admissions_total", "requests admitted into a slot")

        self.sched = StepScheduler(
            self.n_slots, self.L, prefill_chunk=prefill_chunk,
            deadline_s=deadline_s,
        )
        self.n_hist_pages = kv_lib.pages_for(self.S, self.page_size)
        n_pages = 1 + (self.n_slots + self.sched.prefill_chunk
                       + int(share_capacity)) * self.n_hist_pages
        self.alloc = PagedKVAllocator(n_pages)
        self.share = PrefixShareTable(self.alloc, capacity=share_capacity)

        # -- device state (engine-owned arrays, mutated only through jits) --
        cfg = self.cfg
        dtype = transformer._dtype(cfg)
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
        self._k_pool, self._v_pool = kv_lib.init_page_pool(
            cfg.n_layers, n_pages, self.page_size, kv, hd, dtype=dtype)
        Ls = self.L + 1
        zeros6 = jnp.zeros(
            (cfg.n_layers, self.n_slots, self.M, Ls, kv, hd), dtype)
        self._suffix_k, self._suffix_v = zeros6, zeros6
        self._tokens = jnp.zeros((self.n_slots, self.M, self.L), jnp.int32)
        self._scores = jnp.full((self.n_slots, self.M), NEG_INF, jnp.float32)
        self._nodes = jnp.ones((self.n_slots, self.M), jnp.int32)
        self._first_lp = jnp.zeros((self.n_slots, self.V), jnp.float32)
        self._share_acc = jnp.zeros((), jnp.int32)
        self._share_flushed = 0
        # host mirrors: page ownership + per-slot constraint ids
        self._page_table = np.zeros(
            (self.n_slots, self.n_hist_pages), np.int32)
        self._slot_pages: list[tuple[int, ...]] = [()] * self.n_slots
        self._cids = np.zeros(self.n_slots, np.int32)

        # -- the four jitted entry points (compiled once at warmup) ---------
        self._prefill_jit = jax.jit(self._prefill_impl)
        self._commit_jit = jax.jit(self._commit_impl)
        self._admit_jit = jax.jit(self._admit_impl)
        self._step_jit = jax.jit(self._step_impl)
        self._warm = False
        self._warmup()

    @property
    def metrics(self) -> MetricsRegistry:
        return self._m.registry

    @property
    def slots(self) -> int:
        """Concurrent-request capacity (the other engines' batch size)."""
        return self.n_slots

    @property
    def num_sets(self) -> Optional[int]:
        return self.policy.num_sets

    # ------------------------------------------------------------------
    # jitted implementations
    # ------------------------------------------------------------------
    def _prefill_impl(self, params, prompts):
        """(A, S) prompts -> (first SID logits (A, V), per-layer K/V rows)."""
        logits, cache = transformer.prefill(
            params, prompts, self.cfg, max_len=self.S)
        return logits[:, 0, : self.V], cache.k, cache.v

    def _commit_impl(self, k_pool, v_pool, ks, vs, page_ids):
        return (kv_lib.scatter_pages(k_pool, ks, page_ids),
                kv_lib.scatter_pages(v_pool, vs, page_ids))

    def _admit_impl(self, tokens, scores, nodes, first_lp, sk, sv,
                    admit, new_first):
        """Reset admitted slots to beam-search initial state (the exact
        ``_init_state`` of ``core.beam_search``: scores [0, -inf, ...],
        nodes at ROOT=1, tokens zeroed)."""
        slots, M = scores.shape
        init_scores = jnp.where(
            jnp.arange(M) == 0, 0.0, NEG_INF).astype(jnp.float32)
        tokens = jnp.where(admit[:, None, None], 0, tokens)
        scores = jnp.where(admit[:, None], init_scores[None, :], scores)
        nodes = jnp.where(admit[:, None], 1, nodes)
        first_lp = jnp.where(admit[:, None], new_first, first_lp)
        adm6 = admit[None, :, None, None, None, None]
        sk = jnp.where(adm6, 0.0, sk).astype(sk.dtype)
        sv = jnp.where(adm6, 0.0, sv).astype(sv.dtype)
        return tokens, scores, nodes, first_lp, sk, sv

    def _step_impl(self, params, policy, k_pool, v_pool, page_table,
                   sk, sv, tokens, scores, nodes, first_lp,
                   levels, live, cids, share_acc):
        """One decode level for every live slot, at its own level.

        Dead slots ride along (static shapes) with frozen outputs: their
        suffix writes land in the trash column and their beam state is
        select-frozen, so they cost compute but never change bits.
        """
        slots, M, L = tokens.shape
        S, V, Ls = self.S, self.V, self.L + 1
        N = slots * M
        # a live row at level l >= 1 attends positions [0, S + l - 1] —
        # exactly the sequential cache's cur_pos at decode step l
        pos = S + jnp.clip(levels - 1, 0, L - 1)
        decoding = live & (levels > 0)
        write_col = jnp.where(decoding, levels - 1, Ls - 1)
        col = jnp.clip(levels - 1, 0, L - 1)
        last = jnp.take_along_axis(
            tokens, col[:, None, None], axis=2)[:, :, 0]
        logits_raw, sk, sv = transformer.paged_decode_step(
            params, k_pool, v_pool, page_table, sk, sv, last, pos,
            write_col, self.cfg, hist_len=S)
        logits = logits_raw[:, 0, :V].reshape(slots, M, V)
        # level-0 slots consume the prefill's first-token logits (beam
        # search step 0): identical rows per beam, as the reference
        # broadcast makes them
        logits = jnp.where(
            (levels == 0)[:, None, None], first_lp[:, None, :], logits)

        nodes_flat = nodes.reshape(N)
        cids_flat = (jnp.repeat(cids, M)
                     if policy.requires_constraint_ids else None)
        masked, next_dense, _ = policy.shared_mask_step(
            logits.reshape(N, V), nodes_flat, constraint_ids=cids_flat,
            share_width=self.share_width)

        # dense beam advance, verbatim from core.beam_search
        total = scores[:, :, None] + masked.reshape(slots, M, V)
        top_scores, top_idx = jax.lax.top_k(total.reshape(slots, M * V), M)
        beam_idx = top_idx // V
        token = (top_idx % V).astype(jnp.int32)
        batch_ix = jnp.arange(slots)[:, None]
        new_nodes = next_dense.reshape(slots, M, V)[batch_ix, beam_idx, token]
        new_tokens = tokens[batch_ix, beam_idx]
        wmask = (jnp.arange(L, dtype=jnp.int32)[None, None, :]
                 == levels[:, None, None])
        new_tokens = jnp.where(wmask, token[:, :, None], new_tokens)

        tokens = jnp.where(live[:, None, None], new_tokens, tokens)
        scores = jnp.where(live[:, None], top_scores, scores)
        nodes = jnp.where(live[:, None], new_nodes, nodes)
        # beam-permute the decoded suffixes (the reference permutes its
        # whole cache; history pages are beam-invariant so only suffixes
        # need the gather)
        perm = jnp.where(live[:, None], beam_idx, jnp.arange(M)[None, :])
        idx6 = perm[None, :, :, None, None, None]
        sk = jnp.take_along_axis(sk, idx6, axis=2)
        sv = jnp.take_along_axis(sv, idx6, axis=2)

        # prefix-share accounting among LIVE rows only: dead rows get
        # per-row unique sentinel keys so they neither join a share class
        # nor inflate the saved-row count
        if cids_flat is not None:
            n_states = policy.constraints.n_states
            keys = (cids_flat.astype(jnp.int32)
                    * jnp.int32(n_states + 1) + nodes_flat)
        else:
            keys = nodes_flat.astype(jnp.int32)
        live_flat = jnp.repeat(live, M)
        keys = jnp.where(
            live_flat, keys, -1 - jnp.arange(N, dtype=jnp.int32))
        sk_keys = jnp.sort(keys)
        n_uni = 1 + jnp.sum((sk_keys[1:] != sk_keys[:-1]).astype(jnp.int32))
        n_live = jnp.sum(live_flat.astype(jnp.int32))
        hits = jnp.maximum(n_live - (n_uni - (N - n_live)), 0)
        return tokens, scores, nodes, sk, sv, share_acc + hits

    # ------------------------------------------------------------------
    # host-side plumbing
    # ------------------------------------------------------------------
    def _warmup(self):
        """Compile all four entry points before serving, so steady state is
        compile-free (admission/eviction/live-mask changes are traced-array
        values, never shapes)."""
        A = self.sched.prefill_chunk
        first, ks, vs = self._prefill_jit(
            self.params, jnp.zeros((A, self.S), jnp.int32))
        scratch = np.zeros((A, self.n_hist_pages), np.int32)  # NULL page
        self._k_pool, self._v_pool = self._commit_jit(
            self._k_pool, self._v_pool, ks, vs, jnp.asarray(scratch))
        (self._tokens, self._scores, self._nodes, self._first_lp,
         self._suffix_k, self._suffix_v) = self._admit_jit(
            self._tokens, self._scores, self._nodes, self._first_lp,
            self._suffix_k, self._suffix_v,
            jnp.zeros(self.n_slots, bool),
            jnp.zeros((self.n_slots, self.V), jnp.float32))
        self._run_step()
        jax.block_until_ready(self._tokens)
        self._warm = True

    def _run_step(self):
        (self._tokens, self._scores, self._nodes,
         self._suffix_k, self._suffix_v, self._share_acc) = self._step_jit(
            self.params, self.policy, self._k_pool, self._v_pool,
            jnp.asarray(self._page_table), self._suffix_k, self._suffix_v,
            self._tokens, self._scores, self._nodes, self._first_lp,
            jnp.asarray(self.sched.levels()),
            jnp.asarray(self.sched.live_mask()),
            jnp.asarray(self._cids), self._share_acc)

    def _install_current_store(self):
        """Adopt the registry front buffer (ServingEngine's swap contract:
        hot = leaves only, zero recompile; cold = treedef change, the step
        re-specializes exactly once)."""
        store, version = self.registry.current()
        cold = False
        if version != self._installed_version:
            before = jax.tree_util.tree_structure(self.policy)
            new_policy = self.policy.with_constraints(store)
            if not new_policy.supports_level_free:
                raise ValueError(
                    "registry store lost level-free support (rebuild the "
                    "registry with dense_d=0)")
            self.policy = new_policy
            cold = jax.tree_util.tree_structure(self.policy) != before
            if cold:
                self._m.cold.inc()
                record_policy(self._m.registry, self.policy, beams=self.M)
            else:
                self._m.hot.inc()
            self._installed_version = version
            self._m.store_version.set(version)
        return version, cold

    def _padded_prompt(self, request) -> np.ndarray:
        row = np.zeros(self.S, np.int32)
        n = min(request.prompt.shape[0], self.S)
        row[:n] = request.prompt[:n]
        return row

    def _alloc_pages(self) -> list[int]:
        try:
            return self.alloc.alloc(self.n_hist_pages)
        except (MemoryError, InjectedFault):
            # reclaim cached-but-unused prompt KV and retry once (an
            # injected kv.page_alloc fault models the same transient
            # exhaustion; alloc's fault point fires before any mutation,
            # so the free/referenced invariant is intact here)
            self.share.drop_all()
            return self.alloc.alloc(self.n_hist_pages)

    def _admit(self, queue, admissions, fresh):
        """Run the bounded prefill chunk, wire page ownership, and reset the
        admitted slots' device rows — all through the warmed jits.

        A request whose page allocation fails even after the share-table
        reclaim is NOT admitted and does NOT crash the step: it goes back on
        the queue with a bumped ``admit_attempts``, and once the retry
        budget is spent it is shed with reason ``kv_pages`` (degradation
        ladder, DESIGN.md §13).  Other admissions in the chunk proceed.
        """
        now = time.monotonic()
        admit_mask = np.zeros(self.n_slots, bool)
        new_first = np.zeros((self.n_slots, self.V), np.float32)
        if fresh:
            ok = []
            for slot, r in fresh:
                try:
                    pages = self._alloc_pages()
                except (MemoryError, InjectedFault):
                    if self.breaker is not None:
                        self.breaker.record_failure()
                    r.admit_attempts += 1
                    if r.admit_attempts >= self.admit_retry_budget:
                        queue.shed(r, "kv_pages")
                    else:
                        queue_push_back(queue, r)
                    continue
                self._slot_pages[slot] = tuple(pages)
                ok.append((slot, r))
            dropped = {id(r) for _, r in fresh} - {id(r) for _, r in ok}
            if dropped:
                admissions = [a for a in admissions if id(a[1]) not in dropped]
            fresh = ok
        if fresh:
            A = self.sched.prefill_chunk
            block = np.zeros((A, self.S), np.int32)
            page_ids = np.zeros((A, self.n_hist_pages), np.int32)  # pad->NULL
            for j, (slot, r) in enumerate(fresh):
                block[j] = self._padded_prompt(r)
                page_ids[j] = self._slot_pages[slot]
            first_dev, ks, vs = self._prefill_jit(
                self.params, jnp.asarray(block))
            self._k_pool, self._v_pool = self._commit_jit(
                self._k_pool, self._v_pool, ks, vs, jnp.asarray(page_ids))
            first_host = np.asarray(first_dev)  # (A, V) float32, exact
            for j, (slot, r) in enumerate(fresh):
                new_first[slot] = first_host[j]
                self.share.insert(
                    block[j], self._slot_pages[slot], first_host[j])
        num_sets = self.policy.num_sets
        for slot, r, hit in admissions:
            limit = num_sets if num_sets is not None else 1
            if not 0 <= r.constraint_id < limit:
                raise ValueError(
                    f"request {r.rid}: constraint_id {r.constraint_id} "
                    f"outside [0, {limit})")
            if hit:
                entry = self.share.lookup(self._padded_prompt(r))
                if entry is None:
                    # donor entry vanished between planning and admission
                    # (drop_all reclaim under page pressure): requeue as a
                    # fresh prefill for the next step instead of crashing
                    queue_push_back(queue, r)
                    continue
                pages, first_row = entry
                self._slot_pages[slot] = pages
                new_first[slot] = first_row
                self._share_hits.inc(kind="prompt")
            self._page_table[slot, :] = self._slot_pages[slot]
            self._cids[slot] = r.constraint_id
            if self.sched.slots[slot].served > 0:
                self._slot_reuse.inc()
            self._admissions.inc(lane=str(r.constraint_id))
            admit_mask[slot] = True
            self.sched.admit(slot, r, now)
        (self._tokens, self._scores, self._nodes, self._first_lp,
         self._suffix_k, self._suffix_v) = self._admit_jit(
            self._tokens, self._scores, self._nodes, self._first_lp,
            self._suffix_k, self._suffix_v, jnp.asarray(admit_mask),
            jnp.asarray(new_first))

    def _flush_share_hits(self):
        total = int(np.asarray(self._share_acc))
        if total > self._share_flushed:
            self._share_hits.inc(
                total - self._share_flushed, kind="mask_row")
            self._share_flushed = total

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def serve(self, queue, max_steps: int = 50_000) -> dict:
        """Drain the queue; returns ``{rid: {sids, scores, constraint_id,
        store_version, latency_s, queue_s}}`` — the ServingEngine schema —
        plus ``{rid: {"error": ...}}`` for deadline-shed requests."""
        results: dict[int, dict] = {}
        sched = self.sched
        steps = 0
        self._m.record_shed(queue, results)  # submit-time refusals
        while (len(queue) or sched.n_live) and steps < max_steps:
            version, cold = (self._install_current_store()
                             if self.registry is not None else (None, False))
            sched.shed_expired(queue)  # sweeps ALL lanes, stages into queue
            admissions, _fresh = sched.plan_admissions(
                queue, lambda r: self.share.contains(self._padded_prompt(r)))
            if admissions or _fresh:
                self._admit(queue, admissions, _fresh)
            self._m.record_shed(queue, results)
            self._m.sample_queue(queue)
            if sched.n_live == 0:
                if not len(queue):
                    break
                continue

            c0 = compile_events()
            t0 = time.monotonic()
            try:
                fire("decode.slow_step")  # delay => slow step; error => retry
                with annotate("continuous_step"):
                    self._run_step()
                    jax.block_until_ready(self._tokens)
            except InjectedFault:
                # the fault fired before the jit mutated any engine state,
                # so retrying the step next iteration is bit-identical; the
                # failed attempt still burns a step of the budget so an
                # "always" error fault cannot spin forever
                if self.breaker is not None:
                    self.breaker.record_failure()
                steps += 1
                continue
            except Exception:
                if self.breaker is not None:
                    self.breaker.record_failure()
                raise
            if self.breaker is not None:
                self.breaker.record_success()
            dt = time.monotonic() - t0
            steps += 1
            sched.advance()
            self._m.record_batch(
                n_active=sched.n_live, slots=self.n_slots, steps=1, dt=dt,
                compiles=compile_events() - c0, expected=cold or not self._warm)

            done = sched.completed()
            if done:
                toks = np.asarray(self._tokens)
                scs = np.asarray(self._scores)
                t_done = time.monotonic()
                for i in done:
                    st = sched.evict(i)
                    r = st.request
                    self.alloc.release(self._slot_pages[i])
                    self._slot_pages[i] = ()
                    self._page_table[i, :] = 0
                    results[r.rid] = {
                        "sids": toks[i],
                        "scores": scs[i],
                        "constraint_id": r.constraint_id,
                        "store_version": self._installed_version,
                        **self._m.record_request(
                            r, st.t_admit, t_done, t_first=st.t_first,
                            n_out=self.L),
                    }
            self._m.occupancy.set(sched.n_live / max(self.n_slots, 1))
            self._page_util.set(self.alloc.utilization())
        self._m.record_shed(queue, results)
        self._m.sample_queue(queue)
        self._flush_share_hits()
        return results
