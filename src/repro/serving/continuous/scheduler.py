"""Step-boundary slot scheduler for the continuous engine (DESIGN.md §10).

Pure host bookkeeping — no jax imports.  The engine owns the device arrays;
this class owns *which request sits in which slot and how far along it is*,
so its policies (deadline shedding, chunked admission, eviction ordering)
are unit-testable without compiling anything.

Timeline of one engine step::

    evict(levels == L)  ->  admit(free slots, <= prefill_chunk fresh)  ->
    one jitted decode step over ALL slots  ->  levels[live] += 1

Levels advance deterministically (every live slot emits exactly one SID
token per step), so scheduling never reads device state.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

__all__ = ["SlotState", "StepScheduler"]


@dataclasses.dataclass
class SlotState:
    """Host mirror of one batch slot."""
    request: object = None  # serving Request, None when free
    level: int = 0  # SID tokens emitted so far (== next decode level)
    live: bool = False
    t_admit: float = 0.0
    t_first: Optional[float] = None  # wall time level 0 -> 1 completed
    served: int = 0  # completed requests this slot has hosted (reuse count)


class StepScheduler:
    """Admission / eviction planner over ``n_slots`` fixed slots.

    ``prefill_chunk`` caps *fresh prefills* per step — the chunked-prefill
    knob: a burst of long-prompt admissions costs at most one bounded
    ``(A, S)`` prefill per step instead of stalling running decodes behind
    an unbounded one.  Prompt-share hits skip prefill entirely and are not
    counted against the chunk.

    ``deadline_s`` (None = off) sheds requests whose queue wait already
    exceeds the SLO *at admission time* — the cheapest point to drop load,
    before any device work is spent on them.
    """

    def __init__(self, n_slots: int, sid_length: int, *,
                 prefill_chunk: int = 2, deadline_s: Optional[float] = None):
        self.n_slots = int(n_slots)
        self.L = int(sid_length)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.deadline_s = deadline_s
        self.slots = [SlotState() for _ in range(self.n_slots)]

    # -- queries ------------------------------------------------------------
    @property
    def n_live(self) -> int:
        return sum(s.live for s in self.slots)

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.live]

    def live_mask(self) -> np.ndarray:
        return np.array([s.live for s in self.slots], bool)

    def levels(self) -> np.ndarray:
        return np.array([s.level for s in self.slots], np.int32)

    def completed(self) -> list[int]:
        """Slots whose request has emitted all ``L`` tokens (evict next)."""
        return [i for i, s in enumerate(self.slots)
                if s.live and s.level >= self.L]

    # -- transitions --------------------------------------------------------
    def shed_expired(self, queue, now: Optional[float] = None) -> list:
        """Shed every queued request already past its deadline.

        Delegates to :meth:`RequestQueue.shed_expired`, which sweeps ALL
        lanes in place (the pre-reliability version popped and re-pushed the
        whole queue, and only the continuous engine did it at all — now the
        same enqueue-to-admission deadline semantics cover every engine).
        Per-request :class:`~repro.reliability.Deadline`\\ s are always
        honored; the scheduler's ``deadline_s`` is the engine-level default
        for requests submitted without one.  Returns the shed requests; the
        engine surfaces them via ``_EngineMetrics.record_shed``.
        """
        return queue.shed_expired(now=now, default_deadline_s=self.deadline_s)

    def plan_admissions(self, queue, share_probe) -> tuple[list, list]:
        """Fill free slots from the queue at this step boundary.

        ``share_probe(request) -> bool`` says whether the prompt is a
        prefix-share hit (no prefill needed).  Returns
        ``(admissions, fresh)`` where ``admissions`` is ``[(slot, request,
        is_share_hit)]`` and ``fresh`` the subset needing prefill — its
        length is capped at ``prefill_chunk``.
        """
        admissions, fresh = [], []
        for slot in self.free_slots():
            if not len(queue):
                break
            nxt = queue_peek(queue)
            hit = nxt is not None and share_probe(nxt)
            if not hit and len(fresh) >= self.prefill_chunk:
                break  # chunk full: long-prompt burst waits a step
            r = queue.pop()
            if r is None:
                break
            if r is not nxt:
                # the peeked head expired between peek and pop (deadline
                # shed inside pop): re-probe the request we actually got
                hit = share_probe(r)
                if not hit and len(fresh) >= self.prefill_chunk:
                    queue_push_back(queue, r)
                    break
            admissions.append((slot, r, hit))
            if not hit:
                fresh.append((slot, r))
        return admissions, fresh

    def admit(self, slot: int, request, now: Optional[float] = None) -> None:
        s = self.slots[slot]
        assert not s.live, f"admit into live slot {slot}"
        s.request = request
        s.level = 0
        s.live = True
        s.t_admit = time.monotonic() if now is None else now
        s.t_first = None

    def advance(self, now: Optional[float] = None) -> None:
        """One decode step happened: every live slot emitted a token."""
        now = time.monotonic() if now is None else now
        for s in self.slots:
            if s.live:
                if s.level == 0:
                    s.t_first = now
                s.level += 1

    def evict(self, slot: int) -> SlotState:
        s = self.slots[slot]
        assert s.live and s.level >= self.L, f"evict of unfinished slot {slot}"
        done = dataclasses.replace(s)
        s.request, s.level, s.live, s.t_first = None, 0, False, None
        s.served += 1
        return done


# -- queue helpers (RequestQueue has no peek/push-front; keep them here so
#    the queue class stays minimal) -----------------------------------------
def queue_peek(queue):
    peek = getattr(queue, "peek", None)
    if peek is not None:
        return peek()  # sheds expired heads, so peek/pop stay consistent
    if not queue._rr:
        return None
    return queue._lanes[queue._rr[0]][0]


def queue_push_back(queue, request) -> None:
    """Re-enqueue an already-constructed Request preserving its metadata."""
    lane = queue._lanes.get(request.constraint_id)
    if lane is None:
        lane = queue._lanes[request.constraint_id] = deque()
    if not lane:
        queue._rr.append(request.constraint_id)
    lane.append(request)
    queue._len += 1
