"""Host-side ownership of the paged history KV pool (DESIGN.md §10).

The device never sees allocation: pools are flat ``(n_layers, P, page_size,
KVH, Dh)`` arrays (``repro.models.kvcache.init_page_pool``) and the jitted
step reads them through an int32 page table.  Everything that *changes over
time at dynamic granularity* — which pages belong to which slot, how many
slots reference a shared prompt's pages — lives here as plain Python, so
join/evict/share never touches a traced shape.

Two pieces:

* :class:`PagedKVAllocator` — free-list + refcounts over page ids
  ``1..n_pages-1`` (page 0 is the reserved NULL/scratch page: dead slots'
  page-table rows are all-zero, and prefill padding rows scatter there).
  Invariant, checked on every mutation in debug mode and exposed as
  :meth:`check`: every page is on the free list XOR has refcount >= 1.
* :class:`PrefixShareTable` — maps prompt bytes -> (page ids, first-token
  logits row).  A hit at admission reuses the donor's pages (one
  ``retain``) and skips the prefill entirely; prefill is row-independent,
  so the skipped computation is bitwise the one the donor already ran.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.reliability.faults import fire

__all__ = ["PagedKVAllocator", "PrefixShareTable"]

NULL_PAGE = 0


class PagedKVAllocator:
    """Refcounted free-list allocator over pool pages ``1..n_pages-1``."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page beyond NULL")
        self.n_pages = int(n_pages)
        # LIFO free list: recently released pages are re-handed first, which
        # keeps the hot working set of pool pages small.
        self._free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._ref: dict[int, int] = {}

    # -- introspection ------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_referenced(self) -> int:
        return len(self._ref)

    def refcount(self, page: int) -> int:
        return self._ref.get(int(page), 0)

    def utilization(self) -> float:
        """Fraction of allocatable pages currently referenced."""
        return self.n_referenced / max(self.n_pages - 1, 1)

    def check(self) -> None:
        """Assert the ownership invariant; raises AssertionError on breach."""
        free = set(self._free)
        held = set(self._ref)
        assert len(free) == len(self._free), "duplicate page on free list"
        assert not (free & held), f"pages both free and referenced: {free & held}"
        assert NULL_PAGE not in free and NULL_PAGE not in held, \
            "NULL page entered circulation"
        assert len(free) + len(held) == self.n_pages - 1, (
            f"page leak: {len(free)} free + {len(held)} held "
            f"!= {self.n_pages - 1}"
        )
        assert all(c >= 1 for c in self._ref.values()), "zero refcount held"

    # -- mutation -----------------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        """Hand out ``n`` pages at refcount 1; raises MemoryError when the
        pool cannot satisfy the request (the caller sheds or waits)."""
        # fault point sits BEFORE any mutation, so an injected allocation
        # failure leaves the free ⊎ referenced invariant intact by
        # construction (chaos harness calls check() after every fire)
        fire("kv.page_alloc")
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, have {len(self._free)} free"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        for p in pages:
            p = int(p)
            if p not in self._ref:
                raise ValueError(f"retain of unowned page {p}")
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        for p in pages:
            p = int(p)
            c = self._ref.get(p)
            if c is None:
                raise ValueError(f"double free of page {p}")
            if c == 1:
                del self._ref[p]
                self._free.append(p)
            else:
                self._ref[p] = c - 1


class PrefixShareTable:
    """Prompt-prefix -> (pages, first logits) with refcount-aware eviction.

    Keyed on the *padded prompt bytes* (the exact ``(S,)`` int32 row the
    prefill would consume), so a hit guarantees the skipped prefill computes
    bit-for-bit what the stored pages and logits row already hold — prefill
    rows are batch-independent.  Constraint ids do NOT enter the key: the
    prefill is model-only, so tenants share prompt KV safely.

    The table holds one allocator reference per entry; LRU eviction (and
    :meth:`drop_all`) releases it.  Capacity bounds pool pressure:
    an entry's pages stay resident while cached even with no live slot
    using them, which is the point — the next identical prompt skips its
    prefill.
    """

    def __init__(self, allocator: PagedKVAllocator, capacity: int = 64):
        self._alloc = allocator
        self.capacity = int(capacity)
        self._entries: "OrderedDict[bytes, tuple[tuple[int, ...], np.ndarray]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key_of(prompt_row: np.ndarray) -> bytes:
        return np.ascontiguousarray(prompt_row, np.int32).tobytes()

    def contains(self, prompt_row: np.ndarray) -> bool:
        """Side-effect-free probe (no retain, no hit/miss accounting) —
        admission *planning* asks this; the actual admission calls
        :meth:`lookup`."""
        return self.key_of(prompt_row) in self._entries

    def lookup(self, prompt_row: np.ndarray) -> Optional[tuple[tuple[int, ...], np.ndarray]]:
        """On hit: ``(page_ids, first_logits_row)`` with the pages *already
        retained* for the caller (one new reference)."""
        k = self.key_of(prompt_row)
        hit = self._entries.get(k)
        if hit is None:
            self.misses += 1
            return None
        self._entries.move_to_end(k)
        self._alloc.retain(hit[0])
        self.hits += 1
        return hit

    def insert(self, prompt_row: np.ndarray, pages: Sequence[int],
               first_logits_row: np.ndarray) -> None:
        """Cache a freshly prefilled prompt.  Takes its own reference on
        ``pages``; evicts LRU entries beyond capacity."""
        if self.capacity <= 0:
            return
        k = self.key_of(prompt_row)
        if k in self._entries:  # racing duplicate prefill; keep the old one
            return
        self._alloc.retain(pages)
        self._entries[k] = (
            tuple(int(p) for p in pages),
            np.array(first_logits_row, np.float32, copy=True),
        )
        while len(self._entries) > self.capacity:
            _, (old_pages, _) = self._entries.popitem(last=False)
            self._alloc.release(old_pages)

    def drop_all(self) -> None:
        for pages, _ in self._entries.values():
            self._alloc.release(pages)
        self._entries.clear()
