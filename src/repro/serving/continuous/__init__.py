"""Continuous-batching serving subsystem (DESIGN.md §10).

Step-boundary join/evict over a paged history KV pool with trie-prefix
sharing.  See :class:`ContinuousServingEngine` for the contract; the
sequence-boundary engines live one package up
(``repro.serving.ServingEngine`` / ``SpmdServingEngine``).
"""
from repro.serving.continuous.engine import ContinuousServingEngine
from repro.serving.continuous.paged_kv import (
    PagedKVAllocator,
    PrefixShareTable,
)
from repro.serving.continuous.scheduler import SlotState, StepScheduler

__all__ = [
    "ContinuousServingEngine",
    "PagedKVAllocator",
    "PrefixShareTable",
    "StepScheduler",
    "SlotState",
]
