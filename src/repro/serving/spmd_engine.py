"""SPMD constrained serving: mesh-parallel retrieval + continuous batching.

``SpmdRetriever`` is the :class:`~repro.serving.generative_retrieval
.GenerativeRetriever` made SPMD over a ``Mesh`` from
:mod:`repro.launch.mesh`: one jitted ``shard_map`` step runs prefill + the L
constrained beam steps with the *batch* axis split across the mesh's data
axes (rows are independent in Algorithm 1, so sharded decoding is
bit-identical to single-device — asserted in
``tests/test_differential_fuzz.py``).  The DecodePolicy rides in as a pytree
argument with per-backend placements from its ``shardings(mesh)`` hook:
replicated by default (paper §A.3), or CSR-row-sharded along ``model`` with
``rows="model"`` for tries that outgrow one device (DESIGN.md §6).
Candidate-compressed levels (DESIGN.md §8) compose with both placements:
under the default replicated rows the per-beam top-C lists and the
``(B, M*C)`` reduce are dp-local, and under ``rows="model"`` the
``RowShardedStatic`` wrapper runs the shard-local top-C + one-hop psum
merge of ``vntk_row_sharded_topk`` (DESIGN.md §11), still bit-identical.

``SpmdServingEngine`` replaces the one-request-at-a-time admit loop of
``ServingEngine._serve_retrieval`` with continuous data-parallel batching:

  * a **global batch of fixed ``slots``** (padded up to a multiple of the
    data-parallel ways) — static shapes, so occupancy changes never
    recompile;
  * per-row ``constraint_ids`` and an ``active`` mask ride as jit
    *arguments*: free slots are inactive rows whose scores come back
    ``NEG_INF``, not separate (shape-specialized) executables;
  * admission is round-robin-fair across constraint slots
    (:class:`~repro.serving.engine.RequestQueue` lanes), so one tenant's
    burst cannot monopolize the shared batch;
  * the registry's current store is re-read each batch and installed via
    ``retriever.set_constraints`` — a hot-swap changes only pytree leaves,
    and the mesh-compiled executable is reused with **zero recompilation**
    (asserted in ``tests/test_spmd_serving.py``).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.vntk import NEG_INF
from repro.decoding.backends import CpuTrieBackend
from repro.distributed.constraint_sharding import (
    pad_policy_rows,
    policy_pspecs,
    to_row_sharded,
)
from repro.distributed.sharding import dp_axes, dp_size, shard_map_compat
from repro.observability import (
    MetricsRegistry,
    annotate,
    compile_events,
    record_policy,
)
from repro.reliability.faults import InjectedFault, fire
from repro.serving.engine import _EngineMetrics
from repro.serving.generative_retrieval import GenerativeRetriever

__all__ = ["SpmdRetriever", "SpmdServingEngine"]


class SpmdRetriever(GenerativeRetriever):
    """Mesh-parallel constrained retrieval (one shard_map'd jitted step).

    Same constructor surface as :class:`GenerativeRetriever` plus ``mesh``
    and ``rows`` (the CSR placement, see
    :meth:`~repro.decoding.ConstraintBackend.shardings`).  ``retrieve`` pads
    the request batch to a multiple of the mesh's data-parallel ways with
    inactive rows, so any caller batch size maps onto the static SPMD shape.
    """

    def __init__(self, params, cfg, policy=None, sid_length=None,
                 sid_vocab=None, beam_size: int = 20, *, mesh,
                 rows: str = "replicated"):
        super().__init__(params, cfg, policy, sid_length, sid_vocab,
                         beam_size)
        if rows not in ("replicated", "model"):
            raise ValueError(
                f"rows must be 'replicated' or 'model', got {rows!r}"
            )
        for b in self.policy.backends:
            if isinstance(b, CpuTrieBackend):
                raise TypeError(
                    "CpuTrieBackend masks through a host io_callback and "
                    "cannot run inside the SPMD step; use a device-resident "
                    "backend (STATIC, stacked, PPV, bitmap)"
                )
        self.mesh = mesh
        self.rows = rows
        self._dp = dp_axes(mesh)
        self._dp_size = dp_size(mesh)
        if rows == "model":
            # validate early (pallas/fused rejection) + pad CSR rows so the
            # edge slab divides the model axis — deterministic shapes, so
            # re-padding after every hot-swap never recompiles
            to_row_sharded(self.policy)
            self.policy = pad_policy_rows(self.policy, mesh.shape["model"])
        self._build_spmd_step()

    def _build_spmd_step(self) -> None:
        """(Re)build the shard_map'd step for the CURRENT policy structure.

        shard_map in_specs carry the policy's treedef (static metadata
        included), so they are rebuilt whenever the structure changes; the
        jit cache itself still keys on the arguments, so envelope-stable
        hot-swaps (same treedef, new leaves) reuse the old executable.
        """
        self._pol_struct = jax.tree_util.tree_structure(self.policy)
        specs = policy_pspecs(self.policy, self.mesh, rows=self.rows)
        dp = self._dp

        ms = self.mesh.shape["model"] if self.rows == "model" else 1

        def _spmd_impl(params, history, policy, cids, active):
            if self.rows == "model":
                policy = to_row_sharded(policy, n_shards=ms)
            ids = cids if policy.requires_constraint_ids else None
            tokens, scores = self._retrieve_impl(params, history, policy, ids)
            # inactive (padding / free-slot) rows: parked at NEG_INF so no
            # consumer can mistake them for results
            scores = jnp.where(active[:, None], scores, NEG_INF)
            return tokens, scores

        self._spmd_jit = jax.jit(shard_map_compat(
            _spmd_impl, mesh=self.mesh,
            in_specs=(P(), P(dp, None), specs, P(dp), P(dp)),
            out_specs=(P(dp, None, None), P(dp, None)),
        ))

    # -- hot-swap ------------------------------------------------------------
    def set_constraints(self, obj) -> bool:
        """Registry swap under the mesh; returns True iff it was cold.

        A hot swap (envelope-stable, the ConstraintRegistry refresh path)
        changes only leaf values: the swapped-in matrix/store is re-padded
        to the deterministic row-sharded envelope, so neither shapes,
        static metadata, nor the spec tree move — the mesh executable is
        reused as-is.  A cold swap (regrown envelope, DESIGN.md §7 — or a
        raw TransitionMatrix with different state counts) changes static
        metadata: the shard_map step is rebuilt and recompiles exactly
        once, matching the single-device retriever's retrace-on-metadata-
        change behavior.
        """
        self.policy = self.policy.with_constraints(obj)
        if self.rows == "model":
            self.policy = pad_policy_rows(
                self.policy, self.mesh.shape["model"]
            )
        if jax.tree_util.tree_structure(self.policy) != self._pol_struct:
            self._build_spmd_step()
            return True
        return False

    # -- serving -------------------------------------------------------------
    def retrieve(self, history: np.ndarray,
                 constraint_ids: Optional[np.ndarray] = None,
                 active_mask: Optional[np.ndarray] = None):
        """history (B, S) -> (sids (B, M, L), scores (B, M)), SPMD.

        ``active_mask`` (B,) bool marks real rows (default: all).  The batch
        is padded to a multiple of the data-parallel ways with inactive
        rows; padding is sliced off the outputs, and inactive rows return
        ``NEG_INF`` scores.
        """
        hist = np.asarray(history, np.int32)
        B = hist.shape[0]
        n = self._dp_size
        Bp = -(-B // n) * n
        num_sets = self.num_sets
        cids = np.zeros(Bp, np.int32)
        if constraint_ids is not None:
            cids_in = np.asarray(constraint_ids, np.int32)
            if num_sets is None:
                raise ValueError(
                    "constraint_ids requires a stacked ConstraintStore policy"
                )
            if cids_in.min() < 0 or cids_in.max() >= num_sets:
                raise ValueError(
                    f"constraint_ids must be in [0, {num_sets}), got "
                    f"range [{cids_in.min()}, {cids_in.max()}]"
                )
            cids[:B] = cids_in
        elif num_sets is not None:
            raise ValueError(
                "stacked ConstraintStore policies need per-row constraint_ids"
            )
        active = np.zeros(Bp, bool)
        active[:B] = True if active_mask is None else \
            np.asarray(active_mask, bool)
        if Bp != B:
            hist = np.concatenate(
                [hist, np.zeros((Bp - B, hist.shape[1]), np.int32)]
            )
        tokens, scores = self._spmd_jit(
            self.params, jnp.asarray(hist), self.policy,
            jnp.asarray(cids), jnp.asarray(active),
        )
        return np.asarray(tokens)[:B], np.asarray(scores)[:B]


class SpmdServingEngine:
    """Continuous data-parallel batched serving over a mesh.

    Drains a :class:`~repro.serving.engine.RequestQueue` through an
    :class:`SpmdRetriever` in fixed-``slots`` global batches.  Result dict
    matches ``ServingEngine.serve``'s retrieval mode:
    ``{rid: {sids, scores, constraint_id, store_version}}``.
    """

    def __init__(self, retriever: SpmdRetriever, *, registry=None,
                 slots: Optional[int] = None, prompt_width: int = 8,
                 metrics: Optional[MetricsRegistry] = None, breaker=None):
        n = retriever._dp_size
        slots = slots if slots is not None else max(2 * n, 4)
        self.slots = -(-slots // n) * n  # static-shape padding rule (§6)
        self.retriever = retriever
        self.registry = registry
        self.breaker = breaker
        self.prompt_width = prompt_width
        self._installed_version = None
        self._m = _EngineMetrics(metrics)
        self._served_batches = 0
        record_policy(self._m.registry, retriever.policy, beams=retriever.M)

    @property
    def metrics(self) -> MetricsRegistry:
        return self._m.registry

    @property
    def cold_swaps(self) -> int:
        """Envelope regrowths routed through this engine (a property over
        the ``serving_cold_swaps_total`` counter, so pre-telemetry callers
        and tests keep working unchanged)."""
        return int(self._m.cold.total())

    def serve(self, queue, max_batches: int = 10_000) -> dict:
        results: dict[int, dict] = {}
        S = self.prompt_width
        batches = 0
        self._m.record_shed(queue, results)  # submit-time refusals
        while len(queue) and batches < max_batches:
            batches += 1
            t_admit = time.monotonic()
            queue.shed_expired()
            batch = queue.pop_batch(self.slots)  # round-robin fair admit
            self._m.record_shed(queue, results)
            self._m.sample_queue(queue)
            if not batch:
                continue
            version, cold = None, False
            if self.registry is not None:
                store, version = self.registry.current()
                if version != self._installed_version:
                    cold = self.retriever.set_constraints(store)
                    if cold:
                        self._m.cold.inc()  # regrown envelope: one rebuild
                        record_policy(self._m.registry,
                                      self.retriever.policy,
                                      beams=self.retriever.M)
                    else:
                        self._m.hot.inc()
                    self._installed_version = version
                    self._m.store_version.set(version)
            num_sets = self.retriever.num_sets
            limit = num_sets if num_sets is not None else 1
            hist = np.zeros((self.slots, S), np.int32)
            cids = np.zeros(self.slots, np.int32)
            active = np.zeros(self.slots, bool)
            for i, r in enumerate(batch):
                if not 0 <= r.constraint_id < limit:
                    # reject just this request (it raced a registry shrink
                    # or is plain bad input) — killing the whole drain would
                    # discard every already-served and already-popped row
                    results[r.rid] = {
                        "error": f"constraint_id {r.constraint_id} outside "
                                 f"[0, {limit})",
                        "constraint_id": r.constraint_id,
                        "store_version": version,
                    }
                    self._m.rejected.inc(lane=str(r.constraint_id))
                    continue
                hist[i, : min(r.prompt.shape[0], S)] = r.prompt[:S]
                cids[i] = r.constraint_id
                active[i] = True
            c0 = compile_events()
            try:
                fire("decode.slow_step")  # delay => slow batch; error => fail
                with annotate("spmd_serve_batch"):
                    beams, scores = self.retriever.retrieve(
                        hist,
                        constraint_ids=cids if num_sets is not None else None,
                        active_mask=active,
                    )
            except InjectedFault:
                # degrade to failed requests, not a crashed drain loop (and
                # never to unconstrained decoding) — DESIGN.md §13
                if self.breaker is not None:
                    self.breaker.record_failure()
                for r in batch:
                    if r.rid in results:
                        continue
                    self._m.rejected.inc(lane=str(r.constraint_id))
                    self._m.shed.inc(reason="decode_fault")
                    results[r.rid] = {
                        "error": "decode step failed (injected fault)",
                        "reason": "decode_fault",
                        "constraint_id": r.constraint_id,
                    }
                continue
            except Exception:
                if self.breaker is not None:
                    self.breaker.record_failure()
                raise
            if self.breaker is not None:
                self.breaker.record_success()
            t_done = time.monotonic()
            self._m.record_batch(
                n_active=int(active.sum()), slots=self.slots,
                steps=self.retriever.L, dt=t_done - t_admit,
                compiles=compile_events() - c0,
                expected=cold or self._served_batches == 0,
            )
            self._served_batches += 1
            for i, r in enumerate(batch):
                if r.rid in results:
                    continue  # rejected above
                results[r.rid] = {
                    "sids": beams[i],
                    "scores": scores[i],
                    "constraint_id": r.constraint_id,
                    "store_version": version,
                    **self._m.record_request(r, t_admit, t_done,
                                             n_out=self.retriever.L),
                }
        self._m.record_shed(queue, results)
        self._m.sample_queue(queue)
        return results
