"""STATIC-constrained generative-retrieval server (the paper's use case).

``GenerativeRetriever.retrieve`` takes user-history token sequences, prefills
the model once per request, then runs the constrained beam search of
Algorithm 1 over SID tokens.  Which constraint method masks each decode level
is bound by a :class:`~repro.decoding.DecodePolicy` — the paper's STATIC
matrix (100% compliance, §5.4), the stacked multi-tenant store, or any §5.2
baseline all serve through this same jitted path.

Multi-tenant mode (DESIGN.md §4): build the retriever with a stacked policy
(``DecodePolicy.stacked(store)`` — or just pass the ConstraintStore) and a
per-request ``constraint_ids`` vector to ``retrieve`` — each batch row is
then decoded under its own business constraint set in the same jitted beam
search.  The policy rides into jit as a pytree ARGUMENT with swap-invariant
static metadata, so a registry hot-swap (``set_constraints``) never
recompiles.

STATIC policies default to candidate-compressed decoding (DESIGN.md §8):
sparse levels advance beams from per-beam top-C lists instead of
vocab-aligned tensors, bit-identical to the dense path.  Whether a level
compresses is static policy metadata (``supports_topk_at``), so it needs no
plumbing here and cannot flip across a hot-swap.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.core import beam_search
from repro.decoding import as_policy
from repro.models import transformer

__all__ = ["GenerativeRetriever"]


class GenerativeRetriever:
    def __init__(
        self,
        params,
        cfg: TransformerConfig,
        policy=None,  # DecodePolicy | TransitionMatrix | ConstraintStore | None
        sid_length: int = None,
        sid_vocab: int = None,
        beam_size: int = 20,
    ):
        self.params = params
        self.cfg = cfg
        if sid_length is None or sid_vocab is None:
            raise TypeError("sid_length and sid_vocab are required")
        self.policy = as_policy(policy)
        self.L = sid_length
        self.V = sid_vocab
        self.M = beam_size
        # One jitted end-to-end retrieval step (prefill + L constrained beam
        # steps).  The policy rides in as a pytree ARGUMENT, so a registry
        # hot-swap (new leaf values, identical shapes + static metadata)
        # reuses the compiled executable — zero recompilation.  Jitting once
        # here (not per call) also keeps the layer scans out of the
        # per-request eager path, which used to recompile every batch.
        self._retrieve_jit = jax.jit(self._retrieve_impl)

    # -- constraint plumbing -------------------------------------------------
    @property
    def num_sets(self) -> Optional[int]:
        """Stacked-store member count, or None when single-tenant."""
        return self.policy.num_sets

    def set_constraints(self, obj) -> bool:
        """Install a refreshed matrix/store (the registry swap path).

        A hot swap (same capacity envelope) replaces only pytree leaves —
        shapes and static metadata are invariant — so the jitted retrieve
        step is reused as-is.  A cold swap (regrown envelope, DESIGN.md §7)
        changes static metadata, so the next ``retrieve`` re-specializes
        the jitted step exactly once.  Returns True iff the swap was cold.
        """
        before = jax.tree_util.tree_structure(self.policy)
        self.policy = self.policy.with_constraints(obj)
        return jax.tree_util.tree_structure(self.policy) != before

    @property
    def constraints(self):
        """The underlying TransitionMatrix / ConstraintStore (read-only;
        install refreshed constraints via :meth:`set_constraints`)."""
        return self.policy.constraints

    # -- serving -------------------------------------------------------------
    def retrieve(self, history: np.ndarray,
                 constraint_ids: Optional[np.ndarray] = None):
        """history (B, S) int32 -> (sids (B, M, L), scores (B, M)).

        ``constraint_ids`` (B,) int32 selects each request's constraint set
        from the stacked ConstraintStore bound in ``self.policy``.
        """
        cids = None
        if constraint_ids is not None:
            cids_np = np.asarray(constraint_ids, np.int32)
            num_sets = self.num_sets
            if num_sets is not None and (
                cids_np.min() < 0 or cids_np.max() >= num_sets
            ):
                # an out-of-range id would be silently clamped by the stacked
                # gather — i.e. served under the WRONG business constraint
                raise ValueError(
                    f"constraint_ids must be in [0, {num_sets}), got "
                    f"range [{cids_np.min()}, {cids_np.max()}]"
                )
            cids = jnp.asarray(cids_np)
        tokens, scores = self._retrieve_jit(
            self.params, jnp.asarray(history), self.policy, cids
        )
        return np.asarray(tokens), np.asarray(scores)

    def _retrieve_impl(self, params, history, policy, constraint_ids):
        B, S = history.shape
        M = self.M
        max_len = S + self.L + 1
        # named_scope: trace-time profiler labels only (DESIGN.md §9) —
        # no runtime cost, no change to the computation.
        with jax.named_scope("prefill"):
            pre_logits, cache = transformer.prefill(
                params, history, self.cfg, max_len=max_len
            )
        # tile the request cache across beams: (L, B, ...) -> (L, B*M, ...)
        def tile(a):
            if a.ndim >= 2 and a.shape[1] == B:
                return jnp.repeat(a, M, axis=1)
            return a

        import dataclasses as dc

        with jax.named_scope("cache_beam_tile"):
            cache = dc.replace(
                cache,
                **{
                    f.name: tile(getattr(cache, f.name))
                    for f in dc.fields(cache)
                    if f.name in ("k", "v", "c_kv", "k_rope")
                },
            )

        def logits_fn(carry, last_tokens, step):
            c = carry
            toks = last_tokens.reshape(B * M, 1)
            logits, c = transformer.decode_step(params, c, toks, self.cfg)
            return logits[:, 0, : self.V].reshape(B, M, self.V), c

        def gather_cache(c, beam_idx):
            flat = (jnp.arange(B)[:, None] * M + beam_idx).reshape(-1)
            import dataclasses as dc2

            return dc2.replace(
                c,
                **{
                    f.name: jnp.take(getattr(c, f.name), flat, axis=1)
                    for f in dc2.fields(c)
                    if f.name in ("k", "v", "c_kv", "k_rope")
                },
            )

        state, _ = beam_search(
            logits_fn, cache, B, M, self.L, policy,
            carry_gather_fn=gather_cache,
            first_logits=pre_logits[:, 0, : self.V],
            constraint_ids=constraint_ids,
        )
        return state.tokens, state.scores
