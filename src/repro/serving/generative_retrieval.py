"""STATIC-constrained generative-retrieval server (the paper's use case).

``GenerativeRetriever.retrieve`` takes user-history token sequences, prefills
the model once per request, then runs the constrained beam search of
Algorithm 1 over SID tokens — the TransitionMatrix masks every step, so 100%
of returned Semantic IDs are inside the restricted corpus (paper §5.4:
"STATIC achieved 100% compliance").
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.core import TransitionMatrix, beam_search
from repro.models import transformer

__all__ = ["GenerativeRetriever"]


class GenerativeRetriever:
    def __init__(
        self,
        params,
        cfg: TransformerConfig,
        tm: Optional[TransitionMatrix],
        sid_length: int,
        sid_vocab: int,
        beam_size: int = 20,
        impl: str = "xla",
        fused: bool = False,
    ):
        self.params = params
        self.cfg = cfg
        self.tm = tm
        self.L = sid_length
        self.V = sid_vocab
        self.M = beam_size
        self.impl = impl
        self.fused = fused

    def retrieve(self, history: np.ndarray):
        """history (B, S) int32 -> (sids (B, M, L), scores (B, M))."""
        B, S = history.shape
        M = self.M
        max_len = S + self.L + 1
        pre_logits, cache = transformer.prefill(
            self.params, jnp.asarray(history), self.cfg, max_len=max_len
        )
        # tile the request cache across beams: (L, B, ...) -> (L, B*M, ...)
        def tile(a):
            if a.ndim >= 2 and a.shape[1] == B:
                return jnp.repeat(a, M, axis=1)
            return a

        import dataclasses as dc

        cache = dc.replace(
            cache,
            **{
                f.name: tile(getattr(cache, f.name))
                for f in dc.fields(cache)
                if f.name in ("k", "v", "c_kv", "k_rope")
            },
        )

        def logits_fn(carry, last_tokens, step):
            c = carry
            toks = last_tokens.reshape(B * M, 1)
            logits, c = transformer.decode_step(self.params, c, toks, self.cfg)
            return logits[:, 0, : self.V].reshape(B, M, self.V), c

        def gather_cache(c, beam_idx):
            flat = (jnp.arange(B)[:, None] * M + beam_idx).reshape(-1)
            import dataclasses as dc2

            return dc2.replace(
                c,
                **{
                    f.name: jnp.take(getattr(c, f.name), flat, axis=1)
                    for f in dc2.fields(c)
                    if f.name in ("k", "v", "c_kv", "k_rope")
                },
            )

        state, _ = beam_search(
            logits_fn, cache, B, M, self.L, self.tm,
            carry_gather_fn=gather_cache, impl=self.impl, fused=self.fused,
            first_logits=pre_logits[:, 0, : self.V],
        )
        return np.asarray(state.tokens), np.asarray(state.scores)
