"""Batched serving engine: prefill + decode with continuous-batching-lite.

``ServingEngine.generate`` drives a jitted prefill and a jitted decode step
over fixed-size batches (static shapes => no recompilation).  The
``RequestQueue`` admits requests into free slots at step boundaries: a slot
whose sequence finished is immediately refilled from the queue, so the batch
stays full under load (the "continuous batching" serving pattern, simplified
to slot granularity).

Multi-tenant retrieval mode (DESIGN.md §4): construct the engine with a
``retriever`` (and optionally a ``registry``) and every request's
``constraint_id`` rides through the queue into the shared batch — one
constrained beam search serves rows under *different* business constraint
sets simultaneously.  The retriever's constraint method is bound by its
:class:`~repro.decoding.DecodePolicy`; the registry's current store is
re-read at every batch boundary and installed via
``retriever.set_constraints``, so a hot-swap takes effect on the next batch
with zero recompilation (shapes and static metadata are swap-invariant).
A **cold** swap — the registry regrew the capacity envelope because a
snapshot outgrew it (DESIGN.md §7) — changes static metadata: the engine
installs it the same way, the jitted step re-specializes exactly once
(counted in ``cold_swaps``), and serving drains without dropping requests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.models import transformer

__all__ = ["ServingEngine", "RequestQueue"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    n_tokens: int
    constraint_id: int = 0  # which registry slot masks this request's SIDs


class RequestQueue:
    """Per-constraint-slot FIFO lanes drained round-robin.

    The old single deque was strict FIFO: under batched admission a tenant
    that bursts ``batch_size`` requests monopolizes whole batches, and every
    other constraint slot waits a full batch *per queued burst* — unbounded
    in burst length.  Requests now land in one FIFO lane per
    ``constraint_id`` and ``pop`` rotates across non-empty lanes, so a mixed
    batch admits every active tenant each cycle (arrival order is preserved
    *within* a lane, and a single-tenant queue degenerates to plain FIFO).
    """

    def __init__(self):
        self._lanes: dict[int, deque] = {}
        self._rr: deque = deque()  # round-robin order of non-empty lanes
        self._next = 0
        self._len = 0

    def submit(self, prompt: np.ndarray, n_tokens: int,
               constraint_id: int = 0) -> int:
        rid = self._next
        self._next += 1
        lane = self._lanes.get(constraint_id)
        if lane is None:
            lane = self._lanes[constraint_id] = deque()
        if not lane:
            self._rr.append(constraint_id)
        lane.append(
            Request(rid, np.asarray(prompt, np.int32), n_tokens, constraint_id)
        )
        self._len += 1
        return rid

    def pop(self) -> Optional[Request]:
        if not self._rr:
            return None
        cid = self._rr.popleft()
        lane = self._lanes[cid]
        r = lane.popleft()
        if lane:
            self._rr.append(cid)  # rotate: next pop serves another tenant
        self._len -= 1
        return r

    def pop_batch(self, n: int) -> list:
        """Up to ``n`` requests, round-robin across constraint slots."""
        out = []
        while len(out) < n:
            r = self.pop()
            if r is None:
                break
            out.append(r)
        return out

    def __len__(self):
        return self._len


class ServingEngine:
    def __init__(self, params, cfg: TransformerConfig, batch_size: int,
                 max_len: int, *, retriever=None, registry=None):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_len = max_len
        self.retriever = retriever  # GenerativeRetriever: SID serving mode
        self.registry = registry  # ConstraintRegistry: hot-swappable store
        self._installed_version = None
        self.cold_swaps = 0  # envelope regrowths routed through this engine
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(p, t, cfg, max_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, c, t: transformer.decode_step(p, c, t, cfg)
        )

    # -- single-batch synchronous generation --------------------------------
    def generate(self, prompts: np.ndarray, n_tokens: int,
                 greedy: bool = True, key=None) -> np.ndarray:
        B, S = prompts.shape
        assert B == self.batch_size
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        out = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
        out.append(tok)
        for i in range(n_tokens - 1):
            logits, cache = self._decode(self.params, cache, tok)
            if greedy:
                tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1, :])[:, None]
            tok = tok.astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    # -- constrained SID retrieval over a queue -------------------------------
    def _serve_retrieval(self, queue: RequestQueue) -> dict:
        """Drain the queue through the constrained retriever in shared batches.

        Each batch mixes requests with different ``constraint_id``s; the
        per-slot id vector rides into the stacked beam search, so every row's
        SIDs are masked by its own constraint set.  The registry (when
        present) is consulted once per batch — the step boundary at which a
        hot-swapped store becomes visible.
        """
        results: dict[int, dict] = {}
        S = self.max_len // 2  # fixed prompt width => static shapes
        while len(queue):
            batch = queue.pop_batch(self.batch_size)
            version = None
            if self.registry is not None:
                store, version = self.registry.current()
                if version != self._installed_version:
                    # hot-swap path: only policy pytree leaves change, so
                    # the retriever's jitted step is reused without
                    # recompiling; a cold (regrown-envelope) swap changes
                    # static metadata and re-specializes exactly once
                    if self.retriever.set_constraints(store):
                        self.cold_swaps += 1
                    self._installed_version = version
            # A plain single-matrix retriever serves every request under the
            # one set: constraint ids stay host-side and must all be 0.
            num_sets = self.retriever.num_sets
            hist = np.zeros((self.batch_size, S), np.int32)
            cids = np.zeros(self.batch_size, np.int32)
            for i, r in enumerate(batch):
                hist[i, : min(r.prompt.shape[0], S)] = r.prompt[:S]
                limit = num_sets if num_sets is not None else 1
                if not 0 <= r.constraint_id < limit:
                    raise ValueError(
                        f"request {r.rid}: constraint_id {r.constraint_id} "
                        f"outside [0, {limit})"
                    )
                cids[i] = r.constraint_id
            beams, scores = self.retriever.retrieve(
                hist, constraint_ids=cids if num_sets is not None else None
            )
            for i, r in enumerate(batch):
                results[r.rid] = {
                    "sids": beams[i],
                    "scores": scores[i],
                    "constraint_id": r.constraint_id,
                    "store_version": version,
                }
        return results

    # -- continuous batching over a queue ------------------------------------
    def serve(self, queue: RequestQueue, max_steps: int = 10_000) -> dict:
        """Run until the queue drains.

        Plain-LM mode returns {rid: generated token list}; retrieval mode
        (engine built with a ``retriever``) returns {rid: {sids, scores,
        constraint_id, store_version}}.
        """
        if self.retriever is not None:
            return self._serve_retrieval(queue)
        results: dict[int, list] = {}
        active: list[Optional[Request]] = [None] * self.batch_size
        remaining = np.zeros(self.batch_size, np.int64)
        prompts = np.zeros((self.batch_size, self.max_len // 2), np.int32)

        def admit():
            changed = False
            for i in range(self.batch_size):
                if active[i] is None and len(queue):
                    r = queue.pop()
                    active[i] = r
                    remaining[i] = r.n_tokens
                    prompts[i, :] = 0
                    prompts[i, : r.prompt.shape[0]] = r.prompt
                    results[r.rid] = []
                    changed = True
            return changed

        steps = 0
        while (any(a is not None for a in active) or len(queue)) and steps < max_steps:
            admit()
            # (re)prefill the whole batch when composition changed — slot-
            # granular caches would avoid this; fine at example scale.
            logits, cache = self._prefill(self.params, jnp.asarray(prompts))
            tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
            while any(a is not None for a in active):
                steps += 1
                tok_np = np.asarray(tok)[:, 0]
                done_any = False
                for i, r in enumerate(active):
                    if r is None:
                        continue
                    results[r.rid].append(int(tok_np[i]))
                    remaining[i] -= 1
                    if remaining[i] <= 0:
                        active[i] = None
                        done_any = True
                if done_any and len(queue):
                    break  # re-admit + re-prefill with new composition
                if not any(a is not None for a in active) or steps >= max_steps:
                    break
                logits, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
        return results
