"""Batched serving engine: prefill + decode with continuous-batching-lite.

``ServingEngine.generate`` drives a jitted prefill and a jitted decode step
over fixed-size batches (static shapes => no recompilation).  The
``RequestQueue`` admits requests into free slots at step boundaries: a slot
whose sequence finished is immediately refilled from the queue, so the batch
stays full under load (the "continuous batching" serving pattern, simplified
to slot granularity).

Multi-tenant retrieval mode (DESIGN.md §4): construct the engine with a
``retriever`` (and optionally a ``registry``) and every request's
``constraint_id`` rides through the queue into the shared batch — one
constrained beam search serves rows under *different* business constraint
sets simultaneously.  The retriever's constraint method is bound by its
:class:`~repro.decoding.DecodePolicy`; the registry's current store is
re-read at every batch boundary and installed via
``retriever.set_constraints``, so a hot-swap takes effect on the next batch
with zero recompilation (shapes and static metadata are swap-invariant).
A **cold** swap — the registry regrew the capacity envelope because a
snapshot outgrew it (DESIGN.md §7) — changes static metadata: the engine
installs it the same way, the jitted step re-specializes exactly once
(counted in ``cold_swaps``), and serving drains without dropping requests.

Telemetry (DESIGN.md §9): every engine owns (or is handed) a
:class:`~repro.observability.MetricsRegistry`.  Request latency is recorded
in three host-side histograms per tenant lane — queue wait
(enqueue→admit), service (admit→complete) and total (enqueue→complete) —
plus batch occupancy, per-lane queue depth, decode-step counters, and a
**recompile monitor**: compile events observed outside an expected window
(the engine's first batch, a cold swap) increment
``serving_recompiles_total{expected="false"}``, turning the zero-recompile
hot-swap guarantee into a monitored invariant.  All instrumentation runs
around the compiled calls; device work is bit-identical with metrics on or
off.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.models import transformer
from repro.observability import (
    TOKEN_LATENCY_BUCKETS_S,
    MetricsRegistry,
    annotate,
    compile_events,
    record_policy,
)
from repro.reliability.deadline import Deadline
from repro.reliability.faults import InjectedFault, fire

__all__ = ["ServingEngine", "RequestQueue"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    n_tokens: int
    constraint_id: int = 0  # which registry slot masks this request's SIDs
    t_enqueue: float = 0.0  # time.monotonic() at submit (latency accounting)
    deadline: Optional[Deadline] = None  # absolute SLO bound (DESIGN.md §13)
    admit_attempts: int = 0  # failed admission tries (page-alloc retry budget)


class RequestQueue:
    """Per-constraint-slot FIFO lanes drained round-robin.

    The old single deque was strict FIFO: under batched admission a tenant
    that bursts ``batch_size`` requests monopolizes whole batches, and every
    other constraint slot waits a full batch *per queued burst* — unbounded
    in burst length.  Requests now land in one FIFO lane per
    ``constraint_id`` and ``pop`` rotates across non-empty lanes, so a mixed
    batch admits every active tenant each cycle (arrival order is preserved
    *within* a lane, and a single-tenant queue degenerates to plain FIFO).

    **Reliability (DESIGN.md §13).**  ``submit`` is the admission-control
    point for every engine: an optional per-request ``deadline_s`` becomes
    an absolute :class:`~repro.reliability.Deadline`, an optional
    :class:`~repro.reliability.AdmissionController` (breaker state, depth
    cap, staleness bound) may refuse the request, and the
    ``queue.overload`` fault point models an overloaded admission path.
    Refused requests are *shed*, never raised: they collect in an internal
    list with their reason, and the serving engine drains them via
    :meth:`drain_shed` into error results plus the shared
    ``requests_shed_total{reason}`` counter family.  ``pop``/``peek`` also
    shed requests whose deadline expired *while queued*, and
    :meth:`shed_expired` sweeps every lane (not just the head) so a
    deadline deep inside a burst cannot hide behind fresher traffic.
    """

    def __init__(self, *, admission=None):
        self._lanes: dict[int, deque] = {}
        self._rr: deque = deque()  # round-robin order of non-empty lanes
        self._next = 0
        self._len = 0
        self._admission = admission  # AdmissionController (optional)
        self._shed: list[tuple[Request, str]] = []

    def submit(self, prompt: np.ndarray, n_tokens: int,
               constraint_id: int = 0,
               deadline_s: Optional[float] = None) -> int:
        rid = self._next
        self._next += 1
        now = time.monotonic()
        deadline = (Deadline.after(deadline_s, now)
                    if deadline_s is not None else None)
        r = Request(rid, np.asarray(prompt, np.int32), n_tokens,
                    constraint_id, t_enqueue=now, deadline=deadline)
        reason = None
        try:
            fire("queue.overload")
        except InjectedFault:
            reason = "overload"
        if reason is None and self._admission is not None:
            reason = self._admission.admit_reason(
                self._len, deadline=deadline, now=now)
        if reason is None and deadline is not None and deadline.expired(now):
            reason = "deadline"
        if reason is not None:
            self._shed.append((r, reason))
            return rid
        lane = self._lanes.get(constraint_id)
        if lane is None:
            lane = self._lanes[constraint_id] = deque()
        if not lane:
            self._rr.append(constraint_id)
        lane.append(r)
        self._len += 1
        return rid

    def pop(self) -> Optional[Request]:
        while self._rr:
            cid = self._rr.popleft()
            lane = self._lanes[cid]
            r = lane.popleft()
            if lane:
                self._rr.append(cid)  # rotate: next pop serves another tenant
            self._len -= 1
            if r.deadline is not None and r.deadline.expired():
                self._shed.append((r, "deadline"))
                continue  # expired while queued: shed, keep popping
            return r
        return None

    def peek(self) -> Optional[Request]:
        """Next request ``pop`` would return, without removing it (expired
        heads are shed on the way, so peek/pop agree)."""
        while self._rr:
            cid = self._rr[0]
            lane = self._lanes[cid]
            r = lane[0]
            if r.deadline is None or not r.deadline.expired():
                return r
            lane.popleft()
            self._len -= 1
            self._shed.append((r, "deadline"))
            if not lane:
                self._rr.popleft()
        return None

    def shed_expired(self, now: Optional[float] = None,
                     default_deadline_s: Optional[float] = None) -> list:
        """Sweep EVERY lane for expired requests (the old continuous-engine
        check only saw the queue head).  Requests without their own deadline
        fall back to ``default_deadline_s`` measured from enqueue (the
        engine-level SLO knob).  Returns the shed requests; they are also
        staged for :meth:`drain_shed`."""
        now = time.monotonic() if now is None else now
        shed = []
        for cid, lane in self._lanes.items():
            if not lane:
                continue
            survivors = []
            for r in lane:
                if r.deadline is not None:
                    late = r.deadline.expired(now)
                else:
                    late = (default_deadline_s is not None
                            and now - r.t_enqueue > default_deadline_s)
                if late:
                    shed.append(r)
                    self._shed.append((r, "deadline"))
                else:
                    survivors.append(r)
            if len(survivors) != len(lane):
                self._len -= len(lane) - len(survivors)
                lane.clear()
                lane.extend(survivors)
        if shed:
            self._rr = deque(
                cid for cid in self._rr if self._lanes[cid])
        return shed

    def shed(self, request: Request, reason: str) -> None:
        """Stage an already-popped request as shed (e.g. the continuous
        engine's page-allocation retry budget ran out); surfaced by the
        next :meth:`drain_shed`."""
        self._shed.append((request, reason))

    def drain_shed(self) -> list:
        """Return-and-clear ``[(request, reason)]`` of everything shed since
        the last drain (submit-time refusals + queued-deadline expiries)."""
        out, self._shed = self._shed, []
        return out

    def pop_batch(self, n: int) -> list:
        """Up to ``n`` requests, round-robin across constraint slots."""
        out = []
        while len(out) < n:
            r = self.pop()
            if r is None:
                break
            out.append(r)
        return out

    def lane_depths(self) -> dict[int, int]:
        """Current depth of every lane ever seen (emptied lanes report 0,
        so sampled gauges fall back to zero instead of going stale)."""
        return {cid: len(lane) for cid, lane in self._lanes.items()}

    def __len__(self):
        return self._len


class _EngineMetrics:
    """Shared instrumentation for both serving engines (host-side only)."""

    def __init__(self, registry: Optional[MetricsRegistry]):
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self.requests = r.counter(
            "serving_requests_total", "requests completed, by tenant lane")
        self.rejected = r.counter(
            "serving_rejected_total", "requests rejected at admission")
        self.shed = r.counter(
            "requests_shed_total",
            "requests shed before service, by reason (deadline/breaker_open/"
            "overload/stale_constraints/kv_pages) — shared across all engines")
        self.latency = r.histogram(
            "serving_request_latency_seconds",
            "per-request enqueue→complete wall time")
        self.queue_wait = r.histogram(
            "serving_request_queue_seconds",
            "per-request enqueue→admit wait in the RequestQueue")
        self.service = r.histogram(
            "serving_request_service_seconds",
            "per-request admit→complete service time")
        self.ttft = r.histogram(
            "serving_request_ttft_seconds",
            "per-request enqueue→first emitted SID token (sequence-boundary "
            "engines emit all tokens at completion, so there ttft == total)",
            buckets=TOKEN_LATENCY_BUCKETS_S)
        self.tpot = r.histogram(
            "serving_request_tpot_seconds",
            "per-request service time per output token",
            buckets=TOKEN_LATENCY_BUCKETS_S)
        self.batch_s = r.histogram(
            "serving_batch_seconds", "wall time of one shared decode batch")
        self.batches = r.counter("serving_batches_total", "batches served")
        self.steps = r.counter(
            "serving_decode_steps_total", "constrained decode steps executed")
        self.occupancy = r.gauge(
            "serving_batch_occupancy",
            "active-slot fraction of the last shared batch")
        self.queue_depth = r.gauge(
            "serving_queue_depth", "queued requests, by tenant lane")
        self.cold = r.counter(
            "serving_cold_swaps_total",
            "envelope regrowths (expected single recompiles) routed through "
            "this engine")
        self.hot = r.counter(
            "serving_hot_swaps_total",
            "zero-recompile registry store installs")
        self.recompiles = r.counter(
            "serving_recompiles_total",
            "backend compiles during serving; expected=\"false\" must stay 0 "
            "(the hot-swap zero-recompile invariant, monitored)")
        self.store_version = r.gauge(
            "serving_store_version", "registry version currently installed")

    def sample_queue(self, queue) -> None:
        for cid, depth in queue.lane_depths().items():
            self.queue_depth.set(depth, lane=str(cid))

    def record_shed(self, queue, results: dict) -> int:
        """Drain the queue's shed list into error results + counters.

        Every engine calls this each serve cycle so shed requests surface
        as ``{"error": ..., "reason": ...}`` results instead of silently
        vanishing, and the shared ``requests_shed_total{reason}`` family
        counts them uniformly across engines.
        """
        shed = queue.drain_shed()
        for r, reason in shed:
            self.rejected.inc(lane=str(r.constraint_id))
            self.shed.inc(reason=reason)
            results[r.rid] = {
                "error": f"shed before admission: {reason}",
                "reason": reason,
                "constraint_id": r.constraint_id,
            }
        return len(shed)

    def record_batch(self, *, n_active: int, slots: int, steps: int,
                     dt: float, compiles: int, expected: bool) -> None:
        self.batches.inc()
        self.steps.inc(steps)
        self.batch_s.observe(dt)
        self.occupancy.set(n_active / max(slots, 1))
        if compiles:
            self.recompiles.inc(
                compiles, expected="true" if expected else "false")

    def record_request(self, r: Request, t_admit: float, t_done: float, *,
                       t_first: Optional[float] = None,
                       n_out: Optional[int] = None) -> dict:
        """``t_first`` = wall time the first output token existed (defaults
        to ``t_done``: sequence-boundary engines only surface tokens at batch
        completion); ``n_out`` = output tokens, for the per-token rate."""
        lane = str(r.constraint_id)
        wait = max(t_admit - r.t_enqueue, 0.0)
        total = max(t_done - r.t_enqueue, 0.0)
        self.requests.inc(lane=lane)
        self.queue_wait.observe(wait, lane=lane)
        self.service.observe(max(t_done - t_admit, 0.0), lane=lane)
        self.latency.observe(total, lane=lane)
        self.ttft.observe(
            max((t_done if t_first is None else t_first) - r.t_enqueue, 0.0),
            lane=lane)
        if n_out:
            self.tpot.observe(
                max(t_done - t_admit, 0.0) / max(int(n_out), 1), lane=lane)
        return {"latency_s": total, "queue_s": wait}


class ServingEngine:
    def __init__(self, params, cfg: TransformerConfig, batch_size: int,
                 max_len: int, *, retriever=None, registry=None,
                 metrics: Optional[MetricsRegistry] = None, breaker=None):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_len = max_len
        self.retriever = retriever  # GenerativeRetriever: SID serving mode
        self.registry = registry  # ConstraintRegistry: hot-swappable store
        self.breaker = breaker  # CircuitBreaker: step outcomes feed it
        self._installed_version = None
        self._m = _EngineMetrics(metrics)
        self._served_batches = 0
        if retriever is not None:
            record_policy(self._m.registry, retriever.policy,
                          beams=retriever.M)
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(p, t, cfg, max_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, c, t: transformer.decode_step(p, c, t, cfg)
        )

    @property
    def metrics(self) -> MetricsRegistry:
        return self._m.registry

    @property
    def cold_swaps(self) -> int:
        """Envelope regrowths routed through this engine.

        Kept as an attribute-shaped property over the
        ``serving_cold_swaps_total`` counter so pre-telemetry callers and
        tests keep working unchanged.
        """
        return int(self._m.cold.total())

    # -- single-batch synchronous generation --------------------------------
    def generate(self, prompts: np.ndarray, n_tokens: int,
                 greedy: bool = True, key=None) -> np.ndarray:
        B, S = prompts.shape
        assert B == self.batch_size
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        out = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
        out.append(tok)
        for i in range(n_tokens - 1):
            logits, cache = self._decode(self.params, cache, tok)
            if greedy:
                tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1, :])[:, None]
            tok = tok.astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    # -- registry store install (shared by both serve modes) -----------------
    def _install_current_store(self):
        """Adopt the registry's front buffer; returns (version, was_cold)."""
        store, version = self.registry.current()
        cold = False
        if version != self._installed_version:
            # hot-swap path: only policy pytree leaves change, so the
            # retriever's jitted step is reused without recompiling; a cold
            # (regrown-envelope) swap changes static metadata and
            # re-specializes exactly once
            cold = self.retriever.set_constraints(store)
            if cold:
                self._m.cold.inc()
                record_policy(self._m.registry, self.retriever.policy,
                              beams=self.retriever.M)
            else:
                self._m.hot.inc()
            self._installed_version = version
            self._m.store_version.set(version)
        return version, cold

    # -- constrained SID retrieval over a queue -------------------------------
    def _serve_retrieval(self, queue: RequestQueue) -> dict:
        """Drain the queue through the constrained retriever in shared batches.

        Each batch mixes requests with different ``constraint_id``s; the
        per-slot id vector rides into the stacked beam search, so every row's
        SIDs are masked by its own constraint set.  The registry (when
        present) is consulted once per batch — the step boundary at which a
        hot-swapped store becomes visible.
        """
        results: dict[int, dict] = {}
        S = self.max_len // 2  # fixed prompt width => static shapes
        self._m.record_shed(queue, results)  # submit-time refusals
        while len(queue):
            t_admit = time.monotonic()
            queue.shed_expired()
            batch = queue.pop_batch(self.batch_size)
            self._m.record_shed(queue, results)
            self._m.sample_queue(queue)
            if not batch:
                continue
            version, cold = None, False
            if self.registry is not None:
                version, cold = self._install_current_store()
            # A plain single-matrix retriever serves every request under the
            # one set: constraint ids stay host-side and must all be 0.
            num_sets = self.retriever.num_sets
            hist = np.zeros((self.batch_size, S), np.int32)
            cids = np.zeros(self.batch_size, np.int32)
            for i, r in enumerate(batch):
                hist[i, : min(r.prompt.shape[0], S)] = r.prompt[:S]
                limit = num_sets if num_sets is not None else 1
                if not 0 <= r.constraint_id < limit:
                    raise ValueError(
                        f"request {r.rid}: constraint_id {r.constraint_id} "
                        f"outside [0, {limit})"
                    )
                cids[i] = r.constraint_id
            c0 = compile_events()
            try:
                fire("decode.slow_step")  # delay => slow batch; error => fail
                with annotate("serve_batch"):
                    beams, scores = self.retriever.retrieve(
                        hist,
                        constraint_ids=cids if num_sets is not None else None,
                    )
            except InjectedFault:
                # A failed decode step degrades to failed *requests*, never
                # to unconstrained decoding or an engine crash: the batch is
                # reported as errored, the breaker absorbs the failure, and
                # the loop keeps serving (DESIGN.md §13 degradation ladder).
                if self.breaker is not None:
                    self.breaker.record_failure()
                for r in batch:
                    self._m.rejected.inc(lane=str(r.constraint_id))
                    self._m.shed.inc(reason="decode_fault")
                    results[r.rid] = {
                        "error": "decode step failed (injected fault)",
                        "reason": "decode_fault",
                        "constraint_id": r.constraint_id,
                    }
                continue
            except Exception:
                if self.breaker is not None:
                    self.breaker.record_failure()
                raise
            if self.breaker is not None:
                self.breaker.record_success()
            t_done = time.monotonic()
            self._m.record_batch(
                n_active=len(batch), slots=self.batch_size,
                steps=self.retriever.L, dt=t_done - t_admit,
                compiles=compile_events() - c0,
                expected=cold or self._served_batches == 0,
            )
            self._served_batches += 1
            for i, r in enumerate(batch):
                results[r.rid] = {
                    "sids": beams[i],
                    "scores": scores[i],
                    "constraint_id": r.constraint_id,
                    "store_version": version,
                    **self._m.record_request(r, t_admit, t_done,
                                             n_out=self.retriever.L),
                }
        self._m.record_shed(queue, results)
        self._m.sample_queue(queue)
        return results

    # -- continuous batching over a queue ------------------------------------
    def serve(self, queue: RequestQueue, max_steps: int = 10_000) -> dict:
        """Run until the queue drains.

        Plain-LM mode returns {rid: generated token list}; retrieval mode
        (engine built with a ``retriever``) returns {rid: {sids, scores,
        constraint_id, store_version, latency_s, queue_s}}.
        """
        if self.retriever is not None:
            return self._serve_retrieval(queue)
        results: dict[int, list] = {}
        self._m.record_shed(queue, results)  # submit-time refusals
        active: list[Optional[Request]] = [None] * self.batch_size
        admit_t: dict[int, float] = {}
        remaining = np.zeros(self.batch_size, np.int64)
        prompts = np.zeros((self.batch_size, self.max_len // 2), np.int32)

        def admit():
            changed = False
            now = time.monotonic()
            for i in range(self.batch_size):
                if active[i] is None and len(queue):
                    r = queue.pop()
                    if r is None:  # remaining requests expired while queued
                        break
                    active[i] = r
                    remaining[i] = r.n_tokens
                    prompts[i, :] = 0
                    prompts[i, : r.prompt.shape[0]] = r.prompt
                    results[r.rid] = []
                    admit_t[r.rid] = now
                    changed = True
            self._m.sample_queue(queue)
            return changed

        steps = 0
        while (any(a is not None for a in active) or len(queue)) and steps < max_steps:
            admit()
            self._m.occupancy.set(
                sum(a is not None for a in active) / max(self.batch_size, 1)
            )
            # (re)prefill the whole batch when composition changed — slot-
            # granular caches would avoid this; fine at example scale.
            logits, cache = self._prefill(self.params, jnp.asarray(prompts))
            tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
            while any(a is not None for a in active):
                steps += 1
                self._m.steps.inc()
                tok_np = np.asarray(tok)[:, 0]
                done_any = False
                for i, r in enumerate(active):
                    if r is None:
                        continue
                    results[r.rid].append(int(tok_np[i]))
                    remaining[i] -= 1
                    if remaining[i] <= 0:
                        self._m.record_request(
                            r, admit_t.pop(r.rid, r.t_enqueue),
                            time.monotonic())
                        active[i] = None
                        done_any = True
                if done_any and len(queue):
                    break  # re-admit + re-prefill with new composition
                if not any(a is not None for a in active) or steps >= max_steps:
                    break
                logits, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
        return results
