"""Compatibility shims over the scenario pipeline (DESIGN.md §12).

The end-to-end cold-start experiment now lives in
:mod:`repro.scenarios` — declarative :class:`~repro.scenarios
.ScenarioConfig`s resolved by the :class:`~repro.scenarios
.ScenarioRegistry` into composed ``Data -> Tokenizer -> Index -> Train ->
Serve -> Eval`` stages, serving through the production
``ConstraintRegistry`` + ``DecodePolicy`` + engine stack (no hand-rolled
masking).  This module keeps the historical entry points alive:

  * :func:`run_cold_start_experiment` — the paper's §6 protocol, returning
    the same result keys as before (plus the new hit@M metrics), now a thin
    wrapper over the ``cold_start_amazon`` scenario.
  * :func:`gr_model_config` / :func:`train_rqvae` — re-exported from
    :mod:`repro.scenarios.stages`, their new home.

Prefer ``launch/run_scenario.py`` (or ``get_default_registry()`` directly)
for new code.
"""
from __future__ import annotations

from repro.scenarios.stages import gr_model_config, train_rqvae

__all__ = ["run_cold_start_experiment", "train_rqvae", "gr_model_config"]


def run_cold_start_experiment(
    cold_frac: float = 0.02,
    seed: int = 0,
    n_items: int | None = None,
    train_steps: int | None = None,
    beam_size: int | None = None,
    log=lambda *a: None,
    smoke: bool = False,
    trie_aware_weight: float = 0.0,
) -> dict:
    """Run the ``cold_start_amazon`` scenario; returns its result dict.

    Keys match the historical surface (``recall@1_unconstrained``,
    ``recall@1_constrained_random``, ``recall@1_static``, ``cold_frac``,
    ``n_cold``, ``n_test``) plus ``hit@M_static`` / ``hit@M_unconstrained``
    and the ``gates`` block from the scenario's EvalStage.  ``None`` sizes
    defer to the scenario config (the full-size defaults, or the smoke
    shrink under ``smoke=True``).
    """
    from repro.scenarios import get_default_registry

    overrides = {
        "data.cold_frac": cold_frac,
        "train.trie_aware_weight": trie_aware_weight,
    }
    if n_items is not None:
        overrides["data.n_items"] = n_items
    if train_steps is not None:
        overrides["train.steps"] = train_steps
    if beam_size is not None:
        overrides["serve.beam"] = beam_size
    run = get_default_registry().resolve(
        "cold_start_amazon", smoke=smoke, overrides=overrides, seed=seed,
    )
    ctx = run.run(log=log)
    return ctx["result"]
