"""End-to-end pipelines composing the full stack.

``run_cold_start_experiment`` is the paper's §6 protocol on synthetic
Amazon-like data: RQ-VAE tokenization (L=4, |V|=256) -> generative-retrieval
training on no-cold-start sequences -> Recall@1 on cold-start targets for
{unconstrained, constrained-random, STATIC}.  Used by
``benchmarks/table3_coldstart.py`` and ``examples/cold_start_amazon.py``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RQVAEConfig, TransformerConfig
from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.data.amazon import make_cold_start_dataset
from repro.data.loader import ShardedBatcher
from repro.models import rqvae, transformer
from repro.serving.generative_retrieval import GenerativeRetriever
from repro.training.optimizer import adamw
from repro.training.trainer import Trainer, TrainerConfig

__all__ = ["run_cold_start_experiment", "train_rqvae", "gr_model_config"]


def gr_model_config(vocab: int = 256, small: bool = True) -> TransformerConfig:
    return TransformerConfig(
        name="gr-coldstart",
        n_layers=4,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=vocab,
        head_dim=32,
        tie_embeddings=True,
        dtype="float32",
        attn_chunk_q=64,
        attn_chunk_kv=64,
    )


def train_rqvae(feats: np.ndarray, cfg: RQVAEConfig, steps: int = 400,
                seed: int = 0, log=lambda *a: None):
    params = rqvae.init_params(cfg, jax.random.key(seed))
    opt = adamw(lr=3e-3, weight_decay=0.0)
    state = opt.init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, batch, i):
        loss, g = jax.value_and_grad(
            lambda p: rqvae.rqvae_loss(p, batch, cfg)
        )(params)
        params, state = opt.update(g, state, params, i)
        return params, state, loss

    for i in range(steps):
        idx = rng.integers(0, feats.shape[0], 256)
        params, state, loss = step(
            params, state, jnp.asarray(feats[idx]), jnp.asarray(i)
        )
        if i % 100 == 0:
            log(f"rqvae step {i}: loss {float(loss):.4f}")
    return params


def run_cold_start_experiment(
    cold_frac: float = 0.02,
    seed: int = 0,
    n_items: int = 2_000,
    train_steps: int = 500,
    beam_size: int = 20,
    log=lambda *a: None,
) -> dict:
    data = make_cold_start_dataset(seed=seed, n_items=n_items,
                                   cold_frac=cold_frac)
    # L=4 total: 3 RQ-VAE levels + 1 deduplication token (TIGER's collision
    # fix — items sharing an RQ prefix get distinct final tokens, so every
    # item has a unique Semantic ID).
    rq_cfg = RQVAEConfig(feat_dim=data.item_feats.shape[1], n_levels=3,
                         codebook_size=256)
    rq_params = train_rqvae(data.item_feats, rq_cfg, log=log)
    sids3 = np.asarray(
        rqvae.encode_to_sids(rq_params, jnp.asarray(data.item_feats), rq_cfg)
    )  # (N, 3)
    order = np.lexsort(tuple(sids3[:, c] for c in range(2, -1, -1)))
    rank = np.zeros(n_items, np.int64)
    prev = None
    r = 0
    for i in order:
        cur = tuple(sids3[i])
        r = r + 1 if cur == prev else 0
        rank[i] = r
        prev = cur
    sids = np.concatenate(
        [sids3, (rank % rq_cfg.codebook_size)[:, None]], axis=1
    )  # (N, 4)
    L, V = 4, rq_cfg.codebook_size
    log(f"unique SIDs: {np.unique(sids, axis=0).shape[0]}/{n_items}")

    # --- tokenize sequences: item -> its L SID tokens, next-item LM loss ---
    cfg = gr_model_config(V)
    params = transformer.init_params(cfg, jax.random.key(seed + 1))

    def to_tokens(seqs):
        return sids[seqs].reshape(seqs.shape[0], -1).astype(np.int32)

    train_tokens = to_tokens(data.train_seqs)

    def loss_fn(p, batch):
        return transformer.lm_loss(p, batch["tokens"], cfg)

    trainer = Trainer(
        loss_fn, adamw(lr=1e-3, weight_decay=0.0), params,
        TrainerConfig(n_steps=train_steps, log_every=100),
    )
    batches = ShardedBatcher({"tokens": train_tokens}, global_batch=64,
                             seed=seed)
    trainer.fit(batches, log=log)

    # --- evaluation on cold-start targets (paper Table 3 protocol) ---
    cold_sids = sids[data.cold_items]
    tm = TransitionMatrix.from_sids(cold_sids, V, dense_d=2)
    test = data.test_seqs
    if test.shape[0] > 256:
        test = test[:256]
    hist_tokens = to_tokens(test[:, :-1])
    target_sids = sids[test[:, -1]]

    def recall_at_1(retriever) -> float:
        beams, scores = retriever.retrieve(hist_tokens)
        top = beams[:, 0, :]
        alive = scores[:, 0] > NEG_INF / 2
        hit = (top == target_sids).all(axis=1) & alive
        return float(hit.mean())

    gr_static = GenerativeRetriever(
        trainer.params, cfg, tm, sid_length=L, sid_vocab=V, beam_size=beam_size
    )
    gr_uncon = GenerativeRetriever(
        trainer.params, cfg, None, sid_length=L, sid_vocab=V, beam_size=beam_size
    )
    r_static = recall_at_1(gr_static)
    r_uncon = recall_at_1(gr_uncon)
    # constrained random guessing: uniform over the cold-start corpus
    rng = np.random.default_rng(seed + 7)
    guesses = cold_sids[rng.integers(0, cold_sids.shape[0], test.shape[0])]
    r_random = float((guesses == target_sids).all(axis=1).mean())

    return {
        "cold_frac": cold_frac,
        "n_cold": int(data.cold_items.shape[0]),
        "n_test": int(test.shape[0]),
        "recall@1_unconstrained": r_uncon,
        "recall@1_constrained_random": r_random,
        "recall@1_static": r_static,
    }
