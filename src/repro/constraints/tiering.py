"""HBM/host tiering for 100M+-SID tries (DESIGN.md §11).

A 100M-SID catalog's deep trie levels dominate the constraint footprint
(``K1 * min(V^l, |C|)`` bytes per level, paper Appendix B) while serving
touches only ``B*M`` of their rows per step.  This module splits the
canonical CSR slab at a level boundary:

  * **hot tier** — the dense band plus the first sparse levels stay
    device-resident; decode steps below the boundary run the ordinary
    :class:`~repro.decoding.DecodePolicy` (VNTK, candidate-topk, compressed
    slab — all unchanged, on a slab truncated to the hot prefix).  The
    level-major edge layout (``core.trie.LevelBlocks``) is what makes the
    truncation a single slice.
  * **cold tier** — deep levels live in host memory as numpy arrays.  For a
    cold step, the surviving beam nodes (known at the previous step's
    boundary) drive an async host gather of each beam's speculative
    ``(bmax, 2)`` edge burst — ``B*M*bmax`` entries, independent of catalog
    size — which overlaps the decoder's logits computation and lands on
    device as a pregathered slab for :func:`vntk_pregathered`.

Bit-identity: the host gather reproduces exactly the speculative window the
device kernel would have read (zero-filled out-of-range, like the oracle's
``mode="fill"`` gather), and :func:`vntk_pregathered` is the reference
scatter minus the table lookup — so tiered decoding matches
:func:`~repro.core.beam_search.beam_search` on the untiered policy bit for
bit (asserted in ``tests/test_tiering.py``).

The capacity model for the split lives in
:func:`repro.core.memory_model.plan_tiers`; :meth:`TieredTrie.tier_bytes`
reports the realized footprint.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trie import LevelBlocks, infer_level_blocks
from repro.core.transition_matrix import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.reliability.faults import InjectedFault, fire
from repro.reliability.retry import RetryPolicy

__all__ = [
    "TieredTrie",
    "TriePrefetcher",
    "vntk_pregathered",
    "tiered_beam_search",
]


@partial(jax.jit, static_argnames=("vocab",))
def vntk_pregathered(log_probs, gathered, lens, vocab: int):
    """Phases 2-4 of Alg. 2 on a pregathered speculative slab.

    ``gathered`` is the ``(nb, bmax, 2)`` stacked ``[token, next_state]``
    burst the host prefetcher staged (zero-filled outside each row's
    window) and ``lens`` the per-row child counts; the math below is
    :func:`~repro.core.vntk.vntk_reference_scatter` with the device-side
    table gather removed, so outputs are bit-identical to the untiered
    mask step.
    """
    V = vocab
    batch_shape = log_probs.shape[:-1]
    lp_flat = log_probs.reshape(-1, V)
    nb, bmax, _ = gathered.shape
    offsets = jnp.arange(bmax, dtype=jnp.int32)
    valid = offsets[None, :] < lens.reshape(-1)[:, None]
    cols = gathered[:, :, 0]
    nxt = jnp.where(valid, gathered[:, :, 1], 0)
    scatter_idx = jnp.where(valid, cols, V)
    rows = jnp.arange(nb)[:, None]
    cand_lp = jnp.take_along_axis(lp_flat, jnp.clip(cols, 0, V - 1), axis=1)
    masked = jnp.full((nb, V + 1), NEG_INF, dtype=log_probs.dtype)
    masked = masked.at[rows, scatter_idx].set(
        jnp.where(valid, cand_lp, NEG_INF))[:, :V]
    next_dense = jnp.zeros((nb, V + 1), dtype=jnp.int32)
    next_dense = next_dense.at[rows, scatter_idx].set(nxt)[:, :V]
    return (masked.reshape(batch_shape + (V,)),
            next_dense.reshape(batch_shape + (V,)))


@dataclasses.dataclass(frozen=True)
class TieredTrie:
    """Hot/cold split of a single TransitionMatrix at a level boundary.

    ``hot_steps`` is the first COLD decode step: steps ``< hot_steps`` are
    served by the device-resident policy, steps ``>= hot_steps`` by the
    host tier.  ``hot_steps == sid_length`` degenerates to fully-resident.
    """

    tm: TransitionMatrix  # the full matrix the split was derived from
    blocks: LevelBlocks
    hot_steps: int
    cold_base: int  # first cold edge index (== hot edge-prefix length)
    edges_cold: np.ndarray  # (E - cold_base, 2) int32, HOST memory
    row_pointers_host: np.ndarray  # (S+1,) HOST copy driving the prefetch

    @classmethod
    def from_matrix(
        cls,
        tm: TransitionMatrix,
        *,
        hot_steps: Optional[int] = None,
        hbm_budget: Optional[int] = None,
    ) -> "TieredTrie":
        """Split ``tm`` so steps ``>= hot_steps`` read from host memory.

        With ``hot_steps=None`` and an ``hbm_budget`` (bytes), picks the
        deepest boundary whose device bytes (dense tables + row pointers +
        hot edge prefix) fit; with neither, everything stays hot.
        """
        if tm.is_stacked:
            raise NotImplementedError(
                "tiering splits a single TransitionMatrix; tier each "
                "ConstraintStore member before stacking"
            )
        L = tm.sid_length
        d = min(tm.dense_d, L)
        rp = np.asarray(tm.row_pointers)
        edges = np.asarray(tm.edges)
        blocks = infer_level_blocks(
            rp, edges, n_states=tm.n_states, n_edges=tm.n_edges,
            sid_length=L, dense_d=tm.dense_d, vocab_size=tm.vocab_size,
        )
        if hot_steps is None:
            if hbm_budget is None:
                hot_steps = L
            else:
                fixed = tm.nbytes() - edges.nbytes  # dense tables + rp
                hot_steps = d
                for s in range(d, L):
                    prefix = int(blocks.edge_offsets[s + 1]) * 8
                    if fixed + prefix > hbm_budget:
                        break
                    hot_steps = s + 1
        hot_steps = max(d, min(int(hot_steps), L))
        cold_base = int(blocks.edge_offsets[hot_steps])
        return cls(
            tm=tm,
            blocks=blocks,
            hot_steps=hot_steps,
            cold_base=cold_base,
            edges_cold=np.ascontiguousarray(
                edges[cold_base: tm.n_edges], dtype=np.int32
            ),
            row_pointers_host=np.asarray(rp, dtype=np.int64),
        )

    def hot_policy(self, *, impl: str = "xla", topk: bool = True,
                   compressed: bool = False):
        """DecodePolicy for the hot steps, its edge slab cut at the boundary.

        Built from the full matrix (so the compressed slab, plan, and
        static metadata are the canonical ones), then every backend's
        ``edges`` / ``tok_delta`` leaf is sliced to the hot prefix — the
        level-major layout guarantees steps ``< hot_steps`` never index
        past it, and the XLA references zero-fill any speculative
        over-read.  Pallas DMA has no out-of-range story, so the tiered
        driver is XLA-only.
        """
        from repro.decoding.backends import StaticBackend
        from repro.decoding.policy import DecodePolicy

        if impl != "xla":
            raise ValueError(
                "tiered decoding drives the XLA references; impl='pallas' "
                "would DMA past the truncated hot slab"
            )
        pol = DecodePolicy.static(
            self.tm, impl=impl, fused=False, topk=topk,
            compressed=compressed,
        )
        cut = max(self.cold_base, 1)  # keep a non-empty gather axis

        def trunc(b):
            if not isinstance(b, StaticBackend):
                return b
            tm2 = dataclasses.replace(b.tm, edges=b.tm.edges[:cut])
            slab2 = (dataclasses.replace(
                b.slab, tok_delta=b.slab.tok_delta[:cut])
                if b.slab is not None else None)
            return dataclasses.replace(b, tm=tm2, slab=slab2)

        return dataclasses.replace(
            pol, backends=tuple(trunc(b) for b in pol.backends)
        )

    def tier_bytes(self) -> dict:
        """Realized footprint of the split (cf. ``memory_model.plan_tiers``)."""
        edges_nb = int(np.asarray(self.tm.edges).nbytes)
        hot_edges = int(self.cold_base) * 8
        fixed = self.tm.nbytes() - edges_nb
        return dict(
            hot_steps=int(self.hot_steps),
            cold_base=int(self.cold_base),
            hbm_bytes=int(fixed + hot_edges),
            host_bytes=int(self.edges_cold.nbytes),
        )

    def gather_cold(self, nodes: np.ndarray, step: int):
        """Host-side speculative burst for a cold step's beam nodes.

        Returns ``(gathered (nb, bmax, 2) int32, lens (nb,) int32)`` —
        exactly the window the device oracle's ``mode="fill"`` gather
        would read (zeros outside the slab), so the downstream scatter is
        bit-identical.
        """
        if step < self.hot_steps:
            raise ValueError(f"step {step} is hot (< {self.hot_steps})")
        bmax = max(self.tm.bmax_for_step(step), 1)
        n = np.asarray(nodes, dtype=np.int64).reshape(-1)
        rp = self.row_pointers_host
        starts = rp[n]
        lens = rp[n + 1] - starts
        idx = starts[:, None] + np.arange(bmax, dtype=np.int64)[None, :]
        rel = idx - self.cold_base
        n_cold = self.edges_cold.shape[0]
        in_range = (rel >= 0) & (rel < n_cold)
        g = self.edges_cold[np.clip(rel, 0, max(n_cold - 1, 0))]
        g[~in_range] = 0
        return g.astype(np.int32), lens.astype(np.int32)


class TriePrefetcher:
    """Async host->device staging of cold-tier bursts (DESIGN.md §11).

    One background worker overlaps the host gather + transfer with the
    decoder's logits computation: the nodes surviving step ``t-1`` fully
    determine step ``t``'s speculative window, so the prefetch is issued
    the moment the previous beam advance is *dispatched* (JAX's async
    dispatch means the worker's ``np.asarray(nodes)`` blocks only until
    that one array materializes, not the whole step).

    A stalling or failing host fetch (the ``tiering.host_fetch`` fault
    point) is retried under ``retry`` — a
    :class:`~repro.reliability.RetryPolicy` covering transient I/O-shaped
    errors; the retries happen on the worker thread, inside the prefetch
    overlap window, so a recovered fetch costs the decode loop nothing
    unless the backoff outlives the overlapped step.  A terminal failure
    surfaces through the future at ``result()`` — the beam search stops
    rather than decode past the constraint (DESIGN.md §13: degradation
    never falls back to unconstrained decoding).
    """

    def __init__(self, tiered: TieredTrie, *,
                 retry: Optional[RetryPolicy] = None, metrics=None):
        self.tiered = tiered
        self.retry = retry if retry is not None else RetryPolicy(
            max_attempts=3, base_delay_s=0.002, max_delay_s=0.05,
            retryable=(InjectedFault, OSError, MemoryError))
        self._m_retries = None
        if metrics is not None:
            self._m_retries = metrics.counter(
                "tiering_fetch_retries_total",
                "host-tier gathers retried after a transient failure")
        self._pool = ThreadPoolExecutor(max_workers=1)

    def prefetch(self, nodes, step: int):
        """Stage the burst for ``nodes`` at cold ``step``; returns a future
        resolving to device arrays ``(gathered, lens)``."""
        def gather():
            fire("tiering.host_fetch")
            return self.tiered.gather_cold(np.asarray(nodes), step)

        def on_retry(attempt, e):
            if self._m_retries is not None:
                self._m_retries.inc()

        def work():
            g, lens = self.retry.call(gather, on_retry=on_retry)
            return jax.device_put(g), jax.device_put(lens)

        return self._pool.submit(work)

    def close(self):
        self._pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def tiered_beam_search(
    logits_fn,
    carry,
    batch_size: int,
    beam_size: int,
    length: int,
    tiered: TieredTrie,
    *,
    policy=None,
    prefetcher: Optional[TriePrefetcher] = None,
):
    """Constrained beam search over a tiered trie (Alg. 1, host cold tier).

    Hot steps run ``policy`` (default: ``tiered.hot_policy()``) exactly as
    :func:`~repro.core.beam_search.beam_search` would; cold steps consume
    the prefetcher's pregathered slab through :func:`vntk_pregathered`.
    The loop is a host loop (the cold gather is host work), so it cannot
    sit under one ``jax.jit`` — each step's device math is jitted
    per-level like the eager search.  Returns ``(BeamState, carry)``,
    bit-identical to the untiered search.
    """
    from repro.core.beam_search import BeamState, _init_state

    if policy is None:
        policy = tiered.hot_policy()
    own_prefetcher = prefetcher is None
    if own_prefetcher:
        prefetcher = TriePrefetcher(tiered)
    B, M = batch_size, beam_size
    state = _init_state(B, M, length)
    pending = None  # in-flight prefetch for the next cold step
    try:
        for step in range(length):
            last = (state.tokens[:, :, step - 1] if step > 0
                    else jnp.zeros((B, M), jnp.int32))
            logits, carry = logits_fn(carry, last, step)
            V = logits.shape[-1]
            batch_ix = jnp.arange(B)[:, None]
            if step < tiered.hot_steps:
                if policy.supports_topk_at(step):
                    C = policy.candidate_width(M, step)
                    c_lp, c_tok, c_next = policy.step_topk(
                        logits, state.nodes, step, C)
                    total = state.scores[:, :, None] + c_lp
                    top_scores, top_idx = jax.lax.top_k(
                        total.reshape(B, M * C), M)
                    beam_idx = top_idx // C
                    token = jnp.take_along_axis(
                        c_tok.reshape(B, M * C), top_idx, axis=1
                    ).astype(jnp.int32)
                    new_nodes = jnp.take_along_axis(
                        c_next.reshape(B, M * C), top_idx, axis=1)
                else:
                    lp, next_dense = policy.step(logits, state.nodes, step)
                    total = state.scores[:, :, None] + lp
                    top_scores, top_idx = jax.lax.top_k(
                        total.reshape(B, M * V), M)
                    beam_idx = top_idx // V
                    token = (top_idx % V).astype(jnp.int32)
                    new_nodes = next_dense[batch_ix, beam_idx, token]
            else:
                if pending is None:  # first cold step: no overlap possible
                    pending = prefetcher.prefetch(state.nodes, step)
                gathered, lens = pending.result()
                pending = None
                lp_norm = jax.nn.log_softmax(
                    logits.astype(jnp.float32), axis=-1)
                lp, next_dense = vntk_pregathered(lp_norm, gathered, lens, V)
                next_dense = next_dense.reshape(B, M, V)
                total = state.scores[:, :, None] + lp.reshape(B, M, V)
                top_scores, top_idx = jax.lax.top_k(
                    total.reshape(B, M * V), M)
                beam_idx = top_idx // V
                token = (top_idx % V).astype(jnp.int32)
                new_nodes = next_dense[batch_ix, beam_idx, token]

            new_tokens = state.tokens[batch_ix, beam_idx]
            new_tokens = new_tokens.at[:, :, step].set(token)
            state = BeamState(
                tokens=new_tokens, scores=top_scores, nodes=new_nodes)
            if step + 1 >= tiered.hot_steps and step + 1 < length:
                # overlap: next step's window depends only on these nodes
                pending = prefetcher.prefetch(state.nodes, step + 1)
    finally:
        if own_prefetcher:
            prefetcher.close()
    return state, carry
