"""Multi-tenant constraint serving (beyond-paper subsystem; DESIGN.md §4).

The paper serves ONE static restricted vocabulary per engine.  Production
recommenders restrict the output space per request ("business logic, e.g.
enforcing content freshness or product category", paper §1) — so a single
batch must be maskable under *different* constraint sets simultaneously.

Public surface:
  * ``ConstraintStore``     — K TransitionMatrix instances packed into one
                              stacked, replicated device pytree; lookups take
                              a per-row ``constraint_ids`` tensor.
  * ``ConstraintRegistry``  — named business predicates -> built matrices,
                              with integer versioning and double-buffered
                              hot-swap at fixed static shapes.
  * ``ItemCatalog``         — the item-metadata snapshot predicates run on.
  * ``freshness_window`` / ``category_allowlist`` — built-in predicates.
"""
from repro.constraints.registry import (
    ConstraintRegistry,
    ItemCatalog,
    category_allowlist,
    freshness_window,
    synthetic_catalog,
)
from repro.constraints.store import ConstraintStore

__all__ = [
    "ConstraintStore",
    "ConstraintRegistry",
    "ItemCatalog",
    "freshness_window",
    "category_allowlist",
    "synthetic_catalog",
]
