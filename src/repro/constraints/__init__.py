"""Multi-tenant constraint serving (beyond-paper subsystem; DESIGN.md §4).

The paper serves ONE static restricted vocabulary per engine.  Production
recommenders restrict the output space per request ("business logic, e.g.
enforcing content freshness or product category", paper §1) — so a single
batch must be maskable under *different* constraint sets simultaneously.

Public surface:
  * ``ConstraintStore``     — K TransitionMatrix instances packed into one
                              stacked, replicated device pytree; lookups take
                              a per-row ``constraint_ids`` tensor.
  * ``ConstraintRegistry``  — named business predicates -> built matrices,
                              with integer versioning and double-buffered
                              hot-swap at fixed static shapes.
  * ``ItemCatalog``         — the item-metadata snapshot predicates run on.
  * ``CatalogDelta``        — incremental churn (items in / SIDs out) for the
                              O(churn) ``swap_delta`` refresh path.
  * ``TrieSource``          — retained sorted-slab builder state; delta-aware
                              re-flattening bit-identical to a full rebuild.
  * ``AsyncRefresher``      — background rebuild + step-boundary hot-swap
                              pipeline with coalescing and backpressure.
  * ``EnvelopeOverflow``    — a refresh outgrew the capacity envelope (the
                              registry turns this into a cold regrow swap).
  * ``freshness_window`` / ``category_allowlist`` — built-in predicates.
  * ``TieredTrie`` / ``TriePrefetcher`` / ``tiered_beam_search`` — HBM/host
                              tiering for 100M+-SID catalogs (DESIGN.md §11).
"""
from repro.constraints.refresh import AsyncRefresher, TrieSource
from repro.constraints.registry import (
    CatalogDelta,
    ConstraintRegistry,
    ItemCatalog,
    category_allowlist,
    freshness_window,
    synthetic_catalog,
)
from repro.constraints.store import ConstraintStore, EnvelopeOverflow
from repro.constraints.tiering import (
    TieredTrie,
    TriePrefetcher,
    tiered_beam_search,
)

__all__ = [
    "ConstraintStore",
    "ConstraintRegistry",
    "ItemCatalog",
    "CatalogDelta",
    "TrieSource",
    "AsyncRefresher",
    "EnvelopeOverflow",
    "freshness_window",
    "category_allowlist",
    "synthetic_catalog",
    "TieredTrie",
    "TriePrefetcher",
    "tiered_beam_search",
]
