"""Named business-constraint registry with versioned hot-swap (DESIGN.md §4).

Production constraint sets are *derived* objects: a business predicate
(freshness window, category allowlist, ...) evaluated over the current item
catalog snapshot.  The registry owns that mapping:

  * ``register(name, predicate)``   — claim a slot for a named predicate.
  * ``build(catalog)``              — evaluate all predicates, build the
                                      per-slot TransitionMatrix instances, and
                                      pack them into one ConstraintStore
                                      (with headroom, see below).
  * ``swap(catalog)``               — double-buffered refresh: rebuild every
                                      member from a NEW catalog snapshot into
                                      the SAME capacity envelope, then flip
                                      the front buffer atomically and bump the
                                      integer version.  Static shapes are
                                      preserved, so jitted decode steps keyed
                                      on the store never recompile; serving
                                      picks the new store up at its next step
                                      boundary.

Headroom makes the envelope forgiving: a refreshed corpus that grew by less
than ``headroom`` x still fits.  A snapshot that outgrows the envelope makes
``swap`` raise *before* the front buffer is touched (the old store keeps
serving) — the operator then rebuilds with a bigger envelope offline.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional

import numpy as np

from repro.constraints.store import ConstraintStore
from repro.core.transition_matrix import TransitionMatrix

__all__ = [
    "ItemCatalog",
    "ConstraintRegistry",
    "freshness_window",
    "category_allowlist",
    "synthetic_catalog",
]


@dataclasses.dataclass(frozen=True)
class ItemCatalog:
    """Immutable item-metadata snapshot predicates are evaluated against."""

    sids: np.ndarray  # (N, L) Semantic IDs of every servable item
    age_days: np.ndarray  # (N,) content age
    category: np.ndarray  # (N,) int category id

    def __post_init__(self):
        n = self.sids.shape[0]
        if self.age_days.shape != (n,) or self.category.shape != (n,):
            raise ValueError("catalog metadata must be per-item (N,) arrays")


Predicate = Callable[[ItemCatalog], np.ndarray]  # -> (N,) bool item mask


def freshness_window(max_age_days: float) -> Predicate:
    """Items no older than ``max_age_days`` (paper §1: content freshness)."""
    return lambda cat: cat.age_days <= max_age_days


def category_allowlist(*categories: int) -> Predicate:
    """Items whose category is in the allowlist (paper §1: product category)."""
    cats = np.asarray(categories)
    return lambda cat: np.isin(cat.category, cats)


def synthetic_catalog(
    rng: np.random.Generator, n_items: int, vocab_size: int, sid_length: int,
    n_categories: int = 8, max_age_days: float = 90.0,
) -> ItemCatalog:
    """Random catalog for examples/benchmarks/CLI smoke runs."""
    return ItemCatalog(
        sids=rng.integers(0, vocab_size, size=(n_items, sid_length)),
        age_days=rng.uniform(0.0, max_age_days, size=n_items),
        category=rng.integers(0, n_categories, size=n_items),
    )


class ConstraintRegistry:
    """Slot-addressed predicate registry over a double-buffered store."""

    def __init__(self, vocab_size: int, *, dense_d: int = 2,
                 headroom: float = 0.5):
        self.vocab_size = vocab_size
        self.dense_d = dense_d
        self.headroom = headroom
        self._names: list[str] = []
        self._predicates: dict[str, Predicate] = {}
        self._front: Optional[ConstraintStore] = None
        self._version = 0
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def register(self, name: str, predicate: Predicate) -> int:
        """Claim the next slot for ``name``; returns its constraint id."""
        if name in self._predicates:
            raise ValueError(f"predicate {name!r} already registered")
        if self._front is not None:
            raise RuntimeError(
                "cannot register after build(): slot ids are baked into "
                "in-flight requests"
            )
        self._names.append(name)
        self._predicates[name] = predicate
        return len(self._names) - 1

    def slot(self, name: str) -> int:
        return self._names.index(name)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self._names)

    @property
    def version(self) -> int:
        return self._version

    # ------------------------------------------------------------------
    def _build_matrices(self, catalog: ItemCatalog) -> list[TransitionMatrix]:
        mats = []
        for name in self._names:
            mask = np.asarray(self._predicates[name](catalog), bool)
            if mask.shape != (catalog.sids.shape[0],):
                raise ValueError(f"predicate {name!r} returned a non-item mask")
            if not mask.any():
                raise ValueError(
                    f"predicate {name!r} selects zero items in this snapshot"
                )
            mats.append(
                TransitionMatrix.from_sids(
                    catalog.sids[mask], self.vocab_size, dense_d=self.dense_d
                )
            )
        return mats

    def build(self, catalog: ItemCatalog) -> ConstraintStore:
        """Initial (version 1) store from the first catalog snapshot."""
        if not self._names:
            raise RuntimeError("no predicates registered")
        if self._front is not None:
            raise RuntimeError("already built; use swap() to refresh")
        store = ConstraintStore.from_matrices(
            self._build_matrices(catalog), headroom=self.headroom
        )
        with self._lock:
            self._front = store
            self._version = 1
        return store

    def swap(self, catalog: ItemCatalog) -> int:
        """Refresh every slot from a new snapshot; returns the new version.

        Double-buffered: the replacement store is fully built (and validated
        against the capacity envelope) before the front pointer flips, so
        concurrent readers only ever observe a complete store.
        """
        if self._front is None:
            raise RuntimeError("swap() before build()")
        # one-shot bulk replace: validates all slots against the envelope,
        # then builds the back buffer with a single store copy
        back = self._front.with_members(self._build_matrices(catalog))
        with self._lock:
            self._front = back
            self._version += 1
        return self._version

    def current(self) -> tuple[ConstraintStore, int]:
        """The live (store, version) pair; atomic with respect to swap()."""
        with self._lock:
            if self._front is None:
                raise RuntimeError("registry not built yet")
            return self._front, self._version
