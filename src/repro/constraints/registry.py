"""Named business-constraint registry with versioned hot-swap (DESIGN.md §4, §7).

Production constraint sets are *derived* objects: a business predicate
(freshness window, category allowlist, ...) evaluated over the current item
catalog snapshot.  The registry owns that mapping:

  * ``register(name, predicate)``   — claim a slot for a named predicate.
  * ``build(catalog)``              — evaluate all predicates, build the
                                      per-slot TransitionMatrix instances, and
                                      pack them into one ConstraintStore
                                      (with headroom, see below).
  * ``swap(catalog)``               — double-buffered full refresh: rebuild
                                      every member from a NEW catalog snapshot
                                      into the SAME capacity envelope, then
                                      flip the front buffer atomically and
                                      bump the integer version.
  * ``swap_delta(delta)``           — O(churn) refresh: splice a
                                      :class:`CatalogDelta` into each slot's
                                      retained :class:`TrieSource` instead of
                                      re-sorting the whole catalog; bit-
                                      identical to a full ``swap`` over the
                                      post-delta snapshot (DESIGN.md §7).

Headroom makes the envelope forgiving: a refreshed corpus that grew by less
than ``headroom`` x still fits and the swap is **hot** (static shapes
preserved — jitted decode steps keyed on the store never recompile; serving
picks the new store up at its next step boundary).  A snapshot that outgrows
the envelope no longer raises to the operator: by default the registry
*regrows* — it builds a store with a larger envelope from the same matrices
and installs it as a **cold swap** (``envelope_generation`` bumps; engines
re-specialize on the new static metadata, exactly one recompile) — while the
old store keeps serving until the flip.  Pass ``on_overflow="raise"`` to get
the old fail-fast behavior.

Threading contract (needed by :class:`~repro.constraints.refresh
.AsyncRefresher`, which calls ``swap``/``swap_delta`` from its worker
thread while serving threads call ``current()``):

  * ``_lock`` guards the small shared state — ``_front``, ``_version``,
    ``_envelope_generation``, ``_names``, ``_predicates``.  It is held only
    for quick reads/writes, never across a build.
  * ``_refresh_lock`` serializes the builders (``build``/``swap``/
    ``swap_delta``) and guards the retained ``_sources``/``_mats``.  A
    builder acquires ``_lock`` only for the final front-buffer flip, so
    readers never block on a rebuild.
  * ``current()`` returns a consistent ``(store, version)`` pair; stores are
    immutable pytrees, so a reader can keep using a snapshot after a flip.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.constraints.refresh import TrieSource, row_keys
from repro.constraints.store import ConstraintStore, EnvelopeOverflow
from repro.core.transition_matrix import TransitionMatrix
from repro.observability import MetricsRegistry
from repro.reliability.faults import fire

__all__ = [
    "ItemCatalog",
    "CatalogDelta",
    "ConstraintRegistry",
    "freshness_window",
    "category_allowlist",
    "synthetic_catalog",
]


def _check_sid_width(sids: np.ndarray, width: int, what: str) -> None:
    """SID-width mismatches must fail loudly: the byte row keys used for
    set membership null-pad the shorter side, so comparing keys of
    different widths silently matches (and deletes) the WRONG items."""
    if sids.shape[1] != width:
        raise ValueError(
            f"{what} has sid_length {sids.shape[1]}, expected {width}"
        )


@dataclasses.dataclass(frozen=True)
class ItemCatalog:
    """Immutable item-metadata snapshot predicates are evaluated against."""

    sids: np.ndarray  # (N, L) Semantic IDs of every servable item
    age_days: np.ndarray  # (N,) content age
    category: np.ndarray  # (N,) int category id

    def __post_init__(self):
        n = self.sids.shape[0]
        if self.age_days.shape != (n,) or self.category.shape != (n,):
            raise ValueError("catalog metadata must be per-item (N,) arrays")

    def select(self, mask: np.ndarray) -> "ItemCatalog":
        """Row-filtered copy (predicate masks, delta composition)."""
        return ItemCatalog(sids=self.sids[mask], age_days=self.age_days[mask],
                           category=self.category[mask])

    def apply_delta(self, delta: "CatalogDelta") -> "ItemCatalog":
        """The snapshot this catalog becomes after ``delta``.

        Removals (matched by SID) apply first, then additions are appended —
        mirroring the registry's ``swap_delta`` semantics, so
        ``reg.swap_delta(d)`` and ``reg.swap(catalog.apply_delta(d))`` land
        bit-identical stores (asserted in ``tests/test_refresh.py``).
        Assumes SIDs uniquely identify items (the TIGER dedup-token
        contract); metadata updates are expressed as remove + add.
        """
        out = self
        if delta.removed_sids is not None and len(delta.removed_sids):
            _check_sid_width(delta.removed_sids, self.sids.shape[1],
                             "removed_sids")
            rk = np.unique(row_keys(
                np.asarray(delta.removed_sids, dtype=np.int64)))
            keep = ~np.isin(row_keys(out.sids.astype(np.int64)), rk)
            out = out.select(keep)
        if delta.added is not None and delta.added.sids.shape[0]:
            a = delta.added
            _check_sid_width(a.sids, self.sids.shape[1], "added.sids")
            out = ItemCatalog(
                sids=np.concatenate([out.sids, a.sids]),
                age_days=np.concatenate([out.age_days, a.age_days]),
                category=np.concatenate([out.category, a.category]),
            )
        return out


@dataclasses.dataclass(frozen=True)
class CatalogDelta:
    """Incremental catalog churn: items entering and SIDs leaving.

    ``added`` carries full metadata (predicates run on the new items only);
    ``removed_sids`` is a plain (R, L) SID array — removal needs no
    metadata.  Within one delta, removals apply before additions, so a SID
    in both ends up present (with the new metadata).
    """

    added: Optional[ItemCatalog] = None
    removed_sids: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.removed_sids is not None:
            r = np.asarray(self.removed_sids)
            if r.ndim != 2:
                raise ValueError(
                    f"removed_sids must be (R, L), got shape {r.shape}"
                )
            if self.added is not None:
                _check_sid_width(r, self.added.sids.shape[1], "removed_sids")

    @property
    def is_empty(self) -> bool:
        return (
            (self.added is None or self.added.sids.shape[0] == 0)
            and (self.removed_sids is None or len(self.removed_sids) == 0)
        )

    def compose(self, later: "CatalogDelta") -> "CatalogDelta":
        """Sequential composition: ``self`` applied first, then ``later``.

        Used by the AsyncRefresher to coalesce queued deltas: removals
        union; additions that ``later`` removes again are dropped; within
        each apply, removals still precede additions, so re-added SIDs
        survive.  ``compose`` then apply-once equals apply-``self``-then-
        apply-``later`` (asserted in ``tests/test_refresh.py``).
        """
        rm_parts = [
            np.asarray(d.removed_sids, dtype=np.int64)
            for d in (self, later)
            if d.removed_sids is not None and len(d.removed_sids)
        ]
        removed = (np.unique(np.concatenate(rm_parts), axis=0)
                   if rm_parts else None)
        added = self.added
        if (added is not None and added.sids.shape[0]
                and later.removed_sids is not None
                and len(later.removed_sids)):
            later_rm = np.asarray(later.removed_sids)
            _check_sid_width(later_rm, added.sids.shape[1],
                             "later.removed_sids")
            rk = np.unique(row_keys(later_rm.astype(np.int64)))
            added = added.select(
                ~np.isin(row_keys(added.sids.astype(np.int64)), rk)
            )
        adds = [a for a in (added, later.added)
                if a is not None and a.sids.shape[0]]
        if len(adds) == 2:
            merged = ItemCatalog(
                sids=np.concatenate([a.sids for a in adds]),
                age_days=np.concatenate([a.age_days for a in adds]),
                category=np.concatenate([a.category for a in adds]),
            )
        else:
            merged = adds[0] if adds else None
        return CatalogDelta(added=merged, removed_sids=removed)


Predicate = Callable[[ItemCatalog], np.ndarray]  # -> (N,) bool item mask


def freshness_window(max_age_days: float) -> Predicate:
    """Items no older than ``max_age_days`` (paper §1: content freshness)."""
    return lambda cat: cat.age_days <= max_age_days


def category_allowlist(*categories: int) -> Predicate:
    """Items whose category is in the allowlist (paper §1: product category)."""
    cats = np.asarray(categories)
    return lambda cat: np.isin(cat.category, cats)


def synthetic_catalog(
    rng: np.random.Generator, n_items: int, vocab_size: int, sid_length: int,
    n_categories: int = 8, max_age_days: float = 90.0,
) -> ItemCatalog:
    """Random catalog for examples/benchmarks/CLI smoke runs."""
    return ItemCatalog(
        sids=rng.integers(0, vocab_size, size=(n_items, sid_length)),
        age_days=rng.uniform(0.0, max_age_days, size=n_items),
        category=rng.integers(0, n_categories, size=n_items),
    )


class ConstraintRegistry:
    """Slot-addressed predicate registry over a double-buffered store."""

    def __init__(self, vocab_size: int, *, dense_d: int = 2,
                 headroom: float = 0.5,
                 metrics: Optional[MetricsRegistry] = None):
        self.vocab_size = vocab_size
        self.dense_d = dense_d
        self.headroom = headroom
        self._names: list[str] = []
        self._predicates: dict[str, Predicate] = {}
        self._front: Optional[ConstraintStore] = None
        self._version = 0
        self._envelope_generation = 0
        self._lock = threading.Lock()
        # serializes build/swap/swap_delta and guards _sources/_mats
        self._refresh_lock = threading.Lock()
        self._sources: list[TrieSource] = []
        self._mats: list[TransitionMatrix] = []
        # telemetry (DESIGN.md §9) — all host-side, recorded on the refresh
        # path only (never consulted by readers / serving engines)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_refresh_s = self.metrics.histogram(
            "constraint_refresh_seconds",
            "wall time of one registry refresh, by kind")
        self._m_swaps = self.metrics.counter(
            "constraint_swaps_total",
            "front-buffer flips, by kind and hot/cold")
        self._m_version = self.metrics.gauge(
            "constraint_store_version", "front-buffer version")
        self._m_generation = self.metrics.gauge(
            "constraint_envelope_generation",
            "capacity-envelope generation (bumps on cold swaps)")
        self._m_states_frac = self.metrics.gauge(
            "constraint_envelope_states_used_frac",
            "largest member n_states over the envelope capacity — headroom "
            "left before the next swap goes cold")
        self._m_edges_frac = self.metrics.gauge(
            "constraint_envelope_edges_used_frac",
            "largest member n_edges over the envelope edge capacity")
        self._m_store_bytes = self.metrics.gauge(
            "constraint_store_bytes", "device bytes of the front store")
        self._m_slot_sids = self.metrics.gauge(
            "constraint_slot_sids", "live SIDs per predicate slot")
        self._m_slot_util = self.metrics.gauge(
            "constraint_slot_utilization_frac",
            "measured slab bytes over the Appendix-B u_max bound, per slot")

    def _record_store(self, store: ConstraintStore, version: int,
                      names: list[str]) -> None:
        """Publish envelope-headroom + slab-utilization gauges (refresh path)."""
        from repro.core.memory_model import measure  # lazy: import cycle risk

        self._m_version.set(version)
        self._m_generation.set(self._envelope_generation)
        self._m_store_bytes.set(store.nbytes())
        ms = np.asarray(store.member_n_states)
        me = np.asarray(store.member_n_edges)
        self._m_states_frac.set(float(ms.max()) / max(store.n_states, 1))
        self._m_edges_frac.set(float(me.max()) / max(store.n_edges, 1))
        for i, name in enumerate(names):
            if i < len(self._sources):
                self._m_slot_sids.set(self._sources[i].n_sids, slot=name)
            if i < len(self._mats):
                self._m_slot_util.set(
                    measure(self._mats[i])["utilization"], slot=name)

    # ------------------------------------------------------------------
    def register(self, name: str, predicate: Predicate) -> int:
        """Claim the next slot for ``name``; returns its constraint id."""
        with self._lock:
            if name in self._predicates:
                raise ValueError(f"predicate {name!r} already registered")
            if self._front is not None:
                raise RuntimeError(
                    "cannot register after build(): slot ids are baked into "
                    "in-flight requests"
                )
            self._names.append(name)
            self._predicates[name] = predicate
            return len(self._names) - 1

    def slot(self, name: str) -> int:
        with self._lock:
            return self._names.index(name)

    @property
    def names(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(self._names)

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    @property
    def envelope_generation(self) -> int:
        """Bumps on every cold (regrown-envelope) swap; 1 after build()."""
        with self._lock:
            return self._envelope_generation

    # ------------------------------------------------------------------
    def _eval_predicate(self, name: str, catalog: ItemCatalog) -> np.ndarray:
        mask = np.asarray(self._predicates[name](catalog), bool)
        if mask.shape != (catalog.sids.shape[0],):
            raise ValueError(f"predicate {name!r} returned a non-item mask")
        return mask

    def _build_slots(self, catalog: ItemCatalog, names: list[str]):
        """Full rebuild of every slot: (sources, matrices)."""
        sources, mats = [], []
        for name in names:
            mask = self._eval_predicate(name, catalog)
            if not mask.any():
                raise ValueError(
                    f"predicate {name!r} selects zero items in this snapshot"
                )
            src = TrieSource.from_sids(
                catalog.sids[mask], self.vocab_size, dense_d=self.dense_d
            )
            sources.append(src)
            mats.append(TransitionMatrix.from_flat_trie(src.flatten()))
        return sources, mats

    def _fit_or_regrow(self, front: ConstraintStore, mats, on_overflow: str):
        """Back buffer for ``mats``: hot (same envelope) or cold (regrown)."""
        if on_overflow not in ("regrow", "raise"):
            raise ValueError("on_overflow must be 'regrow' or 'raise'")
        try:
            return front.with_members(mats), False
        except EnvelopeOverflow:
            if on_overflow == "raise":
                raise
        # cold path: a fresh envelope (with headroom) from the same
        # matrices — built HERE, off the serving path; the flip hands
        # engines a store with new static metadata and they re-specialize
        # exactly once (tests/test_refresh.py counts the compiles)
        return ConstraintStore.from_matrices(mats, headroom=self.headroom), True

    def _flip(self, back: ConstraintStore, cold: bool) -> int:
        with self._lock:
            self._front = back
            self._version += 1
            if cold:
                self._envelope_generation += 1
            return self._version

    # ------------------------------------------------------------------
    def build(self, catalog: ItemCatalog) -> ConstraintStore:
        """Initial (version 1) store from the first catalog snapshot."""
        with self._refresh_lock:
            with self._lock:
                if not self._names:
                    raise RuntimeError("no predicates registered")
                if self._front is not None:
                    raise RuntimeError("already built; use swap() to refresh")
                names = list(self._names)
            t0 = time.monotonic()
            sources, mats = self._build_slots(catalog, names)
            store = ConstraintStore.from_matrices(mats, headroom=self.headroom)
            with self._lock:
                self._front = store
                self._version = 1
                self._envelope_generation = 1
            self._sources, self._mats = sources, mats
            self._m_refresh_s.observe(time.monotonic() - t0, kind="build")
            self._m_swaps.inc(kind="build", cold="true")
            self._record_store(store, 1, names)
            return store

    def swap(self, catalog: ItemCatalog, *,
             on_overflow: str = "regrow") -> int:
        """Full refresh of every slot from a new snapshot; returns the
        new version.

        Double-buffered: the replacement store is fully built (and checked
        against the capacity envelope) before the front pointer flips, so
        concurrent readers only ever observe a complete store.  An
        outgrown envelope regrows into a cold swap by default (see module
        docstring); ``on_overflow="raise"`` restores fail-fast.
        """
        with self._refresh_lock:
            with self._lock:
                if self._front is None:
                    raise RuntimeError("swap() before build()")
                front = self._front
                names = list(self._names)
            t0 = time.monotonic()
            fire("refresh.build")
            sources, mats = self._build_slots(catalog, names)
            back, cold = self._fit_or_regrow(front, mats, on_overflow)
            # transactional by construction: a failure at (or before) this
            # point leaves front buffer, retained sources and matrices
            # untouched — serving continues on the last good version
            fire("refresh.swap")
            version = self._flip(back, cold)
            self._sources, self._mats = sources, mats
            self._m_refresh_s.observe(time.monotonic() - t0, kind="snapshot")
            self._m_swaps.inc(kind="snapshot",
                              cold="true" if cold else "false")
            self._record_store(back, version, names)
            return version

    def swap_delta(self, delta: CatalogDelta, *,
                   on_overflow: str = "regrow") -> int:
        """O(churn) refresh: splice ``delta`` into every slot's retained
        :class:`TrieSource`; returns the (possibly unchanged) version.

        Predicates run on ``delta.added`` only; ``delta.removed_sids`` is
        dropped from every slot (absent SIDs are no-ops).  Slots the delta
        does not touch reuse their cached matrices — no rebuild, no device
        upload.  Bit-identical to ``swap(catalog.apply_delta(delta))``
        provided SIDs uniquely identify items and predicates are
        *item-local* (a row's verdict depends only on its own metadata) and
        stable on unchanged items between refreshes; predicates that drift
        with time (e.g. freshness re-evaluated much later) should be
        reconciled with a periodic full ``swap``.
        """
        with self._refresh_lock:
            with self._lock:
                if self._front is None:
                    raise RuntimeError("swap_delta() before build()")
                front = self._front
                names = list(self._names)
            if delta.is_empty:
                self._m_swaps.inc(kind="delta", cold="noop")
                with self._lock:
                    return self._version
            t0 = time.monotonic()
            fire("refresh.build")
            added = delta.added
            # STAGE every slot against the original sources (stage_delta
            # never mutates retained state), validate the whole batch
            # against the envelope, and only then commit — transactional
            # across slots without cloning any slab
            staged: list = [None] * len(names)
            mats, changed = [], False
            for i, name in enumerate(names):
                add_sids = None
                if added is not None and added.sids.shape[0]:
                    add_sids = added.sids[self._eval_predicate(name, added)]
                st = self._sources[i].stage_delta(add_sids,
                                                  delta.removed_sids)
                if st is None:
                    mats.append(self._mats[i])  # slot untouched by the delta
                else:
                    changed = True
                    staged[i] = st
                    mats.append(TransitionMatrix.from_flat_trie(st[0]))
            if not changed:
                self._m_swaps.inc(kind="delta", cold="noop")
                with self._lock:
                    return self._version
            back, cold = self._fit_or_regrow(front, mats, on_overflow)
            # staged sources are committed only after the flip, so a fault
            # here cannot publish a half-swapped store or corrupt the
            # retained slabs (the delta is simply retried or dropped whole)
            fire("refresh.swap")
            version = self._flip(back, cold)
            for i, st in enumerate(staged):
                if st is not None:
                    self._sources[i].commit(st)
            self._mats = mats
            self._m_refresh_s.observe(time.monotonic() - t0, kind="delta")
            self._m_swaps.inc(kind="delta", cold="true" if cold else "false")
            self._record_store(back, version, names)
            return version

    def current(self) -> tuple[ConstraintStore, int]:
        """The live (store, version) pair; atomic with respect to swaps."""
        with self._lock:
            if self._front is None:
                raise RuntimeError("registry not built yet")
            return self._front, self._version

    def slot_sids(self, slot: int) -> np.ndarray:
        """Copy of the SID rows currently admissible under ``slot`` —
        exactly the retained sorted slab the slot's trie was built from.
        This is the ground truth the chaos harness checks served SIDs
        against (zero-constraint-violation gate, DESIGN.md §13)."""
        with self._refresh_lock:
            if not self._sources:
                raise RuntimeError("registry not built yet")
            return np.array(self._sources[slot].sids, copy=True)
