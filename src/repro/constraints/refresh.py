"""Incremental catalog refresh: delta-aware trie rebuilds + async hot-swap.

The paper's motivating business constraint is *content freshness* (§1) —
in production the restricted item set changes continuously.  The from-scratch
builder (:func:`~repro.core.trie.build_flat_trie`) pays a full lexsort of the
whole catalog per refresh, so refresh cost scales with catalog size rather
than churn.  This module makes refresh O(churn) and asynchronous
(DESIGN.md §7):

  * :class:`TrieSource` retains the builder's sorted SID slab (stored
    big-endian in the narrowest token dtype, so its byte row keys are a
    zero-copy view) plus a packed per-row ``new_prefix`` bitfield across
    refreshes.  ``apply_delta(add_sids, remove_sids)`` merges the sorted
    delta into the retained slab — O(Δ log Δ) to sort the delta,
    O(Δ log N) to locate it, O(N) to splice — then re-assembles the CSR
    with a *lean* flattening pass that never re-derives what the slab
    already knows (no lexsort, no per-row prefix-rank cumsum, direct
    scatter into the packed dense masks).  The resulting
    :class:`~repro.core.trie.FlatTrie` is **bit-identical** to a
    from-scratch ``build_flat_trie`` over the post-delta SID set —
    ``build_flat_trie`` stays the reference oracle and
    ``tests/test_refresh.py`` / ``tests/test_differential_fuzz.py`` enforce
    the equivalence array-for-array under random churn.

  * :class:`AsyncRefresher` runs predicate evaluation and trie rebuilds on
    a background thread and flips the registry's front buffer at a step
    boundary (the registry flip is lock-atomic; serving engines pick it up
    at their next batch).  Submissions return ``concurrent.futures.Future``
    objects resolving to the installed registry version; build failures
    propagate through the future instead of killing the serving path (the
    old store keeps serving).  Pending work is *coalesced* — a newer full
    snapshot supersedes everything queued before it, consecutive deltas
    compose — so a fast producer cannot queue unbounded rebuild work; when
    coalescing is disabled, submitters block once ``max_pending`` ops are
    queued (backpressure).

Row-key trick: a row of non-negative integer tokens compares
lexicographically exactly like its big-endian byte concatenation, so each
SID row becomes one fixed-width bytes scalar and sorted-set membership /
merge positions are plain ``np.searchsorted`` calls (NumPy compares ``S``
dtypes with memcmp).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.core.trie import (
    FlatTrie,
    check_index_capacity,
    sorted_unique_sids,
)
from repro.observability import MetricsRegistry
from repro.reliability.retry import RetryPolicy

__all__ = ["TrieSource", "AsyncRefresher", "row_keys"]

logger = logging.getLogger("repro.constraints.refresh")


# ---------------------------------------------------------------------------
# sorted-slab maintenance
# ---------------------------------------------------------------------------
def row_keys(s: np.ndarray) -> np.ndarray:
    """(N, L) non-negative integer rows -> (N,) big-endian byte keys.

    Keys of arrays with the same integer width are mutually comparable;
    the TrieSource keeps its slab and every delta in ONE dtype so its
    searchsorted calls always compare like with like.
    """
    w = s.dtype.itemsize
    be = np.ascontiguousarray(s, dtype=s.dtype.newbyteorder(">"))
    return be.view(f"S{w * s.shape[1]}").ravel()


def _slab_dtype(vocab_size: int) -> np.dtype:
    """Narrowest BIG-ENDIAN integer dtype holding every token id.

    The slab is the array every refresh splices, so its width is the
    dominant delta cost; token ids are bounded by the vocab (2k-8k in the
    paper's settings), not by state counts.  Big-endian storage makes the
    row-key array a zero-copy *view* of the slab (see :func:`row_keys`) —
    no second array to keep in sync or splice.  Strict inequality keeps
    ``token + 1`` (the virtual-id convention) overflow-free even before
    the assembly-side upcast.
    """
    for dt in (np.int16, np.int32):
        if vocab_size < np.iinfo(dt).max:
            return np.dtype(dt).newbyteorder(">")
    return np.dtype(np.int64).newbyteorder(">")


def _normalize_delta(sids, vocab_size: int, L: int, dtype,
                     what: str) -> np.ndarray:
    """Validated, lexsorted, deduplicated (D, L) delta rows in slab dtype."""
    if sids is None:
        return np.zeros((0, L), dtype=dtype)
    sids = np.asarray(sids)
    if sids.ndim != 2 or sids.shape[1] != L:
        raise ValueError(
            f"{what} must be (D, {L}), got shape {sids.shape}"
        )
    if sids.shape[0] == 0:
        return np.zeros((0, L), dtype=dtype)
    if sids.min() < 0 or sids.max() >= vocab_size:
        raise ValueError(f"{what}: token ids out of range [0, vocab_size)")
    return sorted_unique_sids(sids.astype(np.int64, copy=False)).astype(dtype)


def _splice(arr: np.ndarray, keep: Optional[np.ndarray],
            ins_pos: np.ndarray, ins_rows: np.ndarray) -> np.ndarray:
    """``arr[keep]`` with ``ins_rows`` inserted before positions ``ins_pos``.

    ``ins_pos`` is sorted and indexes the post-``keep`` array (np.insert
    semantics), but this is ~3x faster than ``np.delete`` + ``np.insert``:
    one boolean compress plus one masked scatter, no index sorting, no
    second full copy.  Always returns a fresh array (the caller's
    transaction commit).
    """
    mid = arr[keep] if keep is not None else arr
    k = ins_pos.shape[0]
    if k == 0:
        return mid if keep is not None else mid.copy()
    n_final = mid.shape[0] + k
    out = np.empty((n_final,) + arr.shape[1:], dtype=arr.dtype)
    ins_final = ins_pos + np.arange(k)
    mask = np.ones(n_final, dtype=bool)
    mask[ins_final] = False
    out[ins_final] = ins_rows
    out[mask] = mid
    return out


def _npx_dtype(L: int) -> np.dtype:
    """Dtype of the packed new-prefix bitfield (one integer per slab row)."""
    for bits, dt in ((8, np.uint8), (16, np.uint16), (32, np.uint32),
                     (64, np.uint64)):
        if L <= bits:
            return np.dtype(dt)
    raise ValueError(f"sid_length {L} > 64 is unsupported by TrieSource")


def _prefix_bits(s: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Packed ``new_prefix`` rows for slab positions ``idx``.

    Bit ``l`` of entry ``i`` is True iff row ``idx[i]`` starts a new
    (l+1)-prefix — i.e. it differs from its predecessor in some column
    ``<= l``.  Packing the per-level booleans into one integer per row
    keeps the refresh splice 1-D (the fast path) and L-times smaller.
    """
    L = s.shape[1]
    dt = _npx_dtype(L)
    out = np.empty(idx.shape[0], dtype=dt)
    interior = idx > 0
    out[~interior] = dt.type((1 << L) - 1)  # row 0 starts every prefix
    if interior.any():
        d = s[idx[interior]] != s[idx[interior] - 1]
        acc = np.logical_or.accumulate(d, axis=1)
        w = np.uint64(1) << np.arange(L, dtype=np.uint64)
        out[interior] = (acc * w).sum(axis=1).astype(dt)
    return out


def _assemble(s: np.ndarray, new_prefix: np.ndarray, vocab_size: int,
              dense_d: int, index_dtype) -> FlatTrie:
    """Lean CSR assembly from a sorted slab and its ``new_prefix`` table.

    Produces output bit-identical to :func:`~repro.core.trie.build_flat_trie`
    but skips everything the retained slab makes redundant: within-level
    prefix ranks are ``arange`` (rows are sorted, so ranks are positional),
    parent ranks come from one ``searchsorted`` per level instead of a full
    (N, L) cumsum, and the per-state edge runs are written directly in CSR
    order (the stable argsort of the reference builder is the identity here
    by construction).
    """
    n, L = s.shape
    # Rows are unique, so every row starts a new L-prefix: the leaf level's
    # positions are all of [0, n) — no scan needed.
    pos = [np.nonzero(new_prefix & new_prefix.dtype.type(1 << lvl))[0]
           for lvl in range(L - 1)]
    pos.append(np.arange(n, dtype=np.int64))
    npl = np.array([p.shape[0] for p in pos], dtype=np.int64)

    level_offsets = np.zeros(L + 2, dtype=np.int64)
    level_offsets[0] = 1  # root
    level_offsets[1] = 2
    for lvl in range(1, L + 1):
        level_offsets[lvl + 1] = level_offsets[lvl] + npl[lvl - 1]
    d_eff = min(dense_d, L)
    shift = int(level_offsets[d_eff]) - 1

    level_bmax = np.zeros(L, dtype=np.int64)
    counts_lvl = []  # per-source-state child counts, levels 0..L-1
    tok_lvl = []
    for lvl in range(L):
        tok_lvl.append(s[pos[lvl], lvl])
        if lvl == 0:
            cnt = np.array([npl[0]], dtype=np.int64)  # the root's children
        else:
            # pos[lvl-1] ⊆ pos[lvl] (new_prefix accumulates along the row),
            # so the children of parent j are the pos[lvl] entries falling
            # in [pos[lvl-1][j], pos[lvl-1][j+1]) — probe the SMALL parent
            # array into the big child array instead of ranking every child
            cnt = np.diff(np.searchsorted(pos[lvl],
                                          np.append(pos[lvl - 1], n)))
        counts_lvl.append(cnt)
        if cnt.size:
            level_bmax[lvl] = int(cnt.max())

    n_states = int(level_offsets[-1]) - shift
    n_edges = int(npl[d_eff:].sum())
    bmax = int(level_bmax.max())
    pad = -bmax % 128 + bmax + 128
    check_index_capacity(index_dtype, n_states=n_states,
                         n_edge_rows=n_edges + pad, vocab_size=vocab_size)

    # Row pointers: [sink] + non-leaf retained levels, then leaves (0 edges).
    rp = np.zeros(n_states + 1, dtype=np.int64)
    counts_full = np.concatenate(
        [np.zeros(1, dtype=np.int64)] + counts_lvl[d_eff:]
    )
    m = counts_full.shape[0]
    np.cumsum(counts_full, out=rp[1 : 1 + m])
    rp[1 + m :] = rp[m]

    # Stacked edges, written level-contiguous: within a level rows are in
    # slab order == (parent ascending, token ascending), matching the
    # reference builder's lexsort + stable-by-source ordering.
    edges = np.zeros((n_edges + pad, 2), dtype=index_dtype)
    o = 0
    for lvl in range(d_eff, L):
        k = int(npl[lvl])
        base = int(level_offsets[lvl + 1]) - shift
        edges[o : o + k, 0] = tok_lvl[lvl]
        edges[o : o + k, 1] = np.arange(base, base + k)
        o += k

    new_offsets = np.maximum(level_offsets - shift, 1)
    new_offsets[:d_eff] = 1
    trie = FlatTrie(
        vocab_size=vocab_size,
        sid_length=L,
        n_constraints=n,
        row_pointers=rp.astype(index_dtype),
        edges=edges,
        n_states=n_states,
        n_edges=n_edges,
        level_offsets=new_offsets,
        level_bmax=level_bmax,
        dense_d=dense_d,
    )

    # Dense tables: scatter set bits straight into the packed words —
    # bit-identical to pack_bits (same little-endian convention: bit
    # ``y & 7`` of word ``y >> 3``) without materializing the (V, V) bool
    # mask or its five-pass packing reduction.
    if dense_d >= 1:
        l0_states = np.zeros(vocab_size, dtype=index_dtype)
        y1 = np.asarray(tok_lvl[0], dtype=np.int64)  # upcast: narrow slabs
        packed0 = np.zeros((vocab_size + 7) // 8, dtype=np.uint8)
        np.bitwise_or.at(packed0, y1 >> 3,
                         np.uint8(1) << (y1 & 7).astype(np.uint8))
        if dense_d == 1 or L < 2:
            l0_states[y1] = (level_offsets[1] + np.arange(npl[0])) - shift
        else:
            l0_states[y1] = y1 + 1  # virtual ids (paper Appendix E)
        trie.l0_mask_packed = packed0
        trie.l0_states = l0_states
    if dense_d >= 2 and L >= 2:
        l1_states = np.zeros((vocab_size, vocab_size), dtype=index_dtype)
        y1 = np.asarray(s[pos[1], 0], dtype=np.int64)
        y2 = np.asarray(tok_lvl[1], dtype=np.int64)
        packed1 = np.zeros((vocab_size, (vocab_size + 7) // 8),
                           dtype=np.uint8)
        np.bitwise_or.at(packed1, (y1, y2 >> 3),
                         np.uint8(1) << (y2 & 7).astype(np.uint8))
        l1_states[y1, y2] = (level_offsets[2] + np.arange(npl[1])) - shift
        trie.l1_mask_packed = packed1
        trie.l1_states = l1_states
    return trie


class TrieSource:
    """Retained builder state for O(churn) re-flattening (DESIGN.md §7).

    Holds the lexsorted deduplicated SID slab (big-endian, so the row-key
    array is a free view) and the per-row ``new_prefix`` table.
    ``flatten()`` assembles the current :class:`FlatTrie`; ``apply_delta``
    splices a churn delta into the slab and re-assembles.  Both are
    bit-identical to ``build_flat_trie(current_sids, ...)``.

    Not thread-safe: callers (the registry's refresh path) serialize access.
    """

    def __init__(self, slab: np.ndarray, new_prefix: np.ndarray,
                 vocab_size: int, dense_d: int, index_dtype):
        self._slab = slab
        self._new_prefix = new_prefix
        self.vocab_size = vocab_size
        self.dense_d = dense_d
        self.index_dtype = index_dtype

    def _keys(self) -> np.ndarray:
        """Row keys as a zero-copy view of the big-endian slab."""
        return row_keys(self._slab)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_sids(cls, sids: np.ndarray, vocab_size: int, *, dense_d: int = 2,
                  index_dtype=np.int32) -> "TrieSource":
        sids = np.asarray(sids)
        if sids.ndim != 2 or sids.size == 0:
            raise ValueError(f"sids must be non-empty (N, L), got {sids.shape}")
        if sids.min() < 0 or sids.max() >= vocab_size:
            raise ValueError("token ids out of range [0, vocab_size)")
        s = sorted_unique_sids(sids.astype(np.int64, copy=False))
        s = s.astype(_slab_dtype(vocab_size))
        return cls(s, _prefix_bits(s, np.arange(s.shape[0])),
                   vocab_size, dense_d, index_dtype)

    def clone(self) -> "TrieSource":
        """Deep copy (benchmarks re-apply deltas to a fresh source)."""
        return TrieSource(self._slab.copy(), self._new_prefix.copy(),
                          self.vocab_size, self.dense_d, self.index_dtype)

    # -- introspection ------------------------------------------------------
    @property
    def n_sids(self) -> int:
        return self._slab.shape[0]

    @property
    def sid_length(self) -> int:
        return self._slab.shape[1]

    @property
    def sids(self) -> np.ndarray:
        """The current SID set (sorted, deduplicated; read-only view)."""
        v = self._slab.view()
        v.flags.writeable = False
        return v

    def __contains__(self, sid) -> bool:
        k = row_keys(np.asarray(sid, dtype=self._slab.dtype).reshape(1, -1))
        keys = self._keys()
        p = int(np.searchsorted(keys, k[0]))
        return p < keys.shape[0] and keys[p] == k[0]

    # -- flattening ---------------------------------------------------------
    def flatten(self) -> FlatTrie:
        """The current slab's FlatTrie (== from-scratch build, bit for bit)."""
        return _assemble(self._slab, self._new_prefix, self.vocab_size,
                         self.dense_d, self.index_dtype)

    def apply_delta(self, add_sids=None,
                    remove_sids=None) -> Optional[FlatTrie]:
        """Splice a churn delta into the slab and re-assemble the trie.

        Removals apply first, then additions (a SID present in both ends up
        in the set).  Removing an absent SID and re-adding a present one are
        no-ops.  Returns ``None`` when the delta removes and inserts nothing
        (callers reuse their previous matrix); otherwise returns a FlatTrie
        bit-identical to ``build_flat_trie`` over the post-delta set — note
        a remove-then-readd of the same SID does splice the slab and returns
        a (value-identical) rebuilt trie.  The update is transactional: on
        any error the retained state is untouched.
        """
        staged = self.stage_delta(add_sids, remove_sids)
        if staged is None:
            return None
        self.commit(staged)
        return staged[0]

    def stage_delta(self, add_sids=None, remove_sids=None):
        """``apply_delta`` without the commit: returns an opaque staged
        tuple (trie first) or ``None`` for a no-op.

        The registry stages every slot of a multi-slot refresh against the
        ORIGINAL sources, validates the whole batch against the capacity
        envelope, and only then :meth:`commit`\\ s each slot — transactional
        across slots with zero slab copies (splices build fresh arrays, so
        the retained state is never touched until commit).
        """
        L = self.sid_length
        dt = self._slab.dtype
        rm = _normalize_delta(remove_sids, self.vocab_size, L, dt,
                              "remove_sids")
        ad = _normalize_delta(add_sids, self.vocab_size, L, dt, "add_sids")
        slab = self._slab
        keys = self._keys()
        n = slab.shape[0]

        removed_idx = np.zeros(0, dtype=np.int64)
        if rm.shape[0]:
            rk = row_keys(rm)
            p = np.searchsorted(keys, rk)
            pc = np.minimum(p, n - 1)
            hit = (p < n) & (keys[pc] == rk)
            removed_idx = p[hit]
        if removed_idx.shape[0]:
            keep = np.ones(n, dtype=bool)
            keep[removed_idx] = False
            # mid-coordinate position of the first survivor after each
            # removed run (its predecessor changed => new_prefix recompute)
            kc = np.cumsum(keep)
            succ_mid = np.unique(kc[removed_idx])
            n_mid = n - removed_idx.shape[0]
        else:
            keep = None
            succ_mid = np.zeros(0, dtype=np.int64)
            n_mid = n

        # Insert positions are searched against the ORIGINAL keys and then
        # shifted down by the removals before them — no post-removal key
        # array is ever materialized.  An add that matches a REMOVED row is
        # not a duplicate (remove-then-readd re-splices, see above).
        ins_mid = np.zeros(0, dtype=np.int64)
        if ad.shape[0]:
            ak = row_keys(ad)
            p = np.searchsorted(keys, ak)
            pc = np.minimum(p, n - 1)
            present = (p < n) & (keys[pc] == ak)
            dup = present.copy()
            if keep is not None:
                dup[present] = keep[p[present]]
            ad, p = ad[~dup], p[~dup]
            ins_mid = (p - np.searchsorted(removed_idx, p)
                       if removed_idx.shape[0] else p)
        if keep is None and not ins_mid.shape[0]:
            return None  # no effective churn: slab unchanged

        if n_mid + ins_mid.shape[0] == 0:
            raise ValueError("delta removes every SID; constraint set must "
                             "be non-empty")

        new_slab = _splice(slab, keep, ins_mid, ad)

        # new_prefix: splice rows, then recompute exactly the rows whose
        # (predecessor, row) pair changed — inserted rows, their successors,
        # and the survivors right after removed runs.  Everything else keeps
        # its value (it depends only on its unchanged predecessor pair).
        npx = _splice(self._new_prefix, keep, ins_mid,
                      np.zeros(ins_mid.shape[0], dtype=self._new_prefix.dtype))
        if ins_mid.shape[0]:
            ins_final = ins_mid + np.arange(ins_mid.shape[0])
            succ_final = succ_mid + np.searchsorted(ins_mid, succ_mid,
                                                    side="right")
            affected = np.concatenate([ins_final, ins_final + 1, succ_final])
        else:
            affected = succ_mid
        n_new = new_slab.shape[0]
        affected = np.unique(affected[affected < n_new])
        npx[affected] = _prefix_bits(new_slab, affected)

        trie = _assemble(new_slab, npx, self.vocab_size, self.dense_d,
                         self.index_dtype)
        return trie, new_slab, npx

    def commit(self, staged) -> None:
        """Install state staged by :meth:`stage_delta`."""
        _, self._slab, self._new_prefix = staged


# ---------------------------------------------------------------------------
# async hot-swap pipeline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Op:
    kind: str  # "snapshot" | "delta"
    payload: object
    futures: list
    t_submit: float = 0.0  # time.monotonic() at enqueue (queue-wait metric)


class AsyncRefresher:
    """Background refresh worker over a :class:`ConstraintRegistry`.

    ``swap_async(catalog)`` / ``apply_delta_async(delta)`` enqueue a rebuild
    and return a ``Future`` resolving to the installed registry version.
    Predicate evaluation, trie construction and envelope checks run on the
    worker thread; the registry's front-buffer flip is lock-atomic, so
    serving engines observe the new store at their next batch boundary with
    zero recompilation (or exactly one, for an envelope-regrowth cold swap —
    the registry decides, see ``ConstraintRegistry.swap``).

    Coalescing (default on): a full snapshot supersedes everything queued
    before it (those submitters' futures resolve with the snapshot's
    version — their state is subsumed by the newer authoritative snapshot),
    and consecutive deltas compose via ``CatalogDelta.compose``.  The queue
    therefore never exceeds two ops (one snapshot + one trailing delta).
    With ``coalesce=False`` every op is preserved and submitters block once
    ``max_pending`` ops are queued — classic backpressure.

    A failing rebuild (predicate error, injected fault, transient allocator
    pressure, ...) is **retried with capped exponential backoff** under
    ``retry`` (a :class:`~repro.reliability.RetryPolicy`; attempts/backoff
    land in ``refresh_retries_total``).  Only a *terminal* failure — every
    attempt exhausted, or a non-retryable error — sets the exception on the
    op's futures (including any futures coalesced into it: nothing is
    silently dropped) and the worker moves on; the registry front buffer is
    untouched either way and serving continues on the previous version.
    The whole retry loop runs inside the worker's busy window, so
    ``drain(timeout=)`` cannot return while an op is still being retried.

    **Staleness**: from the first submission the front buffer is behind
    until the worker catches up; :meth:`staleness_seconds` reports how long
    the oldest unapplied submission has been waiting (0 when caught up) and
    publishes the ``constraint_staleness_seconds`` gauge — the serve-stale
    rung of the degradation ladder made observable (DESIGN.md §13).  A
    terminal failure leaves the clock running: serving is genuinely behind
    the authoritative catalog until a later op succeeds.
    """

    def __init__(self, registry, *, coalesce: bool = True,
                 max_pending: int = 4,
                 metrics: Optional[MetricsRegistry] = None,
                 retry: Optional[RetryPolicy] = None):
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self._registry = registry
        self._coalesce = coalesce
        self._max_pending = max_pending
        self._retry = retry if retry is not None else RetryPolicy(
            max_attempts=3, base_delay_s=0.01, max_delay_s=0.25)
        self._cond = threading.Condition()
        self._queue: list[_Op] = []
        self._busy = False
        self._closed = False
        self._t_behind_since: Optional[float] = None
        self.coalesced = 0  # ops merged into a newer submission
        self.applied = 0  # ops that installed a version
        self.failed = 0  # ops whose build raised
        self.last_error: Optional[BaseException] = None
        # telemetry: default to the registry's MetricsRegistry so refresher
        # and registry metrics land in one scrape/snapshot; the legacy int
        # attributes above stay authoritative for existing callers
        if metrics is None:
            metrics = getattr(registry, "metrics", None)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_ops = self.metrics.counter(
            "refresh_ops_total",
            "async refresh ops, by kind and outcome "
            "(applied/failed/coalesced)")
        self._m_apply_s = self.metrics.histogram(
            "refresh_apply_seconds",
            "worker-side wall time of one refresh op (build + flip), by kind")
        self._m_queue_s = self.metrics.histogram(
            "refresh_queue_seconds",
            "submit→worker-pickup wait of applied/failed ops")
        self._m_depth = self.metrics.gauge(
            "refresh_queue_depth", "ops waiting in the refresher queue")
        self._m_backpressure = self.metrics.counter(
            "refresh_backpressure_waits_total",
            "submitter blocks because the queue was full (coalesce off)")
        self._m_retries = self.metrics.counter(
            "refresh_retries_total",
            "refresh attempts retried after a transient failure, by kind")
        self._m_staleness = self.metrics.gauge(
            "constraint_staleness_seconds",
            "how long the oldest unapplied catalog submission has waited; "
            "0 when the front store is caught up (DESIGN.md §13)")
        self._thread = threading.Thread(
            target=self._worker, daemon=True, name="constraint-refresh"
        )
        self._thread.start()

    # -- submission ---------------------------------------------------------
    def swap_async(self, catalog) -> Future:
        """Full-snapshot refresh of every slot; future -> new version."""
        return self._submit("snapshot", catalog)

    def apply_delta_async(self, delta) -> Future:
        """O(churn) delta refresh of every slot; future -> new version."""
        return self._submit("delta", delta)

    def _submit(self, kind: str, payload) -> Future:
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("AsyncRefresher is closed")
            now = time.monotonic()
            while True:
                if self._coalesce and kind == "snapshot":
                    # authoritative full state: subsume everything queued
                    carried = [f for op in self._queue for f in op.futures]
                    n = len(self._queue)
                    self.coalesced += n
                    if n:
                        self._m_ops.inc(n, kind=kind, outcome="coalesced")
                    self._queue = [_Op(kind, payload, carried + [fut], now)]
                    break
                if (self._coalesce and kind == "delta" and self._queue
                        and self._queue[-1].kind == "delta"):
                    last = self._queue[-1]
                    last.payload = last.payload.compose(payload)
                    last.futures.append(fut)
                    self.coalesced += 1
                    self._m_ops.inc(kind=kind, outcome="coalesced")
                    break
                if len(self._queue) < self._max_pending:
                    self._queue.append(_Op(kind, payload, [fut], now))
                    break
                self._m_backpressure.inc(kind=kind)
                self._cond.wait()  # backpressure: queue full, can't coalesce
                if self._closed:
                    raise RuntimeError("AsyncRefresher is closed")
            if self._t_behind_since is None:
                self._t_behind_since = now  # front store now behind
            self._m_depth.set(len(self._queue))
            self._cond.notify_all()
        return fut

    def staleness_seconds(self, now: Optional[float] = None) -> float:
        """Age of the oldest submission the front store does not reflect
        (0.0 when caught up).  Publishes ``constraint_staleness_seconds``."""
        now = time.monotonic() if now is None else now
        with self._cond:
            t = self._t_behind_since
        s = 0.0 if t is None else max(now - t, 0.0)
        self._m_staleness.set(s)
        return s

    # -- lifecycle ----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is empty and the worker is idle.

        ``_busy`` spans the worker's *entire* retry loop (backoff sleeps
        included), so a True return means no refresh work — queued,
        running, or mid-retry — remains in flight.
        """
        with self._cond:
            return self._cond.wait_for(
                lambda: not self._queue and not self._busy, timeout=timeout
            )

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop accepting work, finish what is queued, join the worker."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "AsyncRefresher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------
    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if not self._queue and self._closed:
                    return
                op = self._queue.pop(0)
                self._busy = True
                self._m_depth.set(len(self._queue))
                self._cond.notify_all()  # wake backpressure waiters
            # Transition futures to RUNNING; a future the caller already
            # cancelled is dropped here — setting a result on it would
            # raise InvalidStateError and kill the worker thread.
            live = [f for f in op.futures if f.set_running_or_notify_cancel()]
            t0 = time.monotonic()
            self._m_queue_s.observe(max(t0 - op.t_submit, 0.0), kind=op.kind)

            def do_apply(op=op):
                if op.kind == "snapshot":
                    return self._registry.swap(op.payload)
                return self._registry.swap_delta(op.payload)

            def on_retry(attempt, e, op=op):
                self._m_retries.inc(kind=op.kind)
                logger.warning(
                    "refresh %s attempt %d failed; retrying in %.3fs: %s",
                    op.kind, attempt + 1, self._retry.delay_s(attempt), e)

            applied_ok = False
            try:
                # retries (and their backoff sleeps) run inside the busy
                # window, so drain() cannot observe an "empty" refresher
                # that still has this op in flight
                version = self._retry.call(do_apply, on_retry=on_retry)
            except BaseException as e:  # propagate, never kill serving
                self.failed += 1
                self.last_error = e
                self._m_ops.inc(kind=op.kind, outcome="failed")
                logger.error(
                    "refresh %s failed terminally after %d attempt(s) "
                    "(serving continues on the previous store): %s",
                    op.kind, self._retry.max_attempts, e, exc_info=e,
                )
                for f in live:
                    f.set_exception(e)
            else:
                applied_ok = True
                self.applied += 1
                self._m_ops.inc(kind=op.kind, outcome="applied")
                self._m_apply_s.observe(time.monotonic() - t0, kind=op.kind)
                logger.debug("refresh %s applied: version %s", op.kind,
                             version)
                for f in live:
                    f.set_result(version)
            finally:
                with self._cond:
                    self._busy = False
                    if applied_ok:
                        # caught up to this op; still behind iff more work
                        # is queued.  A terminal failure keeps the clock
                        # running — the catalog state was never applied.
                        self._t_behind_since = (
                            self._queue[0].t_submit if self._queue else None)
                    self._cond.notify_all()
