"""Stacked multi-constraint transition store (DESIGN.md §4).

``ConstraintStore`` packs K independent :class:`TransitionMatrix` instances
(same vocab / SID length / dense depth) into one device pytree whose leaves
carry a leading constraint axis.  Every decode-path lookup then takes an
optional per-row ``constraint_ids`` tensor — one extra gather level into the
stacked CSR — so a single jitted beam-search batch serves requests under
different business constraints simultaneously.

Capacity envelope: members are padded to common ``n_states`` / ``n_edges``
sizes, optionally with *headroom*, so a refreshed corpus snapshot can be
hot-swapped into a slot (``with_member``) without changing any array shape or
static metadata — and therefore without triggering a single recompilation.
Padded states have empty CSR rows (they behave as the sink) and padded edges
are zeros, which the valid-length sanitization of Alg. 2 masks out, so padding
never changes lookup results.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transition_matrix import TransitionMatrix
from repro.core.trie import check_index_capacity

__all__ = ["ConstraintStore", "EnvelopeOverflow"]

_LEAF_FIELDS = (
    "row_pointers", "edges", "l0_mask_packed", "l0_states",
    "l1_mask_packed", "l1_states", "member_n_states", "member_n_edges",
    "member_n_constraints",
)


class EnvelopeOverflow(ValueError):
    """A refreshed matrix does not fit the store's capacity envelope.

    Raised by :meth:`ConstraintStore._check_fits` (and therefore by the
    ``with_member``/``with_members`` hot-swap path).  The registry catches
    this to route an envelope *regrowth* — a background rebuild with a
    larger envelope and one explicit recompile — instead of surfacing the
    error to the operator while the live store goes stale.
    """


def _edge_pad(bmax: int) -> int:
    """Speculative-slice safety pad (same formula as the trie builder)."""
    return -int(bmax) % 128 + int(bmax) + 128


def _edge_capacity(n_edges: int, bmax_max: int) -> int:
    """Edge rows needed to hold ``n_edges`` real edges under ``bmax_max``.

    THE envelope formula: a speculative fixed-length slice of any branch
    factor ``<= bmax_max`` starting at the final real edge must stay in
    bounds.  ``from_matrices`` sizes the envelope with it and
    ``_check_fits`` validates swaps against it — one helper, so the two
    can never drift apart (a store used to reject its own members because
    the check re-added the pad on top of an already-padded count).
    """
    return int(n_edges) + _edge_pad(bmax_max)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ConstraintStore:
    """K padded TransitionMatrix instances stacked on a leading axis."""

    # --- device arrays (pytree leaves; leading axis K) ---
    row_pointers: jax.Array  # (K, n_states + 1) int32
    edges: jax.Array  # (K, n_edges, 2) int32 stacked [token, next_state]
    l0_mask_packed: jax.Array  # (K, ceil(V/8)) uint8
    l0_states: jax.Array  # (K, V) int32
    l1_mask_packed: jax.Array  # (K, V, ceil(V/8)) uint8 (or (K, 1, 1) dummy)
    l1_states: jax.Array  # (K, V, V) int32 (or (K, 1, 1) dummy)
    # per-member bookkeeping as LEAVES so hot-swap never touches aux data
    member_n_states: jax.Array  # (K,) int32 real state counts
    member_n_edges: jax.Array  # (K,) int32 real edge counts
    member_n_constraints: jax.Array  # (K,) int32 SIDs per member
    # --- static metadata (jit-specialization keys; fixed across hot-swaps) ---
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    sid_length: int = dataclasses.field(metadata=dict(static=True))
    dense_d: int = dataclasses.field(metadata=dict(static=True))
    level_bmax: tuple = dataclasses.field(metadata=dict(static=True))
    n_states: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    num_sets: int = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    @classmethod
    def from_matrices(
        cls, mats: Sequence[TransitionMatrix], *, headroom: float = 0.0
    ) -> "ConstraintStore":
        """Stack matrices into one store, padded to a common envelope.

        ``headroom`` (a fraction, e.g. 0.5) over-allocates the state/edge/
        branch-factor envelope beyond the current members so later
        ``with_member`` hot-swaps of *larger* refreshed matrices still fit
        the static shapes.
        """
        mats = list(mats)
        if not mats:
            raise ValueError("ConstraintStore needs at least one matrix")
        if headroom < 0:
            raise ValueError("headroom must be >= 0")
        ref = mats[0]
        for i, m in enumerate(mats):
            for f in ("vocab_size", "sid_length", "dense_d"):
                if getattr(m, f) != getattr(ref, f):
                    raise ValueError(
                        f"matrix {i}: {f}={getattr(m, f)} != {getattr(ref, f)}"
                        " — all members must share vocab/sid_length/dense_d"
                    )
            if m.l1_mask_packed.shape != ref.l1_mask_packed.shape:
                raise ValueError(f"matrix {i}: inconsistent dense-l1 tables")

        grow = 1.0 + headroom
        bmax_env = tuple(
            int(np.ceil(max(m.level_bmax[l] for m in mats) * grow))
            for l in range(ref.sid_length)
        )
        n_states_env = int(np.ceil(max(m.n_states for m in mats) * grow))
        e_real = max(m.n_edges for m in mats)
        n_edges_env = max(
            _edge_capacity(int(np.ceil(e_real * grow)), max(max(bmax_env), 1)),
            max(m.edges.shape[0] for m in mats),
        )
        check_index_capacity(
            np.asarray(ref.row_pointers).dtype, n_states=n_states_env,
            n_edge_rows=n_edges_env, vocab_size=ref.vocab_size,
        )

        stacked = {
            name: np.stack(
                [_pad_member(m, name, n_states_env, n_edges_env) for m in mats]
            )
            for name in ("row_pointers", "edges", "l0_mask_packed",
                         "l0_states", "l1_mask_packed", "l1_states")
        }
        return cls(
            **{k: jnp.asarray(v) for k, v in stacked.items()},
            member_n_states=jnp.asarray([m.n_states for m in mats], jnp.int32),
            member_n_edges=jnp.asarray([m.n_edges for m in mats], jnp.int32),
            member_n_constraints=jnp.asarray(
                [m.n_constraints for m in mats], jnp.int32
            ),
            vocab_size=ref.vocab_size,
            sid_length=ref.sid_length,
            dense_d=ref.dense_d,
            level_bmax=bmax_env,
            n_states=n_states_env,
            n_edges=n_edges_env,
            num_sets=len(mats),
        )

    # ------------------------------------------------------------------
    @property
    def is_stacked(self) -> bool:
        """K constraint sets on a leading axis; lookups need per-row ids."""
        return True

    def bmax_for_step(self, step: int) -> int:
        """Envelope branch factor at ``step`` (max over members + headroom)."""
        return int(self.level_bmax[step])

    def nbytes(self) -> int:
        total = 0
        for f in _LEAF_FIELDS:
            a = getattr(self, f)
            total += a.size * a.dtype.itemsize
        return total

    def replicated_shardings(self, mesh) -> "ConstraintStore":
        """Fully-replicated NamedShardings pytree (same policy as the single
        matrix, paper §A.3: the store is small next to model weights)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        rep = NamedSharding(mesh, P())
        return jax.tree.map(lambda _: rep, self)

    # ------------------------------------------------------------------
    def member(self, k: int) -> TransitionMatrix:
        """Slice out set ``k`` as a standalone TransitionMatrix.

        The returned matrix carries the store's padded arrays (envelope
        shapes, envelope ``level_bmax``) but the member's REAL ``n_states``/
        ``n_edges``/``n_constraints``: padding is semantically inert (empty
        rows / zero edges), so lookups are bit-identical to the original
        member's, and the real counts keep the matrix re-installable — a
        ``store.with_member(k, store.member(k))`` roundtrip always fits the
        envelope (it used to be rejected because the member reported the
        envelope edge count, which the fit check then padded *again*).
        """
        if not 0 <= k < self.num_sets:
            raise IndexError(f"constraint set {k} outside [0, {self.num_sets})")
        return TransitionMatrix(
            row_pointers=self.row_pointers[k],
            edges=self.edges[k],
            l0_mask_packed=self.l0_mask_packed[k],
            l0_states=self.l0_states[k],
            l1_mask_packed=self.l1_mask_packed[k],
            l1_states=self.l1_states[k],
            vocab_size=self.vocab_size,
            sid_length=self.sid_length,
            dense_d=self.dense_d,
            level_bmax=self.level_bmax,
            n_states=int(self.member_n_states[k]),
            n_edges=int(self.member_n_edges[k]),
            n_constraints=int(self.member_n_constraints[k]),
        )

    def _check_fits(self, tm: TransitionMatrix) -> None:
        """Raise :class:`EnvelopeOverflow` unless ``tm`` fits the envelope."""
        for f in ("vocab_size", "sid_length", "dense_d"):
            if getattr(tm, f) != getattr(self, f):
                raise ValueError(
                    f"hot-swap {f} mismatch: {getattr(tm, f)} != {getattr(self, f)}"
                )
        if tm.n_states > self.n_states:
            raise EnvelopeOverflow(
                f"hot-swap needs {tm.n_states} states but envelope holds "
                f"{self.n_states}; rebuild the store with more headroom"
            )
        needed_edges = max(_edge_capacity(tm.n_edges, max(self.level_bmax)),
                           tm.edges.shape[0])
        if needed_edges > self.n_edges:
            raise EnvelopeOverflow(
                f"hot-swap needs {needed_edges} edge rows but envelope holds "
                f"{self.n_edges}; rebuild the store with more headroom"
            )
        for l, (b_new, b_env) in enumerate(zip(tm.level_bmax, self.level_bmax)):
            if b_new > b_env:
                raise EnvelopeOverflow(
                    f"hot-swap level-{l} branch factor {b_new} exceeds "
                    f"envelope {b_env}; rebuild the store with more headroom"
                )

    def with_member(self, k: int, tm: TransitionMatrix) -> "ConstraintStore":
        """Functional hot-swap: a new matrix in slot ``k``, same envelope.

        The replacement must fit the capacity envelope (states, edges, and
        per-level branch factors); otherwise the swap is rejected and the
        caller should rebuild the store with more headroom.  Static metadata
        and every array shape are preserved, so jitted decode steps keyed on
        this store never recompile across swaps.
        """
        if not 0 <= k < self.num_sets:
            raise IndexError(f"constraint set {k} outside [0, {self.num_sets})")
        self._check_fits(tm)
        updates = {
            name: getattr(self, name).at[k].set(
                jnp.asarray(_pad_member(tm, name, self.n_states, self.n_edges))
            )
            for name in ("row_pointers", "edges", "l0_mask_packed",
                         "l0_states", "l1_mask_packed", "l1_states")
        }
        return dataclasses.replace(
            self,
            **updates,
            member_n_states=self.member_n_states.at[k].set(tm.n_states),
            member_n_edges=self.member_n_edges.at[k].set(tm.n_edges),
            member_n_constraints=self.member_n_constraints.at[k].set(
                tm.n_constraints
            ),
        )

    def with_members(self, mats: Sequence[TransitionMatrix]) -> "ConstraintStore":
        """Hot-swap EVERY slot at once (the registry refresh path).

        All replacements are validated against the envelope first, then the
        new stacked leaves are built host-side and installed with a single
        ``dataclasses.replace`` — one store copy total, versus K full copies
        if the refresh chained :meth:`with_member` per slot.
        """
        mats = list(mats)
        if len(mats) != self.num_sets:
            raise ValueError(
                f"with_members needs {self.num_sets} matrices, got {len(mats)}"
            )
        for tm in mats:
            self._check_fits(tm)
        stacked = {
            name: jnp.asarray(np.stack(
                [_pad_member(tm, name, self.n_states, self.n_edges)
                 for tm in mats]
            ))
            for name in ("row_pointers", "edges", "l0_mask_packed",
                         "l0_states", "l1_mask_packed", "l1_states")
        }
        return dataclasses.replace(
            self,
            **stacked,
            member_n_states=jnp.asarray([m.n_states for m in mats], jnp.int32),
            member_n_edges=jnp.asarray([m.n_edges for m in mats], jnp.int32),
            member_n_constraints=jnp.asarray(
                [m.n_constraints for m in mats], jnp.int32
            ),
        )

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            **{f: np.asarray(getattr(self, f)) for f in _LEAF_FIELDS},
            meta=np.array(
                [self.vocab_size, self.sid_length, self.dense_d,
                 self.n_states, self.n_edges, self.num_sets],
                dtype=np.int64,
            ),
            level_bmax=np.asarray(self.level_bmax, dtype=np.int64),
        )

    @classmethod
    def load(cls, path: str) -> "ConstraintStore":
        z = np.load(path)
        meta = z["meta"]
        return cls(
            **{f: jnp.asarray(z[f]) for f in _LEAF_FIELDS},
            vocab_size=int(meta[0]),
            sid_length=int(meta[1]),
            dense_d=int(meta[2]),
            level_bmax=tuple(int(b) for b in z["level_bmax"]),
            n_states=int(meta[3]),
            n_edges=int(meta[4]),
            num_sets=int(meta[5]),
        )


def _pad_member(tm: TransitionMatrix, name: str, n_states: int,
                n_edges: int) -> np.ndarray:
    """One member array padded to the store envelope (host-side)."""
    a = np.asarray(getattr(tm, name))
    if name == "row_pointers":
        # Padded states get empty CSR rows: repeat the final pointer.
        out = np.full(n_states + 1, a[tm.n_states], dtype=a.dtype)
        out[: tm.n_states + 1] = a[: tm.n_states + 1]
        return out
    if name == "edges":
        out = np.zeros((n_edges, 2), dtype=a.dtype)
        out[: a.shape[0]] = a
        return out
    return a  # dense tables are fixed-shape given (V, dense_d)
