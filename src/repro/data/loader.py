"""Deterministic sharded batch loader.

Epoch order is a pure function of (seed, epoch); every host slices its own
contiguous shard, so (a) any host can be restarted and recompute exactly the
batches it owes (fault tolerance), and (b) resume-from-checkpoint replays
from an exact (epoch, cursor) data state with no coordination.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ShardedBatcher"]


class ShardedBatcher:
    def __init__(
        self,
        arrays: dict,
        global_batch: int,
        seed: int = 0,
        n_hosts: int = 1,
        host_id: int = 0,
        drop_remainder: bool = True,
    ):
        n = next(iter(arrays.values())).shape[0]
        for k, v in arrays.items():
            if v.shape[0] != n:
                raise ValueError(f"array {k} length mismatch")
        if global_batch % n_hosts:
            raise ValueError("global_batch must divide by n_hosts")
        self.arrays = arrays
        self.n = n
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.epoch = 0
        self.cursor = 0  # in global batches
        self.drop_remainder = drop_remainder

    # -- state for exact resume ------------------------------------------
    def state(self) -> dict:
        return {"epoch": self.epoch, "cursor": self.cursor}

    def restore(self, state: dict) -> None:
        self.epoch = int(state["epoch"])
        self.cursor = int(state["cursor"])

    # ----------------------------------------------------------------------
    def _epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.n)

    def __iter__(self):
        return self

    def __next__(self):
        batches_per_epoch = self.n // self.global_batch
        if batches_per_epoch == 0:
            raise ValueError("dataset smaller than one global batch")
        if self.cursor >= batches_per_epoch:
            self.epoch += 1
            self.cursor = 0
        order = self._epoch_order(self.epoch)
        start = self.cursor * self.global_batch
        idx = order[start : start + self.global_batch]
        lo = self.host_id * self.local_batch
        idx = idx[lo : lo + self.local_batch]
        self.cursor += 1
        return {k: v[idx] for k, v in self.arrays.items()}
