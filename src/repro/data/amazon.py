"""Amazon-Reviews-like cold-start protocol (paper §6).

We have no network access, so we generate a corpus with the same *structure*
as the Amazon subdatasets used by the paper (10-20k items, clustered
features, per-item age) and apply the paper's exact split protocol:

  * each item has an "age" (timestamp of oldest review);
  * the newest ``cold_frac`` (2% / 5%) of items form the cold-start set;
  * TRAIN sequences contain no cold-start item anywhere;
  * TEST sequences are those whose *target* (last item) is cold-start.

The generative retrieval model therefore never sees a cold item during
training — reproducing the 0.00% unconstrained Recall@1 of Table 3 — and
STATIC constrains decoding to the cold-start SID set at eval.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import make_item_corpus, make_user_sequences

__all__ = ["ColdStartData", "make_cold_start_dataset"]


@dataclasses.dataclass
class ColdStartData:
    item_feats: np.ndarray  # (N, F)
    item_age: np.ndarray  # (N,) smaller = older
    item_cluster: np.ndarray  # (N,) int cluster id (catalog "category")
    cold_items: np.ndarray  # (n_cold,) item ids
    train_seqs: np.ndarray  # (n_train, T) no cold items anywhere
    test_seqs: np.ndarray  # (n_test, T) target (last) is cold

    @property
    def n_items(self) -> int:
        return self.item_feats.shape[0]

    @property
    def age_days(self) -> np.ndarray:
        """Age rank recast as days-since-publication (newest item = 0).

        ``item_age`` is a recency rank (larger = newer); the constraint
        layer's :func:`~repro.constraints.freshness_window` wants "days
        old", so the newest item maps to 0 and the oldest to ``N - 1``.
        With ``n_cold`` cold items, ``freshness_window(n_cold - 0.5)``
        selects exactly the cold set.
        """
        return (self.n_items - 1 - self.item_age).astype(np.float64)


def make_cold_start_dataset(
    seed: int = 0,
    n_items: int = 2_000,
    n_clusters: int = 64,
    feat_dim: int = 64,
    n_users: int = 6_000,
    seq_len: int = 12,
    cold_frac: float = 0.02,
) -> ColdStartData:
    rng = np.random.default_rng(seed)
    feats, cid = make_item_corpus(rng, n_items, n_clusters, feat_dim)
    age = rng.permutation(n_items)  # rank; larger = newer
    n_cold = max(1, int(n_items * cold_frac))
    cold_items = np.argsort(age)[-n_cold:]
    cold_mask = np.zeros(n_items, bool)
    cold_mask[cold_items] = True

    seqs = make_user_sequences(rng, n_users, seq_len, cid)
    has_cold = cold_mask[seqs].any(axis=1)
    target_cold = cold_mask[seqs[:, -1]]
    train_seqs = seqs[~has_cold]
    test_seqs = seqs[target_cold]
    return ColdStartData(
        item_feats=feats,
        item_age=age,
        item_cluster=cid,
        cold_items=np.sort(cold_items),
        train_seqs=train_seqs,
        test_seqs=test_seqs,
    )
