"""Synthetic corpora for the generative-retrieval stack.

Mirrors the structure the paper relies on: items live in semantic clusters
(so RQ-VAE Semantic IDs share prefixes within a cluster — the "significant
clustering" of Appendix B.2), and user sequences have cluster affinity (so
next-item prediction is learnable by a small transformer).
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_item_corpus", "make_user_sequences"]


def make_item_corpus(
    rng: np.random.Generator,
    n_items: int,
    n_clusters: int,
    feat_dim: int,
    cluster_std: float = 0.15,
):
    """Returns (features (N, F), cluster_id (N,))."""
    centers = rng.normal(size=(n_clusters, feat_dim))
    cid = rng.integers(0, n_clusters, size=n_items)
    feats = centers[cid] + rng.normal(size=(n_items, feat_dim)) * cluster_std
    return feats.astype(np.float32), cid


def make_user_sequences(
    rng: np.random.Generator,
    n_users: int,
    seq_len: int,
    cluster_id: np.ndarray,
    stay_prob: float = 0.85,
):
    """Cluster-sticky random walks over the catalog -> (n_users, seq_len) ids."""
    n_items = cluster_id.shape[0]
    n_clusters = int(cluster_id.max()) + 1
    by_cluster = [np.nonzero(cluster_id == c)[0] for c in range(n_clusters)]
    by_cluster = [b if b.size else np.arange(n_items) for b in by_cluster]
    seqs = np.empty((n_users, seq_len), np.int64)
    cur = rng.integers(0, n_clusters, size=n_users)
    for t in range(seq_len):
        switch = rng.random(n_users) > stay_prob
        cur = np.where(switch, rng.integers(0, n_clusters, n_users), cur)
        for c in range(n_clusters):
            m = cur == c
            if m.any():
                seqs[m, t] = rng.choice(by_cluster[c], size=int(m.sum()))
    return seqs
