"""Fanout neighbor sampler for GNN minibatch training (GraphSAGE-style).

``minibatch_lg`` requires a real sampler: given a CSR adjacency, sample
``fanout`` neighbors per hop from seed nodes and emit a *padded, fixed-shape*
subgraph (node list, edge list, mask) ready for the jitted model — fixed
shapes keep XLA from recompiling across steps.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CSRGraph", "fanout_sample", "random_graph"]


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,) neighbor ids
    node_feats: np.ndarray  # (N, F)

    @property
    def n_nodes(self):
        return self.indptr.shape[0] - 1


def random_graph(rng, n_nodes: int, avg_degree: int, feat_dim: int) -> CSRGraph:
    deg = rng.poisson(avg_degree, n_nodes).clip(1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    indptr[1:] = np.cumsum(deg)
    indices = rng.integers(0, n_nodes, indptr[-1])
    feats = rng.normal(size=(n_nodes, feat_dim)).astype(np.float32)
    return CSRGraph(indptr, indices, feats)


def fanout_sample(
    g: CSRGraph,
    seeds: np.ndarray,
    fanout: tuple,
    rng: np.random.Generator,
    edge_feat_dim: int = 8,
):
    """Sample a fanout subgraph; returns fixed-shape padded arrays.

    Output sizes: nodes = len(seeds) * (1 + f1 + f1*f2 + ...),
                  edges = len(seeds) * (f1 + f1*f2 + ...).
    Local node ids: seeds first, then hop-1 samples, then hop-2, ...
    Edges point child -> parent (message flows toward the seeds).
    """
    n_seeds = seeds.shape[0]
    sizes = np.cumprod(fanout)
    n_pad_nodes = n_seeds * (1 + int(sizes.sum()))
    n_pad_edges = n_seeds * int(sizes.sum())

    local_nodes = np.zeros(n_pad_nodes, np.int64)
    node_mask = np.zeros(n_pad_nodes, bool)
    senders = np.zeros(n_pad_edges, np.int64)
    receivers = np.zeros(n_pad_edges, np.int64)
    edge_mask = np.zeros(n_pad_edges, bool)

    local_nodes[:n_seeds] = seeds
    node_mask[:n_seeds] = True
    frontier_lo, frontier_n = 0, n_seeds
    node_cursor, edge_cursor = n_seeds, 0

    for f in fanout:
        parents = local_nodes[frontier_lo : frontier_lo + frontier_n]
        pmask = node_mask[frontier_lo : frontier_lo + frontier_n]
        for j in range(frontier_n):
            base_n = node_cursor + j * f
            base_e = edge_cursor + j * f
            if not pmask[j]:
                continue
            p = parents[j]
            lo, hi = g.indptr[p], g.indptr[p + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, int(deg))
            picks = g.indices[lo + rng.choice(deg, size=take, replace=deg < f)]
            local_nodes[base_n : base_n + take] = picks
            node_mask[base_n : base_n + take] = True
            senders[base_e : base_e + take] = np.arange(base_n, base_n + take)
            receivers[base_e : base_e + take] = frontier_lo + j
            edge_mask[base_e : base_e + take] = True
        frontier_lo = node_cursor
        frontier_n = frontier_n * f
        node_cursor += frontier_n
        edge_cursor += frontier_n

    feats = g.node_feats[local_nodes] * node_mask[:, None]
    edge_feats = np.zeros((n_pad_edges, edge_feat_dim), np.float32)
    edge_feats[:, 0] = edge_mask.astype(np.float32)
    # masked edges scatter to node 0 with zero features — harmless because
    # their messages are zeroed by edge_feats*edge_mask in the caller's loss.
    return {
        "node_feats": feats.astype(np.float32),
        "edge_feats": edge_feats,
        "senders": senders.astype(np.int32),
        "receivers": receivers.astype(np.int32),
        "node_mask": node_mask,
        "edge_mask": edge_mask,
        "local_to_global": local_nodes,
    }
