"""Fault injection, retry, deadlines, and graceful degradation
(DESIGN.md §13).

The serving stack's failure-handling contract in one package:

* :mod:`~repro.reliability.faults` — seeded deterministic
  :class:`FaultInjector` over the named fault-point registry; production
  code queries :func:`fire` (zero-overhead when disabled).
* :mod:`~repro.reliability.retry` — :class:`RetryPolicy`, capped
  exponential backoff with deterministic jitter (the refresher and the
  tiering prefetcher adopt it).
* :mod:`~repro.reliability.deadline` — absolute per-request
  :class:`Deadline` propagated from submit through every engine.
* :mod:`~repro.reliability.breaker` — :class:`CircuitBreaker` +
  :class:`AdmissionController`: the shed rung of the degradation ladder
  (retry → serve-stale → shed; never unconstrained decoding).
* :mod:`~repro.reliability.health` — :class:`HealthMonitor` backing the
  ``/healthz`` endpoint on the metrics HTTP server.
"""
from repro.reliability.breaker import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    AdmissionController,
    CircuitBreaker,
)
from repro.reliability.deadline import Deadline
from repro.reliability.faults import (
    FAULT_POINTS,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    active_injector,
    fire,
    install,
    uninstall,
)
from repro.reliability.health import HealthMonitor
from repro.reliability.retry import RetryPolicy

__all__ = [
    "FAULT_POINTS",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
    "active_injector",
    "fire",
    "install",
    "uninstall",
    "RetryPolicy",
    "Deadline",
    "CircuitBreaker",
    "AdmissionController",
    "CLOSED",
    "HALF_OPEN",
    "OPEN",
    "HealthMonitor",
]
