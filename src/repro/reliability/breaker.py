"""Circuit breaker + admission control: the shed rung of the degradation
ladder (DESIGN.md §13).

The ladder is **retry → serve-stale constraints → shed at admission** and
it stops there: a request is *never* served with constrained decoding
disabled.  Unconstrained fallback would turn a transient infrastructure
fault into user-visible constraint violations (stale/ineligible items
surfaced), which is the one failure mode the paper's production claim
rules out — shedding is visible, bounded, and recoverable; a violation is
none of those.

:class:`CircuitBreaker` tracks consecutive service failures
(CLOSED → OPEN after ``failure_threshold``), denies admission while OPEN,
probes after ``recovery_s`` (HALF_OPEN), and closes again after
``half_open_successes`` consecutive probe successes.  State and
transitions land in ``circuit_breaker_state`` /
``circuit_breaker_transitions_total{from,to}``.

:class:`AdmissionController` is the enqueue-time gate the
``RequestQueue`` consults: breaker state, queue depth, already-expired
deadlines, and (optionally) a constraint-staleness bound each map to a
shed *reason* — one shared ``requests_shed_total{reason}`` family across
all three engines.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

__all__ = ["CircuitBreaker", "AdmissionController", "CLOSED", "OPEN",
           "HALF_OPEN"]

CLOSED = "closed"
HALF_OPEN = "half_open"
OPEN = "open"
_STATE_CODE = {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}


class CircuitBreaker:
    """Consecutive-failure breaker with timed half-open probing."""

    def __init__(self, *, failure_threshold: int = 5,
                 recovery_s: float = 1.0, half_open_successes: int = 2,
                 name: str = "serving", metrics=None,
                 now_fn: Callable[[], float] = time.monotonic):
        if failure_threshold < 1 or half_open_successes < 1:
            raise ValueError("thresholds must be >= 1")
        self.failure_threshold = int(failure_threshold)
        self.recovery_s = float(recovery_s)
        self.half_open_successes = int(half_open_successes)
        self.name = name
        self._now = now_fn
        self._lock = threading.Lock()
        self._state = CLOSED
        self._failures = 0   # consecutive, in CLOSED
        self._successes = 0  # consecutive, in HALF_OPEN
        self._opened_at = 0.0
        self._m_state = self._m_transitions = None
        if metrics is not None:
            self._m_state = metrics.gauge(
                "circuit_breaker_state",
                "0=closed, 1=half_open, 2=open, by breaker name")
            self._m_transitions = metrics.counter(
                "circuit_breaker_transitions_total",
                "breaker state changes, labeled from/to")
            self._m_state.set(0, name=self.name)

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def _transition(self, new: str) -> None:
        # lock held by caller
        old, self._state = self._state, new
        if self._m_state is not None:
            self._m_state.set(_STATE_CODE[new], name=self.name)
            self._m_transitions.inc(
                **{"name": self.name, "from": old, "to": new})

    def allow(self, now: Optional[float] = None) -> bool:
        """May a new request be admitted right now?  OPEN transitions to
        HALF_OPEN here once ``recovery_s`` has elapsed (probe traffic)."""
        now = self._now() if now is None else now
        with self._lock:
            if self._state == OPEN:
                if now - self._opened_at >= self.recovery_s:
                    self._successes = 0
                    self._transition(HALF_OPEN)
                    return True
                return False
            return True

    def record_success(self, now: Optional[float] = None) -> None:
        with self._lock:
            if self._state == HALF_OPEN:
                self._successes += 1
                if self._successes >= self.half_open_successes:
                    self._failures = 0
                    self._transition(CLOSED)
            else:
                self._failures = 0

    def record_failure(self, now: Optional[float] = None) -> None:
        now = self._now() if now is None else now
        with self._lock:
            if self._state == HALF_OPEN:
                self._opened_at = now
                self._transition(OPEN)  # a probe failed: re-open
                return
            if self._state == CLOSED:
                self._failures += 1
                if self._failures >= self.failure_threshold:
                    self._opened_at = now
                    self._transition(OPEN)


class AdmissionController:
    """Enqueue-time shed decisions; returns a reason string or None.

    Reasons (the label values of ``requests_shed_total{reason}``):
    ``breaker_open``, ``overload``, ``deadline``, ``stale_constraints``.
    """

    def __init__(self, *, breaker: Optional[CircuitBreaker] = None,
                 max_queue_depth: Optional[int] = None,
                 staleness_fn: Optional[Callable[[], float]] = None,
                 staleness_bound_s: Optional[float] = None):
        self.breaker = breaker
        self.max_queue_depth = max_queue_depth
        self.staleness_fn = staleness_fn
        self.staleness_bound_s = staleness_bound_s

    def admit_reason(self, queue_len: int, *, deadline=None,
                     now: Optional[float] = None) -> Optional[str]:
        if deadline is not None and deadline.expired(now):
            return "deadline"
        if self.breaker is not None and not self.breaker.allow(now):
            return "breaker_open"
        if self.max_queue_depth is not None and \
                queue_len >= self.max_queue_depth:
            return "overload"
        if self.staleness_fn is not None and \
                self.staleness_bound_s is not None and \
                self.staleness_fn() > self.staleness_bound_s:
            return "stale_constraints"
        return None
