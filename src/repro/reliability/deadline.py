"""Per-request deadline propagation (DESIGN.md §13).

A :class:`Deadline` is an *absolute* ``time.monotonic()`` instant fixed at
submit time, so it means the same thing at every layer it rides through —
enqueue-time admission, queue residence, scheduler planning — without
re-anchoring arithmetic.  All checks take an optional ``now`` so tests and
the chaos harness can drive virtual time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

__all__ = ["Deadline"]


@dataclasses.dataclass(frozen=True)
class Deadline:
    """Absolute monotonic-clock deadline."""

    t_deadline: float

    @classmethod
    def after(cls, seconds: float,
              now: Optional[float] = None) -> "Deadline":
        now = time.monotonic() if now is None else now
        return cls(now + float(seconds))

    def remaining(self, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        return self.t_deadline - now

    def expired(self, now: Optional[float] = None) -> bool:
        return self.remaining(now) <= 0.0
