"""Readiness/liveness signal for the metrics HTTP server (DESIGN.md §13).

:class:`HealthMonitor` folds breaker state and constraint staleness into
one ``(ready, payload)`` answer.  ``observability.start_http_server``
serves it at ``/healthz`` (200 when ready, 503 otherwise, JSON body either
way) next to ``/metrics``; ``/livez`` always answers 200 — the process is
alive exactly when it can answer at all.

Readiness semantics:

* breaker OPEN → not ready (new work would be shed anyway; a load
  balancer should stop routing here until the breaker half-opens);
* ``constraint_staleness_seconds > staleness_bound_s`` → not ready (the
  store is still *valid* — last-good-version serving continues for
  in-flight traffic — but it is too old to keep advertising this replica
  as healthy).

Degraded-but-serving (stale under the bound, breaker CLOSED/HALF_OPEN)
stays ready: that is the serve-stale rung of the ladder working.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.reliability.breaker import OPEN, CircuitBreaker

__all__ = ["HealthMonitor"]


class HealthMonitor:
    """Callable ``() -> (ready, payload_dict)`` for the health endpoint."""

    def __init__(self, *, breaker: Optional[CircuitBreaker] = None,
                 staleness_fn: Optional[Callable[[], float]] = None,
                 staleness_bound_s: Optional[float] = None,
                 metrics=None):
        self.breaker = breaker
        self.staleness_fn = staleness_fn
        self.staleness_bound_s = staleness_bound_s
        self._m_ready = None
        if metrics is not None:
            self._m_ready = metrics.gauge(
                "serving_ready",
                "1 when /healthz reports ready (breaker not open, "
                "constraint staleness within bound)")

    def check(self) -> tuple[bool, dict]:
        state = self.breaker.state if self.breaker is not None else None
        stale = (float(self.staleness_fn())
                 if self.staleness_fn is not None else 0.0)
        reasons = []
        if state == OPEN:
            reasons.append("breaker_open")
        if self.staleness_bound_s is not None and \
                stale > self.staleness_bound_s:
            reasons.append("stale_constraints")
        ready = not reasons
        if self._m_ready is not None:
            self._m_ready.set(1.0 if ready else 0.0)
        payload = {
            "ready": ready,
            "reasons": reasons,
            "breaker": state if state is not None else "absent",
            "constraint_staleness_seconds": stale,
            "staleness_bound_s": self.staleness_bound_s,
        }
        return ready, payload

    __call__ = check
