"""Capped exponential backoff with deterministic jitter (DESIGN.md §13).

The delay for attempt ``k`` is::

    min(base_delay_s * multiplier**k, max_delay_s) * (1 + jitter_frac * u)

where ``u in [-1, 1]`` is drawn from a counter-keyed stream over
``(seed, k)`` — the same policy instance always produces the same delay
sequence, so retry timing (like fault schedules) is bit-reproducible and
unit-testable without mocking randomness.  Jitter still does its job in a
fleet: give each replica a distinct ``seed`` and their retries decorrelate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["RetryPolicy"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Frozen retry schedule; ``max_attempts=1`` means no retries.

    ``retryable`` is the exception-class tuple worth retrying; anything
    else propagates immediately (a programming error is not a transient).
    """

    max_attempts: int = 3
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter_frac: float = 0.1
    seed: int = 0
    retryable: tuple = (Exception,)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay_s < 0 or self.max_delay_s < self.base_delay_s:
            raise ValueError("need 0 <= base_delay_s <= max_delay_s")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1)")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retrying after failed attempt ``attempt`` (0-based).
        Deterministic in (policy fields, attempt)."""
        d = min(self.base_delay_s * self.multiplier ** attempt,
                self.max_delay_s)
        if self.jitter_frac:
            u = 2.0 * float(
                np.random.default_rng([self.seed, attempt]).random()) - 1.0
            d *= 1.0 + self.jitter_frac * u
        return d

    def call(self, fn: Callable, *,
             on_retry: Optional[Callable] = None,
             sleep: Callable = time.sleep):
        """Run ``fn()`` under this policy; returns its result or raises the
        last error.  ``on_retry(attempt, exc)`` fires before each backoff
        sleep (metrics/logging hook); ``sleep`` is injectable for tests.
        """
        for attempt in range(self.max_attempts):
            try:
                return fn()
            except self.retryable as e:
                if attempt + 1 >= self.max_attempts:
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                sleep(self.delay_s(attempt))
        raise AssertionError("unreachable")  # pragma: no cover
