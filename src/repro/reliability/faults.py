"""Deterministic fault injection for the serving stack (DESIGN.md §13).

Production code asks :func:`fire` at a handful of *named fault points*;
when no injector is installed the call is one module-global load plus a
``None`` check — cheap enough to leave compiled into every hot path.  A
chaos run installs a :class:`FaultInjector` built from a seeded schedule
and the exact same binaries start failing in a *bit-reproducible* way:
each point keeps its own call counter and its own counter-keyed RNG
stream, so which calls fire depends only on (schedule, seed, per-point
call index) — never on wall clock, thread interleaving across points, or
the host's global RNG state.

Two fault kinds:

* **error** — :func:`fire` raises :class:`InjectedFault`.  The production
  code must treat it exactly like the organic failure the point models
  (``refresh.build`` ~ predicate/build error, ``kv.page_alloc`` ~ pool
  exhaustion, ``queue.overload`` ~ admission-control rejection, ...).
* **delay** (``delay_s > 0``) — :func:`fire` sleeps and returns.  Models a
  slow dependency (``decode.slow_step``, a stalling ``tiering.host_fetch``)
  without changing any result bits.

Schedules are plain data (:meth:`FaultInjector.from_json`), so
``launch/serve.py --fault-schedule faults.json`` and the chaos harness
replay byte-identical campaigns.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
import zlib
from contextlib import contextmanager
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "FAULT_POINTS",
    "InjectedFault",
    "FaultSpec",
    "FaultInjector",
    "fire",
    "install",
    "uninstall",
    "active_injector",
]

#: The closed registry of fault points (DESIGN.md §13 documents each one's
#: blast radius and required degradation behavior).  ``fire`` rejects
#: unknown names at schedule-construction time, so a typo cannot silently
#: produce a fault-free "chaos" run.
FAULT_POINTS = frozenset({
    "refresh.build",      # registry slot rebuild (predicate eval / trie)
    "refresh.swap",       # the front-buffer flip about to happen
    "tiering.host_fetch", # host-tier cold-edge gather
    "kv.page_alloc",      # paged-KV pool allocation
    "decode.slow_step",   # jitted decode step dispatch (delay-only)
    "queue.overload",     # RequestQueue admission
})


class InjectedFault(RuntimeError):
    """Raised by :func:`fire` for an error-kind fault."""

    def __init__(self, point: str, call_index: int):
        super().__init__(f"injected fault at {point} (call {call_index})")
        self.point = point
        self.call_index = call_index


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled failure mode at one fault point.

    ``mode``:
      * ``"nth"``    — fire exactly on the 0-based per-point call indices
        listed in ``calls``;
      * ``"always"`` — fire on every call (bounded by ``max_fires``);
      * ``"prob"``   — fire each call with probability ``p`` drawn from a
        per-point counter-keyed stream (deterministic per call index).

    ``delay_s > 0`` makes this a delay fault (sleep, don't raise);
    ``max_fires`` caps total fires (``None`` = unbounded) — e.g.
    ``mode="always", max_fires=2`` models "fails twice, then recovers",
    the canonical transient a retry policy must absorb.
    """

    point: str
    mode: str = "nth"
    calls: tuple = ()
    p: float = 0.0
    delay_s: float = 0.0
    max_fires: Optional[int] = None

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; known: "
                f"{sorted(FAULT_POINTS)}")
        if self.mode not in ("nth", "always", "prob"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if self.mode == "prob" and not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {self.p}")
        object.__setattr__(self, "calls", tuple(int(c) for c in self.calls))


class FaultInjector:
    """Seeded, deterministic fault scheduler over the point registry.

    Thread-safe: points are hit from the refresher worker, the tiering
    prefetcher and the serving thread concurrently, but every decision is
    a function of the *per-point* call index, so cross-point thread
    interleaving cannot change which calls fire.

    ``on_fire(point, call_index, spec)`` runs synchronously before the
    fault takes effect — the chaos harness uses it to check the allocator
    invariant at the exact moment of each injection.
    """

    def __init__(self, specs: Sequence[FaultSpec], *, seed: int = 0,
                 on_fire: Optional[Callable] = None):
        self.seed = int(seed)
        self.on_fire = on_fire
        self._lock = threading.Lock()
        self._specs: dict[str, list[FaultSpec]] = {}
        for s in specs:
            self._specs.setdefault(s.point, []).append(s)
        self._calls: dict[str, int] = {p: 0 for p in self._specs}
        self._fired: dict[int, int] = {id(s): 0 for p in self._specs
                                       for s in self._specs[p]}
        self.fires: list[tuple[str, int, str]] = []  # (point, idx, kind)

    # -- deterministic per-(point, call) uniform draw ----------------------
    def _uniform(self, point: str, idx: int) -> float:
        key = [self.seed, zlib.crc32(point.encode()), idx]
        return float(np.random.default_rng(key).random())

    def calls(self, point: str) -> int:
        with self._lock:
            return self._calls.get(point, 0)

    def n_fires(self, point: Optional[str] = None) -> int:
        with self._lock:
            if point is None:
                return len(self.fires)
            return sum(1 for p, _, _ in self.fires if p == point)

    def fire(self, point: str) -> None:
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}")
        specs = self._specs.get(point)
        if not specs:
            return
        with self._lock:
            idx = self._calls[point]
            self._calls[point] = idx + 1
            hit = None
            for s in specs:
                if s.max_fires is not None and \
                        self._fired[id(s)] >= s.max_fires:
                    continue
                if s.mode == "nth" and idx in s.calls:
                    hit = s
                elif s.mode == "always":
                    hit = s
                elif s.mode == "prob" and \
                        self._uniform(point, idx) < s.p:
                    hit = s
                if hit is not None:
                    break
            if hit is None:
                return
            self._fired[id(hit)] += 1
            kind = "delay" if hit.delay_s > 0 else "error"
            self.fires.append((point, idx, kind))
        if self.on_fire is not None:
            self.on_fire(point, idx, hit)
        if hit.delay_s > 0:
            time.sleep(hit.delay_s)
            return
        raise InjectedFault(point, idx)

    # -- schedule (de)serialization ----------------------------------------
    @classmethod
    def from_json(cls, source, *, on_fire: Optional[Callable] = None
                  ) -> "FaultInjector":
        """Build from a dict, a JSON string, or a path to a JSON file::

            {"seed": 0, "faults": [
              {"point": "decode.slow_step", "mode": "prob",
               "p": 0.2, "delay_s": 0.005},
              {"point": "refresh.build", "mode": "always", "max_fires": 2},
              {"point": "kv.page_alloc", "mode": "nth", "calls": [3, 7]}]}
        """
        if isinstance(source, dict):
            doc = source
        else:
            text = str(source)
            if text.lstrip().startswith("{"):
                doc = json.loads(text)
            else:
                with open(text) as f:
                    doc = json.load(f)
        specs = [FaultSpec(**entry) for entry in doc.get("faults", [])]
        return cls(specs, seed=int(doc.get("seed", 0)), on_fire=on_fire)


# ---------------------------------------------------------------------------
# the global hook production code queries
# ---------------------------------------------------------------------------
_ACTIVE: Optional[FaultInjector] = None


def fire(point: str) -> None:
    """Hit a fault point.  No injector installed: one load + None check."""
    inj = _ACTIVE
    if inj is not None:
        inj.fire(point)


def install(injector: FaultInjector) -> None:
    global _ACTIVE
    _ACTIVE = injector


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


@contextmanager
def active_injector(injector: Optional[FaultInjector]):
    """Scoped install; restores the previous injector on exit."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = injector
    try:
        yield injector
    finally:
        _ACTIVE = prev
