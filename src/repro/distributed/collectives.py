"""HLO collective accounting for the roofline's third term.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (post-GSPMD, per-device) HLO text and sum the tensor sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Link-traffic model (ring algorithms, documented approximations):
  all-reduce         ~ 2 x bytes  (reduce-scatter + all-gather phases)
  all-gather         ~ 1 x output bytes
  reduce-scatter     ~ 1 x input bytes (output printed; we use max operand)
  all-to-all         ~ 1 x bytes
  collective-permute ~ 1 x bytes
HLO shapes are per-device (SPMD), so the totals are per-chip traffic.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["parse_collective_bytes", "collective_link_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum per-op-type tensor bytes over all collective instructions."""
    totals = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:80] and f"{op}-done" in line:
            continue  # avoid double counting start/done pairs
        size = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_type))
        totals[op] += size
        counts[op] += 1
    return {
        "bytes_by_op": dict(totals),
        "counts_by_op": dict(counts),
        "total_bytes": int(sum(totals.values())),
        "link_bytes": int(collective_link_bytes(totals)),
    }


def collective_link_bytes(bytes_by_op: dict) -> float:
    """Apply the ring-traffic factors (module docstring)."""
    factors = {
        "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0,
    }
    return sum(factors.get(op, 1.0) * b for op, b in bytes_by_op.items())
