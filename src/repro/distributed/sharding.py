"""Logical-axis sharding rules for every architecture family.

Mesh axes:
  * ``pod``   — outer data parallelism across pods (multi-pod mesh only)
  * ``data``  — intra-pod data parallelism
  * ``model`` — tensor/expert/sequence parallelism (intra-pod, fastest ICI)

LM rules (Megatron-style TP with GQA-aware KV handling):
  embeddings vocab-sharded; attention Q projections column-parallel on the
  flattened (H*Dh) dim; **K/V projections replicated** (GQA kv-heads [8] do
  not divide the 16-way model axis — replicating the small KV computation
  avoids a reshape-forced resharding, see DESIGN.md §5); output and FFN-down
  row-parallel; FFN-up/gate column-parallel.  MoE experts expert-parallel
  when n_experts % model_size == 0 (deepseek 64e), otherwise per-expert
  tensor-parallel (mixtral 8e on a 16-way axis).

Decode caches are **sequence-sharded** over ``model`` (split-K / flash-
decoding style): KV slots divide evenly, every chip holds 1/16th of the
cache, and the softmax combine is XLA's partial-reduce.

Recsys embedding tables are vocab-sharded over ``model`` when large
(>= 4 * model axis rows), replicated otherwise.  GNN node/edge arrays are
sharded over the flattened (pod, data, model) axis set.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "dp_axes", "dp_size", "model_size", "ns", "replicated",
    "lm_param_pspecs", "lm_batch_pspec", "kv_cache_pspecs",
    "recsys_param_pspecs", "gnn_param_pspecs", "tree_shardings",
    "shard_map_compat",
]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``shard_map`` across JAX versions (same spirit as ``make_mesh_compat``).

    ``jax.shard_map`` is the stable home from 0.6; earlier installs (this
    repo's floor is 0.4.x) only have ``jax.experimental.shard_map``, which
    newer releases removed.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def dp_axes(mesh: Mesh):
    """Axes used for batch (data) parallelism."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_size(mesh: Mesh) -> int:
    """Total data-parallel ways: product of the mesh's dp axis sizes."""
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def tree_shardings(mesh: Mesh, pspec_tree) -> Any:
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# LM family
# --------------------------------------------------------------------------


def _lm_leaf_pspec(path: str, shape, mesh: Mesh, n_kv_heads: int = 0) -> P:
    ms = model_size(mesh)
    rank = len(shape)

    def last_div(d):
        return shape[d] % ms == 0

    if "emb" in path and "unemb" not in path:
        return P("model", None) if last_div(0) else P()
    if "unemb" in path:
        return P(None, "model") if last_div(1) else P()
    # stacked layer params have a leading L axis (rank+1 vs their math rank)
    if any(k in path for k in ("wq", "w_kv_b")):
        # column-parallel: shard the flattened head-output dim (last)
        if rank == 3 and last_div(2):
            return P(None, None, "model")
        if rank == 2 and last_div(1):  # bias (L, F)
            return P(None, "model")
        return P()
    if any(k in path for k in ("wk", "wv")):
        # column-parallel only when kv heads divide TP cleanly (reshape-safe);
        # otherwise ROW-parallel on d_model (partial sums; GSPMD inserts the
        # all-reduce) — keeps KV params + their f32 optimizer moments sharded.
        if n_kv_heads % ms == 0 and rank == 3 and last_div(2):
            return P(None, None, "model")
        if rank == 3 and shape[1] % ms == 0:
            return P(None, "model", None)
        return P()
    if "w_kv_a" in path:  # MLA down-projection: row-parallel on d_model
        return P(None, "model", None) if rank == 3 and shape[1] % ms == 0 else P()
    if "kv_norm" in path:
        return P()
    if "wo" in path:
        if rank == 3 and shape[1] % ms == 0:
            return P(None, "model", None)
        return P()
    if any(k in path for k in ("ffn", "shared")):
        if "w2" in path:
            return P(None, "model", None) if rank == 3 and shape[1] % ms == 0 else P()
        if rank == 3 and last_div(2):
            return P(None, None, "model")
        return P()
    if "router" in path:
        return P()
    if "moe" in path and rank == 4:  # (L, E, D, F) expert weights
        if shape[1] % ms == 0:
            return P(None, "model", None, None)  # expert-parallel
        # per-expert tensor-parallel
        if "w2" in path:
            return P(None, None, "model", None) if shape[2] % ms == 0 else P()
        return P(None, None, None, "model") if shape[3] % ms == 0 else P()
    return P()  # norms, scalars


def lm_param_pspecs(param_specs, mesh: Mesh, n_kv_heads: int = 0):
    """ShapeDtypeStruct pytree -> PartitionSpec pytree."""

    def assign(path, leaf):
        path_str = "/".join(str(getattr(k, "key", k)) for k in path)
        return _lm_leaf_pspec(path_str, leaf.shape, mesh, n_kv_heads)

    return jax.tree_util.tree_map_with_path(assign, param_specs)


def lm_batch_pspec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None)


def kv_cache_pspecs(cache_specs, mesh: Mesh, batch_shardable: bool = True):
    """Sequence-shard decode caches over `model`; batch over dp axes."""
    dp = dp_axes(mesh) if batch_shardable else None

    def assign(path, leaf):
        name = str(getattr(path[-1], "name", getattr(path[-1], "key", "")))
        if name in ("k", "v", "c_kv", "k_rope"):
            # (L, B, slots, ...) — shard slots over model if divisible
            spec = [None, dp, None] + [None] * (len(leaf.shape) - 3)
            if leaf.shape[2] % model_size(mesh) == 0:
                spec[2] = "model"
            return P(*spec)
        return P()  # slot_pos, pos

    return jax.tree_util.tree_map_with_path(assign, cache_specs)


# --------------------------------------------------------------------------
# Recsys
# --------------------------------------------------------------------------


def recsys_param_pspecs(param_specs, mesh: Mesh):
    ms = model_size(mesh)

    def assign(path, leaf):
        path_str = "/".join(str(getattr(k, "key", k)) for k in path)
        if ("table_" in path_str or "wide_" in path_str) and len(leaf.shape) == 2:
            if leaf.shape[0] >= 4 * ms:
                return P("model", None)
        return P()

    return jax.tree_util.tree_map_with_path(assign, param_specs)


def recsys_batch_pspec(mesh: Mesh, rank: int) -> P:
    return P(dp_axes(mesh), *([None] * (rank - 1)))


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------


def gnn_param_pspecs(param_specs, mesh: Mesh):
    return jax.tree.map(lambda _: P(), param_specs)  # tiny params: replicate


def graph_axes(mesh: Mesh):
    """Flattened axis tuple for sharding node/edge arrays."""
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
