"""SPMD sharding story for constraint backends + beam search (DESIGN.md §6).

This module makes the constrained-retrieval hot path run SPMD over a
``Mesh`` from :mod:`repro.launch.mesh`:

  * **Batch/beam parallelism** — :func:`spmd_beam_search` wraps the ordinary
    :func:`~repro.core.beam_search` in ``shard_map``, splitting the *batch*
    axis across the mesh's data axes (``dp_axes``).  Rows are independent in
    Algorithm 1 (beams only compete within their own row's ``M·V``
    candidates), so each device runs the unmodified search on its batch
    shard and results are **bit-identical** to single-device decoding
    (asserted in ``tests/test_differential_fuzz.py``).  The beam axis stays
    device-local: sharding it would turn the per-row ``top_k`` over ``M·V``
    candidates into a cross-device tournament for zero memory win (``M·V``
    floats per row is trivially small).

  * **Constraint placement** — each backend exposes
    ``ConstraintBackend.shardings(mesh, rows=...)`` (a PartitionSpec pytree
    with the backend's own treedef).  Default is paper §A.3: every table
    replicated, the constraint check collective-free.  ``rows="model"``
    row-shards the CSR ``edges`` slab — the one leaf that grows with the
    corpus — along the mesh's ``model`` axis; :func:`vntk_row_sharded` then
    resolves cross-shard rows with a ONE-HOP gather: every device picks the
    speculative edge rows it owns and a single ``psum`` over ``model``
    assembles the full ``(nb, bmax, 2)`` slab on all devices.

  * **Hot-swap invariance** — spec trees are pure functions of the policy's
    *structure* (static metadata), never of leaf values, so a registry
    hot-swap (``with_constraints``) keeps every sharding valid and every
    compiled executable alive (asserted in ``tests/test_spmd_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.vntk import NEG_INF
from repro.decoding.backends import StackedStaticBackend, StaticBackend
from repro.distributed.sharding import (
    dp_axes,
    dp_size,
    shard_map_compat,
    tree_shardings,
)

__all__ = [
    "dp_size",
    "policy_pspecs",
    "shard_policy",
    "pad_rows",
    "pad_policy_rows",
    "vntk_row_sharded",
    "RowShardedStatic",
    "to_row_sharded",
    "spmd_beam_search",
]


def policy_pspecs(policy, mesh: Mesh, *, rows: str = "replicated"):
    """PartitionSpec pytree for a DecodePolicy (its ``shardings`` composed).

    The result has the policy's exact treedef, so it is directly usable as
    ``shard_map`` in_specs or as input to :func:`tree_shardings`.
    """
    return policy.shardings(mesh, rows=rows)


def shard_policy(policy, mesh: Mesh, *, rows: str = "replicated"):
    """``device_put`` the policy's leaves per its spec tree.

    With ``rows="model"`` the CSR edge slab must divide the model axis —
    apply :func:`pad_policy_rows` first (the SPMD serving stack does).
    """
    return jax.device_put(
        policy, tree_shardings(mesh, policy_pspecs(policy, mesh, rows=rows))
    )


# ---------------------------------------------------------------------------
# Row-sharded CSR: padding + one-hop gather lookup
# ---------------------------------------------------------------------------
def pad_rows(obj, n_shards: int):
    """Pad the CSR ``edges`` row count to a multiple of ``n_shards``.

    Works on a TransitionMatrix (rows on axis 0) or a ConstraintStore (rows
    on axis 1).  Pad rows are zeros — outside every CSR row's
    ``[start, start + n_child)`` window, so the ``iota < n_child``
    sanitization of Alg. 2 never reads them as real edges.  Static metadata
    (``n_edges`` = real edge count) is untouched; only the array envelope
    grows, deterministically, so repeated application (every hot-swap) lands
    on the same shapes and never recompiles.
    """
    if n_shards <= 1:
        return obj
    edges = obj.edges
    e = edges.shape[-2]
    e_pad = -(-e // n_shards) * n_shards
    if e_pad == e:
        return obj
    pad = [(0, 0)] * edges.ndim
    pad[-2] = (0, e_pad - e)
    return dataclasses.replace(obj, edges=jnp.pad(edges, pad))


def pad_policy_rows(policy, n_shards: int):
    """Apply :func:`pad_rows` to every CSR-carrying backend in a policy."""
    def pad_backend(b):
        if isinstance(b, StaticBackend):
            return dataclasses.replace(b, tm=pad_rows(b.tm, n_shards))
        if isinstance(b, StackedStaticBackend):
            return dataclasses.replace(b, store=pad_rows(b.store, n_shards))
        return b

    return dataclasses.replace(
        policy, backends=tuple(pad_backend(b) for b in policy.backends)
    )


def vntk_row_sharded(
    log_probs: jax.Array,  # (..., V)
    nodes: jax.Array,  # (...,) int32 current trie states
    row_pointers: jax.Array,  # (S+1,) or (K, S+1) int32, REPLICATED
    edges_local: jax.Array,  # (E/ms, 2) or (K, E/ms, 2): THIS shard's rows
    bmax: int,
    vocab_size: int,
    axis: str,
    constraint_ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 with the CSR edge slab row-sharded along mesh axis ``axis``.

    Must run inside ``shard_map``.  Row pointers are replicated (they are
    ``4(S+1)`` bytes vs the edge slab's ``8E``), so every device computes the
    same global speculative indices; each keeps only the rows it owns
    (``lo <= idx < lo + rows_local``) and one ``psum`` over ``axis``
    assembles the full slab — the "one-hop gather" for cross-shard
    next-states.  int32 summation is exact, and exactly one shard owns each
    index, so results are bit-identical to the replicated
    :func:`~repro.core.vntk.vntk_xla`.
    """
    V = vocab_size
    batch_shape = nodes.shape
    n_flat = nodes.reshape(-1)
    lp_flat = log_probs.reshape(-1, V)
    nb = n_flat.shape[0]

    if constraint_ids is None:
        starts = row_pointers[n_flat]
        lens = row_pointers[n_flat + 1] - starts
    else:
        cid = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
        starts = row_pointers[cid, n_flat]
        lens = row_pointers[cid, n_flat + 1] - starts

    offsets = jnp.arange(bmax, dtype=starts.dtype)
    idx = starts[:, None] + offsets[None, :]  # global edge-row indices
    rows_local = edges_local.shape[-2]
    lo = jax.lax.axis_index(axis) * rows_local
    rel = idx - lo
    own = (rel >= 0) & (rel < rows_local)
    rel_c = jnp.clip(rel, 0, rows_local - 1)
    if constraint_ids is None:
        g = jnp.take(edges_local, rel_c, axis=0)  # (nb, bmax, 2)
    else:
        g = edges_local[cid[:, None], rel_c]
    g = jnp.where(own[..., None], g, 0)
    gathered = jax.lax.psum(g, axis)  # one hop: full slab everywhere

    # Phases 3-4: identical to the replicated formulation (core/vntk.py).
    valid = offsets[None, :] < lens[:, None]
    cols = gathered[:, :, 0]
    nxt = jnp.where(valid, gathered[:, :, 1], 0)
    scatter_idx = jnp.where(valid, cols, V)
    rows = jnp.arange(nb)[:, None]
    cand_lp = jnp.take_along_axis(lp_flat, jnp.clip(cols, 0, V - 1), axis=1)
    masked = jnp.full((nb, V + 1), NEG_INF, dtype=log_probs.dtype)
    masked = masked.at[rows, scatter_idx].set(
        jnp.where(valid, cand_lp, NEG_INF)
    )[:, :V]
    next_dense = jnp.zeros((nb, V + 1), dtype=jnp.int32)
    next_dense = next_dense.at[rows, scatter_idx].set(nxt)[:, :V]
    return (
        masked.reshape(batch_shape + (V,)),
        next_dense.reshape(batch_shape + (V,)),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RowShardedStatic:
    """Shard-local view of a Static/StackedStatic backend inside shard_map.

    Wraps the backend whose ``edges`` leaf arrived row-sharded: dense-band
    steps delegate to the inner backend (dense tables are replicated), sparse
    steps run :func:`vntk_row_sharded`.  Built by :func:`to_row_sharded`
    inside the shard_map body — never constructed by user code.
    """

    inner: object  # StaticBackend | StackedStaticBackend (pytree child)
    axis: str = dataclasses.field(
        default="model", metadata=dict(static=True)
    )

    supports_fused = False
    needs_prefix = False
    # No candidate-compressed formulation for row-sharded CSR yet: the
    # rank-select would have to run after the one-hop psum gather for no
    # bandwidth win (the slab already crossed the interconnect), so
    # rows="model" decodes through the dense branch.  The candidate path
    # itself needs NO sharding machinery beyond this opt-out: with the
    # default replicated placement the per-beam lists and the (B, M*C)
    # top-M reduce are entirely dp-local (DESIGN.md §6/§8).
    supports_topk = False

    @property
    def supports_stacked(self) -> bool:
        return self.inner.supports_stacked

    @property
    def sid_length(self) -> int:
        return self.inner.sid_length

    @property
    def num_sets(self):
        return getattr(self.inner, "num_sets", None)

    @property
    def _constraints(self):
        return (self.inner.store if isinstance(self.inner, StackedStaticBackend)
                else self.inner.tm)

    def shardings(self, mesh, *, rows: str = "replicated"):
        raise TypeError(
            "RowShardedStatic is a shard-local view; take shardings from the "
            "inner backend before entering shard_map"
        )

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del prefix_tokens
        obj = self._constraints
        stacked = self.inner.supports_stacked
        if stacked and constraint_ids is None:
            raise ValueError(
                "ConstraintStore lookups need per-row constraint_ids"
            )
        if step < obj.dense_d:
            # dense band: replicated bit-packed tables, untouched path
            return self.inner.mask_step(
                log_probs, nodes, step,
                constraint_ids=constraint_ids if stacked else None,
            )
        bmax = max(obj.bmax_for_step(step), 1)
        return vntk_row_sharded(
            log_probs, nodes, obj.row_pointers, obj.edges, bmax,
            obj.vocab_size, self.axis,
            constraint_ids=constraint_ids if stacked else None,
        )


def to_row_sharded(policy, axis: str = "model"):
    """Rewrite a policy's sparse Static backends into shard-local views.

    Called inside the shard_map body, where Static backends' ``edges`` leaf
    is this device's row shard.  Dense-band backend instances never touch
    ``edges`` and are left alone.  Pallas/fused sparse paths have no
    row-sharded formulation yet — rejected at entry, not silently wrong.
    """
    def wrap(b):
        if (isinstance(b, (StaticBackend, StackedStaticBackend))
                and b.levels != "dense"):
            if b.impl == "pallas" or b.fused:
                raise ValueError(
                    "rows='model' supports the XLA unfused VNTK only; "
                    "rebuild the policy with impl='xla', fused=False"
                )
            return RowShardedStatic(inner=b, axis=axis)
        return b

    return dataclasses.replace(
        policy, backends=tuple(wrap(b) for b in policy.backends)
    )


# ---------------------------------------------------------------------------
# SPMD beam search: batch axis over the mesh's data axes
# ---------------------------------------------------------------------------
def spmd_beam_search(
    mesh: Mesh,
    logits_fn,
    batch_size: int,
    beam_size: int,
    length: int,
    policy,
    *,
    constraint_ids: Optional[jax.Array] = None,
    rows: str = "replicated",
):
    """Data-parallel :func:`~repro.core.beam_search` over ``mesh``.

    The batch axis is split across ``dp_axes(mesh)`` via ``shard_map``; the
    policy rides in with per-backend specs from its ``shardings`` hook (and
    with ``rows="model"`` its sparse steps run the one-hop-gather VNTK).
    ``logits_fn(carry, last, step)`` must be shard-oblivious — a function of
    its arguments and replicated closures only (the full serving path with a
    transformer + KV cache lives in ``repro.serving.spmd_engine``).

    ``batch_size`` must divide by :func:`dp_size` — callers pad with inactive
    rows (the static-shape padding rule of DESIGN.md §6).  Returns
    ``(tokens (B, M, L), scores (B, M))`` as global arrays, bit-identical to
    the single-device search.
    """
    from repro.decoding.policy import as_policy  # lazy: import cycle

    policy = as_policy(policy)
    dp = dp_axes(mesh)
    n = dp_size(mesh)
    if batch_size % n:
        raise ValueError(
            f"batch_size {batch_size} must divide the {n}-way data "
            f"parallelism (axes {dp}); pad with inactive rows"
        )
    if rows == "model":
        policy = pad_policy_rows(policy, mesh.shape["model"])
    local_b = batch_size // n
    have_ids = constraint_ids is not None
    # jit keys on the wrapped function OBJECT: without this cache a caller
    # looping over spmd_beam_search would recompile every iteration (the
    # exact per-call-jit defect GenerativeRetriever.__init__ fixed)
    key = (mesh, logits_fn, local_b, beam_size, length, rows, have_ids,
           jax.tree_util.tree_structure(policy))
    fn = _SPMD_SEARCH_CACHE.get(key)
    if fn is None:
        specs = policy_pspecs(policy, mesh, rows=rows)

        def body(pol, *maybe_cids):
            p = to_row_sharded(pol) if rows == "model" else pol
            from repro.core.beam_search import beam_search

            state, _ = beam_search(
                logits_fn, None, local_b, beam_size, length, p,
                constraint_ids=maybe_cids[0] if have_ids else None,
            )
            return state.tokens, state.scores

        fn = jax.jit(shard_map_compat(
            body, mesh=mesh,
            in_specs=(specs, P(dp)) if have_ids else (specs,),
            out_specs=(P(dp, None, None), P(dp, None)),
        ))
        _SPMD_SEARCH_CACHE[key] = fn
    args = ((policy, jnp.asarray(constraint_ids, jnp.int32)) if have_ids
            else (policy,))
    return fn(*args)


_SPMD_SEARCH_CACHE: dict = {}
