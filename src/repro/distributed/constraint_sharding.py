"""SPMD sharding story for constraint backends + beam search (DESIGN.md §6).

This module makes the constrained-retrieval hot path run SPMD over a
``Mesh`` from :mod:`repro.launch.mesh`:

  * **Batch/beam parallelism** — :func:`spmd_beam_search` wraps the ordinary
    :func:`~repro.core.beam_search` in ``shard_map``, splitting the *batch*
    axis across the mesh's data axes (``dp_axes``).  Rows are independent in
    Algorithm 1 (beams only compete within their own row's ``M·V``
    candidates), so each device runs the unmodified search on its batch
    shard and results are **bit-identical** to single-device decoding
    (asserted in ``tests/test_differential_fuzz.py``).  The beam axis stays
    device-local: sharding it would turn the per-row ``top_k`` over ``M·V``
    candidates into a cross-device tournament for zero memory win (``M·V``
    floats per row is trivially small).

  * **Constraint placement** — each backend exposes
    ``ConstraintBackend.shardings(mesh, rows=...)`` (a PartitionSpec pytree
    with the backend's own treedef).  Default is paper §A.3: every table
    replicated, the constraint check collective-free.  ``rows="model"``
    row-shards the CSR ``edges`` slab — the one leaf that grows with the
    corpus — along the mesh's ``model`` axis (plus the compressed
    ``tok_delta`` slab when the backend carries one, DESIGN.md §11);
    :func:`vntk_row_sharded` then resolves cross-shard rows with a ONE-HOP
    gather: every device picks the speculative edge rows it owns and a
    single ``psum`` over ``model`` assembles the full ``(nb, bmax, 2)``
    slab on all devices.  The candidate-compressed step (§8) stays sharded
    end-to-end: :func:`vntk_row_sharded_topk` runs a shard-local top-C over
    the rows each device owns and merges the per-shard winner lists with
    one ``psum`` — ``(nb, ms, C)`` floats cross the interconnect instead of
    the ``(nb, bmax, 2)`` edge slab.

  * **Hot-swap invariance** — spec trees are pure functions of the policy's
    *structure* (static metadata), never of leaf values, so a registry
    hot-swap (``with_constraints``) keeps every sharding valid and every
    compiled executable alive (asserted in ``tests/test_spmd_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.vntk import NEG_INF, _topk_from_candidates
from repro.decoding.backends import StackedStaticBackend, StaticBackend
from repro.distributed.sharding import (
    dp_axes,
    dp_size,
    shard_map_compat,
    tree_shardings,
)

__all__ = [
    "dp_size",
    "policy_pspecs",
    "shard_policy",
    "pad_rows",
    "pad_slab",
    "pad_policy_rows",
    "vntk_row_sharded",
    "vntk_row_sharded_topk",
    "vntk_row_sharded_compressed",
    "vntk_row_sharded_compressed_topk",
    "RowShardedStatic",
    "to_row_sharded",
    "spmd_beam_search",
]


def policy_pspecs(policy, mesh: Mesh, *, rows: str = "replicated"):
    """PartitionSpec pytree for a DecodePolicy (its ``shardings`` composed).

    The result has the policy's exact treedef, so it is directly usable as
    ``shard_map`` in_specs or as input to :func:`tree_shardings`.
    """
    return policy.shardings(mesh, rows=rows)


def shard_policy(policy, mesh: Mesh, *, rows: str = "replicated"):
    """``device_put`` the policy's leaves per its spec tree.

    With ``rows="model"`` the CSR edge slab must divide the model axis —
    apply :func:`pad_policy_rows` first (the SPMD serving stack does).
    """
    return jax.device_put(
        policy, tree_shardings(mesh, policy_pspecs(policy, mesh, rows=rows))
    )


# ---------------------------------------------------------------------------
# Row-sharded CSR: padding + one-hop gather lookup
# ---------------------------------------------------------------------------
def pad_rows(obj, n_shards: int):
    """Pad the CSR ``edges`` row count to a multiple of ``n_shards``.

    Works on a TransitionMatrix (rows on axis 0) or a ConstraintStore (rows
    on axis 1).  Pad rows are zeros — outside every CSR row's
    ``[start, start + n_child)`` window, so the ``iota < n_child``
    sanitization of Alg. 2 never reads them as real edges.  Static metadata
    (``n_edges`` = real edge count) is untouched; only the array envelope
    grows, deterministically, so repeated application (every hot-swap) lands
    on the same shapes and never recompiles.
    """
    if n_shards <= 1:
        return obj
    edges = obj.edges
    e = edges.shape[-2]
    e_pad = -(-e // n_shards) * n_shards
    if e_pad == e:
        return obj
    pad = [(0, 0)] * edges.ndim
    pad[-2] = (0, e_pad - e)
    return dataclasses.replace(obj, edges=jnp.pad(edges, pad))


def pad_slab(slab, n_shards: int):
    """Pad a compressed slab's ``tok_delta`` edge axis like :func:`pad_rows`.

    Zero pad deltas sit past every CSR row's window, so the row-start
    anchored cumsum of DESIGN.md §11 never folds them into a *valid* slot's
    token — they decompress to the same garbage the uncompressed path's
    speculative over-read produces, and every consumer masks them.
    """
    if slab is None or n_shards <= 1:
        return slab
    tok_delta = slab.tok_delta
    e = tok_delta.shape[-1]
    e_pad = -(-e // n_shards) * n_shards
    if e_pad == e:
        return slab
    pad = [(0, 0)] * tok_delta.ndim
    pad[-1] = (0, e_pad - e)
    return dataclasses.replace(slab, tok_delta=jnp.pad(tok_delta, pad))


def pad_policy_rows(policy, n_shards: int):
    """Apply :func:`pad_rows` to every CSR-carrying backend in a policy.

    Backends carrying a compressed slab (DESIGN.md §11) get their
    ``tok_delta`` padded in lock-step — both leaves are row-sharded under
    ``rows="model"`` and must divide the model axis.
    """
    def pad_backend(b):
        if isinstance(b, StaticBackend):
            return dataclasses.replace(
                b, tm=pad_rows(b.tm, n_shards),
                slab=pad_slab(b.slab, n_shards),
            )
        if isinstance(b, StackedStaticBackend):
            return dataclasses.replace(
                b, store=pad_rows(b.store, n_shards),
                slab=pad_slab(b.slab, n_shards),
            )
        return b

    return dataclasses.replace(
        policy, backends=tuple(pad_backend(b) for b in policy.backends)
    )


def _sharded_row_window(nodes, row_pointers, bmax, constraint_ids,
                        batch_shape):
    """Phase 1 of Alg. 2, replicated: per-row speculative burst window.

    Row pointers are replicated (``4(S+1)`` bytes vs the edge slab's
    ``8E``), so every device computes the same global edge indices and
    validity mask; only the slab gather itself is shard-local.
    """
    n_flat = nodes.reshape(-1)
    if constraint_ids is None:
        cid = None
        starts = row_pointers[n_flat]
        lens = row_pointers[n_flat + 1] - starts
    else:
        cid = jnp.broadcast_to(constraint_ids, batch_shape).reshape(-1)
        starts = row_pointers[cid, n_flat]
        lens = row_pointers[cid, n_flat + 1] - starts
    offsets = jnp.arange(bmax, dtype=starts.dtype)
    idx = starts[:, None] + offsets[None, :]  # global edge-row indices
    valid = offsets[None, :] < lens[:, None]
    return cid, offsets, idx, valid


def _own_window(idx, rows_local, axis):
    """Ownership mask + clipped local indices for this shard's row block."""
    lo = jax.lax.axis_index(axis) * rows_local
    rel = idx - lo
    own = (rel >= 0) & (rel < rows_local)
    return own, jnp.clip(rel, 0, rows_local - 1)


def _scatter_dense(lp_flat, cols, nxt, valid, vocab_size, out_dtype):
    """Phases 3-4: the replicated scatter-projection (core/vntk.py)."""
    V = vocab_size
    nb = cols.shape[0]
    scatter_idx = jnp.where(valid, cols, V)
    rows = jnp.arange(nb)[:, None]
    cand_lp = jnp.take_along_axis(lp_flat, jnp.clip(cols, 0, V - 1), axis=1)
    masked = jnp.full((nb, V + 1), NEG_INF, dtype=out_dtype)
    masked = masked.at[rows, scatter_idx].set(
        jnp.where(valid, cand_lp, NEG_INF)
    )[:, :V]
    next_dense = jnp.zeros((nb, V + 1), dtype=jnp.int32)
    next_dense = next_dense.at[rows, scatter_idx].set(nxt)[:, :V]
    return masked, next_dense


def vntk_row_sharded(
    log_probs: jax.Array,  # (..., V)
    nodes: jax.Array,  # (...,) int32 current trie states
    row_pointers: jax.Array,  # (S+1,) or (K, S+1) int32, REPLICATED
    edges_local: jax.Array,  # (E/ms, 2) or (K, E/ms, 2): THIS shard's rows
    bmax: int,
    vocab_size: int,
    axis: str,
    constraint_ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 with the CSR edge slab row-sharded along mesh axis ``axis``.

    Must run inside ``shard_map``.  Every device computes the same global
    speculative indices; each keeps only the rows it owns
    (``lo <= idx < lo + rows_local``) and one ``psum`` over ``axis``
    assembles the full slab — the "one-hop gather" for cross-shard
    next-states.  int32 summation is exact, and exactly one shard owns each
    index, so results are bit-identical to the replicated
    :func:`~repro.core.vntk.vntk_xla`.
    """
    V = vocab_size
    batch_shape = nodes.shape
    lp_flat = log_probs.reshape(-1, V)
    cid, offsets, idx, valid = _sharded_row_window(
        nodes, row_pointers, bmax, constraint_ids, batch_shape
    )
    own, rel_c = _own_window(idx, edges_local.shape[-2], axis)
    if cid is None:
        g = jnp.take(edges_local, rel_c, axis=0)  # (nb, bmax, 2)
    else:
        g = edges_local[cid[:, None], rel_c]
    g = jnp.where(own[..., None], g, 0)
    gathered = jax.lax.psum(g, axis)  # one hop: full slab everywhere

    cols = gathered[:, :, 0]
    nxt = jnp.where(valid, gathered[:, :, 1], 0)
    masked, next_dense = _scatter_dense(
        lp_flat, cols, nxt, valid, V, log_probs.dtype
    )
    return (
        masked.reshape(batch_shape + (V,)),
        next_dense.reshape(batch_shape + (V,)),
    )


def vntk_row_sharded_topk(
    log_probs: jax.Array,  # (..., V) normalized log-probs
    nodes: jax.Array,  # (...,) int32 current trie states
    row_pointers: jax.Array,  # (S+1,) or (K, S+1) int32, REPLICATED
    edges_local: jax.Array,  # (E/ms, 2) or (K, E/ms, 2): THIS shard's rows
    bmax: int,
    vocab_size: int,
    width: int,
    axis: str,
    n_shards: int,
    constraint_ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Candidate-compressed Alg. 2 (§8) over the row-sharded edge slab.

    Shard-local top-C + one-hop psum merge: each device scores only the CSR
    slots it owns (everything else pinned to the float minimum), selects its
    local dense-rank top-``width``, and ONE ``psum`` over ``axis``
    assembles the ``(nb, ms, width)`` per-shard winner lists plus the
    additive missing-token counts on every device.  The merged pool is then
    re-ranked with the same ``top_k`` the replicated oracle uses.

    Bit-identity with :func:`~repro.core.vntk._topk_from_candidates` rests
    on two invariants:

      * any entry of the true global top-``width`` ranks at least as high
        within its own shard (its local competitors are a subset of its
        global ones), so it always survives the local cut;
      * the oracle breaks key ties by pool index — i.e. token-ascending
        over the real candidates, then the fill entries.  Each shard emits
        its winners in slot order (token-ascending, rows are token-sorted),
        shards own contiguous — hence token-ordered — slot ranges, and the
        fills are appended last, so the merged pool preserves the oracle's
        exact tie order.  Losing entries all sit at the float minimum and
        can never displace the guaranteed ``width`` real-or-fill entries.

    The i-th-missing-token counts ``|{j : cols[j] - j <= i}|`` sum exactly
    across shards (every valid slot is owned by exactly one shard), so they
    ride in the same psum.  Interconnect traffic is ``(nb, ms, width)``
    floats + ints instead of the full ``(nb, bmax, 2)`` edge slab.
    """
    V = vocab_size
    batch_shape = nodes.shape
    lp_flat = log_probs.reshape(-1, V)
    cid, offsets, idx, valid = _sharded_row_window(
        nodes, row_pointers, bmax, constraint_ids, batch_shape
    )
    own, rel_c = _own_window(idx, edges_local.shape[-2], axis)
    own = own & valid
    if cid is None:
        g = jnp.take(edges_local, rel_c, axis=0)  # (nb, bmax, 2)
    else:
        g = edges_local[cid[:, None], rel_c]
    cols = g[:, :, 0]
    nxt = g[:, :, 1]

    nb = cols.shape[0]
    minf = jnp.asarray(jnp.finfo(jnp.float32).min, lp_flat.dtype)
    cand_lp = jnp.take_along_axis(lp_flat, jnp.clip(cols, 0, V - 1), axis=1)
    key_loc = jnp.where(own, cand_lp, minf)
    tok_loc = jnp.where(own, cols, 0).astype(jnp.int32)
    nxt_loc = jnp.where(own, nxt, 0).astype(jnp.int32)

    # local pool padded with `width` sentinels so top_k is always in range
    # (a shard may own fewer than `width` slots of a row's burst)
    pad_i = jnp.zeros((nb, width), jnp.int32)
    pool_k = jnp.concatenate(
        [key_loc, jnp.full((nb, width), minf, key_loc.dtype)], axis=1
    )
    pool_t = jnp.concatenate([tok_loc, pad_i], axis=1)
    pool_n = jnp.concatenate([nxt_loc, pad_i], axis=1)
    _, win = jax.lax.top_k(pool_k, width)
    win = jnp.sort(win, axis=-1)  # back to slot order == token-ascending
    loc_k = jnp.take_along_axis(pool_k, win, axis=1)
    loc_t = jnp.take_along_axis(pool_t, win, axis=1)
    loc_n = jnp.take_along_axis(pool_n, win, axis=1)

    # i-th missing token's count contribution from this shard's slots
    adj = jnp.where(own, cols - offsets[None, :], V + bmax + 1)
    fill_i = jnp.arange(width, dtype=jnp.int32)
    cnt_loc = jnp.sum(adj[:, None, :] <= fill_i[None, :, None], axis=-1)

    # ONE psum: each shard writes its slice of the zero merge buffers
    s = jax.lax.axis_index(axis)
    buf_k = jax.lax.dynamic_update_slice(
        jnp.zeros((nb, n_shards, width), loc_k.dtype),
        loc_k[:, None, :], (0, s, 0),
    )
    buf_t = jax.lax.dynamic_update_slice(
        jnp.zeros((nb, n_shards, width), jnp.int32),
        loc_t[:, None, :], (0, s, 0),
    )
    buf_n = jax.lax.dynamic_update_slice(
        jnp.zeros((nb, n_shards, width), jnp.int32),
        loc_n[:, None, :], (0, s, 0),
    )
    buf_k, buf_t, buf_n, cnt = jax.lax.psum(
        (buf_k, buf_t, buf_n, cnt_loc), axis
    )

    # replicated finale: merged winners + the oracle's missing-token fills
    fill_tok = fill_i[None, :] + cnt
    in_range = fill_tok < V
    fill_key = jnp.where(in_range, jnp.asarray(NEG_INF, lp_flat.dtype), minf)
    fill_tok = jnp.where(in_range, fill_tok, 0)

    keys = jnp.concatenate([buf_k.reshape(nb, -1), fill_key], axis=1)
    toks = jnp.concatenate([buf_t.reshape(nb, -1), fill_tok], axis=1)
    nxts = jnp.concatenate([buf_n.reshape(nb, -1), pad_i], axis=1)
    top_vals, top_idx = jax.lax.top_k(keys, width)
    out_tok = jnp.take_along_axis(toks, top_idx, axis=1)
    out_next = jnp.take_along_axis(nxts, top_idx, axis=1)
    shp = batch_shape + (width,)
    return (top_vals.reshape(shp), out_tok.reshape(shp),
            out_next.reshape(shp))


def _sharded_delta_decode(log_probs, nodes, row_pointers, tok_delta_local,
                          base, bmax, vocab_size, axis, constraint_ids):
    """Assemble + decode a compressed burst whose slab is row-sharded.

    The delta slab (DESIGN.md §11) is sharded along its edge axis; each
    device contributes the deltas it owns (zeros elsewhere) and one
    ``psum`` assembles the full ``(nb, bmax)`` burst, which then
    decompresses with the usual row-start anchored cumsum — replicated, so
    Phases 3-4 / the candidate selection run unchanged.  Unowned indices
    contribute zero, matching the replicated oracle's out-of-range fill;
    garbage past a row's end differs only on ``~valid`` slots, which every
    consumer masks.
    """
    batch_shape = nodes.shape
    lp_flat = log_probs.reshape(-1, vocab_size)
    cid, offsets, idx, valid = _sharded_row_window(
        nodes, row_pointers, bmax, constraint_ids, batch_shape
    )
    own, rel_c = _own_window(idx, tok_delta_local.shape[-1], axis)
    if cid is None:
        d = jnp.take(tok_delta_local, rel_c, axis=0)
    else:
        d = tok_delta_local[cid[:, None], rel_c]
    deltas = jax.lax.psum(jnp.where(own, d.astype(jnp.int32), 0), axis)
    cols = jnp.cumsum(deltas, axis=1)
    base = jnp.asarray(base, jnp.int32)
    if cid is not None and base.ndim == 1:
        base = base[cid]
    base = base[:, None] if base.ndim == 1 else base
    nxt = jnp.where(valid, idx.astype(jnp.int32) + base, 0)
    return lp_flat, cols, nxt, valid, batch_shape


def vntk_row_sharded_compressed(
    log_probs: jax.Array,  # (..., V)
    nodes: jax.Array,  # (...,) int32
    row_pointers: jax.Array,  # (S+1,) or (K, S+1), REPLICATED
    tok_delta_local: jax.Array,  # (E/ms,) or (K, E/ms): THIS shard's deltas
    base,  # scalar or (K,) int32 per-level next-state base for this step
    bmax: int,
    vocab_size: int,
    axis: str,
    constraint_ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 2 over the row-sharded COMPRESSED slab (§11): the one-hop psum
    carries the ``(nb, bmax)`` int32 delta burst — a quarter of the raw
    ``(nb, bmax, 2)`` edge slab — and the decode is bit-identical to
    :func:`~repro.core.vntk.vntk_compressed_reference`."""
    V = vocab_size
    lp_flat, cols, nxt, valid, batch_shape = _sharded_delta_decode(
        log_probs, nodes, row_pointers, tok_delta_local, base, bmax, V,
        axis, constraint_ids,
    )
    masked, next_dense = _scatter_dense(
        lp_flat, cols, nxt, valid, V, log_probs.dtype
    )
    return (
        masked.reshape(batch_shape + (V,)),
        next_dense.reshape(batch_shape + (V,)),
    )


def vntk_row_sharded_compressed_topk(
    log_probs: jax.Array,  # (..., V) normalized log-probs
    nodes: jax.Array,  # (...,) int32
    row_pointers: jax.Array,  # (S+1,) or (K, S+1), REPLICATED
    tok_delta_local: jax.Array,  # (E/ms,) or (K, E/ms)
    base,  # scalar or (K,) int32
    bmax: int,
    vocab_size: int,
    width: int,
    axis: str,
    constraint_ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Candidate-compressed step over the row-sharded compressed slab.

    The burst must decompress before candidates can be ranked (cumsum needs
    the whole row-start-anchored prefix), so the psum assembles the delta
    burst and the §8 selection runs replicated — the interconnect payload
    is already smaller than the sharded-topk merge for typical widths.
    """
    V = vocab_size
    lp_flat, cols, nxt, valid, batch_shape = _sharded_delta_decode(
        log_probs, nodes, row_pointers, tok_delta_local, base, bmax, V,
        axis, constraint_ids,
    )
    sc, tok, nx = _topk_from_candidates(lp_flat, cols, nxt, valid, width, V)
    shp = batch_shape + (width,)
    return sc.reshape(shp), tok.reshape(shp), nx.reshape(shp)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RowShardedStatic:
    """Shard-local view of a Static/StackedStatic backend inside shard_map.

    Wraps the backend whose ``edges`` leaf arrived row-sharded: dense-band
    steps delegate to the inner backend (dense tables are replicated), sparse
    steps run :func:`vntk_row_sharded`.  Built by :func:`to_row_sharded`
    inside the shard_map body — never constructed by user code.
    """

    inner: object  # StaticBackend | StackedStaticBackend (pytree child)
    axis: str = dataclasses.field(
        default="model", metadata=dict(static=True)
    )
    # static shard count of `axis` — jax.lax has no axis_size query, so the
    # builder (spmd_beam_search) threads mesh.shape[axis] through
    # to_row_sharded; only the sharded-topk merge buffers need it.
    n_shards: int = dataclasses.field(default=1, metadata=dict(static=True))

    supports_fused = False
    needs_prefix = False
    # Candidate compression composes with row sharding (DESIGN.md §8 x §6):
    # topk_step runs the shard-local top-C + one-hop psum merge of
    # vntk_row_sharded_topk, so the interconnect carries (nb, ms, C)
    # winner lists instead of the (nb, bmax, 2) edge slab.
    supports_topk = True

    @property
    def supports_stacked(self) -> bool:
        return self.inner.supports_stacked

    @property
    def sid_length(self) -> int:
        return self.inner.sid_length

    @property
    def num_sets(self):
        return getattr(self.inner, "num_sets", None)

    @property
    def _constraints(self):
        return (self.inner.store if isinstance(self.inner, StackedStaticBackend)
                else self.inner.tm)

    def shardings(self, mesh, *, rows: str = "replicated"):
        raise TypeError(
            "RowShardedStatic is a shard-local view; take shardings from the "
            "inner backend before entering shard_map"
        )

    def topk_at(self, step: int) -> bool:
        return self.inner.topk_at(step)

    def candidate_width(self, beams: int) -> int:
        return self.inner.candidate_width(beams)

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del prefix_tokens
        obj = self._constraints
        stacked = self.inner.supports_stacked
        if stacked and constraint_ids is None:
            raise ValueError(
                "ConstraintStore lookups need per-row constraint_ids"
            )
        if step < obj.dense_d:
            # dense band: replicated bit-packed tables, untouched path
            return self.inner.mask_step(
                log_probs, nodes, step,
                constraint_ids=constraint_ids if stacked else None,
            )
        bmax = max(obj.bmax_for_step(step), 1)
        cids = constraint_ids if stacked else None
        slab = getattr(self.inner, "slab", None)
        if slab is not None:
            return vntk_row_sharded_compressed(
                log_probs, nodes, obj.row_pointers, slab.tok_delta,
                slab.base_for_step(step), bmax, obj.vocab_size, self.axis,
                constraint_ids=cids,
            )
        return vntk_row_sharded(
            log_probs, nodes, obj.row_pointers, obj.edges, bmax,
            obj.vocab_size, self.axis, constraint_ids=cids,
        )

    def topk_step(self, values, nodes, step, width, *, prefix_tokens=None,
                  constraint_ids=None, normalized=True):
        """Sharded candidate-compressed Phases 1-2 (DESIGN.md §8 x §6)."""
        del prefix_tokens
        if not normalized:
            # to_row_sharded rejects fused inners, so the policy hands us
            # normalized log-probs; guard against direct callers.
            values = jax.nn.log_softmax(values.astype(jnp.float32), axis=-1)
        obj = self._constraints
        stacked = self.inner.supports_stacked
        if stacked and constraint_ids is None:
            raise ValueError(
                "ConstraintStore lookups need per-row constraint_ids"
            )
        if not self.topk_at(step):
            raise ValueError(
                f"no candidate row at dense step {step}; fix the policy plan"
            )
        bmax = max(obj.bmax_for_step(step), 1)
        cids = constraint_ids if stacked else None
        slab = getattr(self.inner, "slab", None)
        if slab is not None:
            return vntk_row_sharded_compressed_topk(
                values, nodes, obj.row_pointers, slab.tok_delta,
                slab.base_for_step(step), bmax, obj.vocab_size, width,
                self.axis, constraint_ids=cids,
            )
        return vntk_row_sharded_topk(
            values, nodes, obj.row_pointers, obj.edges, bmax,
            obj.vocab_size, width, self.axis, self.n_shards,
            constraint_ids=cids,
        )


def to_row_sharded(policy, axis: str = "model", n_shards: int = 1):
    """Rewrite a policy's sparse Static backends into shard-local views.

    Called inside the shard_map body, where Static backends' ``edges`` (and
    compressed ``tok_delta``) leaves are this device's row shard.
    Dense-band backend instances never touch ``edges`` and are left alone.
    Pallas/fused sparse paths have no row-sharded formulation yet —
    rejected at entry, not silently wrong.  ``n_shards`` is the static size
    of mesh axis ``axis`` (jax.lax cannot query it inside shard_map); the
    sharded-topk merge buffers are shaped with it.
    """
    def wrap(b):
        if (isinstance(b, (StaticBackend, StackedStaticBackend))
                and b.levels != "dense"):
            if b.impl == "pallas" or b.fused:
                raise ValueError(
                    "rows='model' supports the XLA unfused VNTK only; "
                    "rebuild the policy with impl='xla', fused=False"
                )
            return RowShardedStatic(inner=b, axis=axis, n_shards=n_shards)
        return b

    return dataclasses.replace(
        policy, backends=tuple(wrap(b) for b in policy.backends)
    )


# ---------------------------------------------------------------------------
# SPMD beam search: batch axis over the mesh's data axes
# ---------------------------------------------------------------------------
def spmd_beam_search(
    mesh: Mesh,
    logits_fn,
    batch_size: int,
    beam_size: int,
    length: int,
    policy,
    *,
    constraint_ids: Optional[jax.Array] = None,
    rows: str = "replicated",
):
    """Data-parallel :func:`~repro.core.beam_search` over ``mesh``.

    The batch axis is split across ``dp_axes(mesh)`` via ``shard_map``; the
    policy rides in with per-backend specs from its ``shardings`` hook (and
    with ``rows="model"`` its sparse steps run the one-hop-gather VNTK).
    ``logits_fn(carry, last, step)`` must be shard-oblivious — a function of
    its arguments and replicated closures only (the full serving path with a
    transformer + KV cache lives in ``repro.serving.spmd_engine``).

    ``batch_size`` must divide by :func:`dp_size` — callers pad with inactive
    rows (the static-shape padding rule of DESIGN.md §6).  Returns
    ``(tokens (B, M, L), scores (B, M))`` as global arrays, bit-identical to
    the single-device search.
    """
    from repro.decoding.policy import as_policy  # lazy: import cycle

    policy = as_policy(policy)
    dp = dp_axes(mesh)
    n = dp_size(mesh)
    if batch_size % n:
        raise ValueError(
            f"batch_size {batch_size} must divide the {n}-way data "
            f"parallelism (axes {dp}); pad with inactive rows"
        )
    if rows == "model":
        policy = pad_policy_rows(policy, mesh.shape["model"])
    local_b = batch_size // n
    have_ids = constraint_ids is not None
    # jit keys on the wrapped function OBJECT: without this cache a caller
    # looping over spmd_beam_search would recompile every iteration (the
    # exact per-call-jit defect GenerativeRetriever.__init__ fixed)
    key = (mesh, logits_fn, local_b, beam_size, length, rows, have_ids,
           jax.tree_util.tree_structure(policy))
    fn = _SPMD_SEARCH_CACHE.get(key)
    if fn is None:
        specs = policy_pspecs(policy, mesh, rows=rows)

        ms = mesh.shape["model"] if rows == "model" else 1

        def body(pol, *maybe_cids):
            p = (to_row_sharded(pol, n_shards=ms) if rows == "model"
                 else pol)
            from repro.core.beam_search import beam_search

            state, _ = beam_search(
                logits_fn, None, local_b, beam_size, length, p,
                constraint_ids=maybe_cids[0] if have_ids else None,
            )
            return state.tokens, state.scores

        fn = jax.jit(shard_map_compat(
            body, mesh=mesh,
            in_specs=(specs, P(dp)) if have_ids else (specs,),
            out_specs=(P(dp, None, None), P(dp, None)),
        ))
        _SPMD_SEARCH_CACHE[key] = fn
    args = ((policy, jnp.asarray(constraint_ids, jnp.int32)) if have_ids
            else (policy,))
    return fn(*args)


_SPMD_SEARCH_CACHE: dict = {}
