"""Fault-tolerant training loop.

Features (DESIGN.md §5):
  * microbatch gradient accumulation (scan; bounds activation memory),
  * optional int8 error-feedback gradient compression,
  * atomic + async checkpointing with exact-resume (step, rng, data cursor),
  * straggler mitigation hooks: per-step wall-time watchdog; steps slower
    than ``straggler_factor`` x the running median are logged and counted
    (on real fleets the callback triggers hot-spare swap / re-mesh),
  * elastic restart: ``resume()`` restores onto whatever mesh is current.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint as ckpt_lib
from repro.training.grad_compression import apply_error_feedback, init_error_state
from repro.training.optimizer import Optimizer

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    microbatches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = True
    grad_compression: bool = False
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> scalar loss
        optimizer: Optimizer,
        params,
        cfg: TrainerConfig,
    ):
        self.loss_fn = loss_fn
        self.opt = optimizer
        self.cfg = cfg
        self.params = params
        self.opt_state = optimizer.init(params)
        self.err_state = init_error_state(params) if cfg.grad_compression else None
        self.step = 0
        self.step_times: list[float] = []
        self.straggler_events: list[int] = []
        self._ckpt = (
            ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, cfg.ckpt_keep)
            if cfg.ckpt_dir and cfg.ckpt_async
            else None
        )
        self._jit_step = jax.jit(self._build_step())

    # ------------------------------------------------------------------
    def _build_step(self):
        n_mb = self.cfg.microbatches
        use_comp = self.cfg.grad_compression

        def split_mb(batch):
            return jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
                batch,
            )

        def step_fn(params, opt_state, err_state, step_no, batch):
            if n_mb == 1:
                loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            else:
                mbs = split_mb(batch)

                def mb_body(acc, mb):
                    l, g = jax.value_and_grad(self.loss_fn)(params, mb)
                    acc_l, acc_g = acc
                    return (
                        acc_l + l / n_mb,
                        jax.tree.map(
                            lambda a, b: a + b.astype(jnp.float32) / n_mb,
                            acc_g, g,
                        ),
                    ), None

                zero = (
                    jnp.zeros((), jnp.float32),
                    jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params
                    ),
                )
                (loss, grads), _ = jax.lax.scan(mb_body, zero, mbs)
            if use_comp:
                grads, err_state = apply_error_feedback(grads, err_state)
            params, opt_state = self.opt.update(grads, opt_state, params, step_no)
            return params, opt_state, err_state, loss

        return step_fn

    # ------------------------------------------------------------------
    def train_one(self, batch) -> float:
        t0 = time.time()
        self.params, self.opt_state, self.err_state, loss = self._jit_step(
            self.params,
            self.opt_state,
            self.err_state,
            jnp.asarray(self.step, jnp.int32),
            batch,
        )
        loss = float(loss)
        dt = time.time() - t0
        if len(self.step_times) >= 5:
            med = float(np.median(self.step_times[-50:]))
            if dt > self.cfg.straggler_factor * med:
                self.straggler_events.append(self.step)
        self.step_times.append(dt)
        self.step += 1
        return loss

    def maybe_checkpoint(self, data_state: dict | None = None, force=False):
        c = self.cfg
        if not c.ckpt_dir:
            return
        if not force and (self.step % c.ckpt_every != 0 or self.step == 0):
            return
        tree = {
            "params": self.params,
            "opt": self.opt_state,
            "err": self.err_state if self.err_state is not None else {},
        }
        extra = {"data_state": data_state or {}}
        if self._ckpt is not None:
            self._ckpt.save(self.step, tree, extra)
        else:
            ckpt_lib.save(c.ckpt_dir, self.step, tree, extra)
            ckpt_lib.prune(c.ckpt_dir, c.ckpt_keep)

    def resume(self, shardings=None) -> bool:
        """Restore the latest checkpoint (elastic: onto the current mesh)."""
        c = self.cfg
        if not c.ckpt_dir:
            return False
        step = ckpt_lib.latest_step(c.ckpt_dir)
        if step is None:
            return False
        template = {
            "params": self.params,
            "opt": self.opt_state,
            "err": self.err_state if self.err_state is not None else {},
        }
        tree = ckpt_lib.restore(c.ckpt_dir, step, template, shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        if self.err_state is not None:
            self.err_state = tree["err"]
        self.step = step
        return True

    def fit(self, batches: Iterator, log=print) -> list[float]:
        losses = []
        it = iter(batches)
        while self.step < self.cfg.n_steps:
            try:
                batch = next(it)  # only consume once we will actually train
            except StopIteration:
                break
            loss = self.train_one(batch)
            losses.append(loss)
            if self.step % self.cfg.log_every == 0:
                log(f"step {self.step}: loss {loss:.4f} "
                    f"({np.mean(self.step_times[-self.cfg.log_every:]):.3f}s/step)")
            self.maybe_checkpoint()
        if self._ckpt is not None:
            self._ckpt.wait()
        return losses
