"""Fault-tolerant checkpointing.

* Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash mid-write
  never corrupts the latest checkpoint.
* Self-describing: pytrees are flattened to path-keyed arrays inside an .npz;
  restore validates shapes against a template pytree.
* Async: ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap)
  and writes on a background thread so the train loop never blocks on disk.
* Elastic: ``restore`` takes optional shardings — the same checkpoint can be
  restored onto a different mesh/device count (elastic scaling after node
  loss), because checkpoints store full logical arrays, not shards.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "||"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomically write checkpoint ``step``; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}-{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    meta = {"step": step, "time": time.time(), **(extra or {})}
    mtmp = os.path.join(ckpt_dir, ".meta.tmp")
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, os.path.join(ckpt_dir, f"step_{step:010d}.json"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Restore ``step`` into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding matching template) places
    every leaf directly onto the (possibly different) target mesh — this is
    the elastic-rescale path.
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    z = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (p, leaf) in enumerate(flat):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = z[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint/template shape mismatch at {key}: "
                f"{arr.shape} vs {leaf.shape}"
            )
        if shard_flat is not None:
            leaves.append(jax.device_put(arr.astype(leaf.dtype), shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves
    )


def prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", fn))
    )
    for s in steps[:-keep]:
        for ext in (".npz", ".json"):
            try:
                os.remove(os.path.join(ckpt_dir, f"step_{s:010d}{ext}"))
            except FileNotFoundError:
                pass


class AsyncCheckpointer:
    """Snapshot synchronously (device->host), write on a daemon thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()  # at most one outstanding write
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                prune(self.ckpt_dir, self.keep)
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
