"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce dominates step
time for small models; 4x wire compression (f32 -> int8 + per-tensor scale)
with error feedback (Seide et al. 2014; 1-bit SGD lineage) keeps convergence
while quartering the traffic.

``compress``/``decompress`` are the wire codec; ``apply_error_feedback``
wraps a gradient pytree: the quantization residual is carried in a state
pytree and added back before the next round, so the *accumulated* error stays
bounded.  In multi-host deployment the codec brackets the psum inside
shard_map; in this single-process simulation it brackets the grad exchange
point (after value_and_grad, before the optimizer), which is bit-identical
behaviour for the optimizer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "init_error_state", "apply_error_feedback"]


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f32 tensor -> (int8 tensor, f32 scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, error_state):
    """Returns (decompressed grads as seen post-all-reduce, new error state)."""

    def per_leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = compress(g32)
        deq = decompress(q, s)
        return deq, g32 - deq

    out = jax.tree.map(per_leaf, grads, error_state)
    new_g = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
