"""Optimizers in pure JAX (no optax): AdamW, Adafactor, SGD-momentum.

Each optimizer is a pair of pure functions:
  init(params)            -> state pytree
  update(grads, state, params, step) -> (new_params, new_state)

Conventions: params may be bf16; optimizer state is kept in f32 (the usual
mixed-precision training recipe); the update is computed in f32 and cast back
to the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "sgd_momentum"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    name: str = "opt"


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def adamw(
    lr: float = 1e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float | None = 1.0,
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            step_ = lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
            return _cast_like(p.astype(jnp.float32) - step_, p), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update, "adamw")


def adafactor(lr: float = 1e-3, eps: float = 1e-30, decay: float = 0.8,
              grad_clip: float | None = 1.0) -> Optimizer:
    """Factored second-moment optimizer (memory-light: O(n+m) per matrix)."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(per_leaf, params)

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(p, g, s):
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None]
                u = g * jax.lax.rsqrt(rfac * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            return _cast_like(p.astype(jnp.float32) - lr * u, p), new_s

        is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, params, grads, state, is_leaf=None)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_s

    return Optimizer(init, update, "adafactor")


def sgd_momentum(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        del step

        def upd(p, g, m):
            m = momentum * m + g.astype(jnp.float32)
            return _cast_like(p.astype(jnp.float32) - lr * m, p), m

        out = jax.tree.map(upd, params, grads, state)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_m

    return Optimizer(init, update, "sgd")


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
