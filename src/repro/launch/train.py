"""Training launcher.

Local-scale entry point: trains a reduced (smoke) variant of any assigned
arch on synthetic data with the fault-tolerant trainer.  At fleet scale the
same builders run under the production mesh (see dryrun.py for the lowering
path and DESIGN.md §5 for the mesh/sharding layout).

    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_bundle, smoke_config
from repro.data.loader import ShardedBatcher
from repro.models import gnn, recsys, transformer
from repro.training.optimizer import adamw
from repro.training.trainer import Trainer, TrainerConfig


def synth_batches(arch, cfg, global_batch, seed=0):
    rng = np.random.default_rng(seed)
    fam = get_bundle(arch).family
    n = global_batch * 8
    if fam in ("lm", "gr"):
        data = {"tokens": rng.integers(0, cfg.vocab_size, (n, 33)).astype(np.int32)}
    elif fam == "recsys":
        data = {
            "sparse": np.stack(
                [rng.integers(0, v, (n, cfg.multi_hot)) for v in cfg.vocab_sizes],
                axis=1).astype(np.int32),
            "dense": rng.normal(size=(n, max(cfg.n_dense, 1))).astype(np.float32),
            "hist": rng.integers(0, 40, (n, cfg.hist_len)).astype(np.int32),
            "target": rng.integers(0, 40, (n,)).astype(np.int32),
            "label": rng.integers(0, 2, (n,)).astype(np.float32),
        }
    else:  # gnn: batched small graphs
        N, E = 24, 48
        data = {
            "node_feats": rng.normal(size=(n, N, cfg.node_feat_dim)).astype(np.float32),
            "edge_feats": rng.normal(size=(n, E, cfg.edge_feat_dim)).astype(np.float32),
            "senders": rng.integers(0, N, (n, E)).astype(np.int32),
            "receivers": rng.integers(0, N, (n, E)).astype(np.int32),
            "targets": rng.normal(size=(n, N, cfg.out_dim)).astype(np.float32),
        }
    return ShardedBatcher(data, global_batch, seed=seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    fam = get_bundle(args.arch).family
    cfg = smoke_config(args.arch)
    key = jax.random.key(0)
    if fam in ("lm", "gr"):
        params = transformer.init_params(cfg, key)
        loss = lambda p, b: transformer.lm_loss(p, b["tokens"], cfg)
    elif fam == "recsys":
        params = recsys.init_params(cfg, key)
        loss = lambda p, b: recsys.recsys_loss(p, b, cfg)
    else:
        params = gnn.init_params(cfg, key)
        loss = lambda p, b: gnn.gnn_loss(p, b, cfg)

    trainer = Trainer(
        loss, adamw(lr=1e-3), params,
        TrainerConfig(
            n_steps=args.steps, microbatches=args.microbatches,
            ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 1),
            grad_compression=args.grad_compression, log_every=5,
        ),
    )
    batches = synth_batches(args.arch, cfg, args.batch)
    if args.resume and trainer.resume():
        print(f"resumed from step {trainer.step}")
    losses = trainer.fit(batches)
    print(f"done: {trainer.step} steps, final loss {losses[-1]:.4f}, "
          f"stragglers: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
