"""One launch surface for every registered scenario (DESIGN.md §12).

    PYTHONPATH=src python -m repro.launch.run_scenario --list
    PYTHONPATH=src python -m repro.launch.run_scenario \
        --scenario cold_start_amazon --smoke --json BENCH_coldstart.json
    PYTHONPATH=src python -m repro.launch.run_scenario \
        --scenario refresh_churn --smoke --set serve.refresh_cycles=4

Replaces the per-script flag surfaces of ``examples/cold_start_amazon.py``,
``benchmarks/table3_coldstart.py``, and the demo modes of ``launch/serve.py``:
the scenario name picks the pipeline, ``--smoke`` shrinks it to CI size, and
repeatable ``--set key=value`` overrides any config field by dotted path.

``--json`` writes the machine-readable artifact (config + result + gates);
CI runs the three CPU scenarios in the ``scenarios-smoke`` job, uploads
``BENCH_coldstart.json``, and gates STATIC beating unconstrained on the
held-out cold items.  Exit status is non-zero when a scenario's own gates
fail, so the job needs no extra assertion glue for the compliance and
zero-recompile invariants.
"""
from __future__ import annotations

import argparse
import json
import logging
import sys

from repro.scenarios import (
    config_to_dict,
    get_default_registry,
    parse_override,
)

logger = logging.getLogger("repro.launch.run_scenario")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="resolve + run a registered scenario")
    ap.add_argument("--scenario", default=None,
                    help="scenario name (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="apply the scenario's smoke shrink (CI size)")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="dotted-path config override, repeatable "
                         "(e.g. --set data.cold_frac=0.05)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the scenario seed")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the {config, result, gates} artifact here")
    ap.add_argument("--log-level", default="INFO",
                    choices=["DEBUG", "INFO", "WARNING", "ERROR"])
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=getattr(logging, args.log_level),
        format="%(asctime)s %(levelname)s %(name)s %(message)s",
    )
    registry = get_default_registry()
    if args.list:
        for name, desc in registry.describe().items():
            print(f"{name:20s} {desc}")
        return 0
    if args.scenario is None:
        ap.error("--scenario NAME required (or --list)")

    overrides = dict(parse_override(s) for s in args.overrides)
    run = registry.resolve(args.scenario, smoke=args.smoke,
                           overrides=overrides, seed=args.seed)
    logger.info("scenario %s (smoke=%s, seed=%d)", args.scenario,
                args.smoke, run.config.seed)
    ctx = run.run(log=logger.info)
    result = ctx["result"]
    gates = result.get("gates", {})
    logger.info("result: %s", json.dumps(
        {k: v for k, v in result.items() if not isinstance(v, dict)},
        default=str))

    if args.json:
        artifact = {
            "meta": {"scenario": args.scenario, "smoke": args.smoke,
                     "seed": run.config.seed, "overrides": overrides},
            "config": config_to_dict(run.config),
            "result": result,
            "gates": gates,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2, default=str)
        logger.info("wrote %s", args.json)

    if gates and not gates.get("passed", True):
        logger.error("scenario gates FAILED: %s", gates)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
