import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.distributed.collectives import parse_collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell, list_cells  # noqa: E402

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the step
function on the production mesh — 16x16 (256 chips) AND 2x16x16 (512 chips,
multi-pod) — and record memory_analysis / cost_analysis / the collective
schedule.  A failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system, not in the harness.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape long_500k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --out reports/dryrun.jsonl
"""


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    with jax.set_mesh(mesh):
        jf = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jf.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = parse_collective_bytes(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh.size,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "arg_bytes_per_chip": int(ma.argument_size_in_bytes),
        "temp_bytes_per_chip": int(ma.temp_size_in_bytes),
        "out_bytes_per_chip": int(ma.output_size_in_bytes),
        "hlo_flops_per_chip": float(ca.get("flops", 0.0)),
        "hlo_bytes_per_chip": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "model_flops_per_chip": float(cell.model_flops_per_chip),
        "notes": cell.notes,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape} ({cell.kind}): "
              f"compile {t_compile:.1f}s")
        print(f"  memory_analysis: args {ma.argument_size_in_bytes/1e9:.2f} GB/chip, "
              f"temp {ma.temp_size_in_bytes/1e9:.2f} GB/chip, "
              f"out {ma.output_size_in_bytes/1e9:.2f} GB/chip")
        print(f"  cost_analysis: {ca.get('flops', 0)/1e9:.1f} GFLOP/chip, "
              f"{ca.get('bytes accessed', 0)/1e9:.2f} GB accessed/chip")
        print(f"  collectives: {coll['counts_by_op']} "
              f"link_bytes/chip {coll['link_bytes']/1e6:.1f} MB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="reports/dryrun.jsonl")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    runnable, skipped = list_cells()
    cells = [
        (a, s) for a, s, _ in runnable
        if (args.arch == "all" or a == args.arch)
        and (args.shape == "all" or s == args.shape)
    ]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    n_fail = 0
    with open(args.out, "a") as f:
        for arch, shape in cells:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                if (arch, shape, mesh_name) in done:
                    print(f"[skip cached] {arch} x {shape} @ {mesh_name}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi)
                except Exception as e:  # noqa: BLE001
                    n_fail += 1
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": mesh_name, "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"[FAIL] {arch} x {shape} @ {rec['mesh']}: "
                          f"{rec['error']}")
                    traceback.print_exc()
                f.write(json.dumps(rec) + "\n")
                f.flush()
        for arch, shape, why in skipped:
            f.write(json.dumps({
                "arch": arch, "shape": shape, "mesh": "-", "ok": None,
                "skipped": why,
            }) + "\n")
    print(f"\ndone; {n_fail} failures; skipped cells: "
          f"{[(a, s) for a, s, _ in skipped]}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
