"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the ``pod`` axis
composes with ``data`` for batch sharding (DP across pods) while ``model``
(TP/EP/sequence) stays intra-pod where ICI is fastest.

Defined as a FUNCTION so importing this module never touches jax device
state; ``launch/dryrun.py`` sets xla_force_host_platform_device_count=512
before any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh_compat", "set_mesh_compat", "make_production_mesh",
           "make_debug_mesh", "make_subset_mesh", "POD_SHAPE",
           "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)
MULTIPOD_SHAPE = (2, 16, 16)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with ``axis_types=Auto`` when this JAX supports it.

    ``jax.sharding.AxisType`` only exists from jax 0.5.x; older installs get
    the plain call (whose axes are Auto-equivalent by default).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def set_mesh_compat(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` arrived after 0.4.x; on older installs ``Mesh`` itself is
    the resource-env context manager, so return it directly.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return make_mesh_compat((n // model, model), ("data", "model"))


def make_subset_mesh(data: int, model: int = 1):
    """(data, model) mesh over the FIRST ``data * model`` devices.

    ``jax.make_mesh`` insists on covering every device; device-count scaling
    sweeps (``benchmarks/spmd_throughput.py``) need meshes over a prefix of
    the simulated host devices instead.
    """
    import numpy as np

    devs = jax.devices()
    need = data * model
    if need > len(devs):
        raise ValueError(
            f"subset mesh needs {need} devices, only {len(devs)} exist"
        )
    return jax.sharding.Mesh(
        np.asarray(devs[:need]).reshape(data, model), ("data", "model")
    )
