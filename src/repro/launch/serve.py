"""Serving launcher: STATIC-constrained generative retrieval.

    PYTHONPATH=src python -m repro.launch.serve --constraints 20000 \
        --batch 4 --beam 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.models import transformer
from repro.pipelines import gr_model_config
from repro.serving.generative_retrieval import GenerativeRetriever


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--constraints", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--sid-length", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--beam", type=int, default=8)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--unconstrained", action="store_true")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    cfg = gr_model_config(args.vocab)
    params = transformer.init_params(cfg, jax.random.key(0))
    sids = rng.integers(0, args.vocab, size=(args.constraints, args.sid_length))
    tm = None
    if not args.unconstrained:
        t0 = time.time()
        tm = TransitionMatrix.from_sids(sids, args.vocab, dense_d=2)
        print(f"constraint index: {tm.n_states} states "
              f"({time.time()-t0:.2f}s build)")
    r = GenerativeRetriever(params, cfg, tm, args.sid_length, args.vocab,
                            beam_size=args.beam)
    hist = rng.integers(0, args.vocab, (args.batch, 16)).astype(np.int32)
    beams, scores = r.retrieve(hist)  # compile
    t0 = time.time()
    for _ in range(args.requests):
        beams, scores = r.retrieve(hist)
    dt = (time.time() - t0) / args.requests
    valid = {tuple(x) for x in sids}
    compliant = all(
        tuple(beams[b, m]) in valid
        for b in range(args.batch) for m in range(args.beam)
        if scores[b, m] > NEG_INF / 2
    ) if tm is not None else "n/a"
    print(f"{dt*1e3:.1f} ms/request-batch of {args.batch} "
          f"(beam {args.beam}); compliance: {compliant}")
    print("top-1 SIDs:", beams[:, 0, :].tolist())


if __name__ == "__main__":
    main()
