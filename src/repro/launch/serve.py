"""Serving launcher: STATIC-constrained generative retrieval.

    PYTHONPATH=src python -m repro.launch.serve --constraints 20000 \
        --batch 4 --beam 8

Which engine when (``--engine``):

===========  ==============================================================
batch        Sequence-boundary ``ServingEngine`` (default).  One fused jit
             per batch — the lowest per-request dispatch overhead.  Best
             for offline/bulk retrieval and uniform prompt lengths, where
             slots finishing together wastes nothing.
spmd         ``SpmdServingEngine`` over a (data, model) mesh.  Same
             sequence-boundary semantics scaled across devices; pick it
             when one host's devices must serve a single logical batch.
continuous   Step-boundary ``ContinuousServingEngine`` (DESIGN.md §10).
             Paged history KV + chunked prefill + trie-prefix sharing:
             slots refill the moment a request completes, repeat prompts
             skip their prefill, and per-request TTFT is L steps from
             admission instead of a whole batch drain.  Best under live
             mixed traffic (hot prompts, ragged arrivals, SLO deadlines);
             needs a ``dense_d=0`` constraint index.
===========  ==============================================================

Per-request results are bit-identical across all three engines (fuzz-
asserted in tests/test_continuous.py and tests/test_spmd_serving.py).
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.observability import (
    MetricsRegistry,
    StepTimer,
    start_http_server,
)
from repro.pipelines import gr_model_config
from repro.serving.generative_retrieval import GenerativeRetriever

logger = logging.getLogger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--constraints", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--sid-length", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--beam", type=int, default=8)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--unconstrained", action="store_true")
    ap.add_argument("--impl", choices=["xla", "pallas"], default="xla",
                    help="VNTK formulation for sparse decode levels")
    ap.add_argument("--fused", action="store_true",
                    help="fuse Phase-1 log-softmax into the masking kernel")
    ap.add_argument("--no-topk", action="store_true",
                    help="disable candidate-compressed decoding and use the "
                         "vocab-aligned dense advance at every level "
                         "(DESIGN.md §8; bit-identical, for A/B timing)")
    ap.add_argument("--num-constraint-sets", type=int, default=0, metavar="K",
                    help="also build K synthetic business-constraint sets via "
                         "the ConstraintRegistry and report the stacked "
                         "ConstraintStore footprint + a mixed-constraint "
                         "retrieval batch")
    ap.add_argument("--refresh-interval", type=float, default=0.0,
                    metavar="SECS",
                    help="with --num-constraint-sets: run an AsyncRefresher "
                         "that churns ~1%% of the catalog every SECS seconds "
                         "on a background thread (delta-aware trie rebuilds, "
                         "DESIGN.md §7) while serving keeps retrieving; "
                         "reports versions observed and asserts the swaps "
                         "stayed zero-recompile")
    ap.add_argument("--refresh-cycles", type=int, default=3,
                    help="churn cycles to run under --refresh-interval")
    ap.add_argument("--engine", choices=["batch", "spmd", "continuous"],
                    default="batch",
                    help="serving engine (see the module docstring's "
                         "which-engine-when table); 'continuous' runs the "
                         "step-boundary engine demo over a RequestQueue")
    ap.add_argument("--spmd", action="store_true",
                    help="alias for --engine spmd: serve SPMD over a (data, "
                         "model) mesh spanning every visible device "
                         "(simulate a multi-chip host with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--spmd-rows", choices=["replicated", "model"],
                    default="replicated",
                    help="CSR placement under --spmd: replicate the trie "
                         "(paper §A.3) or row-shard edges along the model "
                         "axis with a one-hop gather (DESIGN.md §6)")
    ap.add_argument("--log-level", default="INFO",
                    choices=["DEBUG", "INFO", "WARNING", "ERROR"],
                    help="stdlib logging level for the repro.* loggers")
    ap.add_argument("--metrics-json", metavar="PATH", default=None,
                    help="append a JSON-lines MetricsRegistry snapshot to "
                         "PATH on exit (DESIGN.md §9)")
    ap.add_argument("--metrics-port-file", metavar="PATH", default=None,
                    help="serve Prometheus text at /metrics on an ephemeral "
                         "localhost port and write the bound port to PATH")
    args = ap.parse_args()

    logging.basicConfig(
        level=getattr(logging, args.log_level),
        format="%(asctime)s %(levelname)s %(name)s %(message)s",
    )
    metrics = MetricsRegistry()
    if args.metrics_port_file:
        _, port = start_http_server(metrics, port=0)
        with open(args.metrics_port_file, "w") as f:
            f.write(str(port))
        logger.info("metrics: http://127.0.0.1:%d/metrics", port)

    if args.spmd:
        args.engine = "spmd"

    rng = np.random.default_rng(0)
    cfg = gr_model_config(args.vocab)
    params = transformer.init_params(cfg, jax.random.key(0))
    sids = rng.integers(0, args.vocab, size=(args.constraints, args.sid_length))
    tm = None
    policy = DecodePolicy.unconstrained()
    if not args.unconstrained or args.engine == "continuous":
        t0 = time.time()
        # the continuous engine's level-free masking needs the all-sparse
        # index (node ids globally unique across levels)
        dense_d = 0 if args.engine == "continuous" else 2
        tm = TransitionMatrix.from_sids(sids, args.vocab, dense_d=dense_d)
        policy = DecodePolicy.static(tm, impl=args.impl, fused=args.fused,
                                     topk=not args.no_topk)
        logger.info("constraint index: %d states (%.2fs build); policy %s",
                    tm.n_states, time.time() - t0, policy.describe())

    if args.engine == "continuous":
        from repro.serving.continuous import ContinuousServingEngine
        from repro.serving.engine import RequestQueue

        r = GenerativeRetriever(params, cfg, policy, args.sid_length,
                                args.vocab, beam_size=args.beam)
        engine = ContinuousServingEngine(
            r, slots=args.batch, prompt_width=16,
            prefill_chunk=max(args.batch // 2, 1), metrics=metrics)
        queue = RequestQueue()
        n_req = args.requests * args.batch
        pool = rng.integers(0, args.vocab, (max(n_req // 3, 1), 16))
        rids = [queue.submit(pool[i % len(pool)].astype(np.int32),
                             args.sid_length) for i in range(n_req)]
        t0 = time.time()
        results = engine.serve(queue)
        lat = np.array([results[i]["latency_s"] for i in rids])
        hits = engine.metrics.counter("serving_prefix_share_hits_total")
        logger.info(
            "continuous: %d requests in %.1f ms (p50 %.1f ms, p99 %.1f ms); "
            "slot reuse %d, share hits prompt=%d mask_row=%d",
            n_req, (time.time() - t0) * 1e3,
            float(np.quantile(lat, 0.5)) * 1e3,
            float(np.quantile(lat, 0.99)) * 1e3,
            int(engine.metrics.counter("serving_slot_reuse_total").total()),
            int(hits.value(kind="prompt")), int(hits.value(kind="mask_row")))
        top1 = results[rids[0]]["sids"][0].tolist()
        logger.info("top-1 SIDs (request 0): %s", top1)
        if args.metrics_json:
            metrics.write_snapshot(args.metrics_json)
            logger.info("metrics snapshot appended to %s", args.metrics_json)
        return

    if args.engine == "spmd":
        from repro.launch.mesh import make_debug_mesh
        from repro.serving.spmd_engine import SpmdRetriever

        mesh = make_debug_mesh(model=2 if args.spmd_rows == "model" else 1)
        logger.info("SPMD mesh: %s over %d device(s), CSR rows=%s",
                    dict(mesh.shape), mesh.devices.size, args.spmd_rows)
        r = SpmdRetriever(params, cfg, policy, args.sid_length, args.vocab,
                          beam_size=args.beam, mesh=mesh, rows=args.spmd_rows)
    else:
        r = GenerativeRetriever(params, cfg, policy, args.sid_length,
                                args.vocab, beam_size=args.beam)
    hist = rng.integers(0, args.vocab, (args.batch, 16)).astype(np.int32)
    # StepTimer: warmup absorbs compilation, trials block on all outputs,
    # and every trial lands in the step_wall_seconds{step} histogram
    timer = StepTimer("retrieve_batch", metrics, warmup=1,
                      trials=args.requests)
    stats = timer.measure(lambda: r.retrieve(hist))
    beams, scores = r.retrieve(hist)
    valid = {tuple(x) for x in sids}
    compliant = all(
        tuple(beams[b, m]) in valid
        for b in range(args.batch) for m in range(args.beam)
        if scores[b, m] > NEG_INF / 2
    ) if tm is not None else "n/a"
    logger.info(
        "%.1f ms/request-batch of %d (beam %d, p99 %.1f ms, dispatch "
        "%.2f ms); compliance: %s",
        stats.median * 1e3, args.batch, args.beam, stats.p99 * 1e3,
        stats.dispatch_median * 1e3, compliant,
    )
    logger.info("top-1 SIDs: %s", beams[:, 0, :].tolist())

    if args.num_constraint_sets > 0 and tm is not None:
        from repro.constraints import (
            ConstraintRegistry, freshness_window, synthetic_catalog,
        )

        K = args.num_constraint_sets
        catalog = synthetic_catalog(
            rng, args.constraints, args.vocab, args.sid_length
        )
        reg = ConstraintRegistry(args.vocab, headroom=0.5, metrics=metrics)
        for k in range(K):
            # staggered freshness windows: slot k serves items newer than
            # (k+1)/K of the catalog age span
            reg.register(f"fresh_{k}", freshness_window(90.0 * (k + 1) / K))
        t0 = time.time()
        store = reg.build(catalog)
        logger.info(
            "constraint store: K=%d sets, %d state envelope (%.2fs build, "
            "registry v%d)", K, store.n_states, time.time() - t0, reg.version)
        logger.info(
            "  stacked store %.2f MB vs single matrix %.2f MB (%.1fx for "
            "%d tenants)", store.nbytes() / 1e6, tm.nbytes() / 1e6,
            store.nbytes() / max(tm.nbytes(), 1), K)
        mc_policy = DecodePolicy.stacked(store, impl=args.impl,
                                         fused=args.fused,
                                         topk=not args.no_topk)
        r_mc = GenerativeRetriever(params, cfg, mc_policy, args.sid_length,
                                   args.vocab, beam_size=args.beam)
        cids = np.arange(args.batch, dtype=np.int32) % K
        beams_mc, scores_mc = r_mc.retrieve(hist, constraint_ids=cids)
        valid_per_set = [
            {tuple(x) for x in catalog.sids[
                catalog.age_days <= 90.0 * (k + 1) / K]}
            for k in range(K)
        ]
        ok = all(
            tuple(beams_mc[b, m]) in valid_per_set[cids[b]]
            for b in range(args.batch) for m in range(args.beam)
            if scores_mc[b, m] > NEG_INF / 2
        )
        logger.info("  mixed-constraint batch (cids %s): per-request "
                    "compliance %s", cids.tolist(), ok)

        if args.refresh_interval > 0:
            from repro.constraints import AsyncRefresher, CatalogDelta

            compiles = []
            jax.monitoring.register_event_duration_secs_listener(
                lambda name, *a, **kw: compiles.append(name)
                if "backend_compile" in name else None
            )
            current = catalog
            cold_swaps = 0
            with AsyncRefresher(reg) as refresher:
                for cycle in range(args.refresh_cycles):
                    churn = max(1, current.sids.shape[0] // 100)
                    rm = current.sids[
                        rng.choice(current.sids.shape[0], churn,
                                   replace=False)
                    ]
                    added = synthetic_catalog(
                        rng, churn, args.vocab, args.sid_length
                    )
                    fut = refresher.apply_delta_async(
                        CatalogDelta(added=added, removed_sids=rm)
                    )
                    current = current.apply_delta(
                        CatalogDelta(added=added, removed_sids=rm)
                    )
                    # serving keeps going while the rebuild runs off-thread
                    beams_mc, _ = r_mc.retrieve(hist, constraint_ids=cids)
                    v = fut.result(timeout=120)
                    store, _ = reg.current()
                    cold = r_mc.set_constraints(store)  # engine batch boundary
                    cold_swaps += int(cold)
                    beams_mc, _ = r_mc.retrieve(hist, constraint_ids=cids)
                    logger.info(
                        "  refresh cycle %d: +/-%d items -> registry v%s "
                        "(cold=%s), top-1 %s", cycle, churn, v, cold,
                        beams_mc[0, 0].tolist())
                    time.sleep(args.refresh_interval)
            # a cold (regrown-envelope) swap retraces exactly once; hot
            # swaps must compile NOTHING — enforce it, don't just print it
            if len(compiles) != cold_swaps:
                raise SystemExit(
                    f"refresh demo: {len(compiles)} recompiles for "
                    f"{cold_swaps} cold swap(s) — hot swaps must stay "
                    "zero-recompile"
                )
            logger.info(
                "  async refresh: %d cycles, %d cold swap(s), %d recompiles "
                "(hot swaps stayed zero-recompile)", args.refresh_cycles,
                cold_swaps, len(compiles))

    if args.metrics_json:
        metrics.write_snapshot(args.metrics_json)
        logger.info("metrics snapshot appended to %s", args.metrics_json)


if __name__ == "__main__":
    main()
