"""Serving launcher: STATIC-constrained generative retrieval.

    PYTHONPATH=src python -m repro.launch.serve --constraints 20000 \
        --batch 4 --beam 8

Which engine when (``--engine``):

===========  ==============================================================
batch        Sequence-boundary ``ServingEngine`` (default).  One fused jit
             per batch — the lowest per-request dispatch overhead.  Best
             for offline/bulk retrieval and uniform prompt lengths, where
             slots finishing together wastes nothing.  Degradation: a
             failed decode fails the whole batch (every request in it gets
             a ``decode_fault`` error result); expired requests shed at
             enqueue and again before each batch forms.
spmd         ``SpmdServingEngine`` over a (data, model) mesh.  Same
             sequence-boundary semantics scaled across devices; pick it
             when one host's devices must serve a single logical batch.
             Degradation: identical to ``batch`` (whole-batch blast
             radius — one mesh, one program).
continuous   Step-boundary ``ContinuousServingEngine`` (DESIGN.md §10).
             Paged history KV + chunked prefill + trie-prefix sharing:
             slots refill the moment a request completes, repeat prompts
             skip their prefill, and per-request TTFT is L steps from
             admission instead of a whole batch drain.  Best under live
             mixed traffic (hot prompts, ragged arrivals, SLO deadlines);
             needs a ``dense_d=0`` constraint index.  Degradation: a
             failed step retries bit-identically next iteration (state is
             only mutated on success); KV exhaustion drops the share
             table, then retries admission, then sheds ``kv_pages``.
===========  ==============================================================

All three engines share one reliability contract (DESIGN.md §13): the
degradation ladder is retry -> serve-stale -> shed at admission, and a
request is NEVER decoded unconstrained as a fallback.  ``--fault-schedule``
arms the deterministic fault injector for chaos drills; ``--health-port-file``
exposes ``/healthz``, ``/readyz`` and ``/livez`` next to ``/metrics``.

Per-request results are bit-identical across all three engines (fuzz-
asserted in tests/test_continuous.py and tests/test_spmd_serving.py).

The old demo modes (``--num-constraint-sets`` mixed-tenant batches and the
``--refresh-interval`` async-churn loop) moved to the scenario registry —
they are the ``multi_constraint`` and ``refresh_churn`` scenarios::

    PYTHONPATH=src python -m repro.launch.run_scenario \
        --scenario multi_constraint --smoke
    PYTHONPATH=src python -m repro.launch.run_scenario \
        --scenario refresh_churn --smoke --set serve.refresh_cycles=4
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.observability import (
    MetricsRegistry,
    StepTimer,
    start_http_server,
)
from repro.reliability import CircuitBreaker, FaultInjector, HealthMonitor, install
from repro.scenarios import gr_model_config
from repro.serving.generative_retrieval import GenerativeRetriever

logger = logging.getLogger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--constraints", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--sid-length", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--beam", type=int, default=8)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--unconstrained", action="store_true")
    ap.add_argument("--impl", choices=["xla", "pallas"], default="xla",
                    help="VNTK formulation for sparse decode levels")
    ap.add_argument("--fused", action="store_true",
                    help="fuse Phase-1 log-softmax into the masking kernel")
    ap.add_argument("--no-topk", action="store_true",
                    help="disable candidate-compressed decoding and use the "
                         "vocab-aligned dense advance at every level "
                         "(DESIGN.md §8; bit-identical, for A/B timing)")
    ap.add_argument("--engine", choices=["batch", "spmd", "continuous"],
                    default="batch",
                    help="serving engine (see the module docstring's "
                         "which-engine-when table); 'continuous' runs the "
                         "step-boundary engine demo over a RequestQueue")
    ap.add_argument("--spmd", action="store_true",
                    help="alias for --engine spmd: serve SPMD over a (data, "
                         "model) mesh spanning every visible device "
                         "(simulate a multi-chip host with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--spmd-rows", choices=["replicated", "model"],
                    default="replicated",
                    help="CSR placement under --spmd: replicate the trie "
                         "(paper §A.3) or row-shard edges along the model "
                         "axis with a one-hop gather (DESIGN.md §6)")
    ap.add_argument("--log-level", default="INFO",
                    choices=["DEBUG", "INFO", "WARNING", "ERROR"],
                    help="stdlib logging level for the repro.* loggers")
    ap.add_argument("--metrics-json", metavar="PATH", default=None,
                    help="append a JSON-lines MetricsRegistry snapshot to "
                         "PATH on exit (DESIGN.md §9)")
    ap.add_argument("--metrics-port-file", metavar="PATH", default=None,
                    help="serve Prometheus text at /metrics on an ephemeral "
                         "localhost port and write the bound port to PATH")
    ap.add_argument("--fault-schedule", metavar="JSON", default=None,
                    help="arm the deterministic fault injector (DESIGN.md "
                         "§13): inline JSON or a path to a JSON file of the "
                         "form {\"seed\": 0, \"faults\": [{\"point\": ..., "
                         "\"mode\": ...}, ...]}")
    ap.add_argument("--health-port-file", metavar="PATH", default=None,
                    help="serve /healthz, /readyz and /livez (plus /metrics) "
                         "on an ephemeral localhost port and write the bound "
                         "port to PATH; readiness reflects the serving "
                         "circuit breaker")
    args = ap.parse_args()

    logging.basicConfig(
        level=getattr(logging, args.log_level),
        format="%(asctime)s %(levelname)s %(name)s %(message)s",
    )
    metrics = MetricsRegistry()

    injector = None
    if args.fault_schedule:
        injector = FaultInjector.from_json(args.fault_schedule)
        install(injector)
        logger.info("fault injection armed (seed=%d)", injector.seed)

    breaker = CircuitBreaker(name="serve", metrics=metrics)
    if args.metrics_port_file or args.health_port_file:
        health = None
        if args.health_port_file:
            health = HealthMonitor(breaker=breaker, metrics=metrics)
        _, port = start_http_server(metrics, port=0, health=health)
        for path in (args.metrics_port_file, args.health_port_file):
            if path:
                with open(path, "w") as f:
                    f.write(str(port))
        logger.info("metrics: http://127.0.0.1:%d/metrics", port)
        if health is not None:
            logger.info("health:  http://127.0.0.1:%d/healthz", port)

    if args.spmd:
        args.engine = "spmd"

    rng = np.random.default_rng(0)
    cfg = gr_model_config(args.vocab)
    params = transformer.init_params(cfg, jax.random.key(0))
    sids = rng.integers(0, args.vocab, size=(args.constraints, args.sid_length))
    tm = None
    policy = DecodePolicy.unconstrained()
    if not args.unconstrained or args.engine == "continuous":
        t0 = time.time()
        # the continuous engine's level-free masking needs the all-sparse
        # index (node ids globally unique across levels)
        dense_d = 0 if args.engine == "continuous" else 2
        tm = TransitionMatrix.from_sids(sids, args.vocab, dense_d=dense_d)
        policy = DecodePolicy.static(tm, impl=args.impl, fused=args.fused,
                                     topk=not args.no_topk)
        logger.info("constraint index: %d states (%.2fs build); policy %s",
                    tm.n_states, time.time() - t0, policy.describe())

    if args.engine == "continuous":
        from repro.serving.continuous import ContinuousServingEngine
        from repro.serving.engine import RequestQueue

        r = GenerativeRetriever(params, cfg, policy, args.sid_length,
                                args.vocab, beam_size=args.beam)
        engine = ContinuousServingEngine(
            r, slots=args.batch, prompt_width=16,
            prefill_chunk=max(args.batch // 2, 1), metrics=metrics,
            breaker=breaker)
        queue = RequestQueue()
        n_req = args.requests * args.batch
        pool = rng.integers(0, args.vocab, (max(n_req // 3, 1), 16))
        rids = [queue.submit(pool[i % len(pool)].astype(np.int32),
                             args.sid_length) for i in range(n_req)]
        t0 = time.time()
        results = engine.serve(queue)
        done = [i for i in rids if "latency_s" in results[i]]
        if injector is not None and len(done) < n_req:
            logger.info("degraded under faults: %d/%d completed (%s)",
                        len(done), n_req,
                        {results[i].get("reason", "?")
                         for i in rids if i not in set(done)})
        lat = np.array([results[i]["latency_s"] for i in done]
                       or [float("nan")])
        hits = engine.metrics.counter("serving_prefix_share_hits_total")
        logger.info(
            "continuous: %d requests in %.1f ms (p50 %.1f ms, p99 %.1f ms); "
            "slot reuse %d, share hits prompt=%d mask_row=%d",
            len(done), (time.time() - t0) * 1e3,
            float(np.quantile(lat, 0.5)) * 1e3,
            float(np.quantile(lat, 0.99)) * 1e3,
            int(engine.metrics.counter("serving_slot_reuse_total").total()),
            int(hits.value(kind="prompt")), int(hits.value(kind="mask_row")))
        if done:
            top1 = results[done[0]]["sids"][0].tolist()
            logger.info("top-1 SIDs (request %d): %s", done[0], top1)
        if injector is not None:
            logger.info("injected faults fired: %d", injector.n_fires())
        if args.metrics_json:
            metrics.write_snapshot(args.metrics_json)
            logger.info("metrics snapshot appended to %s", args.metrics_json)
        return

    if args.engine == "spmd":
        from repro.launch.mesh import make_debug_mesh
        from repro.serving.spmd_engine import SpmdRetriever

        mesh = make_debug_mesh(model=2 if args.spmd_rows == "model" else 1)
        logger.info("SPMD mesh: %s over %d device(s), CSR rows=%s",
                    dict(mesh.shape), mesh.devices.size, args.spmd_rows)
        r = SpmdRetriever(params, cfg, policy, args.sid_length, args.vocab,
                          beam_size=args.beam, mesh=mesh, rows=args.spmd_rows)
    else:
        r = GenerativeRetriever(params, cfg, policy, args.sid_length,
                                args.vocab, beam_size=args.beam)
    hist = rng.integers(0, args.vocab, (args.batch, 16)).astype(np.int32)
    # StepTimer: warmup absorbs compilation, trials block on all outputs,
    # and every trial lands in the step_wall_seconds{step} histogram
    timer = StepTimer("retrieve_batch", metrics, warmup=1,
                      trials=args.requests)
    stats = timer.measure(lambda: r.retrieve(hist))
    beams, scores = r.retrieve(hist)
    valid = {tuple(x) for x in sids}
    compliant = all(
        tuple(beams[b, m]) in valid
        for b in range(args.batch) for m in range(args.beam)
        if scores[b, m] > NEG_INF / 2
    ) if tm is not None else "n/a"
    logger.info(
        "%.1f ms/request-batch of %d (beam %d, p99 %.1f ms, dispatch "
        "%.2f ms); compliance: %s",
        stats.median * 1e3, args.batch, args.beam, stats.p99 * 1e3,
        stats.dispatch_median * 1e3, compliant,
    )
    logger.info("top-1 SIDs: %s", beams[:, 0, :].tolist())

    if args.metrics_json:
        metrics.write_snapshot(args.metrics_json)
        logger.info("metrics snapshot appended to %s", args.metrics_json)


if __name__ == "__main__":
    main()
