"""Serving launcher: STATIC-constrained generative retrieval.

    PYTHONPATH=src python -m repro.launch.serve --constraints 20000 \
        --batch 4 --beam 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.decoding import DecodePolicy
from repro.models import transformer
from repro.pipelines import gr_model_config
from repro.serving.generative_retrieval import GenerativeRetriever


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--constraints", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--sid-length", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--beam", type=int, default=8)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--unconstrained", action="store_true")
    ap.add_argument("--impl", choices=["xla", "pallas"], default="xla",
                    help="VNTK formulation for sparse decode levels")
    ap.add_argument("--fused", action="store_true",
                    help="fuse Phase-1 log-softmax into the masking kernel")
    ap.add_argument("--no-topk", action="store_true",
                    help="disable candidate-compressed decoding and use the "
                         "vocab-aligned dense advance at every level "
                         "(DESIGN.md §8; bit-identical, for A/B timing)")
    ap.add_argument("--num-constraint-sets", type=int, default=0, metavar="K",
                    help="also build K synthetic business-constraint sets via "
                         "the ConstraintRegistry and report the stacked "
                         "ConstraintStore footprint + a mixed-constraint "
                         "retrieval batch")
    ap.add_argument("--refresh-interval", type=float, default=0.0,
                    metavar="SECS",
                    help="with --num-constraint-sets: run an AsyncRefresher "
                         "that churns ~1%% of the catalog every SECS seconds "
                         "on a background thread (delta-aware trie rebuilds, "
                         "DESIGN.md §7) while serving keeps retrieving; "
                         "reports versions observed and asserts the swaps "
                         "stayed zero-recompile")
    ap.add_argument("--refresh-cycles", type=int, default=3,
                    help="churn cycles to run under --refresh-interval")
    ap.add_argument("--spmd", action="store_true",
                    help="serve SPMD over a (data, model) mesh spanning every "
                         "visible device (simulate a multi-chip host with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--spmd-rows", choices=["replicated", "model"],
                    default="replicated",
                    help="CSR placement under --spmd: replicate the trie "
                         "(paper §A.3) or row-shard edges along the model "
                         "axis with a one-hop gather (DESIGN.md §6)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    cfg = gr_model_config(args.vocab)
    params = transformer.init_params(cfg, jax.random.key(0))
    sids = rng.integers(0, args.vocab, size=(args.constraints, args.sid_length))
    tm = None
    policy = DecodePolicy.unconstrained()
    if not args.unconstrained:
        t0 = time.time()
        tm = TransitionMatrix.from_sids(sids, args.vocab, dense_d=2)
        policy = DecodePolicy.static(tm, impl=args.impl, fused=args.fused,
                                     topk=not args.no_topk)
        print(f"constraint index: {tm.n_states} states "
              f"({time.time()-t0:.2f}s build); policy {policy.describe()}")
    if args.spmd:
        from repro.launch.mesh import make_debug_mesh
        from repro.serving.spmd_engine import SpmdRetriever

        mesh = make_debug_mesh(model=2 if args.spmd_rows == "model" else 1)
        print(f"SPMD mesh: {dict(mesh.shape)} over {mesh.devices.size} "
              f"device(s), CSR rows={args.spmd_rows}")
        r = SpmdRetriever(params, cfg, policy, args.sid_length, args.vocab,
                          beam_size=args.beam, mesh=mesh, rows=args.spmd_rows)
    else:
        r = GenerativeRetriever(params, cfg, policy, args.sid_length,
                                args.vocab, beam_size=args.beam)
    hist = rng.integers(0, args.vocab, (args.batch, 16)).astype(np.int32)
    beams, scores = r.retrieve(hist)  # compile
    t0 = time.time()
    for _ in range(args.requests):
        beams, scores = r.retrieve(hist)
    dt = (time.time() - t0) / args.requests
    valid = {tuple(x) for x in sids}
    compliant = all(
        tuple(beams[b, m]) in valid
        for b in range(args.batch) for m in range(args.beam)
        if scores[b, m] > NEG_INF / 2
    ) if tm is not None else "n/a"
    print(f"{dt*1e3:.1f} ms/request-batch of {args.batch} "
          f"(beam {args.beam}); compliance: {compliant}")
    print("top-1 SIDs:", beams[:, 0, :].tolist())

    if args.num_constraint_sets > 0 and tm is not None:
        from repro.constraints import (
            ConstraintRegistry, freshness_window, synthetic_catalog,
        )

        K = args.num_constraint_sets
        catalog = synthetic_catalog(
            rng, args.constraints, args.vocab, args.sid_length
        )
        reg = ConstraintRegistry(args.vocab, headroom=0.5)
        for k in range(K):
            # staggered freshness windows: slot k serves items newer than
            # (k+1)/K of the catalog age span
            reg.register(f"fresh_{k}", freshness_window(90.0 * (k + 1) / K))
        t0 = time.time()
        store = reg.build(catalog)
        print(f"constraint store: K={K} sets, {store.n_states} state envelope "
              f"({time.time()-t0:.2f}s build, registry v{reg.version})")
        print(f"  stacked store {store.nbytes()/1e6:.2f} MB vs single matrix "
              f"{tm.nbytes()/1e6:.2f} MB "
              f"({store.nbytes()/max(tm.nbytes(),1):.1f}x for {K} tenants)")
        mc_policy = DecodePolicy.stacked(store, impl=args.impl,
                                         fused=args.fused,
                                         topk=not args.no_topk)
        r_mc = GenerativeRetriever(params, cfg, mc_policy, args.sid_length,
                                   args.vocab, beam_size=args.beam)
        cids = np.arange(args.batch, dtype=np.int32) % K
        beams_mc, scores_mc = r_mc.retrieve(hist, constraint_ids=cids)
        valid_per_set = [
            {tuple(x) for x in catalog.sids[
                catalog.age_days <= 90.0 * (k + 1) / K]}
            for k in range(K)
        ]
        ok = all(
            tuple(beams_mc[b, m]) in valid_per_set[cids[b]]
            for b in range(args.batch) for m in range(args.beam)
            if scores_mc[b, m] > NEG_INF / 2
        )
        print(f"  mixed-constraint batch (cids {cids.tolist()}): "
              f"per-request compliance {ok}")

        if args.refresh_interval > 0:
            from repro.constraints import AsyncRefresher, CatalogDelta

            compiles = []
            jax.monitoring.register_event_duration_secs_listener(
                lambda name, *a, **kw: compiles.append(name)
                if "backend_compile" in name else None
            )
            current = catalog
            cold_swaps = 0
            with AsyncRefresher(reg) as refresher:
                for cycle in range(args.refresh_cycles):
                    churn = max(1, current.sids.shape[0] // 100)
                    rm = current.sids[
                        rng.choice(current.sids.shape[0], churn,
                                   replace=False)
                    ]
                    added = synthetic_catalog(
                        rng, churn, args.vocab, args.sid_length
                    )
                    fut = refresher.apply_delta_async(
                        CatalogDelta(added=added, removed_sids=rm)
                    )
                    current = current.apply_delta(
                        CatalogDelta(added=added, removed_sids=rm)
                    )
                    # serving keeps going while the rebuild runs off-thread
                    beams_mc, _ = r_mc.retrieve(hist, constraint_ids=cids)
                    v = fut.result(timeout=120)
                    store, _ = reg.current()
                    cold = r_mc.set_constraints(store)  # engine batch boundary
                    cold_swaps += int(cold)
                    beams_mc, _ = r_mc.retrieve(hist, constraint_ids=cids)
                    print(f"  refresh cycle {cycle}: +/-{churn} items -> "
                          f"registry v{v} (cold={cold}), top-1 "
                          f"{beams_mc[0, 0].tolist()}")
                    time.sleep(args.refresh_interval)
            # a cold (regrown-envelope) swap retraces exactly once; hot
            # swaps must compile NOTHING — enforce it, don't just print it
            if len(compiles) != cold_swaps:
                raise SystemExit(
                    f"refresh demo: {len(compiles)} recompiles for "
                    f"{cold_swaps} cold swap(s) — hot swaps must stay "
                    "zero-recompile"
                )
            print(f"  async refresh: {args.refresh_cycles} cycles, "
                  f"{cold_swaps} cold swap(s), {len(compiles)} recompiles "
                  "(hot swaps stayed zero-recompile)")


if __name__ == "__main__":
    main()
