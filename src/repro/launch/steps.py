"""Per-(architecture x shape) step builders for the multi-pod dry-run.

``build_cell(arch_id, shape_name, mesh)`` returns a :class:`Cell` holding the
jittable step function, its ``input_specs()`` (ShapeDtypeStruct stand-ins —
weak-type-correct, shardable, never allocated), the in/out shardings, and the
analytic MODEL_FLOPS used by the roofline (§Roofline: 6·N·D dense,
6·N_active·D MoE, + exact attention terms).

Step kinds:
  lm/train    — loss + grads + AdamW update (full training step)
  lm/prefill  — forward + KV-cache build, last-token logits
  lm/decode   — one token against a (sequence-sharded) KV cache
  gr/serve    — one *constrained* SID decode step: prefix-shared decode +
                Algorithm 1 (LogSoftmax -> VNTK mask -> beam top-k -> gather)
  gnn/train   — full-graph or sampled-subgraph regression step
  recsys/*    — train / bulk-serve / retrieval scoring
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_bundle, static_gr, supports_shape
from repro.configs.base import GraphShape, LMShape, RecsysShape
from repro.distributed import sharding as sh
from repro.models import gnn, recsys, transformer
from repro.training.optimizer import adamw

__all__ = ["Cell", "build_cell", "input_specs", "list_cells"]

_OPT = adamw(lr=1e-4)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    model_flops_per_chip: float  # analytic useful flops / chip / step
    notes: str = ""
    donate_argnums: tuple = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _bspec(mesh, batch, rank):
    dp = sh.dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    lead = dp if (batch % n_dp == 0 and batch >= n_dp) else None
    return P(lead, *([None] * (rank - 1)))


def _round_to(x, m):
    return -(-x // m) * m


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------


def _lm_attn_flops(cfg, n_tokens, kv_len=None, causal=True):
    hd = cfg.resolved_head_dim() if cfg.attention != "mla" else (
        cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    kv_len = kv_len or n_tokens
    if cfg.sliding_window:
        kv_len = min(kv_len, cfg.sliding_window)
    f = 2 * 2 * n_tokens * kv_len * cfg.n_heads * hd
    return f / 2 if causal else f


def _lm_train_cell(arch_id, bundle, shape: LMShape, mesh) -> Cell:
    cfg = bundle.config
    dp_ok = shape.global_batch % int(
        np.prod([mesh.shape[a] for a in sh.dp_axes(mesh)])) == 0
    if cfg.use_sp and dp_ok:
        cfg = dataclasses.replace(cfg, sp_axes=sh.dp_axes(mesh))
    p_specs = transformer.param_specs(cfg)
    o_specs = jax.eval_shape(_OPT.init, p_specs)
    p_psh = sh.tree_shardings(
        mesh, sh.lm_param_pspecs(p_specs, mesh, cfg.n_kv_heads)
    )
    o_psh = {"m": p_psh, "v": p_psh}
    tok = _sds((shape.global_batch, shape.seq_len), jnp.int32)
    tok_psh = NamedSharding(mesh, _bspec(mesh, shape.global_batch, 2))
    step_psh = NamedSharding(mesh, P())

    n_mb = cfg.train_microbatches

    def train_step(params, opt_state, step_no, tokens):
        if n_mb == 1:
            loss, grads = jax.value_and_grad(
                lambda p: transformer.lm_loss(p, tokens, cfg)
            )(params)
        else:
            mbs = tokens.reshape(n_mb, tokens.shape[0] // n_mb, -1)

            def mb_body(acc, mb):
                l, g = jax.value_and_grad(
                    lambda p: transformer.lm_loss(p, mb, cfg)
                )(params)
                return (acc[0] + l / n_mb,
                        jax.tree.map(lambda a, b: a + b / n_mb, acc[1], g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params))
            (loss, grads), _ = jax.lax.scan(mb_body, zero, mbs)
        new_params, new_opt = _OPT.update(grads, opt_state, params, step_no)
        return new_params, new_opt, loss

    n_chips = mesh.size
    tokens_total = shape.global_batch * shape.seq_len
    mf = (
        6 * cfg.active_param_count() * tokens_total
        + 3 * shape.global_batch * _lm_attn_flops(cfg, shape.seq_len)
    ) / n_chips
    return Cell(
        arch_id, shape.name, "train", train_step,
        (p_specs, o_specs, _sds((), jnp.int32), tok),
        (p_psh, o_psh, step_psh, tok_psh),
        (p_psh, o_psh, NamedSharding(mesh, P())),
        mf,
        donate_argnums=(0, 1),
    )


def _lm_prefill_cell(arch_id, bundle, shape: LMShape, mesh) -> Cell:
    cfg = bundle.config
    p_specs = transformer.param_specs(cfg)
    p_psh = sh.tree_shardings(
        mesh, sh.lm_param_pspecs(p_specs, mesh, cfg.n_kv_heads)
    )
    tok = _sds((shape.global_batch, shape.seq_len), jnp.int32)
    tok_psh = NamedSharding(mesh, _bspec(mesh, shape.global_batch, 2))

    def prefill_step(params, tokens):
        logits, cache = transformer.prefill(params, tokens, cfg)
        return logits, cache

    cache_specs = jax.eval_shape(
        lambda p, t: transformer.prefill(p, t, cfg)[1], p_specs, tok
    )
    cache_psh = sh.tree_shardings(
        mesh,
        sh.kv_cache_pspecs(cache_specs, mesh,
                           batch_shardable=shape.global_batch >= mesh.size // 16),
    )
    n_chips = mesh.size
    tokens_total = shape.global_batch * shape.seq_len
    mf = (
        2 * cfg.active_param_count() * tokens_total
        + shape.global_batch * _lm_attn_flops(cfg, shape.seq_len)
    ) / n_chips
    return Cell(
        arch_id, shape.name, "prefill", prefill_step,
        (p_specs, tok),
        (p_psh, tok_psh),
        (NamedSharding(mesh, _bspec(mesh, shape.global_batch, 3)), cache_psh),
        mf,
    )


def _lm_decode_cell(arch_id, bundle, shape: LMShape, mesh) -> Cell:
    cfg = bundle.config
    p_specs = transformer.param_specs(cfg)
    p_psh = sh.tree_shardings(
        mesh, sh.lm_param_pspecs(p_specs, mesh, cfg.n_kv_heads)
    )
    B = shape.global_batch
    slots = _round_to(shape.seq_len + 128, 256)
    if cfg.sliding_window and cfg.sliding_window < slots:
        slots = cfg.sliding_window
    cache_specs = jax.eval_shape(
        functools.partial(transformer.init_cache, cfg, B, slots)
    )
    cache_psh = sh.tree_shardings(
        mesh, sh.kv_cache_pspecs(cache_specs, mesh, batch_shardable=B > 1)
    )
    tok = _sds((B, 1), jnp.int32)
    tok_psh = NamedSharding(mesh, _bspec(mesh, B, 2))

    def decode(params, cache, tokens):
        # place the query at the end of the prefilled context
        cache = dataclasses.replace(cache, pos=jnp.asarray(shape.seq_len, jnp.int32))
        return transformer.decode_step(params, cache, tokens, cfg)

    kv_len = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    if cfg.attention == "mla":
        attn = 2 * 2 * B * kv_len * cfg.n_heads * (
            cfg.kv_lora_rank + cfg.qk_rope_head_dim)
    else:
        attn = 2 * 2 * B * kv_len * cfg.n_heads * cfg.resolved_head_dim()
    mf = (2 * cfg.active_param_count() * B + attn) / mesh.size
    logits_psh = NamedSharding(mesh, _bspec(mesh, B, 3))
    if cfg.defer_cache_write:
        bdp = _bspec(mesh, B, 1)[0]
        pend_psh = NamedSharding(mesh, P(None, bdp, None, None, None)) \
            if cfg.attention != "mla" \
            else NamedSharding(mesh, P(None, bdp, None, None))
        out_sh = (logits_psh, cache_psh, (pend_psh, pend_psh))
    else:
        out_sh = (logits_psh, cache_psh)
    return Cell(
        arch_id, shape.name, "decode", decode,
        (p_specs, cache_specs, tok),
        (p_psh, cache_psh, tok_psh),
        out_sh,
        mf,
        donate_argnums=(1,),
    )


# --------------------------------------------------------------------------
# GR (paper) cells
# --------------------------------------------------------------------------


def _gr_trie_specs():
    """Spec-only stand-in for the 20M-constraint CSR (see DESIGN.md §6)."""
    V, L, C = static_gr.SID_VOCAB, static_gr.SID_LENGTH, static_gr.N_CONSTRAINTS
    n_states = 1 + sum(min(V ** l, C) for l in range(2, L + 1))
    n_edges = sum(min(V ** l, C) for l in range(3, L + 1))
    return {
        "row_pointers": _sds((n_states + 1,), jnp.int32),
        "edges": _sds((n_edges + 256, 2), jnp.int32),
        "l1_mask_packed": _sds((V, V // 8), jnp.uint8),
        "l1_states": _sds((V, V), jnp.int32),
    }


def _gr_serve_cell(arch_id, bundle, shape, mesh, constrained: bool) -> Cell:
    cfg = bundle.config
    V = cfg.vocab_size
    sid_v = static_gr.SID_VOCAB
    B, M = shape.global_batch, shape.beam_size
    S_h = shape.history_len
    S_sid = shape.sid_length
    hd = cfg.resolved_head_dim()
    KV, L = cfg.n_kv_heads, cfg.n_layers
    dt = jnp.bfloat16

    p_specs = transformer.param_specs(cfg)
    if cfg.serve_replicate_weights:
        # weights fit per-chip; batch shards over ALL axes => no TP psums
        p_psh = jax.tree.map(lambda _: NamedSharding(mesh, P()), p_specs)
        dp = tuple(mesh.axis_names)
    else:
        p_psh = sh.tree_shardings(
            mesh, sh.lm_param_pspecs(p_specs, mesh, cfg.n_kv_heads)
        )
        dp = _bspec(mesh, B, 1)[0]

    batched_beams = cfg.gr_batched_beams
    hist_k = _sds((L, B, S_h, KV, hd), dt)
    if batched_beams:
        beam_k = _sds((L, B, M, S_sid, KV, hd), dt)
        beam_psh = NamedSharding(mesh, P(None, dp, None, None, None, None))
    else:
        beam_k = _sds((L, B * M, S_sid, KV, hd), dt)
        beam_psh = NamedSharding(mesh, P(None, dp, None, None, None))
    hist_psh = NamedSharding(mesh, P(None, dp, None, None, None))
    tok = _sds((B * M, 1), jnp.int32)
    tm_specs = _gr_trie_specs()
    tm_psh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tm_specs)
    scores = _sds((B, M), jnp.float32)
    nodes = _sds((B, M), jnp.int32)
    bm_psh = NamedSharding(mesh, P(dp, None))
    tokp = NamedSharding(mesh, P(dp, None))

    SID_STEP = 2  # first sparse (VNTK) level — the representative step
    BMAX = 32  # level-2 max branch factor bound for |C|=20M (DESIGN.md §6)

    def serve_step(params, hk, hv, bk, bv, tokens, beam_scores, beam_nodes, tm):
        logits, bk, bv = transformer.gr_decode_step(
            params, hk, hv, bk, bv, tokens,
            jnp.asarray(SID_STEP, jnp.int32), cfg,
        )
        logits = logits[:, 0, :sid_v].reshape(B, M, sid_v)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        if constrained:
            from repro.core.vntk import vntk_reference_scatter

            masked, nxt = vntk_reference_scatter(
                lp, beam_nodes, tm["row_pointers"], tm["edges"], BMAX, sid_v
            )
        else:
            masked, nxt = lp, jnp.zeros((B, M, sid_v), jnp.int32)
        total = beam_scores[:, :, None] + masked
        top_scores, top_idx = jax.lax.top_k(total.reshape(B, M * sid_v), M)
        beam_idx = top_idx // sid_v
        token = (top_idx % sid_v).astype(jnp.int32)
        bix = jnp.arange(B)[:, None]
        new_nodes = nxt[bix, beam_idx, token] if constrained else beam_nodes
        # beam-permute the suffix caches
        if batched_beams:
            # batch-local: take_along_axis over the beam axis only — never
            # crosses the dp-sharded batch axis (no cache all-gather).
            idx = beam_idx[None, :, :, None, None, None]
            bk = jnp.take_along_axis(bk, idx, axis=2)
            bv = jnp.take_along_axis(bv, idx, axis=2)
        else:
            flat = (bix * M + beam_idx).reshape(-1)
            bk = jnp.take(bk, flat, axis=1)
            bv = jnp.take(bv, flat, axis=1)
        return token, top_scores, new_nodes, bk, bv

    attn = 2 * 2 * B * M * (S_h + S_sid) * cfg.n_heads * hd
    mf = (2 * cfg.active_param_count() * B * M + attn) / mesh.size
    return Cell(
        arch_id, shape.name,
        "serve_constrained" if constrained else "serve_unconstrained",
        serve_step,
        (p_specs, hist_k, hist_k, beam_k, beam_k, tok, scores, nodes, tm_specs),
        (p_psh, hist_psh, hist_psh, beam_psh, beam_psh, tokp, bm_psh, bm_psh,
         tm_psh),
        (bm_psh, bm_psh, bm_psh, beam_psh, beam_psh),
        mf,
        notes="prefix-shared beam KV; VNTK at SID level 2 (bmax=32)",
    )


def _gr_train_cell(arch_id, bundle, shape, mesh) -> Cell:
    lm_shape = LMShape(shape.name, "train", shape.history_len, shape.global_batch)
    return _lm_train_cell(arch_id, bundle, lm_shape, mesh)


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------


def _gnn_batch_specs(cfg, shape: GraphShape, pad_multiple: int = 512):
    """Node/edge arrays padded to a mesh-divisible size (sharding requires
    divisibility at the jit boundary); padding is masked out in the loss and
    routed to a sink node in segment_sum."""
    if shape.kind == "batched":
        B, N, E = shape.batch, shape.n_nodes, shape.n_edges
        return {
            "node_feats": _sds((B, N, shape.d_feat), jnp.float32),
            "edge_feats": _sds((B, E, cfg.edge_feat_dim), jnp.float32),
            "senders": _sds((B, E), jnp.int32),
            "receivers": _sds((B, E), jnp.int32),
            "targets": _sds((B, N, cfg.out_dim), jnp.float32),
        }
    if shape.kind == "sampled":
        # fanout 15-10 from 1024 seeds: nodes = 1024*(1+15+150),
        # edges = 1024*(15+150) — already 512-divisible
        seeds = shape.batch_nodes
        n_pad = seeds * (1 + int(sum(np.cumprod(shape.fanout))))
        e_pad = seeds * int(sum(np.cumprod(shape.fanout)))
    else:
        n_pad = _round_to(shape.n_nodes, pad_multiple)
        e_pad = _round_to(shape.n_edges, pad_multiple)
    return {
        "node_feats": _sds((n_pad, shape.d_feat), jnp.float32),
        "edge_feats": _sds((e_pad, cfg.edge_feat_dim), jnp.float32),
        "senders": _sds((e_pad,), jnp.int32),
        "receivers": _sds((e_pad,), jnp.int32),
        "targets": _sds((n_pad, cfg.out_dim), jnp.float32),
        "node_mask": _sds((n_pad,), jnp.bool_),
    }


def _gnn_train_cell(arch_id, bundle, shape: GraphShape, mesh) -> Cell:
    import dataclasses as dc

    cfg = dc.replace(bundle.config, node_feat_dim=shape.d_feat)
    p_specs = gnn.param_specs(cfg)
    o_specs = jax.eval_shape(_OPT.init, p_specs)
    rep = NamedSharding(mesh, P())
    p_psh = jax.tree.map(lambda _: rep, p_specs)
    o_psh = {"m": p_psh, "v": p_psh}
    batch = _gnn_batch_specs(cfg, shape)
    gaxes = sh.graph_axes(mesh)

    def bspec(path_name, leaf):
        if shape.kind == "batched":
            return NamedSharding(mesh, _bspec(mesh, shape.batch, len(leaf.shape)))
        lead = gaxes if leaf.shape[0] % mesh.size == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(leaf.shape) - 1))))

    b_psh = {k: bspec(k, v) for k, v in batch.items()}

    def train_step(params, opt_state, step_no, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn.gnn_loss(p, batch, cfg)
        )(params)
        new_p, new_o = _OPT.update(grads, opt_state, params, step_no)
        return new_p, new_o, loss

    H, Lp = cfg.d_hidden, cfg.n_layers
    n_eff = shape.n_nodes * (shape.batch if shape.kind == "batched" else 1)
    e_eff = shape.n_edges * (shape.batch if shape.kind == "batched" else 1)
    if shape.kind == "sampled":
        n_eff = batch["node_feats"].shape[0]
        e_eff = batch["edge_feats"].shape[0]
    per_layer = 2 * e_eff * (3 * H * H + H * H) + 2 * n_eff * (2 * H * H + H * H)
    enc = 2 * n_eff * shape.d_feat * H + 2 * e_eff * cfg.edge_feat_dim * H
    mf = 3 * (Lp * per_layer + enc) / mesh.size
    return Cell(
        arch_id, shape.name, "train", train_step,
        (p_specs, o_specs, _sds((), jnp.int32), batch),
        (p_psh, o_psh, rep, b_psh),
        (p_psh, o_psh, rep),
        mf,
        donate_argnums=(0, 1),
    )


# --------------------------------------------------------------------------
# Recsys cells
# --------------------------------------------------------------------------


def _recsys_batch_specs(cfg, batch: int):
    return {
        "dense": _sds((batch, max(cfg.n_dense, 1)), jnp.float32),
        "sparse": _sds((batch, cfg.n_sparse, cfg.multi_hot), jnp.int32),
        "hist": _sds((batch, cfg.hist_len), jnp.int32),
        "target": _sds((batch,), jnp.int32),
        "label": _sds((batch,), jnp.float32),
    }


def _recsys_cell(arch_id, bundle, shape: RecsysShape, mesh) -> Cell:
    cfg = bundle.config
    p_specs = recsys.param_specs(cfg)
    p_psh = sh.tree_shardings(mesh, sh.recsys_param_pspecs(p_specs, mesh))
    rep = NamedSharding(mesh, P())

    def mlp_flops(dims, d_in):
        f, prev = 0, d_in
        for d in dims:
            f += 2 * prev * d
            prev = d
        return f

    if cfg.model == "dlrm":
        per_row = (
            mlp_flops(cfg.bot_mlp, cfg.n_dense)
            + mlp_flops(cfg.top_mlp, (cfg.n_sparse + 1) * cfg.n_sparse // 2
                        + cfg.embed_dim)
            + 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
        )
    elif cfg.model == "wide_deep":
        per_row = mlp_flops(cfg.mlp + (1,), cfg.n_sparse * cfg.embed_dim)
    elif cfg.model == "fm":
        per_row = 4 * cfg.n_sparse * cfg.embed_dim
    else:  # mind
        per_row = (
            2 * cfg.hist_len * cfg.embed_dim ** 2
            + cfg.capsule_iters * 4 * cfg.n_interests * cfg.hist_len * cfg.embed_dim
        )

    if shape.kind == "train":
        o_specs = jax.eval_shape(_OPT.init, p_specs)
        o_psh = {"m": p_psh, "v": p_psh}
        batch = _recsys_batch_specs(cfg, shape.batch)
        b_psh = {k: NamedSharding(mesh, _bspec(mesh, shape.batch, len(v.shape)))
                 for k, v in batch.items()}

        def train_step(params, opt_state, step_no, batch):
            loss, grads = jax.value_and_grad(
                lambda p: recsys.recsys_loss(p, batch, cfg)
            )(params)
            new_p, new_o = _OPT.update(grads, opt_state, params, step_no)
            return new_p, new_o, loss

        mf = 3 * shape.batch * per_row / mesh.size
        return Cell(
            arch_id, shape.name, "train", train_step,
            (p_specs, o_specs, _sds((), jnp.int32), batch),
            (p_psh, o_psh, rep, b_psh),
            (p_psh, o_psh, rep),
            mf,
            donate_argnums=(0, 1),
        )

    if shape.kind == "retrieval":
        if cfg.model == "mind":
            hist = _sds((max(shape.batch, 1), cfg.hist_len), jnp.int32)
            cand = _sds((shape.n_candidates,), jnp.int32)

            def retrieve(params, hist, cand_ids):
                return recsys.mind_retrieval_scores(params, hist, cand_ids, cfg)

            mf = (shape.n_candidates * 2 * cfg.n_interests * cfg.embed_dim
                  + shape.batch * per_row) / mesh.size
            cand_psh = NamedSharding(
                mesh, P("model" if shape.n_candidates % mesh.shape["model"] == 0
                        else None))
            return Cell(
                arch_id, shape.name, "retrieval", retrieve,
                (p_specs, hist, cand),
                (p_psh, rep, cand_psh),
                NamedSharding(mesh, P(None, "model")),
                mf,
                notes="single batched max-over-interest dot vs 1M candidates",
            )
        # non-two-tower models: bulk-score candidates as a serve batch
        batch = _recsys_batch_specs(cfg, shape.n_candidates)
        b_psh = {k: NamedSharding(mesh, _bspec(mesh, shape.n_candidates, len(v.shape)))
                 for k, v in batch.items()}

        def serve(params, batch):
            return recsys.forward(params, batch, cfg)

        mf = shape.n_candidates * per_row / mesh.size
        return Cell(
            arch_id, shape.name, "retrieval", serve,
            (p_specs, batch), (p_psh, b_psh),
            NamedSharding(mesh, _bspec(mesh, shape.n_candidates, 1)),
            mf,
            notes="scored as bulk batch (model is not two-tower factorizable)",
        )

    # serve_p99 / serve_bulk
    batch = _recsys_batch_specs(cfg, shape.batch)
    b_psh = {k: NamedSharding(mesh, _bspec(mesh, shape.batch, len(v.shape)))
             for k, v in batch.items()}

    def serve(params, batch):
        return recsys.forward(params, batch, cfg)

    mf = shape.batch * per_row / mesh.size
    return Cell(
        arch_id, shape.name, "serve", serve,
        (p_specs, batch), (p_psh, b_psh),
        NamedSharding(mesh, _bspec(mesh, shape.batch, 1)),
        mf,
    )


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh,
               cfg_overrides: dict | None = None) -> Cell:
    bundle = get_bundle(arch_id)
    if cfg_overrides:
        cfg_overrides = dict(cfg_overrides)
        moe_groups = cfg_overrides.pop("moe_dispatch_groups", None)
        cfg = dataclasses.replace(bundle.config, **cfg_overrides)
        if moe_groups is not None and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=moe_groups)
            )
        bundle = dataclasses.replace(bundle, config=cfg)
    shape = next(s for s in bundle.shapes if s.name == shape_name)
    ok, why = supports_shape(arch_id, shape_name)
    if not ok:
        raise ValueError(f"{arch_id} x {shape_name} skipped: {why}")
    if bundle.family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(arch_id, bundle, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(arch_id, bundle, shape, mesh)
        return _lm_decode_cell(arch_id, bundle, shape, mesh)
    if bundle.family == "gr":
        if shape.kind == "train":
            return _gr_train_cell(arch_id, bundle, shape, mesh)
        return _gr_serve_cell(
            arch_id, bundle, shape, mesh,
            constrained=shape.kind == "serve_constrained",
        )
    if bundle.family == "gnn":
        return _gnn_train_cell(arch_id, bundle, shape, mesh)
    if bundle.family == "recsys":
        return _recsys_cell(arch_id, bundle, shape, mesh)
    raise ValueError(bundle.family)


def input_specs(arch_id: str, shape_name: str, mesh) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    return build_cell(arch_id, shape_name, mesh).args


def list_cells(include_gr: bool = True):
    """All runnable (arch, shape) pairs + documented skips."""
    from repro.configs import ARCHS

    runnable, skipped = [], []
    for arch_id, bundle in ARCHS.items():
        if bundle.family == "gr" and not include_gr:
            continue
        for shape in bundle.shapes:
            ok, why = supports_shape(arch_id, shape.name)
            (runnable if ok else skipped).append((arch_id, shape.name, why))
    return runnable, skipped
