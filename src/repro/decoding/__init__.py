"""Decode-policy layer: one compiled constraint-backend API (DESIGN.md §5).

The paper's Table 1 compares constraint methods *inside the same decoding
loop*; this package is the repo's expression of that: every method — STATIC
dense/VNTK (XLA, Pallas, fused), the stacked multi-tenant store, and the
§5.2 baselines (CPU trie, DISC-PPV, hash bitmap, unconstrained) — is a
:class:`ConstraintBackend`, and a :class:`DecodePolicy` binds a per-level
backend plan that ``beam_search`` / ``GenerativeRetriever`` /
``ServingEngine`` drive without knowing which method is underneath.

Public surface:
  * ``DecodePolicy``        — per-level backend plan; the object serving code
                              passes around (a pytree: hot-swap safe).
  * ``as_policy``           — coercion helper: matrix / store / baseline /
                              None -> policy.
  * ``ConstraintBackend``   — the protocol (mask_step + static metadata).
  * Backends: ``StaticBackend``, ``StackedStaticBackend``,
    ``CpuTrieBackend``, ``PPVBackend``, ``HashBitmapBackend``,
    ``UnconstrainedBackend``.
"""
from repro.decoding.backends import (
    ConstraintBackend,
    CpuTrieBackend,
    HashBitmapBackend,
    Impl,
    PPVBackend,
    Rows,
    StackedStaticBackend,
    StaticBackend,
    UnconstrainedBackend,
)
from repro.decoding.policy import (
    DecodePolicy,
    as_policy,
)

__all__ = [
    "ConstraintBackend",
    "DecodePolicy",
    "as_policy",
    "Impl",
    "Rows",
    "StaticBackend",
    "StackedStaticBackend",
    "CpuTrieBackend",
    "PPVBackend",
    "HashBitmapBackend",
    "UnconstrainedBackend",
]
