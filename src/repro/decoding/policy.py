"""DecodePolicy — a compiled per-level constraint plan for beam decoding.

The policy is the single object the decoding stack passes around instead of
the old ``(tm, impl, fused, constraint_ids)`` kwarg tunnel: it binds, at
construction, which :mod:`~repro.decoding.backends` backend masks each decode
level, and normalizes Phase 1 (log-softmax) unless the chosen backend fuses
it.  ``beam_search``, ``GenerativeRetriever`` and ``ServingEngine`` all take
a policy; the Table 1 harness times every backend through the same
``policy.step`` entry point.

The policy is a frozen pytree: backends are children (their device tables are
jit *arguments*), the per-level plan is static aux data.  Swapping the
underlying :class:`~repro.constraints.ConstraintStore` via
:meth:`DecodePolicy.with_constraints` preserves tree structure and every
static field, so jitted steps keyed on a policy never recompile across a
registry hot-swap (DESIGN.md §4, asserted in ``tests/test_constraint_store``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.constraints.store import ConstraintStore
from repro.core.baselines import (
    CpuTrieBaseline,
    HashBitmapBaseline,
    PPVBaseline,
)
from repro.core.compressed_slab import CompressedSlab
from repro.core.transition_matrix import TransitionMatrix
from repro.core.vntk import NEG_INF
from repro.decoding.backends import (
    ConstraintBackend,
    CpuTrieBackend,
    HashBitmapBackend,
    Impl,
    PPVBackend,
    StackedStaticBackend,
    StaticBackend,
    UnconstrainedBackend,
)

__all__ = ["DecodePolicy", "as_policy"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DecodePolicy:
    """Per-level backend plan: ``backends[plan[step]]`` masks step ``step``.

    Steps beyond ``len(plan)`` reuse the final entry (relevant only for the
    unconstrained policy, whose length is unbounded).
    """

    backends: tuple  # of ConstraintBackend pytrees (children)
    plan: tuple = dataclasses.field(metadata=dict(static=True))
    # Candidate-compressed decoding (DESIGN.md §8): when True, beam_search
    # uses ``step_topk`` at every level whose backend supports it, shrinking
    # the per-step HBM writes from O(B*M*V) to O(B*M*C) and the host top-k
    # from M*V to M*C lanes.  Static aux data: invariant under hot-swaps, so
    # toggling it re-specializes while swapping constraints never does.
    candidate_topk: bool = dataclasses.field(
        default=True, metadata=dict(static=True)
    )

    def __post_init__(self):
        if not self.backends:
            raise ValueError("DecodePolicy needs at least one backend")
        if not self.plan:
            raise ValueError("DecodePolicy needs a non-empty plan")
        bad = [i for i in self.plan if not 0 <= i < len(self.backends)]
        if bad:
            raise ValueError(f"plan references unknown backends: {bad}")

    # -- static introspection (stable across hot-swaps) ---------------------
    def backend_for(self, step: int) -> ConstraintBackend:
        return self.backends[self.plan[min(step, len(self.plan) - 1)]]

    @property
    def sid_length(self) -> Optional[int]:
        for b in self.backends:
            if b.sid_length is not None:
                return b.sid_length
        return None

    @property
    def is_constrained(self) -> bool:
        return any(
            not isinstance(b, UnconstrainedBackend) for b in self.backends
        )

    @property
    def requires_constraint_ids(self) -> bool:
        return any(b.supports_stacked for b in self.backends)

    @property
    def needs_prefix(self) -> bool:
        return any(b.needs_prefix for b in self.backends)

    @property
    def num_sets(self) -> Optional[int]:
        """Member count of the stacked store, or ``None`` if single-tenant."""
        for b in self.backends:
            if b.supports_stacked:
                return b.num_sets
        return None

    @property
    def constraints(self):
        """The underlying TransitionMatrix / ConstraintStore (or ``None``)."""
        for b in self.backends:
            if isinstance(b, StackedStaticBackend):
                return b.store
            if isinstance(b, StaticBackend):
                return b.tm
        return None

    def shardings(self, mesh, *, rows: str = "replicated") -> "DecodePolicy":
        """PartitionSpec pytree with the policy's own treedef (DESIGN.md §6).

        Composes every backend's :meth:`ConstraintBackend.shardings` hook, so
        the result can be used directly as ``shard_map`` in_specs, or turned
        into NamedShardings via ``distributed.sharding.tree_shardings`` for
        ``device_put`` / jit sharding constraints.  Static metadata is
        preserved, so the spec tree matches the policy leaf-for-leaf.
        """
        return dataclasses.replace(
            self,
            backends=tuple(
                b.shardings(mesh, rows=rows) for b in self.backends
            ),
        )

    # -- candidate-compressed decoding (DESIGN.md §8) ----------------------
    def supports_topk_at(self, step: int) -> bool:
        """True iff decode level ``step`` runs the candidate-compressed path.

        A pure function of static metadata (``candidate_topk``, the plan,
        each backend's ``topk_at``), so jitted steps keyed on the policy
        treat it as a trace-time constant and hot-swaps never flip it.
        """
        if not self.candidate_topk:
            return False
        b = self.backend_for(step)
        # The protocol flag is the opt-out contract (RowShardedStatic and
        # the baselines set it False); topk_at then narrows per level.  Both
        # must agree — a wrapper delegating topk_at without the flag (or
        # vice versa) stays on the dense path rather than silently
        # compressing.
        if not getattr(b, "supports_topk", False):
            return False
        topk_at = getattr(b, "topk_at", None)
        return bool(topk_at(step)) if topk_at is not None else False

    def candidate_width(self, beams: int, step: int) -> int:
        """Per-beam candidate count ``C`` for ``step`` (backend-specific
        lane rounding; see ``core.vntk.candidate_width``)."""
        return self.backend_for(step).candidate_width(beams)

    def with_topk(self, enabled: bool) -> "DecodePolicy":
        """The same plan with candidate compression forced on or off (used
        by the differential tests and the dense-baseline benchmarks)."""
        return dataclasses.replace(self, candidate_topk=bool(enabled))

    def step_topk(
        self,
        logits: jax.Array,  # (..., V) raw logits (or log-probs, see below)
        nodes: jax.Array,  # (...,) int32 per-beam states
        step: int,  # static decode level
        width: int,  # static per-beam candidate count C
        *,
        constraint_ids: Optional[jax.Array] = None,
        normalized: bool = False,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Candidate-compressed Phases 1-2: per-beam dense-rank top-``width``
        ``(scores, tokens, next_states)``, each ``(..., width)``.

        Bit-exact contract (DESIGN.md §8): the lists are the top-``width``
        entries of the vocab-aligned row :meth:`step` would produce, in
        ``jax.lax.top_k``'s dense tie-break order, so a top-M over the
        flattened ``(B, M*width)`` equals the dense top-M over ``(B, M*V)``.
        """
        if not self.supports_topk_at(step):
            raise ValueError(
                f"step {step} has no candidate-compressed backend "
                f"(plan {self.describe()}); use step() or check "
                "supports_topk_at first"
            )
        b = self.backend_for(step)
        if constraint_ids is not None and not self.requires_constraint_ids:
            raise ValueError(
                "constraint_ids requires a stacked ConstraintStore policy"
            )
        cids = constraint_ids if b.supports_stacked else None
        if not normalized and getattr(b, "fused", False) and b.supports_fused:
            return b.topk_step(
                logits, nodes, step, width, constraint_ids=cids,
                normalized=False,
            )
        lp = logits if normalized else jax.nn.log_softmax(
            logits.astype(jnp.float32), axis=-1
        )
        return b.topk_step(
            lp, nodes, step, width, constraint_ids=cids, normalized=True
        )

    def plan_info(self, beams: int = 1) -> list:
        """Machine-readable per-level plan for telemetry (DESIGN.md §9).

        One dict per decode level: the backend class masking it, whether it
        takes the candidate-compressed sparse branch, and (on compressed
        levels) the per-beam top-C width for ``beams``.  Pure static
        metadata — the values cannot change across registry hot-swaps, so
        :func:`repro.observability.record_policy` publishes them once per
        policy install.
        """
        rows = []
        for step in range(len(self.plan)):
            b = self.backend_for(step)
            topk = self.supports_topk_at(step)
            rows.append(dict(
                level=step,
                backend=type(b).__name__.replace("Backend", "").lower(),
                sparse=not (hasattr(b, "levels")
                            and getattr(b, "levels", None) == "dense"),
                topk=topk,
                candidate_width=(self.candidate_width(beams, step)
                                 if topk else 0),
            ))
        return rows

    def describe(self) -> str:
        """Human-readable per-level plan, e.g. for benchmark/CLI banners."""
        def label(b):
            if isinstance(b, (StaticBackend, StackedStaticBackend)):
                kind = "dense-bitpack" if b.levels == "dense" else (
                    f"vntk[{b.impl}{'+fused' if b.fused else ''}"
                    f"{'+topk' if self.candidate_topk else ''}"
                    f"{'+slab' if b.slab is not None else ''}]")
                if isinstance(b, StackedStaticBackend):
                    return f"stacked(K={b.num_sets}):{kind}"
                return kind
            return type(b).__name__.replace("Backend", "").lower()

        parts, start = [], 0
        for s in range(1, len(self.plan) + 1):
            if s == len(self.plan) or self.plan[s] != self.plan[start]:
                band = (f"L{start}" if s - start == 1 else f"L{start}-{s - 1}")
                parts.append(f"{band}:{label(self.backends[self.plan[start]])}")
                start = s
        return " ".join(parts)

    # -- the per-step entry point ------------------------------------------
    def step(
        self,
        logits: jax.Array,  # (..., V) raw logits (or log-probs, see below)
        nodes: jax.Array,  # (...,) int32 per-beam states
        step: int,  # static decode level
        *,
        prefix_tokens: Optional[jax.Array] = None,  # (..., L) history
        constraint_ids: Optional[jax.Array] = None,  # (...,) set ids
        normalized: bool = False,  # True: ``logits`` are already log-probs
    ) -> tuple[jax.Array, jax.Array]:
        """Phases 1-2 of Alg. 1 under this policy's backend for ``step``.

        Returns ``(masked_log_probs, next_dense)``, both vocab-aligned; the
        caller advances beams with one gather (Phase 4, DESIGN.md §3.1).
        """
        b = self.backend_for(step)
        if b.needs_prefix and prefix_tokens is None:
            raise ValueError(
                f"{type(b).__name__} needs prefix_tokens at step {step}"
            )
        if constraint_ids is not None and not self.requires_constraint_ids:
            raise ValueError(
                "constraint_ids requires a stacked ConstraintStore policy"
            )
        # Per-level plans may mix stacked and single-set backends; ids are
        # only handed to the backends that consume them.
        cids = constraint_ids if b.supports_stacked else None
        if not normalized and getattr(b, "fused", False) and b.supports_fused:
            return b.fused_step(
                logits, nodes, step, prefix_tokens=prefix_tokens,
                constraint_ids=cids,
            )
        lp = logits if normalized else jax.nn.log_softmax(
            logits.astype(jnp.float32), axis=-1
        )
        return b.mask_step(
            lp, nodes, step, prefix_tokens=prefix_tokens,
            constraint_ids=cids,
        )

    # -- level-free masking (continuous batching, DESIGN.md §10) -----------
    @property
    def supports_level_free(self) -> bool:
        """True when one mask call serves rows at heterogeneous decode
        levels: a single-backend plan whose backend is all-sparse
        (``dense_d == 0``, so node ids are globally unique across levels and
        ``(constraint_id, node)`` alone determines the admissible set)."""
        if len(set(self.plan)) != 1:
            return False
        return bool(getattr(
            self.backends[self.plan[0]], "supports_level_free", False
        ))

    def level_free_step(
        self,
        logits: jax.Array,  # (N, V) raw logits (or log-probs)
        nodes: jax.Array,  # (N,) int32 per-row states, ANY mixture of levels
        *,
        constraint_ids: Optional[jax.Array] = None,
        normalized: bool = False,
    ) -> tuple[jax.Array, jax.Array]:
        """Phases 1-2 with per-row levels: ``(masked_log_probs, next_dense)``.

        Bit-identical to :meth:`step` at whatever level each row's node sits
        on (asserted in ``tests/test_continuous.py``).  Always
        normalize-then-mask — the fused kernel is per-level and is not
        consulted here.
        """
        if not self.supports_level_free:
            raise ValueError(
                f"[{self.describe()}] cannot mask level-free; build the "
                "policy over a dense_d=0 index "
                "(TransitionMatrix.from_sids(..., dense_d=0))"
            )
        b = self.backends[self.plan[0]]
        if constraint_ids is not None and not self.requires_constraint_ids:
            raise ValueError(
                "constraint_ids requires a stacked ConstraintStore policy"
            )
        cids = constraint_ids if b.supports_stacked else None
        lp = logits if normalized else jax.nn.log_softmax(
            logits.astype(jnp.float32), axis=-1
        )
        return b.level_free_mask(lp, nodes, constraint_ids=cids)

    def shared_mask_step(
        self,
        logits: jax.Array,  # (N, V) raw logits (or log-probs)
        nodes: jax.Array,  # (N,) int32 per-row states
        *,
        constraint_ids: Optional[jax.Array] = None,
        share_width: Optional[int] = None,
        normalized: bool = False,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Trie-prefix-shared Phases 1-2: rows with equal
        ``(constraint_id, node)`` — beams sitting on the same trie node —
        compute ONE mask/next-state row instead of N independent ones.

        Returns ``(masked_log_probs, next_dense, n_unique)``.  The mask and
        next-state rows are pure functions of the key, so deduplication is
        exact: the mask row is materialized once from zero log-probs (its
        entries are then exactly ``0.0`` on admissible tokens and
        ``NEG_INF`` elsewhere) and re-applied per row by select, which is
        bitwise identical to masking each row independently.
        ``share_width`` caps the representative-row count ``U`` (static
        shape); batches with more than ``U`` distinct keys fall back to the
        full per-row computation under a ``lax.cond``.  ``N - n_unique`` is
        the number of mask rows saved this step (the prefix-share hit
        counter).
        """
        if not self.supports_level_free:
            raise ValueError(
                f"[{self.describe()}] cannot mask level-free; build the "
                "policy over a dense_d=0 index"
            )
        b = self.backends[self.plan[0]]
        if constraint_ids is not None and not self.requires_constraint_ids:
            raise ValueError(
                "constraint_ids requires a stacked ConstraintStore policy"
            )
        cids = constraint_ids if b.supports_stacked else None
        lp = logits if normalized else jax.nn.log_softmax(
            logits.astype(jnp.float32), axis=-1
        )
        N, V = lp.shape
        if cids is not None:
            idx = self.constraints
            keys = (cids.astype(jnp.int32) * jnp.int32(idx.n_states + 1)
                    + nodes.astype(jnp.int32))
        else:
            keys = nodes.astype(jnp.int32)
        order = jnp.argsort(keys)  # stable; any representative is valid
        sk = jnp.take(keys, order)
        newk = jnp.concatenate(
            [jnp.ones((1,), bool), sk[1:] != sk[:-1]]
        )
        uid_sorted = jnp.cumsum(newk.astype(jnp.int32)) - 1  # (N,)
        n_unique = uid_sorted[-1] + 1
        inv = jnp.zeros(N, jnp.int32).at[order].set(uid_sorted)
        U = N if share_width is None else int(share_width)

        def _shared(lp):
            # representative source row per unique key (mode="drop" parks
            # overflow keys harmlessly when n_unique > U — that branch is
            # unreachable under the cond below)
            rep_src = jnp.zeros(U, jnp.int32).at[uid_sorted].set(
                order.astype(jnp.int32), mode="drop"
            )
            rep_nodes = jnp.take(nodes, rep_src)
            rep_cids = None if cids is None else jnp.take(cids, rep_src)
            mask_rows, next_rows = b.level_free_mask(
                jnp.zeros((U, V), lp.dtype), rep_nodes,
                constraint_ids=rep_cids,
            )
            mask = jnp.take(mask_rows, inv, axis=0)  # (N, V) in {0, NEG_INF}
            nxt = jnp.take(next_rows, inv, axis=0)
            return jnp.where(mask == 0.0, lp, NEG_INF), nxt

        def _full(lp):
            return b.level_free_mask(lp, nodes, constraint_ids=cids)

        if U >= N:
            masked, nxt = _shared(lp)
        else:
            masked, nxt = jax.lax.cond(n_unique <= U, _shared, _full, lp)
        return masked, nxt, n_unique

    # -- hot-swap ----------------------------------------------------------
    def with_constraints(self, obj) -> "DecodePolicy":
        """A new policy with ``obj`` (matrix or store) in place of the old.

        Only the backends whose kind matches ``obj`` are swapped — a
        mixed per-level plan keeps its other backends untouched.  Tree
        structure and static metadata are preserved — this is the registry
        hot-swap path, so jitted steps never recompile across it.
        """
        stacked = bool(getattr(obj, "is_stacked", False))
        swapped, hit = [], False
        for b in self.backends:
            if isinstance(b, StackedStaticBackend) and stacked:
                # backends carrying a compressed slab (DESIGN.md §11) get it
                # recomputed for the new constraints: the envelope fixes the
                # slab's shapes/dtypes, so the treedef — and therefore every
                # jitted step keyed on this policy — is unchanged
                slab = (CompressedSlab.from_store(obj)
                        if b.slab is not None else None)
                swapped.append(dataclasses.replace(b, store=obj, slab=slab))
                hit = True
            elif (isinstance(b, StaticBackend) and not stacked
                    and isinstance(obj, TransitionMatrix)):
                slab = (CompressedSlab.from_matrix(obj)
                        if b.slab is not None else None)
                swapped.append(dataclasses.replace(b, tm=obj, slab=slab))
                hit = True
            else:
                swapped.append(b)
        if not hit:
            raise TypeError(
                f"[{self.describe()}]: no swappable backend accepts "
                f"{type(obj).__name__} (StackedStaticBackend hot-swaps a "
                "ConstraintStore, StaticBackend a TransitionMatrix)"
            )
        return dataclasses.replace(self, backends=tuple(swapped))

    # -- factories ---------------------------------------------------------
    @classmethod
    def static(cls, tm: TransitionMatrix, *, impl: Impl = "xla",
               fused: bool = False, topk: bool = True,
               compressed: bool = False) -> "DecodePolicy":
        """STATIC plan: dense bit-packed lookups for levels < ``dense_d``,
        VNTK (``impl``, optionally ``fused``) for the deeper levels.
        ``topk`` opts the sparse levels into candidate-compressed decoding
        (on by default; DESIGN.md §8).  ``compressed`` builds the
        delta-compressed edge slab (DESIGN.md §11) and routes every sparse
        lookup through it — bit-identical outputs, int16 DMA bursts."""
        if getattr(tm, "is_stacked", False):
            return cls.stacked(tm, impl=impl, fused=fused, topk=topk,
                               compressed=compressed)
        L, d = tm.sid_length, min(tm.dense_d, tm.sid_length)
        slab = (CompressedSlab.from_matrix(tm)
                if compressed and d < L else None)
        if d == 0:
            return cls(
                backends=(StaticBackend(tm, slab=slab, impl=impl,
                                        fused=fused, levels="sparse"),),
                plan=(0,) * L,
                candidate_topk=topk,
            )
        if d >= L:
            # fully dense band: nothing to compress
            return cls(backends=(StaticBackend(tm, levels="dense"),),
                       plan=(0,) * L, candidate_topk=topk)
        return cls(
            backends=(
                StaticBackend(tm, levels="dense"),
                StaticBackend(tm, slab=slab, impl=impl, fused=fused,
                              levels="sparse"),
            ),
            plan=tuple(0 if s < d else 1 for s in range(L)),
            candidate_topk=topk,
        )

    @classmethod
    def stacked(cls, store: ConstraintStore, *, impl: Impl = "xla",
                fused: bool = False, topk: bool = True,
                compressed: bool = False) -> "DecodePolicy":
        """Multi-tenant STATIC plan over a stacked ConstraintStore."""
        L, d = store.sid_length, min(store.dense_d, store.sid_length)
        slab = (CompressedSlab.from_store(store)
                if compressed and d < L else None)
        if d == 0:
            return cls(
                backends=(StackedStaticBackend(store, slab=slab, impl=impl,
                                               fused=fused, levels="sparse"),),
                plan=(0,) * L,
                candidate_topk=topk,
            )
        if d >= L:
            return cls(backends=(StackedStaticBackend(store, levels="dense"),),
                       plan=(0,) * L, candidate_topk=topk)
        return cls(
            backends=(
                StackedStaticBackend(store, levels="dense"),
                StackedStaticBackend(store, slab=slab, impl=impl,
                                     fused=fused, levels="sparse"),
            ),
            plan=tuple(0 if s < d else 1 for s in range(L)),
            candidate_topk=topk,
        )

    @classmethod
    def cpu_trie(cls, sids=None, vocab_size: Optional[int] = None, *,
                 baseline: Optional[CpuTrieBaseline] = None) -> "DecodePolicy":
        b = baseline or CpuTrieBaseline(sids, vocab_size)
        return cls(backends=(CpuTrieBackend(b),), plan=(0,) * b.sid_length)

    @classmethod
    def ppv(cls, sids=None, vocab_size: Optional[int] = None, *,
            exact: bool = True, top_k: int = 50,
            baseline: Optional[PPVBaseline] = None) -> "DecodePolicy":
        b = (PPVBackend.from_baseline(baseline) if baseline is not None
             else PPVBackend.from_sids(sids, vocab_size, exact=exact,
                                       top_k=top_k))
        return cls(backends=(b,), plan=(0,) * b.sid_length)

    @classmethod
    def hash_bitmap(cls, sids=None, vocab_size: Optional[int] = None, *,
                    log2_bits: int = 27,
                    baseline: Optional[HashBitmapBaseline] = None,
                    ) -> "DecodePolicy":
        b = (HashBitmapBackend.from_baseline(baseline)
             if baseline is not None
             else HashBitmapBackend.from_sids(sids, vocab_size,
                                              log2_bits=log2_bits))
        return cls(backends=(b,), plan=(0,) * b.sid_length)

    @classmethod
    def unconstrained(cls) -> "DecodePolicy":
        return cls(backends=(UnconstrainedBackend(),), plan=(0,))

    @classmethod
    def per_level(cls, backends: Sequence[ConstraintBackend],
                  plan: Sequence[int]) -> "DecodePolicy":
        """Escape hatch: an arbitrary per-level composition."""
        return cls(backends=tuple(backends), plan=tuple(plan))


def as_policy(obj, *, impl: Impl = "xla", fused: bool = False) -> DecodePolicy:
    """Coerce legacy constraint carriers into a :class:`DecodePolicy`.

    Accepts ``None`` (unconstrained), a ``TransitionMatrix``, a
    ``ConstraintStore``, any of the §5.2 baseline objects, a single backend,
    or an existing policy (returned as-is; ``impl``/``fused`` then must match
    what the policy was built with — they are not re-applied).
    """
    if isinstance(obj, DecodePolicy):
        return obj
    if obj is None:
        return DecodePolicy.unconstrained()
    if isinstance(obj, ConstraintStore) or getattr(obj, "is_stacked", False):
        return DecodePolicy.stacked(obj, impl=impl, fused=fused)
    if isinstance(obj, TransitionMatrix):
        return DecodePolicy.static(obj, impl=impl, fused=fused)
    if isinstance(obj, CpuTrieBaseline):
        return DecodePolicy.cpu_trie(baseline=obj)
    if isinstance(obj, PPVBaseline) and not isinstance(obj, PPVBackend):
        return DecodePolicy.ppv(baseline=obj)
    if isinstance(obj, HashBitmapBaseline) and not isinstance(
            obj, HashBitmapBackend):
        return DecodePolicy.hash_bitmap(baseline=obj)
    if isinstance(obj, ConstraintBackend):
        length = obj.sid_length or 1
        return DecodePolicy(backends=(obj,), plan=(0,) * length)
    raise TypeError(
        f"cannot build a DecodePolicy from {type(obj).__name__}; pass a "
        "DecodePolicy, TransitionMatrix, ConstraintStore, baseline, backend, "
        "or None"
    )
