"""Constraint backends — one compiled interface for STATIC and the baselines.

A *backend* is the unit a :class:`~repro.decoding.DecodePolicy` composes: it
masks one decode step and reports, vocab-aligned, where each token emission
would lead (DESIGN.md §3.1 convention) so Phase 4 of Algorithm 1 is a single
gather ``next_dense[batch, beam, token]`` no matter which method produced the
mask.  All state a backend needs at step ``t`` rides in the beam state the
policy-driven ``beam_search`` already maintains:

  * ``nodes``          — per-beam trie states (STATIC family);
  * ``prefix_tokens``  — per-beam emitted-token history (the baselines'
                         prefix interface, paper §5.2);
  * ``constraint_ids`` — per-row constraint-set ids (stacked multi-tenant
                         store, DESIGN.md §4).

Backends are frozen pytree dataclasses: device tables are leaves (so jitted
steps take them as *arguments*, never as constant-folded HLO literals) and
configuration is static aux data (so it participates in jit specialization
and is invariant under a registry hot-swap).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec

from repro.constraints.store import ConstraintStore
from repro.core import dense_mask
from repro.core.baselines import (
    CpuTrieBaseline,
    HashBitmapBaseline,
    PPVBaseline,
)
from repro.core.compressed_slab import CompressedSlab
from repro.core.transition_matrix import TransitionMatrix
from repro.core.types import Impl
from repro.core.vntk import (
    candidate_width,
    topk_lane,
    vntk_stacked_topk_xla,
    vntk_stacked_xla,
    vntk_topk_xla,
    vntk_xla,
)

__all__ = [
    "Impl",
    "Levels",
    "Rows",
    "ConstraintBackend",
    "StaticBackend",
    "StackedStaticBackend",
    "CpuTrieBackend",
    "PPVBackend",
    "HashBitmapBackend",
    "UnconstrainedBackend",
]

Levels = Literal["auto", "dense", "sparse"]
Rows = Literal["replicated", "model"]


def _check_rows(rows: str) -> None:
    if rows not in ("replicated", "model"):
        raise ValueError(
            f"rows must be 'replicated' or 'model', got {rows!r}"
        )


def _replicated_specs(backend, mesh):
    """PartitionSpec pytree replicating every leaf (the §A.3 default)."""
    del mesh
    return jax.tree.map(lambda _: PartitionSpec(), backend)


@runtime_checkable
class ConstraintBackend(Protocol):
    """Protocol every constraint backend implements.

    Static metadata (read by the policy, stable across hot-swaps):
      * ``sid_length``       — SID length the backend was built for (``None``
                               for the unconstrained lower bound);
      * ``supports_fused``   — has a ``fused_step`` that folds the Phase-1
                               log-softmax into the masking pass;
      * ``supports_stacked`` — consumes per-row ``constraint_ids``;
      * ``needs_prefix``     — consumes the emitted-token history instead of
                               (or in addition to) trie states;
      * ``supports_topk``    — has a candidate-compressed ``topk_step``
                               (DESIGN.md §8) emitting per-beam ``(scores,
                               tokens, next_states)`` of width ``C`` instead
                               of the vocab-aligned pair; ``topk_at(step)``
                               gates it per level (the dense bit-packed band
                               has no CSR row to compress).  Backends without
                               it fall back to the dense path in
                               ``beam_search``.
    """

    sid_length: Optional[int]
    supports_fused: bool
    supports_stacked: bool
    needs_prefix: bool
    supports_topk: bool

    def mask_step(
        self,
        log_probs: jax.Array,  # (..., V) normalized log-probs
        nodes: jax.Array,  # (...,) int32 per-beam states
        step: int,  # static decode level
        *,
        prefix_tokens: Optional[jax.Array] = None,  # (..., L) emitted history
        constraint_ids: Optional[jax.Array] = None,  # (...,) int32 set ids
    ) -> tuple[jax.Array, jax.Array]:
        """Phase 2 of Alg. 1: returns ``(masked_lp, next_dense)``, both
        vocab-aligned ``(..., V)``; ``next_dense[..., v] == 0`` iff emitting
        ``v`` is invalid."""
        ...

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        """PartitionSpec pytree (same treedef as the backend) mapping each
        device-table leaf to a mesh placement (DESIGN.md §6).

        ``rows="replicated"`` replicates every leaf (paper §A.3: constraint
        tables are small next to model weights).  ``rows="model"`` row-shards
        the big CSR ``edges`` slab along the mesh's ``model`` axis for tries
        that outgrow a single device's HBM — lookups then do a one-hop gather
        (``repro.distributed.constraint_sharding.vntk_row_sharded``).
        Backends without a CSR ignore the distinction and replicate.
        """
        ...


def _check_step(step: int, sid_length: int) -> None:
    if step < 0 or step >= sid_length:
        raise ValueError(f"step {step} outside [0, {sid_length})")


def _reject_constraint_ids(constraint_ids, who: str) -> None:
    if constraint_ids is not None:
        raise ValueError(
            f"constraint_ids requires a stacked ConstraintStore backend, "
            f"got {who}"
        )


def _dense_at(step: int, dense_d: int, levels: Levels, who: str) -> bool:
    """Route ``step`` to the dense bit-packed tables or the sparse VNTK.

    ``levels`` narrows which band this backend instance serves — a
    :class:`~repro.decoding.DecodePolicy` plan composes a ``"dense"`` and a
    ``"sparse"`` instance per-level instead of branching inside one opaque
    function (the old ``constrain_log_probs`` hardcoded routing).
    """
    dense = step < dense_d
    if levels == "dense" and not dense:
        raise ValueError(
            f"{who}(levels='dense') consulted at sparse step {step} "
            f"(dense_d={dense_d}); fix the policy plan"
        )
    if levels == "sparse" and dense:
        raise ValueError(
            f"{who}(levels='sparse') consulted at dense step {step} "
            f"(dense_d={dense_d}); fix the policy plan"
        )
    return dense


def _topk_lane(impl: Impl) -> int:
    """Lane granularity for the candidate width ``C`` (DESIGN.md §8).

    Delegates to :func:`repro.core.vntk.topk_lane` — the single source the
    kernels, this routing layer, and the §8 traffic model
    (:func:`repro.core.memory_model.decode_step_traffic`) all share, so the
    width the model quotes is the width the kernel actually allocates
    (128 Pallas lanes; sublane-only 8 for the layout-free XLA oracle).
    """
    return topk_lane(impl)


# ---------------------------------------------------------------------------
# STATIC (paper Alg. 1/2): single TransitionMatrix
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StaticBackend:
    """STATIC constraint enforcement over one :class:`TransitionMatrix`.

    ``levels`` selects the band this instance serves: ``"dense"`` (bit-packed
    lookups for steps < ``dense_d``), ``"sparse"`` (VNTK for the rest), or
    ``"auto"`` (route per step, the legacy one-backend-for-all-levels shape).
    ``impl`` picks the XLA formulation or the Pallas TPU kernel for sparse
    steps; ``fused`` opts into the fused masked-logsoftmax kernel.

    ``slab`` (optional) is the delta-compressed edge slab of DESIGN.md §11:
    when present, every sparse lookup routes through the compressed kernels
    — the speculative burst moves the int16 delta tokens instead of the
    ``(slot, 2)`` int32 pairs, bit-identical outputs — and a registry
    hot-swap recomputes it alongside the matrix (same envelope, same
    treedef, zero recompiles).
    """

    tm: TransitionMatrix
    slab: Optional[CompressedSlab] = None
    impl: Impl = dataclasses.field(default="xla", metadata=dict(static=True))
    fused: bool = dataclasses.field(default=False, metadata=dict(static=True))
    levels: Levels = dataclasses.field(
        default="auto", metadata=dict(static=True)
    )

    supports_fused = True
    supports_stacked = False
    needs_prefix = False
    supports_topk = True

    @property
    def sid_length(self) -> int:
        return self.tm.sid_length

    def topk_at(self, step: int) -> bool:
        """Candidate compression applies to the sparse (CSR) band only —
        the dense bit-packed levels have no edge row to compress."""
        if self.levels == "dense":
            return False
        return step >= min(self.tm.dense_d, self.tm.sid_length)

    def candidate_width(self, beams: int) -> int:
        return candidate_width(
            beams, self.tm.vocab_size, lane=_topk_lane(self.impl)
        )

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        _check_rows(rows)
        specs = _replicated_specs(self, mesh)
        if rows == "model" and "model" in mesh.axis_names:
            specs = dataclasses.replace(
                specs,
                tm=dataclasses.replace(
                    specs.tm, edges=PartitionSpec("model", None)
                ),
            )
            if self.slab is not None:
                specs = dataclasses.replace(
                    specs,
                    slab=dataclasses.replace(
                        specs.slab, tok_delta=PartitionSpec("model")
                    ),
                )
        return specs

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del prefix_tokens
        _reject_constraint_ids(constraint_ids, "a single TransitionMatrix")
        _check_step(step, self.tm.sid_length)
        if _dense_at(step, self.tm.dense_d, self.levels, "StaticBackend"):
            if step == 0:
                return dense_mask.dense_lookup_l0(log_probs, self.tm)
            return dense_mask.dense_lookup_l1(log_probs, nodes, self.tm)
        bmax = max(self.tm.bmax_for_step(step), 1)
        if self.slab is not None:
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk_compressed(
                log_probs, nodes, self.tm.row_pointers, self.slab.tok_delta,
                self.slab.base_for_step(step), bmax, self.tm.vocab_size,
                impl=self.impl,
            )
        if self.impl == "pallas":
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk(
                log_probs, nodes, self.tm.row_pointers, self.tm.edges, bmax,
                self.tm.vocab_size,
            )
        return vntk_xla(log_probs, nodes, self.tm, bmax)

    @property
    def supports_level_free(self) -> bool:
        """True when ONE mask call can serve rows at heterogeneous decode
        levels (continuous batching): needs an all-sparse index
        (``dense_d == 0``) so every level — including the root — resolves
        through the CSR and node ids are globally unique across levels.
        The compressed slab opts out: its next states derive from a
        per-LEVEL base, so one call cannot serve mixed depths."""
        return (self.levels != "dense" and self.tm.dense_d == 0
                and self.slab is None)

    def level_free_mask(self, log_probs, nodes, *, constraint_ids=None):
        """Level-agnostic ``mask_step``: rows may sit at different trie
        depths.  The admissible set is fully determined by each row's node's
        CSR row; ``bmax`` (the speculative edge-gather width) is the global
        maximum over levels, and only sizes the gather — extra invalid slots
        scatter to the overflow column, so the output is bit-identical to
        the per-level call at whatever level each node is on."""
        del constraint_ids
        if not self.supports_level_free:
            raise ValueError(
                "level-free masking needs an all-sparse index (dense_d == 0)"
                f"; this StaticBackend has dense_d={self.tm.dense_d}, "
                f"levels={self.levels!r}"
            )
        bmax = max(
            max(self.tm.bmax_for_step(s) for s in range(self.tm.sid_length)),
            1,
        )
        if self.impl == "pallas":
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk(
                log_probs, nodes, self.tm.row_pointers, self.tm.edges, bmax,
                self.tm.vocab_size,
            )
        return vntk_xla(log_probs, nodes, self.tm, bmax)

    def fused_step(self, logits, nodes, step, *, prefix_tokens=None,
                   constraint_ids=None):
        """Phases 1-2 in one HBM pass (sparse steps; dense steps fall back
        to normalize-then-lookup, exactly as the legacy ``fused=True``)."""
        del prefix_tokens
        _reject_constraint_ids(constraint_ids, "a single TransitionMatrix")
        _check_step(step, self.tm.sid_length)
        if _dense_at(step, self.tm.dense_d, self.levels, "StaticBackend"):
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            return self.mask_step(lp, nodes, step)
        from repro.kernels import ops as kernel_ops

        bmax = max(self.tm.bmax_for_step(step), 1)
        if self.slab is not None:
            return kernel_ops.vntk_compressed(
                logits, nodes, self.tm.row_pointers, self.slab.tok_delta,
                self.slab.base_for_step(step), bmax, self.tm.vocab_size,
                impl=self.impl, fused_logsoftmax=True,
            )
        return kernel_ops.vntk_fused_logsoftmax(
            logits, nodes, self.tm.row_pointers, self.tm.edges, bmax,
            self.tm.vocab_size,
        )

    def topk_step(self, values, nodes, step, width, *, prefix_tokens=None,
                  constraint_ids=None, normalized=True):
        """Candidate-compressed Phases 1-2 (DESIGN.md §8): per-beam
        dense-rank top-``width`` ``(scores, tokens, next_states)``.

        ``values`` are normalized log-probs (``normalized=True``) or raw
        logits (the fused single-pass kernel normalizes in-register)."""
        del prefix_tokens
        _reject_constraint_ids(constraint_ids, "a single TransitionMatrix")
        _check_step(step, self.tm.sid_length)
        if not self.topk_at(step):
            raise ValueError(
                f"StaticBackend(levels={self.levels!r}) has no candidate "
                f"row at dense step {step}; fix the policy plan"
            )
        bmax = max(self.tm.bmax_for_step(step), 1)
        if self.slab is not None:
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk_compressed_topk(
                values, nodes, self.tm.row_pointers, self.slab.tok_delta,
                self.slab.base_for_step(step), bmax, self.tm.vocab_size,
                width, impl=self.impl, fused_logsoftmax=not normalized,
            )
        if self.impl == "pallas":
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk_topk(
                values, nodes, self.tm.row_pointers, self.tm.edges, bmax,
                self.tm.vocab_size, width, fused_logsoftmax=not normalized,
            )
        lp = values if normalized else jax.nn.log_softmax(
            values.astype(jnp.float32), axis=-1
        )
        return vntk_topk_xla(lp, nodes, self.tm, bmax, width)


# ---------------------------------------------------------------------------
# Stacked STATIC: ConstraintStore + per-row constraint ids (DESIGN.md §4)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StackedStaticBackend:
    """STATIC over a stacked multi-tenant :class:`ConstraintStore`.

    Every lookup gathers through one extra leading constraint axis indexed by
    the per-row ``constraint_ids``.  The store rides as a pytree leaf with
    swap-invariant static metadata, so a registry hot-swap never recompiles
    a jitted step holding this backend.

    ``slab`` (optional) is the per-member delta-compressed edge slab
    (DESIGN.md §11); see :class:`StaticBackend`.
    """

    store: ConstraintStore
    slab: Optional[CompressedSlab] = None
    impl: Impl = dataclasses.field(default="xla", metadata=dict(static=True))
    fused: bool = dataclasses.field(default=False, metadata=dict(static=True))
    levels: Levels = dataclasses.field(
        default="auto", metadata=dict(static=True)
    )

    supports_fused = True
    supports_stacked = True
    needs_prefix = False
    supports_topk = True

    @property
    def sid_length(self) -> int:
        return self.store.sid_length

    @property
    def num_sets(self) -> int:
        return self.store.num_sets

    def topk_at(self, step: int) -> bool:
        if self.levels == "dense":
            return False
        return step >= min(self.store.dense_d, self.store.sid_length)

    def candidate_width(self, beams: int) -> int:
        return candidate_width(
            beams, self.store.vocab_size, lane=_topk_lane(self.impl)
        )

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        _check_rows(rows)
        specs = _replicated_specs(self, mesh)
        if rows == "model" and "model" in mesh.axis_names:
            specs = dataclasses.replace(
                specs,
                store=dataclasses.replace(
                    specs.store, edges=PartitionSpec(None, "model", None)
                ),
            )
            if self.slab is not None:
                specs = dataclasses.replace(
                    specs,
                    slab=dataclasses.replace(
                        specs.slab, tok_delta=PartitionSpec(None, "model")
                    ),
                )
        return specs

    def _require_ids(self, constraint_ids):
        if constraint_ids is None:
            raise ValueError(
                "ConstraintStore lookups need per-row constraint_ids"
            )

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del prefix_tokens
        self._require_ids(constraint_ids)
        _check_step(step, self.store.sid_length)
        if _dense_at(step, self.store.dense_d, self.levels,
                     "StackedStaticBackend"):
            if step == 0:
                return dense_mask.dense_lookup_l0(
                    log_probs, self.store, constraint_ids=constraint_ids
                )
            return dense_mask.dense_lookup_l1(
                log_probs, nodes, self.store, constraint_ids=constraint_ids
            )
        bmax = max(self.store.bmax_for_step(step), 1)
        if self.slab is not None:
            from repro.kernels import ops as kernel_ops

            return kernel_ops.vntk_compressed(
                log_probs, nodes, self.store.row_pointers,
                self.slab.tok_delta, self.slab.base_for_step(step), bmax,
                self.store.vocab_size, impl=self.impl,
                constraint_ids=constraint_ids,
            )
        if self.impl == "pallas":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.vntk(
                log_probs, nodes, self.store.row_pointers, self.store.edges,
                bmax, self.store.vocab_size, constraint_ids=constraint_ids,
            )
        return vntk_stacked_xla(
            log_probs, nodes, self.store, bmax, constraint_ids
        )

    @property
    def supports_level_free(self) -> bool:
        """See :attr:`StaticBackend.supports_level_free` — the stacked
        variant additionally keys every lookup on ``constraint_ids``."""
        return (self.levels != "dense" and self.store.dense_d == 0
                and self.slab is None)

    def level_free_mask(self, log_probs, nodes, *, constraint_ids=None):
        """Level-agnostic stacked ``mask_step`` (see
        :meth:`StaticBackend.level_free_mask`)."""
        self._require_ids(constraint_ids)
        if not self.supports_level_free:
            raise ValueError(
                "level-free masking needs an all-sparse index (dense_d == 0)"
                f"; this StackedStaticBackend has "
                f"dense_d={self.store.dense_d}, levels={self.levels!r}"
            )
        bmax = max(
            max(self.store.bmax_for_step(s)
                for s in range(self.store.sid_length)),
            1,
        )
        if self.impl == "pallas":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.vntk(
                log_probs, nodes, self.store.row_pointers, self.store.edges,
                bmax, self.store.vocab_size, constraint_ids=constraint_ids,
            )
        return vntk_stacked_xla(
            log_probs, nodes, self.store, bmax, constraint_ids
        )

    def fused_step(self, logits, nodes, step, *, prefix_tokens=None,
                   constraint_ids=None):
        del prefix_tokens
        self._require_ids(constraint_ids)
        _check_step(step, self.store.sid_length)
        if _dense_at(step, self.store.dense_d, self.levels,
                     "StackedStaticBackend"):
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            return self.mask_step(lp, nodes, step,
                                  constraint_ids=constraint_ids)
        from repro.kernels import ops as kernel_ops

        bmax = max(self.store.bmax_for_step(step), 1)
        if self.slab is not None:
            return kernel_ops.vntk_compressed(
                logits, nodes, self.store.row_pointers, self.slab.tok_delta,
                self.slab.base_for_step(step), bmax, self.store.vocab_size,
                impl=self.impl, constraint_ids=constraint_ids,
                fused_logsoftmax=True,
            )
        return kernel_ops.vntk_fused_logsoftmax(
            logits, nodes, self.store.row_pointers, self.store.edges, bmax,
            self.store.vocab_size, constraint_ids=constraint_ids,
        )

    def topk_step(self, values, nodes, step, width, *, prefix_tokens=None,
                  constraint_ids=None, normalized=True):
        """Candidate-compressed Phases 1-2 through the stacked store."""
        del prefix_tokens
        self._require_ids(constraint_ids)
        _check_step(step, self.store.sid_length)
        if not self.topk_at(step):
            raise ValueError(
                f"StackedStaticBackend(levels={self.levels!r}) has no "
                f"candidate row at dense step {step}; fix the policy plan"
            )
        bmax = max(self.store.bmax_for_step(step), 1)
        if self.slab is not None:
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk_compressed_topk(
                values, nodes, self.store.row_pointers, self.slab.tok_delta,
                self.slab.base_for_step(step), bmax, self.store.vocab_size,
                width, impl=self.impl, constraint_ids=constraint_ids,
                fused_logsoftmax=not normalized,
            )
        if self.impl == "pallas":
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.vntk_topk(
                values, nodes, self.store.row_pointers, self.store.edges,
                bmax, self.store.vocab_size, width,
                constraint_ids=constraint_ids,
                fused_logsoftmax=not normalized,
            )
        lp = values if normalized else jax.nn.log_softmax(
            values.astype(jnp.float32), axis=-1
        )
        return vntk_stacked_topk_xla(
            lp, nodes, self.store, bmax, constraint_ids, width
        )


# ---------------------------------------------------------------------------
# Baseline backends: prefix-token interface (paper §5.2) behind the protocol
# ---------------------------------------------------------------------------
def _require_prefix(prefix_tokens, who: str):
    if prefix_tokens is None:
        raise ValueError(
            f"{who} masks by emitted-token history; run it through a "
            "DecodePolicy-driven beam_search (which carries the prefix in "
            "its beam state) or pass prefix_tokens explicitly"
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CpuTrieBackend:
    """Host pointer-chasing trie behind ``io_callback`` (Table 1 baseline).

    The nested-dict trie lives on the host, so the baseline object is static
    aux data rather than a pytree leaf; two jitted steps share a compilation
    only when they hold the *same* baseline instance.
    """

    baseline: CpuTrieBaseline = dataclasses.field(metadata=dict(static=True))

    supports_fused = False
    supports_stacked = False
    needs_prefix = True
    supports_topk = False

    @property
    def sid_length(self) -> int:
        return self.baseline.sid_length

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        _check_rows(rows)
        # The trie lives on the host (static aux data): no device leaves.
        return _replicated_specs(self, mesh)

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del nodes
        _reject_constraint_ids(constraint_ids, "CpuTrieBackend")
        _require_prefix(prefix_tokens, "CpuTrieBackend")
        _check_step(step, self.baseline.sid_length)
        return self.baseline.mask_step(log_probs, prefix_tokens, step)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PPVBackend(PPVBaseline):
    """DISC-PPV: parallel binary search over the sorted SID matrix.

    Subclasses :class:`PPVBaseline` to reuse its (jit-traceable) search and
    verification math, but re-declares the state as a frozen pytree: the
    sorted SID/key tables are leaves so jitted policy steps take them as
    runtime arguments (closing over them would constant-fold multi-MB tables
    into the HLO — see ``benchmarks.common.jit_masker``'s war story).
    """

    sids_sorted: jax.Array  # (N, L) int32, lexicographically sorted
    keys: jax.Array  # (N, 4) uint32 packed keys
    n: int = dataclasses.field(metadata=dict(static=True))
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    sid_length: int = dataclasses.field(metadata=dict(static=True))
    exact: bool = dataclasses.field(metadata=dict(static=True))
    top_k: int = dataclasses.field(metadata=dict(static=True))
    n_search_steps: int = dataclasses.field(metadata=dict(static=True))

    supports_fused = False
    supports_stacked = False
    needs_prefix = True
    supports_topk = False

    @classmethod
    def from_baseline(cls, b: PPVBaseline) -> "PPVBackend":
        return cls(
            sids_sorted=b.sids_sorted, keys=b.keys, n=b.n,
            vocab_size=b.vocab_size, sid_length=b.sid_length, exact=b.exact,
            top_k=b.top_k, n_search_steps=b.n_search_steps,
        )

    @classmethod
    def from_sids(cls, sids, vocab_size: int, *, exact: bool = True,
                  top_k: int = 50) -> "PPVBackend":
        return cls.from_baseline(
            PPVBaseline(sids, vocab_size, exact=exact, top_k=top_k)
        )

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        _check_rows(rows)
        # Sorted SID/key tables are modest and probed by binary search:
        # replicate (row-sharding would need log2(N) cross-shard hops).
        return _replicated_specs(self, mesh)

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del nodes
        _reject_constraint_ids(constraint_ids, "PPVBackend")
        _require_prefix(prefix_tokens, "PPVBackend")
        _check_step(step, self.sid_length)
        return PPVBaseline.mask_step(self, log_probs, prefix_tokens, step)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HashBitmapBackend(HashBitmapBaseline):
    """Bloom-style hashed-prefix bitmap (constant time, false positives).

    Subclasses :class:`HashBitmapBaseline` for the hash/probe math; the
    bitmap is a pytree leaf (see :class:`PPVBackend` for why)."""

    bitmap: jax.Array  # (2^log2_bits / 8,) uint8
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    sid_length: int = dataclasses.field(metadata=dict(static=True))
    log2_bits: int = dataclasses.field(metadata=dict(static=True))

    supports_fused = False
    supports_stacked = False
    needs_prefix = True
    supports_topk = False

    @classmethod
    def from_baseline(cls, b: HashBitmapBaseline) -> "HashBitmapBackend":
        return cls(bitmap=b.bitmap, vocab_size=b.vocab_size,
                   sid_length=b.sid_length, log2_bits=b.log2_bits)

    @classmethod
    def from_sids(cls, sids, vocab_size: int, *,
                  log2_bits: int = 27) -> "HashBitmapBackend":
        return cls.from_baseline(
            HashBitmapBaseline(sids, vocab_size, log2_bits=log2_bits)
        )

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        _check_rows(rows)
        # Constant-time probes at random bit positions: replicate the bitmap.
        return _replicated_specs(self, mesh)

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del nodes
        _reject_constraint_ids(constraint_ids, "HashBitmapBackend")
        _require_prefix(prefix_tokens, "HashBitmapBackend")
        _check_step(step, self.sid_length)
        return HashBitmapBaseline.mask_step(
            self, log_probs, prefix_tokens, step
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UnconstrainedBackend:
    """No validity check at all — the latency lower bound of Table 1."""

    supports_fused = False
    supports_stacked = False
    needs_prefix = False
    supports_topk = False
    sid_length = None

    def shardings(self, mesh, *, rows: Rows = "replicated"):
        _check_rows(rows)
        return _replicated_specs(self, mesh)

    def mask_step(self, log_probs, nodes, step, *, prefix_tokens=None,
                  constraint_ids=None):
        del nodes, step, prefix_tokens
        _reject_constraint_ids(constraint_ids, "UnconstrainedBackend")
        # Every token is "valid"; beams stay parked at the root state.
        return log_probs, jnp.ones(log_probs.shape, jnp.int32)
