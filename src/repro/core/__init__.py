"""STATIC — the paper's primary contribution as a composable JAX module.

Public surface:
  * ``build_flat_trie`` / ``FlatTrie``      — offline trie -> stacked CSR
  * ``TransitionMatrix``                    — device-resident constraint index
  * ``constrain_log_probs``                 — Alg. 1 Phase 2 (dense + VNTK)
  * ``constrained_decoding_step``           — Alg. 1 Phases 1-2
  * ``beam_search`` / ``BeamState``         — Alg. 1 Phases 3-4 driver
  * ``baselines``                           — CPU trie / PPV / hash bitmap
  * ``memory_model``                        — Appendix B capacity model
"""
from repro.core.beam_search import BeamState, beam_search, recall_at_k
from repro.core.constrained import constrain_log_probs, constrained_decoding_step
from repro.core.transition_matrix import ROOT_STATE, SINK_STATE, TransitionMatrix
from repro.core.trie import FlatTrie, build_flat_trie, random_constraint_set
from repro.core.vntk import NEG_INF, vntk_xla

__all__ = [
    "BeamState",
    "beam_search",
    "recall_at_k",
    "constrain_log_probs",
    "constrained_decoding_step",
    "ROOT_STATE",
    "SINK_STATE",
    "TransitionMatrix",
    "FlatTrie",
    "build_flat_trie",
    "random_constraint_set",
    "NEG_INF",
    "vntk_xla",
]
