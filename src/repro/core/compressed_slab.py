"""Delta-compressed CSR edge slab for 100M+-SID catalogs (DESIGN.md §11).

The uncompressed stacked CSR spends 8 B per edge: an int32 token column and
an int32 next state.  Both are redundant under the canonical builder layout
(:func:`repro.core.trie.infer_level_blocks`):

  * **tokens** are strictly ascending within a row, so each edge stores the
    delta to its left neighbour — the row start keeps the absolute token.
    Deltas are bounded by ``vocab_size - 1``, so any vocab ``<= 32768`` fits
    int16 and the column array halves;
  * **next states** are consecutive over each level's edge block
    (``dst[e] = e + base[level]``), so the whole next-state array collapses
    to an O(L) per-level base table.

Per-node bytes drop from 12 (4 rowptr + 4 token + 4 next) to 6
(4 rowptr + 2 delta) — a 50% slab cut, 2x that of a 4x-larger vocab's
next-state savings alone.  Decompression is one int32 cumsum over the
speculative burst (which always begins at a row start), fused into the
VNTK DMA wave: XLA oracles in :mod:`repro.core.vntk`
(``vntk_compressed_*``), Pallas kernels in :mod:`repro.kernels.vntk`.
Outputs are bit-identical to the uncompressed path — garbage beyond a
row's end decompresses to garbage exactly like the uncompressed
speculative over-read, and every consumer masks it with ``iota < n_child``.

A slab that does not satisfy the canonical layout (hand-built arrays,
corruption) raises at construction; there is no silent fallback.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trie import infer_level_blocks

__all__ = ["CompressedSlab", "INT16_MAX_VOCAB"]

# Largest vocab whose tokens/deltas (<= V-1) fit an int16 delta slab.
INT16_MAX_VOCAB = 32768


def _delta_encode(row_pointers, edges, *, n_states, n_edges, sid_length,
                  dense_d, vocab_size, pad_to, dtype) -> tuple:
    """(tok_delta (pad_to,), level_base (L,)) for one member, verified."""
    rp = np.asarray(row_pointers, dtype=np.int64)
    eg = np.asarray(edges)
    blocks = infer_level_blocks(
        rp, eg, n_states=n_states, n_edges=n_edges, sid_length=sid_length,
        dense_d=dense_d, vocab_size=vocab_size,
    )
    E = int(n_edges)
    out = np.zeros(pad_to, dtype=dtype)
    if E:
        tok = eg[:E, 0].astype(np.int64)
        mark = np.zeros(E + 1, dtype=bool)
        mark[rp[:n_states]] = True  # every row's first edge keeps the absolute
        d = tok.copy()
        d[1:] = np.where(mark[1:E], tok[1:], tok[1:] - tok[:-1])
        # round-trip check: segment cumsum (the kernel decode) must recover
        # the tokens exactly — this is the whole bit-identity contract
        starts = np.nonzero(mark[:E])[0]
        gov = starts[np.searchsorted(starts, np.arange(E), side="right") - 1]
        c = np.cumsum(d)
        if not np.array_equal(c - (c[gov] - d[gov]), tok):
            raise ValueError("delta encoding failed round-trip verification")
        out[:E] = d.astype(dtype)
    return out, blocks.base.astype(np.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressedSlab:
    """Device-resident compressed edge slab (single matrix or stacked store).

    A frozen pytree designed to ride next to its TransitionMatrix /
    ConstraintStore inside a jitted decode step: leaf shapes and dtypes are
    functions of the envelope only, so a registry hot-swap that recomputes
    the slab never changes the treedef (zero-recompile contract, §4).
    """

    # --- device arrays (pytree leaves) ---
    tok_delta: jax.Array  # (E+pad,) or (K, E+pad) int16|int32 delta tokens
    level_base: jax.Array  # (L,) or (K, L) int32: next = edge_idx + base[step]
    # --- static metadata ---
    vocab_size: int = dataclasses.field(metadata=dict(static=True))
    sid_length: int = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    @classmethod
    def from_matrix(cls, tm) -> "CompressedSlab":
        """Compress a single TransitionMatrix-like object (duck-typed:
        ``row_pointers``/``edges`` + the usual static metadata)."""
        dtype = np.int16 if tm.vocab_size <= INT16_MAX_VOCAB else np.int32
        tok, base = _delta_encode(
            tm.row_pointers, tm.edges, n_states=tm.n_states,
            n_edges=tm.n_edges, sid_length=tm.sid_length, dense_d=tm.dense_d,
            vocab_size=tm.vocab_size, pad_to=tm.edges.shape[-2], dtype=dtype,
        )
        return cls(tok_delta=jnp.asarray(tok), level_base=jnp.asarray(base),
                   vocab_size=int(tm.vocab_size),
                   sid_length=int(tm.sid_length))

    @classmethod
    def from_store(cls, store) -> "CompressedSlab":
        """Compress every member of a stacked ConstraintStore.

        Members share one capacity envelope; each member's real
        ``n_states``/``n_edges`` prefix is compressed independently and the
        delta slab zero-padded to the envelope (zero deltas decompress to a
        constant run that the ``iota < n_child`` sanitization never admits —
        envelope padding stays semantically invisible, §4).
        """
        K = store.num_sets
        E = store.edges.shape[-2]
        dtype = np.int16 if store.vocab_size <= INT16_MAX_VOCAB else np.int32
        toks = np.zeros((K, E), dtype=dtype)
        bases = np.zeros((K, store.sid_length), dtype=np.int32)
        for k in range(K):
            m = store.member(k)
            toks[k], bases[k] = _delta_encode(
                m.row_pointers, m.edges, n_states=m.n_states,
                n_edges=m.n_edges, sid_length=m.sid_length,
                dense_d=m.dense_d, vocab_size=m.vocab_size, pad_to=E,
                dtype=dtype,
            )
        return cls(tok_delta=jnp.asarray(toks), level_base=jnp.asarray(bases),
                   vocab_size=int(store.vocab_size),
                   sid_length=int(store.sid_length))

    @classmethod
    def build(cls, obj) -> "CompressedSlab":
        """Compress a matrix or store by shape (stacked iff ``is_stacked``)."""
        return (cls.from_store(obj) if getattr(obj, "is_stacked", False)
                else cls.from_matrix(obj))

    # ------------------------------------------------------------------
    @property
    def is_stacked(self) -> bool:
        return self.level_base.ndim == 2

    def base_for_step(self, step: int) -> jax.Array:
        """Next-state base at decode step ``step`` (scalar, or (K,) stacked)."""
        return self.level_base[..., step].astype(jnp.int32)

    def nbytes(self) -> int:
        return (self.tok_delta.size * self.tok_delta.dtype.itemsize
                + self.level_base.size * self.level_base.dtype.itemsize)
