"""Batched constrained beam search over Semantic IDs (paper §3.2 + Alg. 1).

The search maintains, per batch element, the ``M`` best prefixes, their
cumulative log-probabilities, and — when a :class:`TransitionMatrix` is
supplied — the trie state of every beam (Phase 4 of Alg. 1 advances it with a
single vocab-aligned gather).

The decoder is abstracted as ``logits_fn(carry, last_tokens, step)`` returning
``(logits, carry)`` so the same search drives toy scorers, full transformers
with KV caches, and the latency benchmarks.  Because each decode step
specializes on the per-level max branch factor (a static constant, paper
§4.4), the step loop is a Python loop over the fixed SID length L; every
iteration is one fused XLA computation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.constrained import constrained_decoding_step
from repro.core.transition_matrix import TransitionMatrix
from repro.core.vntk import NEG_INF

__all__ = ["BeamState", "beam_search", "recall_at_k"]

LogitsFn = Callable  # (carry, last_tokens (B, M) int32, step) -> (logits, carry)
CarryGatherFn = Callable  # (carry, beam_idx (B, M) int32) -> carry


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BeamState:
    tokens: jax.Array  # (B, M, L) int32 decoded prefixes
    scores: jax.Array  # (B, M) float32 cumulative log-probs
    nodes: jax.Array  # (B, M) int32 trie states (ROOT when unconstrained)


def _init_state(batch: int, beams: int, length: int) -> BeamState:
    scores = jnp.full((batch, beams), NEG_INF, jnp.float32).at[:, 0].set(0.0)
    return BeamState(
        tokens=jnp.zeros((batch, beams, length), jnp.int32),
        scores=scores,
        nodes=jnp.ones((batch, beams), jnp.int32),  # ROOT_STATE
    )


def beam_search(
    logits_fn: LogitsFn,
    carry,
    batch_size: int,
    beam_size: int,
    length: int,
    tm: Optional[TransitionMatrix],
    carry_gather_fn: Optional[CarryGatherFn] = None,
    impl: str = "xla",
    fused: bool = False,
    first_logits: Optional[jax.Array] = None,
    constraint_ids: Optional[jax.Array] = None,
) -> tuple[BeamState, object]:
    """Run L constrained decode steps; returns final beams sorted by score.

    ``first_logits`` (B, V) short-circuits step 0 with logits already
    available from the prefill's last position (a prefill pass ends exactly
    where SID decoding starts, so re-deriving them would waste one decode).

    ``constraint_ids`` (B,) int32 selects, per batch row, which member of a
    stacked :class:`~repro.constraints.ConstraintStore` (passed as ``tm``)
    masks that row — every beam of a row shares its request's constraint set,
    so the ids broadcast over the beam axis and beam reordering never moves
    them (DESIGN.md §4).
    """
    state = _init_state(batch_size, beam_size, length)
    B, M = batch_size, beam_size
    cids_bm = (
        None
        if constraint_ids is None
        else jnp.broadcast_to(
            jnp.asarray(constraint_ids, jnp.int32)[:, None], (B, M)
        )
    )

    for step in range(length):
        last = (
            state.tokens[:, :, step - 1]
            if step > 0
            else jnp.zeros((B, M), jnp.int32)
        )
        if step == 0 and first_logits is not None:
            logits = jnp.broadcast_to(
                first_logits[:, None, :], (B, M, first_logits.shape[-1])
            )
        else:
            logits, carry = logits_fn(carry, last, step)  # (B, M, V)
        V = logits.shape[-1]
        lp, next_dense = constrained_decoding_step(
            logits, state.nodes, tm, step, impl=impl, fused=fused,
            constraint_ids=cids_bm,
        )
        total = state.scores[:, :, None] + lp  # (B, M, V)
        flat = total.reshape(B, M * V)
        top_scores, top_idx = jax.lax.top_k(flat, M)  # (B, M)
        beam_idx = top_idx // V
        token = (top_idx % V).astype(jnp.int32)

        # Phase 4: state update via gathers.
        batch_ix = jnp.arange(B)[:, None]
        new_tokens = state.tokens[batch_ix, beam_idx]  # (B, M, L)
        new_tokens = new_tokens.at[:, :, step].set(token)
        if tm is not None:
            new_nodes = next_dense[batch_ix, beam_idx, token]
        else:
            new_nodes = state.nodes[batch_ix, beam_idx]
        state = BeamState(tokens=new_tokens, scores=top_scores, nodes=new_nodes)
        if carry_gather_fn is not None:
            carry = carry_gather_fn(carry, beam_idx)
    return state, carry


def recall_at_k(
    beams: jax.Array,  # (B, M, L) decoded SIDs, best-first
    targets: jax.Array,  # (B, L) ground-truth SIDs
    k: int,
) -> jax.Array:
    """Fraction of batch rows whose target appears in the top-k beams."""
    hit = jnp.all(beams[:, :k, :] == targets[:, None, :], axis=-1)  # (B, k)
    return jnp.mean(jnp.any(hit, axis=-1).astype(jnp.float32))
